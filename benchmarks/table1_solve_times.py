"""Paper Table 1 + Fig. 1: per-matrix solve times under the four orderings.

Selects the highest-nnz matrices of the suite (the paper picks >100k-nnz
Florida matrices) and prints factor+solve seconds per ordering, plus the
Fig.-1-style normalized matrix (min-normalized per row)."""
from __future__ import annotations

import numpy as np

from .common import campaign_dataset, csv_line


def main(top: int = 9, heatmap_rows: int = 30) -> str:
    ds = campaign_dataset()
    order = np.argsort(-ds.nnzs)[:top]
    lines = ["matrix,amd_s,scotch_s,nd_s,rcm_s,nnz,dimension"]
    alg_idx = {a: i for i, a in enumerate(ds.algorithms)}
    for i in order:
        t = ds.times[i]
        lines.append(
            f"{ds.names[i]},{t[alg_idx['amd']]:.4f},{t[alg_idx['scotch']]:.4f},"
            f"{t[alg_idx['nd']]:.4f},{t[alg_idx['rcm']]:.4f},"
            f"{ds.nnzs[i]},{ds.dims[i]}")
    # Fig. 1 heatmap analogue: 30 random matrices, min-normalized rows
    rng = np.random.default_rng(0)
    sel = rng.choice(len(ds.names), heatmap_rows, replace=False)
    norm = ds.times[sel] / ds.times[sel].min(axis=1, keepdims=True)
    lines.append("# fig1: per-row min-normalized times "
                 "(1.0 = best ordering for that matrix)")
    for j, i in enumerate(sel):
        lines.append("fig1," + ds.names[i] + ","
                     + ",".join(f"{v:.2f}" for v in norm[j]))
    # headline heterogeneity stats (paper: "differences up to 1000x")
    spread = (ds.times.max(axis=1) / ds.times.min(axis=1))
    lines.append(csv_line("table1_max_spread", 0.0,
                          f"max_time_ratio={spread.max():.1f}x;"
                          f"median_ratio={np.median(spread):.2f}x"))
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
