"""Paper Table 7: the ten largest-dimension test-set matrices — AMD time vs
predicted-ordering time and the speedup ratio."""
from __future__ import annotations

import numpy as np

from .common import csv_line, trained_selector


def main(top: int = 10) -> str:
    sel, rep, ds = trained_selector()
    ite = np.asarray(rep["test_idx"])
    pred = np.asarray(rep["predictions"])
    amd = ds.algorithms.index("amd")
    order = ite[np.argsort(-ds.dims[ite])][:top]
    pred_of = {int(i): int(p) for i, p in zip(ite, pred)}
    lines = ["matrix,amd_s,model_prediction_s,speedup_ratio"]
    speedups = []
    for i in order:
        t_amd = ds.times[i, amd]
        t_pred = ds.times[i, pred_of[int(i)]]
        s = t_amd / max(t_pred, 1e-12)
        speedups.append(s)
        lines.append(f"{ds.names[i]},{t_amd:.4f},{t_pred:.4f},{s:.2f}")
    lines.append(csv_line(
        "table7_largest", 0.0,
        f"mean_speedup={np.mean(speedups):.2f};max={np.max(speedups):.2f}"))
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
