# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: reproduces every paper table/figure from the cached
labeling campaign, then emits the roofline table from dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import time


def _section(title: str) -> None:
    print(f"\n## {title}")


def main() -> None:
    from . import (extended_features, fig4_model_accuracy, roofline,
                   table1_solve_times, table5_predictions, table6_statistics,
                   table7_largest)

    benches = [
        ("table1_solve_times", table1_solve_times.main),
        ("fig4_model_accuracy", fig4_model_accuracy.main),
        ("table5_predictions", table5_predictions.main),
        ("table6_statistics", table6_statistics.main),
        ("table7_largest", table7_largest.main),
        ("extended_features", extended_features.main),
    ]
    for name, fn in benches:
        _section(name)
        t0 = time.perf_counter()
        out = fn()
        dt = (time.perf_counter() - t0) * 1e6
        print(out)
        print(f"{name},{dt:.0f},ok")

    _section("roofline (single-pod)")
    print(roofline.main("pod16x16"))
    _section("roofline (multi-pod)")
    print(roofline.main("pod2x16x16"))


if __name__ == "__main__":
    main()
