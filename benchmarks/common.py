"""Shared benchmark utilities: cached campaign dataset + trained selector."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import load_or_build, train_selector
from repro.core.selector import ReorderSelector

ART = os.environ.get("REPRO_ARTIFACTS", "artifacts")
CAMPAIGN = dict(count=960, seed=0, size_scale=1.0, repeats=2)


def campaign_dataset():
    return load_or_build(cache_dir=ART, **CAMPAIGN, verbose=True)


def trained_selector(model_name="random_forest", scaling="standard"):
    """Final selector (RF + standardization, grid-searched); cached."""
    sel_path = os.path.join(ART, f"selector_{model_name}_{scaling}.pkl")
    rep_path = sel_path.replace(".pkl", "_report.json")
    ds = campaign_dataset()
    if os.path.exists(sel_path) and os.path.exists(rep_path):
        with open(rep_path) as f:
            rep = json.load(f)
        return ReorderSelector.load(sel_path), rep, ds
    sel, rep = train_selector(ds, model_name, scaling)
    sel.save(sel_path)
    slim = {k: (v.tolist() if isinstance(v, np.ndarray) else v)
            for k, v in rep.items()}
    with open(rep_path, "w") as f:
        json.dump(slim, f, indent=2)
    return sel, slim, ds


def timed(fn, *args, repeats=1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
