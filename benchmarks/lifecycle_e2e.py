#!/usr/bin/env python
"""End-to-end bundle-lifecycle smoke: campaign → shadow → promote → rollback.

The CI lifecycle leg's executable proof of the control plane's contract:

1. A tiny labeling **campaign** produces per-matrix artifacts and a
   trainable dataset (resume correctness is exercised by the CLI's
   ``--max-cells`` + ``--gate-resume`` pair, outside this script).
2. An **incumbent** trained on a subset serves through the dispatcher; a
   **candidate** trained on the full suite shadow-serves next to it. The
   client-visible plans must be byte-identical with and without the
   shadow riding, and no extra plan builds may happen.
3. A strict gate (impossible win-rate threshold) must **reject**; the
   permissive gate must **promote** — after which the old plans are
   invisible (fresh build under the new fingerprint) — and **rollback**
   must restore the incumbent with its disk-cached plans intact (no new
   symbolic analysis).

Exits nonzero on any violated assertion. Writes ``BENCH_lifecycle.json``.

    PYTHONPATH=src python -m benchmarks.lifecycle_e2e --count 8 --scale 0.25
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

from repro.core.labeling import LabeledDataset
from repro.engine import EngineConfig, SolverEngine
from repro.lifecycle import (CampaignConfig, GateRejected, PromotionGate,
                             assemble_dataset, run_campaign)
from repro.sparse.dataset import generate_suite


def _subset(ds: LabeledDataset, k: int) -> LabeledDataset:
    return LabeledDataset(ds.features[:k], ds.labels[:k], ds.times[:k],
                          ds.order_times[:k], ds.fills[:k], ds.flops[:k],
                          ds.names[:k], ds.groups[:k], ds.dims[:k],
                          ds.nnzs[:k], ds.algorithms, ds.feature_set)


def _engine(workdir: str, seed: int) -> SolverEngine:
    return SolverEngine(EngineConfig(
        model="decision_tree", path="host", fast_grids=True, cv=2,
        test_size=0.34, seed=seed,
        cache_dir=os.path.join(workdir, "plan_cache"),
        bundle_dir=os.path.join(workdir, "bundles"),
        promote_min_accuracy=0.0, promote_min_shadow_requests=1,
        promote_min_win_rate=0.0))


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    p.add_argument("--count", type=int, default=8)
    p.add_argument("--seed", type=int, default=7)
    p.add_argument("--scale", type=float, default=0.25)
    p.add_argument("--workdir", default=None,
                   help="working directory (default: a fresh tempdir)")
    p.add_argument("--out", default="BENCH_lifecycle.json")
    args = p.parse_args()
    workdir = args.workdir or tempfile.mkdtemp(prefix="lifecycle_e2e_")
    os.makedirs(workdir, exist_ok=True)
    report: dict = dict(count=args.count, seed=args.seed, scale=args.scale)
    t_start = time.perf_counter()

    # 1. campaign → dataset ------------------------------------------------
    mats = list(generate_suite(count=args.count, seed=args.seed,
                               size_scale=args.scale))
    ccfg = CampaignConfig(campaign_id="lifecycle_e2e",
                          labels_dir=os.path.join(workdir, "labels"))
    res = run_campaign(mats, ccfg, verbose=True)
    assert res.report["complete"], "campaign did not complete"
    ds = res.dataset or assemble_dataset(mats, ccfg)
    report["campaign"] = res.report

    # 2. incumbent serves, candidate shadows -------------------------------
    engine = _engine(workdir, seed=args.seed)
    engine.train(_subset(ds, max(4, len(mats) // 2)))  # the mini-suite fit
    fp_incumbent = engine.fingerprint
    cand = _engine(workdir, seed=args.seed + 1)
    cand.train(ds)                                     # the larger suite
    cand_path = os.path.join(workdir, "candidate.bundle")
    cand.save(cand_path)

    server = engine.serve(batch_size=4, max_wait_ms=2.0)
    try:
        baseline = [f.result(60) for f in
                    [server.submit(a) for a in mats]]
        built0 = engine.builder.plans_built
        engine.start_shadow(cand_path)
        shadowed = [f.result(60) for f in
                    [server.submit(a) for a in mats]]
        assert ([pl.algorithm for pl in baseline]
                == [pl.algorithm for pl in shadowed]), \
            "client-visible plans changed while the shadow rode along"
        assert engine.builder.plans_built == built0, \
            "shadow evaluation triggered plan builds on the serving path"
        assert engine.shadow.drain(60), "shadow queue did not drain"
        stats = engine.shadow.stats()
        assert stats["evaluated"] >= len(mats), \
            f"shadow evaluated {stats['evaluated']} < {len(mats)}"
        report["shadow"] = stats
        print(f"[lifecycle] shadow: {stats['evaluated']} evaluated, "
              f"agreement {stats['agreement_rate']:.2f}, "
              f"win rate {stats['win_rate']:.2f}")

        # 3a. the strict gate must hold the line ---------------------------
        try:
            engine.promote(gate=PromotionGate(
                min_test_accuracy=0.0, min_shadow_requests=1,
                min_shadow_win_rate=1.01))   # > 1: unreachable by design
            raise AssertionError("impossible win-rate gate let the "
                                 "candidate through")
        except GateRejected as exc:
            failed = [c["check"] for c in exc.decision["checks"]
                      if not c["passed"]]
            assert "shadow.win_rate" in failed
            report["gate_rejection"] = exc.decision
            print(f"[lifecycle] strict gate rejected (checks: {failed})")
        assert engine.fingerprint == fp_incumbent, \
            "a rejected promotion must change nothing"

        # 3b. permissive gate promotes; old plans become invisible ---------
        decision = engine.promote()
        report["promotion"] = {k: decision[k] for k in
                               ("version", "previous_version", "passed")}
        assert engine.fingerprint != fp_incumbent
        sb = engine.builder.sym_builds          # new builder: counters at 0
        engine.plan(mats[0])
        assert engine.builder.sym_builds == sb + 1, \
            "promote did not invalidate the plan cache (stale plan served)"
        print(f"[lifecycle] promoted {decision['version']} "
              f"(was {decision['previous_version']})")

        # 3c. rollback restores the incumbent and its cached plans ---------
        entry = engine.rollback()
        assert engine.fingerprint == fp_incumbent, \
            "rollback did not restore the incumbent fingerprint"
        sb = engine.builder.sym_builds
        engine.plan(mats[0])
        assert engine.builder.sym_builds == sb, \
            "rollback lost the incumbent's plans (symbolic analysis re-ran)"
        report["rollback"] = dict(version=entry["version"],
                                  status=entry["status"])
        print(f"[lifecycle] rolled back to {entry['version']}; "
              f"incumbent plans served from disk")
    finally:
        engine.stop_shadow()
        server.close()

    report["wall_s"] = time.perf_counter() - t_start
    report["ok"] = True
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(report, f, indent=2, default=str)
    print(f"[lifecycle] OK ({report['wall_s']:.1f} s) → {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
