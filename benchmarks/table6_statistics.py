"""Paper Table 6: test-set solve-time totals under (a) AMD-only,
(b) model-predicted ordering, (c) ideal oracle — plus total prediction time.

The headline claims this reproduces: 55.37% reduction vs AMD, +19.86% vs
ideal, mean speedup 1.45."""
from __future__ import annotations

import numpy as np

from .common import csv_line, trained_selector


def main() -> str:
    sel, rep, ds = trained_selector()
    ite = np.asarray(rep["test_idx"])
    pred = np.asarray(rep["predictions"])
    amd = ds.algorithms.index("amd")
    t_amd = ds.times[ite, amd].sum()
    t_pred = ds.times[ite, pred].sum()
    t_ideal = ds.times[ite].min(axis=1).sum()
    # prediction time for the whole test set
    import time
    t0 = time.perf_counter()
    sel.predict_features(ds.features[ite])
    t_predict = time.perf_counter() - t0
    lines = ["scenario,total_solve_time_s",
             f"amd,{t_amd:.4f}",
             f"prediction,{t_pred:.4f}",
             f"ideal,{t_ideal:.4f}",
             f"prediction_time,{t_predict:.4f}"]
    lines.append(csv_line(
        "table6_summary", t_predict / max(len(ite), 1) * 1e6,
        f"reduction_vs_amd={100 * (1 - t_pred / t_amd):.2f}%;"
        f"excess_vs_ideal={100 * (t_pred / t_ideal - 1):.2f}%;"
        f"test_accuracy={rep['test_accuracy']:.4f};"
        f"mean_speedup={rep['mean_speedup_vs_amd']:.2f}"))
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
