"""Traffic replay: Zipfian, bursty load against the RPC serving plane.

    PYTHONPATH=src python -m benchmarks.traffic_replay \
        --requests 400 --distinct 32 --clients 4 --deadline-ms 2000

The serving plane's production story — admission control, deadline
shedding, priority batching, per-stage telemetry — is only credible under
*realistic* traffic, which means skew and bursts, not a uniform for-loop:

* **Zipfian structure keys.** Real workloads re-solve a few hot sparsity
  structures constantly (the same mesh each timestep, the same circuit
  per corner) and a long tail rarely: request keys are drawn with
  p(rank) ∝ 1/rank^alpha over a pool of distinct structures, so the plan
  cache sees a realistic hot set.
* **Bursty arrivals.** Requests arrive in bursts of ``--burst`` with
  ``--pause-ms`` gaps, fanned out by ``--clients`` concurrent RPC client
  threads — exactly the fan-in the micro-batcher and the bounded queue
  exist for.

Every request travels the wire with a ``deadline_ms`` (and hot keys get
``priority`` when ``--hot-priority`` is set), so the run measures the full
RequestContext machinery end-to-end: per-stage spans come back in each
response, shed/rejected requests surface as typed errors, and the server's
metrics snapshot supplies queue depth and cache tiers.

The run writes ``BENCH_traffic.json`` (p50/p99 per stage, client-observed
latency, shed rate, reject rate, hit rates, queue depth) — the repo's
serving-perf trajectory file — and ``--gate-shed-rate`` turns it into a CI
gate: exit nonzero when the shed rate at the calibrated load exceeds the
bound.
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time


def parse_args():
    p = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    p.add_argument("--requests", type=int, default=400)
    p.add_argument("--distinct", type=int, default=32,
                   help="distinct structures in the key pool")
    p.add_argument("--zipf-alpha", type=float, default=1.1,
                   help="popularity skew: p(rank) ∝ 1/rank^alpha")
    p.add_argument("--burst", type=int, default=32,
                   help="requests per arrival burst")
    p.add_argument("--pause-ms", type=float, default=50.0,
                   help="idle gap between bursts")
    p.add_argument("--clients", type=int, default=4,
                   help="concurrent RPC client threads")
    p.add_argument("--deadline-ms", type=float, default=5000.0,
                   help="per-request deadline carried on the wire "
                        "(0/negative: none)")
    p.add_argument("--hot-priority", action="store_true",
                   help="send the hottest decile of keys at priority 1")
    p.add_argument("--max-queue", type=int, default=256,
                   help="dispatcher admission-control bound")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--max-wait-ms", type=float, default=5.0)
    p.add_argument("--build-workers", type=int, default=2)
    p.add_argument("--model", default="decision_tree")
    p.add_argument("--devices", type=int, default=None,
                   help="serving-mesh width (forces N virtual host devices)")
    p.add_argument("--campaign-count", type=int, default=12)
    p.add_argument("--campaign-scale", type=float, default=0.25)
    p.add_argument("--size-scale", type=float, default=0.35,
                   help="size of the replayed structures")
    p.add_argument("--seed", type=int, default=7)
    p.add_argument("--warmup", type=int, default=2,
                   help="untimed warm-up requests (jit compile)")
    p.add_argument("--out", default="BENCH_traffic.json")
    p.add_argument("--gate-shed-rate", type=float, default=None,
                   help="exit nonzero if shed+reject rate exceeds this")
    return p.parse_args()


def _pct(xs, q):
    if not xs:
        return 0.0
    data = sorted(xs)
    return data[max(0, min(len(data) - 1,
                           int(round(q / 100.0 * (len(data) - 1)))))]


def main() -> int:
    args = parse_args()
    if args.devices is not None and args.devices > 1:
        # must precede jax backend init — hence stdlib-only module imports
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()

    import numpy as np

    from repro.core.labeling import load_or_build
    from repro.core.reqctx import DeadlineExceeded, QueueFull
    from repro.engine import EngineConfig, SolverEngine
    from repro.launch.rpc import PlanRPCClient, RPCError
    from repro.sparse.dataset import generate_suite

    deadline_ms = args.deadline_ms if args.deadline_ms > 0 else None

    # -- serve a tiny trained engine over RPC, in this process --------------
    engine = SolverEngine(EngineConfig(
        model=args.model, cache_dir=None, batch_size=args.batch,
        max_wait_ms=args.max_wait_ms, build_workers=args.build_workers,
        max_queue=args.max_queue, serving_devices=args.devices,
        fast_grids=True, cv=3, seed=0))
    ds = load_or_build(cache_dir=os.environ.get("REPRO_ARTIFACTS",
                                                "artifacts"),
                       count=args.campaign_count, seed=7,
                       size_scale=args.campaign_scale, repeats=1,
                       verbose=False)
    rep = engine.train(ds)
    server = engine.serve(rpc=True, port=0)
    print(f"[traffic] model={args.model} "
          f"test_acc={rep['test_accuracy']:.2f} serving on "
          f"127.0.0.1:{server.port} (mesh {args.devices or 1})")

    # -- the request stream: Zipfian keys in bursts --------------------------
    pool = list(generate_suite(count=args.distinct, seed=args.seed + 1,
                               size_scale=args.size_scale))
    rng = np.random.default_rng(args.seed)
    pop = 1.0 / np.power(1.0 + np.arange(len(pool)), args.zipf_alpha)
    pop /= pop.sum()
    stream = rng.choice(len(pool), size=args.requests, p=pop)
    hot_cut = max(1, len(pool) // 10)  # hottest decile by rank

    # warm-up outside the measured window: compile the featurize→infer jit
    with PlanRPCClient("127.0.0.1", server.port) as c:
        for i in range(max(0, args.warmup)):
            c.plan(pool[i % len(pool)])
    server.dispatcher.reset_stats()

    # -- drive: bursts fanned over a client-thread pool ----------------------
    results = []  # (outcome, client_ms, spans_ms, rank)
    res_lock = threading.Lock()
    work: "list" = []
    work_lock = threading.Lock()

    def worker():
        with PlanRPCClient("127.0.0.1", server.port, timeout=300) as c:
            while True:
                with work_lock:
                    if not work:
                        return
                    rank = work.pop()
                prio = (1 if (args.hot_priority and rank < hot_cut) else 0)
                t0 = time.perf_counter()
                try:
                    r = c.plan_detailed(pool[rank], deadline_ms=deadline_ms,
                                        priority=prio)
                    out = ("ok", (time.perf_counter() - t0) * 1e3,
                           r.get("spans_ms", {}), rank)
                except DeadlineExceeded:
                    out = ("shed", (time.perf_counter() - t0) * 1e3, {},
                           rank)
                except QueueFull:
                    out = ("rejected", (time.perf_counter() - t0) * 1e3, {},
                           rank)
                except RPCError as exc:
                    out = ("error", (time.perf_counter() - t0) * 1e3,
                           {"error": str(exc)}, rank)
                with res_lock:
                    results.append(out)

    # queue-depth sampler: polls the server's metrics snapshot so the
    # report shows backlog behavior over the run, not just the end state
    depth_samples = []
    stop_sampling = threading.Event()

    def sampler():
        with PlanRPCClient("127.0.0.1", server.port) as c:
            while not stop_sampling.is_set():
                try:
                    snap = c.metrics()
                    depth_samples.append(
                        float(snap.get("dispatch.queue_depth", 0.0)))
                except Exception:
                    pass
                stop_sampling.wait(0.02)

    t_start = time.perf_counter()
    mon = threading.Thread(target=sampler, daemon=True)
    mon.start()
    idx = 0
    while idx < len(stream):
        burst = [int(r) for r in stream[idx : idx + args.burst]]
        idx += args.burst
        with work_lock:
            work.extend(burst)
        threads = [threading.Thread(target=worker, daemon=True)
                   for _ in range(min(args.clients, len(burst)))]
        for t in threads:
            t.start()
        for t in threads:
            t.join(600)
        if idx < len(stream) and args.pause_ms > 0:
            time.sleep(args.pause_ms / 1e3)
    wall_s = time.perf_counter() - t_start
    stop_sampling.set()
    mon.join(5)

    stats = server.dispatcher.stats()
    metrics = engine.metrics.snapshot()
    server.close()

    # -- aggregate ------------------------------------------------------------
    n = len(results)
    ok = [r for r in results if r[0] == "ok"]
    shed = sum(1 for r in results if r[0] == "shed")
    rejected = sum(1 for r in results if r[0] == "rejected")
    errors = sum(1 for r in results if r[0] == "error")
    client_ms = [r[1] for r in ok]
    stages = sorted({k for r in ok for k in r[2]})
    per_stage = {
        st: dict(p50_ms=_pct([r[2][st] for r in ok if st in r[2]], 50),
                 p99_ms=_pct([r[2][st] for r in ok if st in r[2]], 99),
                 requests=sum(1 for r in ok if st in r[2]))
        for st in stages}
    shed_rate = (shed + rejected) / n if n else 0.0

    report = dict(
        config=dict(requests=args.requests, distinct=args.distinct,
                    zipf_alpha=args.zipf_alpha, burst=args.burst,
                    pause_ms=args.pause_ms, clients=args.clients,
                    deadline_ms=deadline_ms, max_queue=args.max_queue,
                    batch=args.batch, max_wait_ms=args.max_wait_ms,
                    build_workers=args.build_workers, model=args.model,
                    devices=args.devices, hot_priority=args.hot_priority,
                    seed=args.seed),
        traffic=dict(sent=n, ok=len(ok), shed=shed, rejected=rejected,
                     errors=errors, shed_rate=shed_rate,
                     wall_s=wall_s,
                     throughput_rps=(n / wall_s if wall_s else 0.0)),
        latency=dict(client_p50_ms=_pct(client_ms, 50),
                     client_p99_ms=_pct(client_ms, 99),
                     per_stage=per_stage),
        cache=dict(hit_rate=stats.get("hit_rate"),
                   hits=stats.get("hits"), misses=stats.get("misses"),
                   warm_hits=stats.get("warm_hits"),
                   disk_hits=stats.get("disk_hits")),
        queue=dict(depth_max=max(depth_samples, default=0.0),
                   depth_mean=(sum(depth_samples) / len(depth_samples)
                               if depth_samples else 0.0),
                   samples=len(depth_samples)),
        server=dict(stats={k: v for k, v in stats.items()
                           if isinstance(v, (int, float, str, type(None)))},
                    metrics=metrics),
    )
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, default=float)

    print(f"[traffic] {n} requests in {wall_s:.1f} s "
          f"({report['traffic']['throughput_rps']:.0f} rps): "
          f"{len(ok)} ok, {shed} shed, {rejected} rejected, "
          f"{errors} errors (shed rate {shed_rate:.1%})")
    print(f"[traffic] client latency p50 {_pct(client_ms, 50):.1f} ms, "
          f"p99 {_pct(client_ms, 99):.1f} ms; queue depth "
          f"max {report['queue']['depth_max']:.0f}")
    for st in stages:
        print(f"[traffic]   stage {st:>8}: "
              f"p50 {per_stage[st]['p50_ms']:8.2f} ms  "
              f"p99 {per_stage[st]['p99_ms']:8.2f} ms  "
              f"({per_stage[st]['requests']} reqs)")
    print(f"[traffic] cache hit rate {stats.get('hit_rate', 0.0):.2f} "
          f"({stats.get('warm_hits', 0)} warm submits); wrote {args.out}")

    if errors:
        print(f"[traffic] FAIL: {errors} unexpected errors")
        return 1
    if args.gate_shed_rate is not None and shed_rate > args.gate_shed_rate:
        print(f"[traffic] FAIL: shed rate {shed_rate:.1%} exceeds gate "
              f"{args.gate_shed_rate:.1%}")
        return 1
    if args.gate_shed_rate is not None:
        print(f"[traffic] shed-rate gate OK "
              f"({shed_rate:.1%} ≤ {args.gate_shed_rate:.1%})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
