"""Paper Fig. 4 + Table 4: prediction accuracy of the 7 model families under
Max-Min scaling vs Standardization, grid-searched; prints the selected RF
hyperparameters (Table 4)."""
from __future__ import annotations

import json
import os
import time

from repro.core import train_selector
from repro.core.ml import MODEL_ZOO

from .common import ART, campaign_dataset, csv_line

CACHE = os.path.join(ART, "fig4_results.json")


def main(fast: bool = False) -> str:
    ds = campaign_dataset()
    if os.path.exists(CACHE):
        with open(CACHE) as f:
            results = json.load(f)
    else:
        results = {}
        for model_name in sorted(MODEL_ZOO):
            for scaling in ("minmax", "standard"):
                t0 = time.perf_counter()
                _, rep = train_selector(ds, model_name, scaling, fast=fast)
                results[f"{model_name}|{scaling}"] = dict(
                    accuracy=rep["test_accuracy"],
                    cv_score=rep["cv_score"],
                    best_params={k: str(v) for k, v in
                                 rep["best_params"].items()},
                    fit_seconds=time.perf_counter() - t0)
        with open(CACHE, "w") as f:
            json.dump(results, f, indent=2)
    lines = ["model,scaling,test_accuracy,cv_score,fit_seconds"]
    best = ("", 0.0)
    for key, r in sorted(results.items()):
        m, s = key.split("|")
        lines.append(f"{m},{s},{r['accuracy']:.4f},{r['cv_score']:.4f},"
                     f"{r['fit_seconds']:.1f}")
        if r["accuracy"] > best[1]:
            best = (key, r["accuracy"])
    lines.append(csv_line("fig4_best", 0.0,
                          f"best={best[0]};accuracy={best[1]:.4f}"))
    rf = results.get("random_forest|standard")
    if rf:
        lines.append("# table4 (RF hyperparameters, grid-searched): "
                     + json.dumps(rf["best_params"]))
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
