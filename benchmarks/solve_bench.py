"""Numeric-solve benchmark: the perf gate for the level-scheduled backend.

PR 2's e2e benchmark showed >95% of warm-path time is the numeric
factorization, so this is the trajectory that matters now. Per matrix ×
backend (numpy / per-front pallas / level-batched):

* cold (first call, includes kernel compilation) and warm factor+solve
  wall times, residuals,
* achieved GFLOP/s against the **symbolic flop model**
  (``SymbolicFactor.flops`` — exact) and the dense-front flop count
  (``LevelSchedule`` — includes amalgamation padding; the ratio of the two
  is the structural overhead the supernode relaxation chose),
* per-level batch occupancy and fronts-per-level (the parallelism the
  batched backend can actually exploit),
* roofline terms (compute vs memory seconds from the flop model + front
  bytes) consumed by ``benchmarks/roofline.py``,
* for the batched backend: the fp32 residual and the fp32+fp64-refinement
  residual/iterations.

Emits ``BENCH_solve.json`` and exits non-zero when a gate fails:
``--gate-residual-fp64`` (numpy backend), ``--gate-residual-refine``
(batched + refinement), and ``--gate-flop-ratio`` (dense-front flops vs
symbolic model drift). CI runs ``--quick`` on the interpret backend and
uploads the JSON as the second ``BENCH_*`` trajectory artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List

import numpy as np

from repro.sparse.dataset import (banded, block_arrow, grid2d,
                                  permuted_banded, scalefree)
from repro.sparse.multifrontal import (factor_and_solve_timed,
                                       multifrontal_cholesky,
                                       multifrontal_solve)
from repro.sparse.refine import refine_solve
from repro.sparse.schedule import build_schedule
from repro.sparse.symbolic import symbolic_cholesky

# v4-ish single-core roofline constants (same as the dry-run roofline):
# achieved/peak ratios in the JSON are meaningful relative to each other,
# not as absolute hardware truth on the CPU interpret backend.
PEAK_FLOPS = 197e12
HBM_BW = 819e9
BYTES_PER_FRONT_CELL = 4 * 2   # f32 workspace, read + write


def make_suite(scale: float, rng: np.random.Generator) -> List:
    d = lambda base: max(4, int(round(base * scale)))
    return [
        grid2d(d(16), d(16), "grid2d"),
        banded(d(300), 4, 0.8, rng, "banded"),
        permuted_banded(d(300), 3, 0.85, rng, "pbanded"),
        scalefree(d(260), 2, rng, "scalefree"),
        block_arrow(max(4, int(4 * scale)), d(24), 8, rng, "block_arrow"),
    ]


def bench_matrix(a, backends: List[str], repeats: int) -> Dict:
    rng = np.random.default_rng(0)
    b = rng.standard_normal(a.n)
    t0 = time.perf_counter()
    sym = symbolic_cholesky(a)
    t_sym = time.perf_counter() - t0
    sched = build_schedule(sym)
    s = sched.stats()
    front_bytes = sum(fp.m * fp.m for fp in sched.fronts) * BYTES_PER_FRONT_CELL
    rec: Dict = dict(
        name=a.name, n=a.n, nnz=a.nnz, t_symbolic=t_sym,
        nsup=s["nsup"], nlevels=s["nlevels"],
        max_level_width=s["max_level_width"],
        fronts_per_level=s["nsup"] / max(s["nlevels"], 1),
        occupancy=s["occupancy"], nbatches=s["nbatches"],
        sym_flops=sym.flops, front_flops=s["front_flops"],
        flop_ratio=s["front_flops"] / max(sym.flops, 1),
        roofline=dict(
            compute_s=s["front_flops"] / PEAK_FLOPS,
            memory_s=front_bytes / HBM_BW,
            front_bytes=front_bytes,
        ),
        backends={},
    )
    for backend in backends:
        t0 = time.perf_counter()
        r = factor_and_solve_timed(a, b, sym=sym, backend=backend)
        cold = time.perf_counter() - t0
        warm = r
        for _ in range(max(repeats - 1, 0)):
            rr = factor_and_solve_timed(a, b, sym=sym, backend=backend)
            if rr["t_factor"] + rr["t_solve"] < warm["t_factor"] + warm["t_solve"]:
                warm = rr
        entry = dict(
            cold_s=cold,
            warm_factor_s=warm["t_factor"], warm_solve_s=warm["t_solve"],
            warm_s=warm["t_factor"] + warm["t_solve"],
            residual=warm["residual"],
            gflops=s["front_flops"] / max(warm["t_factor"], 1e-12) / 1e9,
        )
        if backend == "batched":
            f = multifrontal_cholesky(a, sym, backend="batched")
            t0 = time.perf_counter()
            _, info = refine_solve(a.matvec,
                                   lambda r_: multifrontal_solve(f, r_), b)
            entry["refine_s"] = time.perf_counter() - t0
            entry["residual_refined"] = info.final_residual
            entry["refine_iterations"] = info.iterations
            entry["refine_converged"] = info.converged
        rec["backends"][backend] = entry
    bk = rec["backends"]
    if "batched" in bk and "pallas" in bk:
        rec["speedup_batched_vs_pallas"] = (bk["pallas"]["warm_factor_s"]
                                            / max(bk["batched"]["warm_factor_s"],
                                                  1e-12))
    if "batched" in bk and "numpy" in bk:
        rec["speedup_batched_vs_numpy"] = (bk["numpy"]["warm_factor_s"]
                                           / max(bk["batched"]["warm_factor_s"],
                                                 1e-12))
    return rec


def run_gates(records: List[Dict], args) -> List[str]:
    fails: List[str] = []
    for r in records:
        bk = r["backends"]
        if "numpy" in bk and bk["numpy"]["residual"] > args.gate_residual_fp64:
            fails.append(f"{r['name']}: numpy residual "
                         f"{bk['numpy']['residual']:.2e} > "
                         f"{args.gate_residual_fp64:.0e}")
        if "batched" in bk:
            rb = bk["batched"]
            if rb["residual_refined"] > args.gate_residual_refine:
                fails.append(f"{r['name']}: batched+refine residual "
                             f"{rb['residual_refined']:.2e} > "
                             f"{args.gate_residual_refine:.0e}")
        # the dense-front cubic model can sit a hair under the per-column
        # symbolic sum on fundamental supernodes; amalgamation (relax=8)
        # legitimately pads a few ×. Outside [0.8, gate] means the supernode
        # partition or the flop accounting drifted.
        ratio = r["flop_ratio"]
        if not (0.8 <= ratio <= args.gate_flop_ratio):
            fails.append(f"{r['name']}: front/symbolic flop ratio {ratio:.2f} "
                         f"outside [0.8, {args.gate_flop_ratio}]")
    return fails


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--scale", type=float, default=1.0,
                   help="suite size multiplier")
    p.add_argument("--quick", action="store_true",
                   help="CI mode: small suite, fewer repeats")
    p.add_argument("--repeats", type=int, default=3)
    p.add_argument("--backends", default="numpy,pallas,batched",
                   help="comma-separated: numpy,pallas,batched")
    p.add_argument("--out", default="BENCH_solve.json")
    p.add_argument("--gate-residual-fp64", type=float, default=1e-10)
    p.add_argument("--gate-residual-refine", type=float, default=1e-6)
    p.add_argument("--gate-flop-ratio", type=float, default=6.0)
    p.add_argument("--no-gate", action="store_true")
    args = p.parse_args(argv)
    if args.quick:
        args.scale = min(args.scale, 0.6)
        args.repeats = min(args.repeats, 2)

    rng = np.random.default_rng(0)
    mats = make_suite(args.scale, rng)
    backends = [b.strip() for b in args.backends.split(",") if b.strip()]
    records = []
    for a in mats:
        rec = bench_matrix(a, backends, args.repeats)
        records.append(rec)
        line = (f"{rec['name']:>12s} n={rec['n']:>5d} nsup={rec['nsup']:>4d} "
                f"levels={rec['nlevels']:>3d} "
                f"f/lvl={rec['fronts_per_level']:.1f} "
                f"occ={rec['occupancy']:.2f}")
        for be in backends:
            e = rec["backends"][be]
            line += f" | {be} {e['warm_s']*1e3:8.2f}ms r={e['residual']:.1e}"
        print(line)
    doc = dict(
        bench="solve", scale=args.scale, repeats=args.repeats,
        backends=backends, peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW,
        records=records,
    )
    with open(args.out, "w") as fh:
        json.dump(doc, fh, indent=2)
    print(f"wrote {args.out} ({len(records)} matrices)")

    wide = [r for r in records
            if r["fronts_per_level"] >= 4 and "speedup_batched_vs_pallas" in r]
    if wide:
        sp = [r["speedup_batched_vs_pallas"] for r in wide]
        print(f"batched vs per-front pallas on ≥4-fronts/level matrices: "
              f"min {min(sp):.1f}×, mean {float(np.mean(sp)):.1f}×")

    if not args.no_gate:
        fails = run_gates(records, args)
        if fails:
            print("GATE FAILURES:")
            for f in fails:
                print("  " + f)
            return 1
        print("gates: OK (residuals + flop-ratio drift)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
