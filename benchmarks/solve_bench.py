"""Numeric-solve benchmark: the perf gate for the level-scheduled backends.

PR 2's e2e benchmark showed >95% of warm-path time is the numeric
factorization, so this is the trajectory that matters now. Per matrix ×
backend (numpy / per-front pallas / level-batched / pipelined):

* cold (first call, includes kernel compilation) and warm factor+solve
  wall times, residuals,
* achieved GFLOP/s against the **symbolic flop model**
  (``SymbolicFactor.flops`` — exact) and the dense-front flop count
  (``LevelSchedule`` — includes amalgamation padding; the ratio of the two
  is the structural overhead the supernode relaxation chose),
* per-level batch occupancy and fronts-per-level (the parallelism the
  batched backend can actually exploit),
* roofline terms (compute vs memory seconds from the flop model + front
  bytes) consumed by ``benchmarks/roofline.py``,
* for the batched/pipelined backends: the **overlap efficiency** (host
  assembly seconds over assembly + device-blocked seconds — the fraction
  of overlappable time the backend kept the host busy) and the solve-stage
  split (assemble/dispatch/sync),
* for the batched backend: the fp32 residual and the fp32+fp64-refinement
  residual/iterations,
* when both run: the max-abs solution difference pipelined vs batched
  (the two share every kernel, so this is 0.0 up to nondeterminism-free
  reordering — the parity gate),
* for the pipelined backend: the **device-sweep leg** — warm
  ``sweep="device"`` vs host ``"level"`` single-RHS times, raw and
  *refined* device-vs-host solution parity (the sweeps are f32, so the
  gated comparison is after fp64 refinement on both sides), the
  device-resident refinement residual/iterations, and the multi-RHS
  record: one ``(n, k)`` device solve vs ``k`` per-vector host level
  sweeps, with the achieved sweep GFLOP/s from
  ``LevelSchedule.sweep_flops``.

Emits ``BENCH_solve.json`` and exits non-zero when a gate fails:
``--gate-residual-fp64`` (numpy backend), ``--gate-residual-refine``
(batched + refinement), ``--gate-flop-ratio`` (dense-front flops vs
symbolic model drift), ``--gate-pipelined-parity`` (solution drift vs
batched), ``--gate-overlap-margin`` (pipelined overlap efficiency must
reach this fraction of the batched baseline), ``--gate-device-parity``
(refined device-sweep vs refined host-sweep solution drift), and
``--gate-rhs-speedup`` (suite-mean multi-RHS device throughput over
per-vector host sweeps). CI runs ``--quick`` on the interpret backend and
uploads the JSON as the second ``BENCH_*`` trajectory artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List

import numpy as np

from repro.sparse.dataset import (banded, block_arrow, grid2d,
                                  permuted_banded, scalefree)
from repro.sparse.multifrontal import (factor_and_solve_timed,
                                       multifrontal_cholesky,
                                       multifrontal_solve)
from repro.sparse.refine import refine_solve, refine_solve_device
from repro.sparse.schedule import build_schedule
from repro.sparse.symbolic import symbolic_cholesky

# v4-ish single-core roofline constants (same as the dry-run roofline):
# achieved/peak ratios in the JSON are meaningful relative to each other,
# not as absolute hardware truth on the CPU interpret backend.
PEAK_FLOPS = 197e12
HBM_BW = 819e9
BYTES_PER_FRONT_CELL = 4 * 2   # f32 workspace, read + write


def make_suite(scale: float, rng: np.random.Generator) -> List:
    d = lambda base: max(4, int(round(base * scale)))
    return [
        grid2d(d(16), d(16), "grid2d"),
        banded(d(300), 4, 0.8, rng, "banded"),
        permuted_banded(d(300), 3, 0.85, rng, "pbanded"),
        scalefree(d(260), 2, rng, "scalefree"),
        block_arrow(max(4, int(4 * scale)), d(24), 8, rng, "block_arrow"),
    ]


def _best(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_sweeps(a, sym, sched, b, repeats: int, rhs_k: int = 8) -> Dict:
    """The device-sweep leg: warm level vs device single-RHS, refined
    parity, device-resident refinement, and the multi-RHS throughput
    record (one (n, k) device dispatch vs k per-vector host sweeps)."""
    rng = np.random.default_rng(1)
    f = multifrontal_cholesky(a, sym, backend="pipelined")
    B = rng.standard_normal((a.n, rhs_k))
    # warm-up: compile the device sweep buckets for both RHS widths
    xl = multifrontal_solve(f, b, mode="level")
    xd = multifrontal_solve(f, b, mode="device")
    multifrontal_solve(f, B, mode="device")
    denom = max(float(np.abs(xl).max()), 1e-30)
    xh, _ = refine_solve(a.matvec,
                         lambda r_: multifrontal_solve(f, r_, mode="level"),
                         b)
    xdr, info = refine_solve_device(a, f, b)
    level_s = _best(lambda: multifrontal_solve(f, b, mode="level"), repeats)
    device_s = _best(lambda: multifrontal_solve(f, b, mode="device"),
                     repeats)
    device_multi_s = _best(lambda: multifrontal_solve(f, B, mode="device"),
                           repeats)
    host_pervec_s = _best(
        lambda: [multifrontal_solve(f, B[:, j], mode="level")
                 for j in range(rhs_k)], repeats)
    return dict(
        rhs_k=rhs_k,
        level_s=level_s, device_s=device_s,
        device_multi_s=device_multi_s, host_pervec_s=host_pervec_s,
        multi_rhs_speedup=host_pervec_s / max(device_multi_s, 1e-12),
        sweep_gflops=sched.sweep_flops(rhs_k)
        / max(device_multi_s, 1e-12) / 1e9,
        raw_parity=float(np.abs(xd - xl).max()) / denom,     # f32 floor
        refined_parity=float(np.abs(xdr - xh).max())
        / max(float(np.abs(xh).max()), 1e-30),
        residual_device_refined=info.final_residual,
        refine_iterations_device=info.iterations,
        refine_converged_device=info.converged,
    )


def bench_matrix(a, backends: List[str], repeats: int) -> Dict:
    rng = np.random.default_rng(0)
    b = rng.standard_normal(a.n)
    t0 = time.perf_counter()
    sym = symbolic_cholesky(a)
    t_sym = time.perf_counter() - t0
    sched = build_schedule(sym)
    s = sched.stats()
    front_bytes = sum(fp.m * fp.m for fp in sched.fronts) * BYTES_PER_FRONT_CELL
    rec: Dict = dict(
        name=a.name, n=a.n, nnz=a.nnz, t_symbolic=t_sym,
        nsup=s["nsup"], nlevels=s["nlevels"],
        max_level_width=s["max_level_width"],
        fronts_per_level=s["nsup"] / max(s["nlevels"], 1),
        occupancy=s["occupancy"], nbatches=s["nbatches"],
        per_level_occupancy=s["per_level_occupancy"],
        min_level_occupancy=s["min_level_occupancy"],
        pad=s["pad"],
        sym_flops=sym.flops, front_flops=s["front_flops"],
        flop_ratio=s["front_flops"] / max(sym.flops, 1),
        roofline=dict(
            compute_s=s["front_flops"] / PEAK_FLOPS,
            memory_s=front_bytes / HBM_BW,
            front_bytes=front_bytes,
        ),
        backends={},
    )
    for backend in backends:
        t0 = time.perf_counter()
        r = factor_and_solve_timed(a, b, sym=sym, backend=backend)
        cold = time.perf_counter() - t0
        warm = r
        for _ in range(max(repeats - 1, 0)):
            rr = factor_and_solve_timed(a, b, sym=sym, backend=backend)
            if rr["t_factor"] + rr["t_solve"] < warm["t_factor"] + warm["t_solve"]:
                warm = rr
        entry = dict(
            cold_s=cold,
            warm_factor_s=warm["t_factor"], warm_solve_s=warm["t_solve"],
            warm_s=warm["t_factor"] + warm["t_solve"],
            residual=warm["residual"],
            gflops=s["front_flops"] / max(warm["t_factor"], 1e-12) / 1e9,
        )
        # level-scheduled backends report their solve-stage split and the
        # overlap metric the pipelined gate runs on
        for k in ("t_factor_assemble", "t_factor_dispatch", "t_factor_sync",
                  "overlap_efficiency"):
            if k in warm:
                entry[k] = warm[k]
        if backend == "batched":
            f = multifrontal_cholesky(a, sym, backend="batched")
            t0 = time.perf_counter()
            _, info = refine_solve(a.matvec,
                                   lambda r_: multifrontal_solve(f, r_), b)
            entry["refine_s"] = time.perf_counter() - t0
            entry["residual_refined"] = info.final_residual
            entry["refine_iterations"] = info.iterations
            entry["refine_converged"] = info.converged
        rec["backends"][backend] = entry
    bk = rec["backends"]
    if "batched" in bk and "pallas" in bk:
        rec["speedup_batched_vs_pallas"] = (bk["pallas"]["warm_factor_s"]
                                            / max(bk["batched"]["warm_factor_s"],
                                                  1e-12))
    if "batched" in bk and "numpy" in bk:
        rec["speedup_batched_vs_numpy"] = (bk["numpy"]["warm_factor_s"]
                                           / max(bk["batched"]["warm_factor_s"],
                                                 1e-12))
    if "batched" in bk and "pipelined" in bk:
        rec["speedup_pipelined_vs_batched"] = (
            bk["batched"]["warm_factor_s"]
            / max(bk["pipelined"]["warm_factor_s"], 1e-12))
        # parity: both paths run the same kernels, so the factors agree to
        # the last bit — compare the solutions directly
        fb = multifrontal_cholesky(a, sym, backend="batched")
        fp_ = multifrontal_cholesky(a, sym, backend="pipelined")
        xb = multifrontal_solve(fb, b)
        xp = multifrontal_solve(fp_, b)
        denom = max(float(np.abs(xb).max()), 1e-30)
        rec["pipelined_parity_maxdiff"] = float(np.abs(xp - xb).max()) / denom
    if "pipelined" in bk:
        rec["sweeps"] = bench_sweeps(a, sym, sched, b, repeats)
    return rec


def run_gates(records: List[Dict], args) -> List[str]:
    fails: List[str] = []
    for r in records:
        bk = r["backends"]
        if "numpy" in bk and bk["numpy"]["residual"] > args.gate_residual_fp64:
            fails.append(f"{r['name']}: numpy residual "
                         f"{bk['numpy']['residual']:.2e} > "
                         f"{args.gate_residual_fp64:.0e}")
        if "batched" in bk:
            rb = bk["batched"]
            if rb["residual_refined"] > args.gate_residual_refine:
                fails.append(f"{r['name']}: batched+refine residual "
                             f"{rb['residual_refined']:.2e} > "
                             f"{args.gate_residual_refine:.0e}")
        # the dense-front cubic model can sit a hair under the per-column
        # symbolic sum on fundamental supernodes; amalgamation (relax=8)
        # legitimately pads a few ×. Outside [0.8, gate] means the supernode
        # partition or the flop accounting drifted.
        ratio = r["flop_ratio"]
        if not (0.8 <= ratio <= args.gate_flop_ratio):
            fails.append(f"{r['name']}: front/symbolic flop ratio {ratio:.2f} "
                         f"outside [0.8, {args.gate_flop_ratio}]")
        if "pipelined_parity_maxdiff" in r:
            d = r["pipelined_parity_maxdiff"]
            if d > args.gate_pipelined_parity:
                fails.append(f"{r['name']}: pipelined vs batched solution "
                             f"drift {d:.2e} > "
                             f"{args.gate_pipelined_parity:.0e}")
        bkk = r["backends"]
        if "batched" in bkk and "pipelined" in bkk:
            ob = bkk["batched"].get("overlap_efficiency")
            op = bkk["pipelined"].get("overlap_efficiency")
            if (ob is not None and op is not None
                    and op < ob * args.gate_overlap_margin):
                fails.append(
                    f"{r['name']}: pipelined overlap efficiency {op:.2f} "
                    f"< {args.gate_overlap_margin:.2f}× batched baseline "
                    f"{ob:.2f}")
        if "sweeps" in r:
            sw = r["sweeps"]
            if sw["refined_parity"] > args.gate_device_parity:
                fails.append(f"{r['name']}: refined device-sweep vs "
                             f"host-sweep drift {sw['refined_parity']:.2e} "
                             f"> {args.gate_device_parity:.0e}")
    # throughput is gated on the suite mean: tiny matrices pay fixed
    # dispatch overhead per call, the wide ones amortize it
    sp = [r["sweeps"]["multi_rhs_speedup"] for r in records
          if "sweeps" in r]
    if sp and float(np.mean(sp)) < args.gate_rhs_speedup:
        fails.append(f"multi-RHS device sweep speedup mean "
                     f"{float(np.mean(sp)):.2f}× < "
                     f"{args.gate_rhs_speedup:.2f}× over per-vector "
                     f"host sweeps")
    return fails


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--scale", type=float, default=1.0,
                   help="suite size multiplier")
    p.add_argument("--quick", action="store_true",
                   help="CI mode: small suite, fewer repeats")
    p.add_argument("--repeats", type=int, default=3)
    p.add_argument("--backends", default="numpy,pallas,batched,pipelined",
                   help="comma-separated: numpy,pallas,batched,pipelined")
    p.add_argument("--out", default="BENCH_solve.json")
    p.add_argument("--gate-residual-fp64", type=float, default=1e-10)
    p.add_argument("--gate-residual-refine", type=float, default=1e-6)
    p.add_argument("--gate-flop-ratio", type=float, default=6.0)
    p.add_argument("--gate-pipelined-parity", type=float, default=1e-6,
                   help="max relative solution drift pipelined vs batched")
    # the pipelined backend defers every device wait to one drain, so its
    # overlap efficiency should dominate batched's blocking loop; the
    # margin < 1 absorbs scheduler jitter on tiny CI matrices
    p.add_argument("--gate-overlap-margin", type=float, default=0.75,
                   help="pipelined overlap efficiency must be ≥ margin × "
                        "the batched baseline")
    # the sweeps are f32, so parity is gated after fp64 refinement on both
    # sides — the raw f32 floor (~1e-7) is recorded but not gated
    p.add_argument("--gate-device-parity", type=float, default=1e-6,
                   help="max refined device-sweep vs host-sweep drift")
    p.add_argument("--gate-rhs-speedup", type=float, default=1.5,
                   help="min suite-mean multi-RHS device throughput over "
                        "per-vector host level sweeps")
    p.add_argument("--no-gate", action="store_true")
    args = p.parse_args(argv)
    if args.quick:
        args.scale = min(args.scale, 0.6)
        args.repeats = min(args.repeats, 2)

    rng = np.random.default_rng(0)
    mats = make_suite(args.scale, rng)
    backends = [b.strip() for b in args.backends.split(",") if b.strip()]
    records = []
    for a in mats:
        rec = bench_matrix(a, backends, args.repeats)
        records.append(rec)
        line = (f"{rec['name']:>12s} n={rec['n']:>5d} nsup={rec['nsup']:>4d} "
                f"levels={rec['nlevels']:>3d} "
                f"f/lvl={rec['fronts_per_level']:.1f} "
                f"occ={rec['occupancy']:.2f}")
        for be in backends:
            e = rec["backends"][be]
            line += f" | {be} {e['warm_s']*1e3:8.2f}ms r={e['residual']:.1e}"
        print(line)
    doc = dict(
        bench="solve", scale=args.scale, repeats=args.repeats,
        backends=backends, peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW,
        records=records,
    )
    with open(args.out, "w") as fh:
        json.dump(doc, fh, indent=2)
    print(f"wrote {args.out} ({len(records)} matrices)")

    wide = [r for r in records
            if r["fronts_per_level"] >= 4 and "speedup_batched_vs_pallas" in r]
    if wide:
        sp = [r["speedup_batched_vs_pallas"] for r in wide]
        print(f"batched vs per-front pallas on ≥4-fronts/level matrices: "
              f"min {min(sp):.1f}×, mean {float(np.mean(sp)):.1f}×")
    ov = [(r["backends"]["batched"].get("overlap_efficiency"),
           r["backends"]["pipelined"].get("overlap_efficiency"))
          for r in records
          if "batched" in r["backends"] and "pipelined" in r["backends"]]
    ov = [(b_, p_) for b_, p_ in ov if b_ is not None and p_ is not None]
    if ov:
        print(f"overlap efficiency (host-busy fraction): batched mean "
              f"{float(np.mean([b_ for b_, _ in ov])):.2f}, pipelined mean "
              f"{float(np.mean([p_ for _, p_ in ov])):.2f}")
    sw = [r["sweeps"] for r in records if "sweeps" in r]
    if sw:
        sp_ = [s["multi_rhs_speedup"] for s in sw]
        print(f"device sweeps: multi-RHS (k={sw[0]['rhs_k']}) speedup over "
              f"per-vector host sweeps min {min(sp_):.1f}×, mean "
              f"{float(np.mean(sp_)):.1f}×; sweep GFLOP/s mean "
              f"{float(np.mean([s['sweep_gflops'] for s in sw])):.3f}; "
              f"refined parity max "
              f"{max(s['refined_parity'] for s in sw):.1e}")

    if not args.no_gate:
        fails = run_gates(records, args)
        if fails:
            print("GATE FAILURES:")
            for f in fails:
                print("  " + f)
            return 1
        print("gates: OK (residuals + flop-ratio drift)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
