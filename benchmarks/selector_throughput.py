"""Selection serving throughput: host vs device featurizer paths.

    PYTHONPATH=src python -m benchmarks.selector_throughput [--use-pallas]

Reports matrices/sec for ``ReorderSelector.select_batch`` at batch sizes
1/8/64 on the host (per-matrix numpy) path and the device (CSR-native
padded-batch) path. The device path amortizes dispatch and jit overhead
across the batch — the spread between batch=1 and batch=64 is the argument
for request batching in ``repro.launch.serve_selector``.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

try:
    from .common import ART
except ImportError:  # run as a loose script: benchmarks/ on sys.path
    from common import ART

from repro.core.labeling import load_or_build
from repro.core.selector import train_selector
from repro.sparse.dataset import generate_suite

BATCH_SIZES = (1, 8, 64)


def bench_path(sel, mats, bs: int, path: str, use_pallas: bool,
               repeats: int = 3) -> float:
    """matrices/sec for select_batch at batch size bs (best of repeats).

    Batches are formed from a size-sorted pool (as the serving loop does),
    so padded batch dims track their members' true sizes.
    """
    mats = sorted(mats, key=lambda m: (m.nnz, m.n))
    batches = [mats[lo : lo + bs] for lo in range(0, len(mats), bs)]
    batches = [b for b in batches if len(b) == bs]
    # warmup: compile/trace once per (shape-bucket, path)
    sel.select_batch(batches[0], path=path, use_pallas=use_pallas)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for b in batches:
            sel.select_batch(b, path=path, use_pallas=use_pallas)
        best = min(best, time.perf_counter() - t0)
    return bs * len(batches) / best


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--use-pallas", action="store_true",
                   help="route device reductions through the Pallas kernels")
    p.add_argument("--pool", type=int, default=64)
    p.add_argument("--model", default="logistic_regression")
    args = p.parse_args()

    ds = load_or_build(cache_dir=ART, count=36, seed=7, size_scale=0.35,
                       repeats=1, verbose=True)
    sel, rep = train_selector(ds, args.model, "standard", fast=True, cv=3)
    print(f"# selector: {args.model} (test_acc {rep['test_accuracy']:.2f})")

    mats = list(generate_suite(count=args.pool, seed=11, size_scale=0.4))
    print(f"# pool: {len(mats)} matrices, n∈[{min(m.n for m in mats)}, "
          f"{max(m.n for m in mats)}], nnz_max "
          f"{max(m.nnz for m in mats)}")
    print("path,batch,matrices_per_sec")
    for path in ("host", "device"):
        for bs in BATCH_SIZES:
            if bs > len(mats):
                print(f"{path},{bs},skipped (pool < batch)")
                continue
            rate = bench_path(sel, mats, bs, path, args.use_pallas
                              if path == "device" else False)
            print(f"{path},{bs},{rate:.1f}")


if __name__ == "__main__":
    main()
