"""Selection serving benchmarks: featurizer throughput and end-to-end plans.

    PYTHONPATH=src python -m benchmarks.selector_throughput [--use-pallas]
    PYTHONPATH=src python -m benchmarks.selector_throughput --devices 4
    PYTHONPATH=src python -m benchmarks.selector_throughput --mode e2e

Both modes drive :class:`repro.engine.SolverEngine` — the same facade the
serving entrypoint uses, so the numbers measure the production path. The
engine versions its plan cache with the trained model's fingerprint; no
manual cache ``version=`` handling appears anywhere here.

``--mode throughput`` (default) reports matrices/sec for batched selection
at batch sizes 1/8/64 on the host (per-matrix numpy) path and the device
(CSR-native padded-batch) path. The device path amortizes dispatch and jit
overhead across the batch — the spread between batch=1 and batch=64 is the
argument for request batching in ``repro.launch.serve_selector``.

``--devices N`` measures the *scaling curve* of the distributed serving
plane: it forces N host-platform virtual devices (XLA_FLAGS, set before
jax initializes), then runs the device path on serving meshes of every
power-of-two width up to N — same process, same matrices — reporting
aggregate and per-shard (per-device) matrices/sec for each width. The
1-device row is the same shard_map code on the degenerate mesh, so the
comparison isolates the mesh width.

``--mode e2e`` measures the full request lifecycle — select + reorder +
symbolic + numeric solve — through the :class:`ExecutionPlan` pipeline,
cold (empty two-tier plan cache: every stage runs) vs. warm (every
structure cached: fingerprint → plan → numeric solve only), and reports
cache hit rate and p50/p99 per-request latency alongside matrices/sec.
The warm/cold gap is the payoff of caching *plans* instead of algorithm
names. ``--campaign-count/--campaign-scale/--pool`` shrink everything for
smoke runs (CI uses a tiny suite).
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np

try:
    from .common import ART
except ImportError:  # run as a loose script: benchmarks/ on sys.path
    from common import ART

# NOTE: repro/jax imports happen inside main(), *after* --devices has had
# the chance to set XLA_FLAGS — the host-platform virtual device count is
# fixed at backend initialization.

BATCH_SIZES = (1, 8, 64)


def bench_path(engine, mats, bs: int, path: str, use_pallas: bool,
               repeats: int = 3) -> float:
    """matrices/sec for select_batch at batch size bs (best of repeats).

    Batches are formed from a size-sorted pool (as the serving loop does),
    so padded batch dims track their members' true sizes.
    """
    sel = engine.selector
    mats = sorted(mats, key=lambda m: (m.nnz, m.n))
    batches = [mats[lo : lo + bs] for lo in range(0, len(mats), bs)]
    batches = [b for b in batches if len(b) == bs]
    # warmup: compile/trace once per (shape-bucket, path)
    sel.select_batch(batches[0], path=path, use_pallas=use_pallas)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for b in batches:
            sel.select_batch(b, path=path, use_pallas=use_pallas)
        best = min(best, time.perf_counter() - t0)
    return bs * len(batches) / best


def _pct(lat, q):
    return float(np.percentile(np.asarray(lat) * 1e3, q))


def bench_e2e(engine, mats, repeats: int = 2) -> None:
    """Cold vs. warm per-request latency through the ExecutionPlan pipeline.

    Each request = plan the structure (``engine.plan_batch``), then
    numerically factor+solve with it. Cold requests pay select + reorder +
    symbolic + numeric; warm requests (same structures, fresh values) pay
    fingerprint + numeric only. The engine was built over a fresh temp
    cache dir, which keeps the cold pass honest across runs.
    """
    from repro.core.plan import execute_plan

    rng = np.random.default_rng(0)
    builder = engine.builder
    # jit warm-up outside the timed region: per-request selection over
    # the whole pool compiles every padded shape bucket exactly as the
    # cold pass will hit them (one matrix per micro-batch), so the
    # cold/warm gap measures the plan cache, not jit compiles; then
    # reset the selection counters so the report reflects serving
    for m in mats:
        builder.select_names([m])
    builder.reset_stats()

    def run_pass():
        lats, solves = [], []
        for m in mats:
            q = m.copy()  # fresh numeric values, same structure
            q.data = q.data * float(rng.uniform(0.5, 2.0))
            b = rng.standard_normal(m.n)
            t0 = time.perf_counter()
            plan = engine.plan_batch([q])[0]
            res = execute_plan(q, plan, b)
            lats.append(time.perf_counter() - t0)
            solves.append(res["time"])
        return lats, solves

    cold_lat, cold_solve = run_pass()
    warm_lat, warm_solve = [], []
    for _ in range(repeats):  # every warm measurement is aggregated
        lat, solve = run_pass()
        warm_lat += lat
        warm_solve += solve

    s = builder.stats()
    print("pass,requests,mean_ms,p50_ms,p99_ms,matrices_per_sec")
    for tag, lat in (("cold", cold_lat), ("warm", warm_lat)):
        print(f"{tag},{len(lat)},{1e3*np.mean(lat):.2f},"
              f"{_pct(lat, 50):.2f},{_pct(lat, 99):.2f},"
              f"{len(lat)/sum(lat):.1f}")
    print(f"# cache: hit_rate {s['hit_rate']:.2f} "
          f"({s['hits']} hits / {s['misses']} misses, "
          f"disk entries {s['disk_entries']}), "
          f"{s['plans_built']} plans built, "
          f"select {s['select_seconds']*1e3:.0f} ms, "
          f"build {s['build_seconds']*1e3:.0f} ms")
    print(f"# total request time: cold {1e3*sum(cold_lat):.0f} ms vs "
          f"warm {1e3*sum(warm_lat):.0f} ms; numeric solve share "
          f"cold {sum(cold_solve)/max(sum(cold_lat), 1e-12):.2f} vs "
          f"warm {sum(warm_solve)/max(sum(warm_lat), 1e-12):.2f}")
    speedup = np.mean(cold_lat) / max(np.mean(warm_lat), 1e-12)
    verdict = "OK" if np.mean(warm_lat) < np.mean(cold_lat) else "FAIL"
    print(f"# warm below cold: {verdict} "
          f"(mean {1e3*np.mean(cold_lat):.2f} ms → "
          f"{1e3*np.mean(warm_lat):.2f} ms, {speedup:.1f}x)")


def _mesh_widths(n: int):
    """Powers of two up to n, n included: 4 → [1, 2, 4]; 6 → [1, 2, 4, 6]."""
    w, out = 1, []
    while w < n:
        out.append(w)
        w *= 2
    out.append(n)
    return out


def bench_devices(engine, mats, args) -> None:
    """Scaling curve of the sharded featurize→infer path.

    Same pool, same process: for each serving-mesh width up to
    ``--devices``, install the mesh, re-run batched selection, and report
    aggregate and per-shard throughput. Every width runs the identical
    shard_map code — width 1 *is* the degenerate mesh, so row 1 is the
    single-device baseline the aggregate rows are judged against.
    """
    from repro.distributed.meshctx import make_serving_mesh, set_serving_mesh

    bs = max(b for b in BATCH_SIZES if b <= len(mats))
    print(f"# sharded scaling: pool {len(mats)}, batch {bs}, "
          f"widths {_mesh_widths(args.devices)}")
    print("path,batch,devices,agg_matrices_per_sec,per_shard_matrices_per_sec")
    base = None
    try:
        for nd in _mesh_widths(args.devices):
            set_serving_mesh(make_serving_mesh(nd))
            rate = bench_path(engine, mats, bs, "device", args.use_pallas)
            if base is None:
                base = rate
            print(f"device,{bs},{nd},{rate:.1f},{rate / nd:.1f}")
        if args.devices == 1:
            # nothing to judge: the single width IS the baseline
            print(f"# 1-device baseline: {base:.1f} matrices/sec")
        else:
            speedup = rate / base if base else float("nan")
            verdict = "OK" if rate > base else "FAIL"
            print(f"# aggregate above 1-device baseline: {verdict} "
                  f"({base:.1f} → {rate:.1f} matrices/sec, "
                  f"{speedup:.2f}x at {args.devices} devices)")
    finally:
        set_serving_mesh(None)  # leave the process on the degenerate mesh


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--mode", choices=["throughput", "e2e"],
                   default="throughput")
    p.add_argument("--devices", type=int, default=None,
                   help="force N host-platform virtual devices and bench "
                        "the sharded device path on meshes of width "
                        "1..N (throughput mode only)")
    p.add_argument("--use-pallas", action="store_true",
                   help="route device reductions through the Pallas kernels")
    p.add_argument("--pool", type=int, default=64)
    p.add_argument("--batch", type=int, default=8,
                   help="selector micro-batch size in e2e mode")
    p.add_argument("--model", default="logistic_regression")
    p.add_argument("--campaign-count", type=int, default=36,
                   help="labeling-campaign size (shrink for smoke runs)")
    p.add_argument("--campaign-scale", type=float, default=0.35)
    args = p.parse_args()

    if args.devices is not None and args.devices > 1:
        # must precede jax backend init — which is why the repro imports
        # below live inside main()
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()

    from repro.core.labeling import load_or_build
    from repro.engine import EngineConfig, SolverEngine
    from repro.sparse.dataset import generate_suite

    if args.devices is not None:
        import jax

        if jax.device_count() < args.devices:
            raise SystemExit(
                f"--devices {args.devices} but only {jax.device_count()} "
                f"jax devices materialized (XLA_FLAGS set too late?)")

    ds = load_or_build(cache_dir=ART, count=args.campaign_count, seed=7,
                       size_scale=args.campaign_scale, repeats=1,
                       verbose=True)
    mats = list(generate_suite(count=args.pool, seed=11, size_scale=0.4))
    print(f"# pool: {len(mats)} matrices, n∈[{min(m.n for m in mats)}, "
          f"{max(m.n for m in mats)}], nnz_max "
          f"{max(m.nnz for m in mats)}")

    with tempfile.TemporaryDirectory(prefix="plan_cache_bench_") as d:
        engine = SolverEngine(EngineConfig(
            model=args.model, cache_dir=d, cache_capacity=4 * len(mats),
            path="device", use_pallas=args.use_pallas,
            batch_size=args.batch, fast_grids=True, cv=3))
        rep = engine.train(ds)
        print(f"# selector: {args.model} "
              f"(test_acc {rep['test_accuracy']:.2f}, "
              f"fingerprint {engine.fingerprint[:12]})")
        if args.mode == "e2e":
            bench_e2e(engine, mats)
            return
        if args.devices is not None:
            bench_devices(engine, mats, args)
            return
        print("path,batch,matrices_per_sec")
        for path in ("host", "device"):
            for bs in BATCH_SIZES:
                if bs > len(mats):
                    print(f"{path},{bs},skipped (pool < batch)")
                    continue
                rate = bench_path(engine, mats, bs, path, args.use_pallas
                                  if path == "device" else False)
                print(f"{path},{bs},{rate:.1f}")


if __name__ == "__main__":
    main()
