"""Paper Table 5: per-matrix predicted label, prediction latency, true label
for the Table-1 (largest) matrices."""
from __future__ import annotations

import numpy as np

from .common import campaign_dataset, csv_line, trained_selector


def main(top: int = 9) -> str:
    sel, rep, ds = trained_selector()
    order = np.argsort(-ds.nnzs)[:top]
    lines = ["matrix,predict_label,predict_time_s,true_label"]
    times = []
    correct = 0
    for i in order:
        feats = ds.features[i]
        import time
        t0 = time.perf_counter()
        pred = int(sel.predict_features(feats)[0])
        dt = time.perf_counter() - t0
        times.append(dt)
        true = int(ds.labels[i])
        correct += int(pred == true)
        lines.append(f"{ds.names[i]},{ds.algorithms[pred]},{dt:.4f},"
                     f"{ds.algorithms[true]}")
    lines.append(csv_line("table5_predict", np.mean(times) * 1e6,
                          f"accuracy_on_largest={correct}/{top}"))
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
