"""Beyond-paper experiment (paper-side hillclimb, EXPERIMENTS.md §Perf):
retrain the selector with the extended 19-feature set.

The suite is deterministic, so the extended features are recomputed from the
regenerated matrices and merged with the *cached* solve times — no re-solving.
Also reports "effective accuracy": predictions whose ordering is within 5 %
of the per-matrix optimum (near-ties carry no real cost; exact-argmin
accuracy under-credits them)."""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.core.features import EXTENDED_FEATURE_NAMES, extract_features_extended
from repro.core.selector import train_selector
from repro.sparse.dataset import generate_suite

from .common import ART, CAMPAIGN, campaign_dataset, csv_line

CACHE = os.path.join(ART, "extended_features.npz")


def extended_dataset():
    ds = campaign_dataset()
    if os.path.exists(CACHE):
        feats = np.load(CACHE)["features"]
    else:
        mats = generate_suite(count=CAMPAIGN["count"], seed=CAMPAIGN["seed"],
                              size_scale=CAMPAIGN["size_scale"])
        feats = np.stack([extract_features_extended(m) for m in mats])
        np.savez_compressed(CACHE, features=feats)
    assert feats.shape[0] == ds.features.shape[0]
    return dataclasses.replace(ds, features=feats)


def effective_accuracy(ds, test_idx, pred, tol=0.05):
    t = ds.times[test_idx]
    chosen = t[np.arange(len(test_idx)), pred]
    best = t.min(axis=1)
    return float((chosen <= best * (1 + tol)).mean())


def main() -> str:
    base = campaign_dataset()
    ext = extended_dataset()
    lines = [f"featureset,n_features,test_accuracy,effective_accuracy@5%,"
             f"reduction_vs_amd,mean_speedup"]
    out = {}
    for name, ds in [("paper_12", base), ("extended_19", ext)]:
        sel, rep = train_selector(ds, "random_forest", "standard")
        ite = np.asarray(rep["test_idx"])
        pred = np.asarray(rep["predictions"])
        eff = effective_accuracy(ds, ite, pred)
        lines.append(f"{name},{ds.features.shape[1]},"
                     f"{rep['test_accuracy']:.4f},{eff:.4f},"
                     f"{rep['reduction_vs_amd']:.4f},"
                     f"{rep['mean_speedup_vs_amd']:.3f}")
        out[name] = dict(acc=rep["test_accuracy"], eff=eff,
                         red=rep["reduction_vs_amd"])
    with open(os.path.join(ART, "extended_features_result.json"), "w") as f:
        json.dump(out, f, indent=2)
    d = out["extended_19"]["acc"] - out["paper_12"]["acc"]
    lines.append(csv_line("extended_features", 0.0,
                          f"accuracy_delta={d:+.4f}"))
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
