"""Roofline view of the numeric solve, from ``BENCH_solve.json`` records.

Per matrix: the two roofline terms of the dense-front work
    compute = front FLOPs / PEAK_FLOPS
    memory  = front workspace bytes / HBM_BW
(recomputed here from the raw fields so the peak constants can evolve
without re-running the bench), the dominant bottleneck, and per backend the
achieved GFLOP/s and its fraction of the compute roof. Run
``benchmarks/solve_bench.py`` first to produce the input; this is a pure
formatter of its records.
"""
from __future__ import annotations

import json
import os
import sys

PEAK_FLOPS = 197e12
HBM_BW = 819e9

DEFAULT_PATH = os.environ.get("REPRO_BENCH_SOLVE", "BENCH_solve.json")


def load(path: str = DEFAULT_PATH) -> dict:
    with open(path) as fh:
        return json.load(fh)


def terms_of(rec: dict):
    compute = rec["front_flops"] / PEAK_FLOPS
    memory = rec["roofline"]["front_bytes"] / HBM_BW
    terms = dict(compute_s=compute, memory_s=memory)
    return terms, max(terms, key=terms.get)


def fmt_row(rec: dict, backends) -> str:
    t, dom = terms_of(rec)
    cells = [f"| {rec['name']} | {rec['n']} | {t['compute_s']*1e6:.2f} | "
             f"{t['memory_s']*1e6:.2f} | **{dom.replace('_s', '')}** | "
             f"{rec['flop_ratio']:.2f} | {rec['occupancy']:.2f} "]
    for be in backends:
        e = rec["backends"].get(be)
        if e is None:
            cells.append("| — ")
            continue
        frac = e["gflops"] * 1e9 / PEAK_FLOPS
        cells.append(f"| {e['gflops']:.3f} ({frac*100:.2g}%) ")
    return "".join(cells) + "|"


def main(path: str = DEFAULT_PATH) -> str:
    doc = load(path)
    backends = doc.get("backends", [])
    head = ["### Solve roofline — front work terms (µs) + achieved GFLOP/s",
            "",
            "| matrix | n | compute µs | memory µs | bottleneck | "
            "flops/symbolic | occupancy | "
            + " | ".join(f"{b} GF/s (of peak)" for b in backends) + " |",
            "|---" * (7 + len(backends)) + "|"]
    rows = [fmt_row(r, backends) for r in doc["records"]]
    recs = doc["records"]
    best = max(recs, key=lambda r: max(e["gflops"]
                                       for e in r["backends"].values()))
    tail = ["",
            f"peak achieved: {best['name']} "
            f"({max(e['gflops'] for e in best['backends'].values()):.3f} "
            f"GFLOP/s); all records from {path}"]
    return "\n".join(head + rows + tail)


if __name__ == "__main__":
    print(main(sys.argv[1] if len(sys.argv) > 1 else DEFAULT_PATH))
