"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh): the three terms in seconds
    compute    = per-device dot FLOPs / 197 TFLOP/s
    memory     = per-device HBM bytes / 819 GB/s
    collective = per-device wire bytes / 50 GB/s/link
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio, and
per-device residency (the fits-in-HBM proof)."""
from __future__ import annotations

import glob
import json
import os

ART = os.environ.get("REPRO_ARTIFACTS", "artifacts")


def load_records(mesh="pod16x16", tag=None):
    recs = []
    for p in sorted(glob.glob(os.path.join(ART, "dryrun", mesh, "*.json"))):
        name = os.path.basename(p)[:-5]
        parts = name.split("__")
        if tag is None and len(parts) > 2:
            continue
        if tag is not None and (len(parts) < 3 or parts[2] != tag):
            continue
        with open(p) as f:
            recs.append(json.load(f))
    return recs


PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def terms_of(r):
    """Recompute roofline terms from per-device artifact fields (so metric
    definitions can evolve without re-running the 80-cell sweep)."""
    pd = r["per_device"]
    compute = pd["dot_flops"] / PEAK_FLOPS
    memory = pd.get("dot_bytes", pd.get("bytes", 0.0)) / HBM_BW
    collective = pd["collective_bytes"] / ICI_BW
    terms = dict(compute_s=compute, memory_s=memory, collective_s=collective)
    bottleneck = max(terms, key=terms.get)
    return terms, bottleneck


def fmt_row(r):
    if r.get("status") != "ok":
        status = r.get("status", "?")
        short = "SKIP (full attention)" if "skipped" in status else status[:40]
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | "
                f"{short} |")
    t, dom = terms_of(r)
    ratio = r["roofline"]["useful_flops_ratio"]
    res = r["resident_bytes"] / 1e9
    return (f"| {r['arch']} | {r['shape']} | {t['compute_s']*1e3:.2f} | "
            f"{t['memory_s']*1e3:.2f} | {t['collective_s']*1e3:.2f} | "
            f"**{dom.replace('_s', '')}** | {ratio:.3f} | {res:.1f} | ok |")


def main(mesh: str = "pod16x16") -> str:
    recs = load_records(mesh)
    lines = [
        f"### Roofline — mesh {mesh} (ms per step; per-device terms)",
        "",
        "| arch | shape | compute ms | memory ms | collective ms | "
        "bottleneck | MODEL/HLO flops | GB/dev | status |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        lines.append(fmt_row(r))
    # aggregate: worst usefulness, most collective-bound
    ok = [r for r in recs if r.get("status") == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline"]["useful_flops_ratio"])
        coll = max(ok, key=lambda r: (terms_of(r)[0]["collective_s"]
                                      / max(max(terms_of(r)[0]["compute_s"],
                                                terms_of(r)[0]["memory_s"]),
                                            1e-12)))
        lines.append("")
        lines.append(f"worst useful-FLOPs ratio: {worst['arch']}×"
                     f"{worst['shape']} "
                     f"({worst['roofline']['useful_flops_ratio']:.3f}); "
                     f"most collective-bound: {coll['arch']}×{coll['shape']}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(main())
    print()
    print(main("pod2x16x16"))
