"""LR schedule: linear warmup + cosine decay to a floor."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine"]


def warmup_cosine(step, *, warmup_steps: int = 100, total_steps: int = 10000,
                  floor: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
    t = jnp.clip((step - warmup_steps) / jnp.maximum(
        total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return warm * cos
