"""Sharded-logical checkpointing: atomic, manifest-described, resumable.

Arrays are saved *logically* (full value per leaf, gathered to host), so a
restore may use a different mesh — the elastic-rescale path: save on 512
devices, restore on 256, and GSPMD reshards at the first step. Writes are
atomic (tmp dir + rename), a manifest records step/tree structure, and
`keep_last` old checkpoints are garbage-collected.

In a true multi-host deployment each host would write only its addressable
shards (same manifest format, `shards/<host>` subdirs) — the single-process
container exercises the same code path with one shard set.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]

_SEP = "::"
# npz cannot represent bf16 — store as uint16 view, record the true dtype.
_VIEW_AS = {"bfloat16": np.uint16}
_VIEW_BACK = {"bfloat16": ml_dtypes.bfloat16}


def _flatten(tree: Any) -> Tuple[Dict[str, np.ndarray], Dict[str, str]]:
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        arr = np.asarray(jax.device_get(leaf))
        dtypes[key] = str(arr.dtype)
        if str(arr.dtype) in _VIEW_AS:
            arr = arr.view(_VIEW_AS[str(arr.dtype)])
        flat[key] = arr
    return flat, dtypes


def save_checkpoint(ckpt_dir: str, step: int, trees: Dict[str, Any],
                    keep_last: int = 3, extra: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "trees": {}, "extra": extra or {}}
    for name, tree in trees.items():
        flat, dtypes = _flatten(tree)
        np.savez(os.path.join(tmp, f"{name}.npz"), **flat)
        manifest["trees"][name] = {
            k: dict(shape=list(v.shape), dtype=dtypes[k])
            for k, v in flat.items()}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, templates: Dict[str, Any],
                       step: Optional[int] = None
                       ) -> Tuple[int, Dict[str, Any], dict]:
    """templates: name → pytree with the target structure (values may be
    ShapeDtypeStructs or arrays; only the structure is used)."""
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    out: Dict[str, Any] = {}
    for name, template in templates.items():
        z = np.load(os.path.join(d, f"{name}.npz"))
        meta = manifest["trees"][name]
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, leaf in paths:
            key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
            arr = z[key]
            true_dtype = meta[key]["dtype"]
            if true_dtype in _VIEW_BACK:
                arr = arr.view(_VIEW_BACK[true_dtype])
            assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                           leaf.shape)
            leaves.append(arr)
        out[name] = jax.tree_util.tree_unflatten(treedef, leaves)
    return step, out, manifest.get("extra", {})
