"""AdamW with fp32 master weights and ZeRO-shardable state.

State layout: per parameter leaf — master (f32), m (f32), v (f32). The
trainer shards these over the data axis via
:func:`repro.distributed.sharding.opt_state_spec_for` (ZeRO-1); parameters
themselves stay bf16 in the model's layout.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update",
           "global_norm", "clip_by_global_norm"]


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_opt_state(params: Any) -> Dict[str, Any]:
    f32 = lambda p: p.astype(jnp.float32)  # noqa: E731
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return dict(
        master=jax.tree_util.tree_map(f32, params),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(grads: Any, opt_state: Dict[str, Any], params: Any,
                 ocfg: AdamWConfig, lr_scale: jax.Array
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new bf16 params, new opt state, metrics).

    The clip scale is folded into the per-leaf update (never materializing a
    second fp32 gradient tree — at 42B params that copy alone is 10+ GB per
    device).
    """
    gnorm = global_norm(grads)
    clip_scale = jnp.minimum(1.0, ocfg.grad_clip / (gnorm + 1e-12))
    count = opt_state["count"] + 1
    b1c = 1.0 - ocfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - ocfg.b2 ** count.astype(jnp.float32)
    lr = ocfg.lr * lr_scale

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * clip_scale
        m_new = ocfg.b1 * m + (1 - ocfg.b1) * g
        v_new = ocfg.b2 * v + (1 - ocfg.b2) * g * g
        step = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + ocfg.eps)
        master_new = master - lr * (step + ocfg.weight_decay * master)
        return m_new, v_new, master_new

    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    flat_w = jax.tree_util.tree_leaves(opt_state["master"])
    outs = [upd(g, m, v, w) for g, m, v, w in
            zip(flat_g, flat_m, flat_v, flat_w)]
    unf = lambda i: jax.tree_util.tree_unflatten(tree, [o[i] for o in outs])  # noqa: E731
    new_m, new_v, new_master = unf(0), unf(1), unf(2)
    param_dtypes = jax.tree_util.tree_map(lambda p: p.dtype, params)
    new_params = jax.tree_util.tree_map(
        lambda w, dt: w.astype(dt), new_master, param_dtypes)
    new_state = dict(master=new_master, m=new_m, v=new_v, count=count)
    return new_params, new_state, dict(grad_norm=gnorm)
