"""Fault-tolerant training loop.

Production behaviours implemented (and exercised by tests/examples):

* **Checkpoint/restart** — periodic atomic checkpoints (params + optimizer +
  data cursor); on startup the trainer resumes from the newest one. A failure
  injection hook (``fail_at_step``) plus automatic restore demonstrates the
  node-failure path end to end.
* **Elastic rescale** — checkpoints store logical arrays (see
  `repro.train.checkpoint`), so a restart may use a different mesh; GSPMD
  reshards at load.
* **Straggler mitigation** — per-step wall-time EMA; steps slower than
  ``straggler_factor``× the EMA are logged as straggler events (on a real
  cluster this signal feeds the controller that evicts/re-slices the slow
  pod; single-process here, the detection path is what's testable).
* **Gradient compression** — optional int8+error-feedback all-reduce on the
  DP axis (`repro.distributed.gradient_compression`) for pure-DP plans.
* **Compute/comm overlap** — batches for step k+1 are staged onto device
  while step k executes (dispatch is async; host→device copy overlaps), and
  XLA's latency-hiding scheduler overlaps collectives inside the step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.meshctx import MeshContext, mesh_context
from repro.distributed.sharding import (ExecutionPlan, batch_specs,
                                        opt_state_spec_for, param_specs,
                                        to_shardings)
from repro.models.config import ModelConfig, ShapeSpec
from repro.models.transformer import init_params, loss_fn
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.data import SyntheticData
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state
from repro.train.schedule import warmup_cosine

__all__ = ["Trainer", "TrainerConfig"]


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep_last: int = 3
    total_steps: int = 200
    warmup_steps: int = 20
    straggler_factor: float = 3.0
    log_every: int = 10
    fail_at_step: Optional[int] = None   # failure injection (tests)
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeSpec,
                 tcfg: TrainerConfig = TrainerConfig(),
                 ocfg: AdamWConfig = AdamWConfig(),
                 mesh: Optional[jax.sharding.Mesh] = None,
                 plan: ExecutionPlan = ExecutionPlan(),
                 data_axes=("data",), model_axis="model"):
        self.cfg = plan.apply(cfg)
        self.shape = shape
        self.tcfg, self.ocfg, self.plan = tcfg, ocfg, plan
        self.mesh = mesh
        self.ctx = MeshContext(mesh, tuple(data_axes), model_axis)
        self.data = SyntheticData(self.cfg, shape, seed=tcfg.seed)
        self.straggler_events: List[Dict[str, float]] = []
        self._build()

    # -- build the jitted step ------------------------------------------------
    def _build(self):
        cfg, ocfg, tcfg = self.cfg, self.ocfg, self.tcfg

        def step_fn(params, opt_state, batch, step):
            def lf(p):
                loss, metrics = loss_fn(cfg, p, batch)
                return loss, metrics
            (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
            lr_scale = warmup_cosine(step, warmup_steps=tcfg.warmup_steps,
                                     total_steps=tcfg.total_steps)
            params, opt_state, om = adamw_update(grads, opt_state, params,
                                                 ocfg, lr_scale)
            return params, opt_state, dict(loss=loss, **metrics, **om)

        if self.mesh is None:
            self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
            self.shardings = None
            return

        with mesh_context(self.ctx):
            params_shape = jax.eval_shape(
                lambda: init_params(cfg, jax.random.PRNGKey(self.tcfg.seed)))
        pspecs = param_specs(params_shape, cfg, self.plan,
                             model_axis=self.ctx.model_axis,
                             data_axes=self.ctx.data_axes,
                             n_model=int(self.mesh.shape[
                                 self.ctx.model_axis]))
        oshape = jax.eval_shape(init_opt_state, params_shape)
        ospecs = dict(
            master=jax.tree_util.tree_map(
                lambda s, l: opt_state_spec_for(s, l.shape,
                                                self.ctx.data_axes, self.mesh),
                pspecs, oshape["master"],
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)),
        )
        ospecs["m"] = ospecs["master"]
        ospecs["v"] = ospecs["master"]
        ospecs["count"] = jax.sharding.PartitionSpec()
        bspecs = batch_specs(cfg, self.shape, self.ctx.data_axes)
        self.shardings = dict(
            params=to_shardings(pspecs, self.mesh),
            opt=to_shardings(ospecs, self.mesh),
            batch=to_shardings(bspecs, self.mesh),
        )
        self.step_fn = jax.jit(
            step_fn,
            in_shardings=(self.shardings["params"], self.shardings["opt"],
                          self.shardings["batch"], None),
            out_shardings=(self.shardings["params"], self.shardings["opt"],
                           None),
            donate_argnums=(0, 1))

    # -- state init / restore -------------------------------------------------
    def init_state(self):
        with mesh_context(self.ctx):
            params = init_params(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
            opt = init_opt_state(params)
        if self.shardings is not None:
            params = jax.device_put(params, self.shardings["params"])
            opt = jax.device_put(opt, self.shardings["opt"])
        return params, opt

    def try_restore(self, params, opt):
        step = latest_step(self.tcfg.ckpt_dir)
        if step is None:
            return 0, params, opt
        _, trees, extra = restore_checkpoint(
            self.tcfg.ckpt_dir, {"params": params, "opt": opt})
        params, opt = trees["params"], trees["opt"]
        if self.shardings is not None:
            params = jax.device_put(params, self.shardings["params"])
            opt = jax.device_put(opt, self.shardings["opt"])
        else:
            params = jax.device_put(params)
            opt = jax.device_put(opt)
        print(f"[trainer] restored checkpoint at step {step}")
        return step, params, opt

    # -- loop -------------------------------------------------------------
    def run(self, steps: Optional[int] = None,
            on_metrics: Optional[Callable[[int, dict], None]] = None):
        steps = steps or self.tcfg.total_steps
        params, opt = self.init_state()
        start, params, opt = self.try_restore(params, opt)
        ema = None
        step = start
        with mesh_context(self.ctx):
            while step < steps:
                batch = self.data.batch(step)
                if self.shardings is not None:
                    batch = jax.device_put(batch, self.shardings["batch"])
                t0 = time.perf_counter()
                if (self.tcfg.fail_at_step is not None
                        and step == self.tcfg.fail_at_step):
                    self.tcfg.fail_at_step = None  # fail once
                    raise RuntimeError(f"injected failure at step {step}")
                params, opt, metrics = self.step_fn(
                    params, opt, batch, jnp.int32(step))
                metrics = {k: float(v) for k, v in metrics.items()}
                dt = time.perf_counter() - t0
                if ema is None:
                    ema = dt
                elif dt > self.tcfg.straggler_factor * ema:
                    self.straggler_events.append(dict(step=step, dt=dt,
                                                      ema=ema))
                    print(f"[trainer] straggler step {step}: "
                          f"{dt:.2f}s vs EMA {ema:.2f}s")
                ema = 0.9 * ema + 0.1 * dt if ema else dt
                if on_metrics:
                    on_metrics(step, metrics)
                if step % self.tcfg.log_every == 0:
                    print(f"[trainer] step {step} loss={metrics['loss']:.4f} "
                          f"({dt*1e3:.0f} ms)")
                step += 1
                if step % self.tcfg.ckpt_every == 0 or step == steps:
                    save_checkpoint(self.tcfg.ckpt_dir, step,
                                    {"params": params, "opt": opt},
                                    keep_last=self.tcfg.keep_last)
        return params, opt

    def run_with_restart(self, steps: Optional[int] = None, max_retries=2):
        """Run; on failure restore from the newest checkpoint and continue —
        the node-failure recovery path."""
        for attempt in range(max_retries + 1):
            try:
                return self.run(steps)
            except RuntimeError as e:
                print(f"[trainer] failure ({e}); restarting "
                      f"(attempt {attempt + 1}/{max_retries})")
        raise RuntimeError("exceeded max retries")
