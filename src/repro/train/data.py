"""Deterministic synthetic data pipeline.

Every batch is a pure function of (seed, step) via fold_in — restartable from
any step with no iterator state to checkpoint, and identical across hosts
(each host materializes only its shard in a multi-process deployment; here
one process materializes the global batch).

Token streams are Zipf-distributed (vocab-realistic softmax pressure);
embedding-mode archs (VLM/audio stubs) get unit-variance frame/patch
embeddings; Qwen2-VL also gets stub M-RoPE position ids shaped like a
(t, h, w) grid traversal.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, ShapeSpec

__all__ = ["SyntheticData", "input_specs"]


class SyntheticData:
    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, seed: int = 0):
        self.cfg, self.shape, self.seed = cfg, shape, seed

    def batch(self, step: int) -> Dict[str, Any]:
        cfg, shp = self.cfg, self.shape
        rng = np.random.default_rng((self.seed << 20) ^ step)
        b, s = shp.global_batch, shp.seq_len
        out: Dict[str, Any] = {}
        if cfg.input_mode == "tokens":
            # Zipf tokens clipped to vocab (power-law like natural text)
            toks = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64)
            toks = np.minimum(toks - 1, cfg.vocab_size - 1).astype(np.int32)
            out["tokens"] = toks[:, :-1]
            out["labels"] = toks[:, 1:].astype(np.int32)
        else:
            out["embeds"] = rng.standard_normal((b, s, cfg.d_model)
                                                ).astype(np.float32)
            out["labels"] = rng.integers(0, cfg.vocab_size, (b, s)
                                         ).astype(np.int32)
            if cfg.mrope:
                out["positions3"] = _stub_mrope_positions(b, s)
        return out

    def decode_batch(self, step: int) -> Any:
        """One decode token per sequence."""
        cfg, shp = self.cfg, self.shape
        rng = np.random.default_rng((self.seed << 21) ^ step)
        b = shp.global_batch
        if cfg.input_mode == "tokens":
            return rng.integers(0, cfg.vocab_size, (b, 1)).astype(np.int32)
        return rng.standard_normal((b, 1, cfg.d_model)).astype(np.float32)


def _stub_mrope_positions(b: int, s: int) -> np.ndarray:
    """(3, B, S): a text prefix then a fake image grid (t=const, h/w raster)."""
    text = s // 2
    grid = s - text
    side = max(int(np.sqrt(grid)), 1)
    t = np.concatenate([np.arange(text), np.full(grid, text)])
    h = np.concatenate([np.arange(text),
                        text + (np.arange(grid) // side)])
    w = np.concatenate([np.arange(text),
                        text + (np.arange(grid) % side)])
    pos = np.stack([t, h, w]).astype(np.int32)          # (3, S)
    return np.broadcast_to(pos[:, None], (3, b, s)).copy()


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for dry-run lowering (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        if cfg.input_mode == "tokens":
            return {"tokens": sds((b, 1), jnp.int32)}
        return {"embeds": sds((b, 1, cfg.d_model), jnp.float32)}
    out: Dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        out["tokens"] = sds((b, s), jnp.int32)
    else:
        out["embeds"] = sds((b, s, cfg.d_model), jnp.float32)
        if cfg.mrope:
            out["positions3"] = sds((3, b, s), jnp.int32)
    if shape.kind == "train":
        out["labels"] = sds((b, s), jnp.int32)
    return out
