"""Training substrate: optimizer (AdamW + ZeRO), schedule, checkpointing,
synthetic data pipeline, fault-tolerant trainer."""
from .checkpoint import latest_step, restore_checkpoint, save_checkpoint
from .data import SyntheticData, input_specs
from .optimizer import AdamWConfig, adamw_update, init_opt_state
from .schedule import warmup_cosine
from .trainer import Trainer, TrainerConfig

__all__ = ["latest_step", "restore_checkpoint", "save_checkpoint",
           "SyntheticData", "input_specs", "AdamWConfig", "adamw_update",
           "init_opt_state", "warmup_cosine", "Trainer", "TrainerConfig"]
