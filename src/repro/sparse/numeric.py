"""Numeric sparse solvers (reference / envelope paths).

Two simplicial solvers live here; the production path is the multifrontal
solver in :mod:`repro.sparse.multifrontal`.

* :func:`sparse_cholesky` — up-looking simplicial Cholesky on the exact
  symbolic pattern. O(FLOPs) but Python-loop bound; used as the correctness
  oracle for the multifrontal solver and for small systems.
* :func:`skyline_cholesky` — envelope (profile) Cholesky: stores each row
  from its first nonzero to the diagonal densely. Its cost is
  Σ_i w_i² where w_i is the row envelope width — the solver family for which
  RCM-style bandwidth/profile reduction is the right objective.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from .csr import CSRMatrix
from .symbolic import SymbolicFactor, symbolic_cholesky

__all__ = [
    "sparse_cholesky", "cholesky_solve", "SparseCholesky",
    "skyline_cholesky", "skyline_solve", "SkylineFactor",
]


# ---------------------------------------------------------------------------
# Simplicial sparse Cholesky (up-looking, CSC factor)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SparseCholesky:
    sym: SymbolicFactor
    Lx: np.ndarray  # values aligned with sym.Li / sym.Lp (CSC, diag first-by-sort)


def sparse_cholesky(a: CSRMatrix, sym: SymbolicFactor | None = None) -> SparseCholesky:
    """Left-looking numeric factorization on the precomputed pattern.

    ``sym`` may come from a cached :class:`repro.core.plan.ExecutionPlan`
    (valid for any matrix with the plan's structure fingerprint), in which
    case no symbolic analysis runs here — straight to numeric work.

    For column j:  L[j:,j] = (A[j:,j] − Σ_{k<j, L_jk≠0} L_jk · L[j:,k]) / L_jj
    The set {k : L_jk ≠ 0} is exactly the nonzeros of row j of L, which we
    accumulate with per-row lists as columns complete.
    """
    if sym is None:
        sym = symbolic_cholesky(a)
    n = a.n
    Lp, Li = sym.Lp, sym.Li
    Lx = np.zeros(Li.shape[0], dtype=np.float64)
    # position of row i within column j for scatter: use a dense work vector
    work = np.zeros(n, dtype=np.float64)
    # rows_of[j] = list of (k, idx into column k where row j sits)
    row_entries: list[list[Tuple[int, int]]] = [[] for _ in range(n)]

    indptr, indices, data = a.indptr, a.indices, a.data
    assert data is not None, "numeric factorization needs values"

    for j in range(n):
        lo, hi = Lp[j], Lp[j + 1]
        pattern = Li[lo:hi]  # sorted ascending, pattern[0] == j
        # scatter A[j:, j] — by symmetry read row j of A, cols >= j
        a_lo, a_hi = indptr[j], indptr[j + 1]
        arow = indices[a_lo:a_hi]
        avals = data[a_lo:a_hi]
        sel = arow >= j
        work[arow[sel]] = avals[sel]
        # gather updates from earlier columns k with L[j,k] != 0
        for (k, idx) in row_entries[j]:
            ljk = Lx[idx]
            klo, khi = idx, Lp[k + 1]  # entries of column k from row j down
            rows_k = Li[klo:khi]
            work[rows_k] -= ljk * Lx[klo:khi]
        dj = work[j]
        if dj <= 0.0:
            raise np.linalg.LinAlgError(
                f"matrix not positive definite at column {j} (d={dj:.3e})")
        dj = np.sqrt(dj)
        colvals = work[pattern]
        colvals[0] = dj
        colvals[1:] /= dj
        Lx[lo:hi] = colvals
        work[pattern] = 0.0
        # register this column in the row lists of its below-diagonal rows
        for t in range(lo + 1, hi):
            row_entries[Li[t]].append((j, t))
    return SparseCholesky(sym, Lx)


def cholesky_solve(f: SparseCholesky, b: np.ndarray) -> np.ndarray:
    """Solve A x = b given A = L Lᵀ."""
    n = f.sym.Lp.shape[0] - 1
    Lp, Li, Lx = f.sym.Lp, f.sym.Li, f.Lx
    x = b.astype(np.float64).copy()
    # forward: L y = b (column-oriented)
    for j in range(n):
        lo, hi = Lp[j], Lp[j + 1]
        x[j] /= Lx[lo]
        if hi > lo + 1:
            x[Li[lo + 1 : hi]] -= Lx[lo + 1 : hi] * x[j]
    # backward: Lᵀ x = y
    for j in range(n - 1, -1, -1):
        lo, hi = Lp[j], Lp[j + 1]
        if hi > lo + 1:
            x[j] -= np.dot(Lx[lo + 1 : hi], x[Li[lo + 1 : hi]])
        x[j] /= Lx[lo]
    return x


# ---------------------------------------------------------------------------
# Skyline / envelope Cholesky
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SkylineFactor:
    first: np.ndarray   # first[i] = column of first stored entry of row i
    rows: list          # rows[i] = dense row i segment first[i]..i of L
    flops: int


def skyline_cholesky(a: CSRMatrix) -> SkylineFactor:
    """Envelope Cholesky: row i of L is dense on [first[i], i].

    Cost Σ w_i² with w_i = i − first[i] + 1: directly minimized by
    profile-reducing orderings (RCM). Vectorized with numpy per row.
    """
    n = a.n
    indptr, indices, data = a.indptr, a.indices, a.data
    assert data is not None
    first = np.empty(n, dtype=np.int64)
    for i in range(n):
        row = indices[indptr[i] : indptr[i + 1]]
        row = row[row <= i]
        first[i] = row[0] if row.size else i
    # skyline must be monotone enough for the algorithm: widen rows so that
    # the needed leading entries of previous rows exist
    rows: list[np.ndarray] = []
    flops = 0
    for i in range(n):
        fi = int(first[i])
        seg = np.zeros(i - fi + 1, dtype=np.float64)
        arow = indices[indptr[i] : indptr[i + 1]]
        avals = data[indptr[i] : indptr[i + 1]]
        sel = (arow >= fi) & (arow <= i)
        seg[arow[sel] - fi] = avals[sel]
        # eliminate against previous rows j in [fi, i)
        for j in range(fi, i):
            fj = int(first[j])
            lo = max(fi, fj)
            # dot(L[i, lo:j], L[j, lo:j])
            li = seg[lo - fi : j - fi]
            lj = rows[j][lo - fj : j - fj]
            s = seg[j - fi] - (li @ lj if li.size else 0.0)
            djj = rows[j][j - fj]
            seg[j - fi] = s / djj
            flops += 2 * li.size + 2
        dii = seg[i - fi] - (seg[: i - fi] @ seg[: i - fi] if i > fi else 0.0)
        if dii <= 0:
            raise np.linalg.LinAlgError(f"not SPD at row {i}")
        seg[i - fi] = np.sqrt(dii)
        flops += 2 * (i - fi) + 2
        rows.append(seg)
    return SkylineFactor(first, rows, flops)


def skyline_solve(f: SkylineFactor, b: np.ndarray) -> np.ndarray:
    n = len(f.rows)
    y = b.astype(np.float64).copy()
    for i in range(n):
        fi = int(f.first[i])
        seg = f.rows[i]
        if i > fi:
            y[i] -= seg[: i - fi] @ y[fi:i]
        y[i] /= seg[i - fi]
    x = y
    for i in range(n - 1, -1, -1):
        fi = int(f.first[i])
        seg = f.rows[i]
        x[i] /= seg[i - fi]
        if i > fi:
            x[fi:i] -= seg[: i - fi] * x[i]
    return x
