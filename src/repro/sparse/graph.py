"""Adjacency-graph utilities shared by the reordering algorithms.

A sparse matrix's *graph* is the undirected graph of its symmetrized
off-diagonal pattern. All reordering algorithms in the paper (CM/RCM, MD/AMD,
ND, SCOTCH-like hybrids) operate on this graph.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from .csr import CSRMatrix, coo_to_csr, symmetrize_pattern

__all__ = [
    "adjacency",
    "degrees",
    "bfs_levels",
    "pseudo_peripheral_node",
    "connected_components",
]


def adjacency(a: CSRMatrix) -> CSRMatrix:
    """Undirected adjacency structure: symmetrized pattern, no diagonal."""
    s = a if a.is_structurally_symmetric() else symmetrize_pattern(a)
    rows, cols, _ = s.to_coo()
    off = rows != cols
    return coo_to_csr(rows[off], cols[off], None, a.shape, a.name, a.group,
                      sum_duplicates=False)


def degrees(adj: CSRMatrix) -> np.ndarray:
    return np.diff(adj.indptr).astype(np.int64)


def bfs_levels(adj: CSRMatrix, root: int,
               mask: np.ndarray | None = None) -> Tuple[np.ndarray, List[np.ndarray]]:
    """BFS level structure from `root`.

    Returns (level, levels) where level[v] = depth or -1 if unreached /
    masked out, and levels is the list of per-depth vertex arrays.
    """
    n = adj.n
    level = np.full(n, -1, dtype=np.int64)
    if mask is not None:
        allowed = mask
    else:
        allowed = np.ones(n, dtype=bool)
    if not allowed[root]:
        return level, []
    level[root] = 0
    frontier = np.array([root], dtype=np.int64)
    levels = [frontier]
    depth = 0
    indptr, indices = adj.indptr, adj.indices
    while frontier.size:
        # Gather all neighbours of the frontier, vectorized.
        starts, ends = indptr[frontier], indptr[frontier + 1]
        total = int((ends - starts).sum())
        if total == 0:
            break
        nbr = np.empty(total, dtype=np.int64)
        pos = 0
        for s, e in zip(starts, ends):
            nbr[pos : pos + (e - s)] = indices[s:e]
            pos += e - s
        nbr = np.unique(nbr)
        new = nbr[(level[nbr] == -1) & allowed[nbr]]
        if new.size == 0:
            break
        depth += 1
        level[new] = depth
        frontier = new
        levels.append(frontier)
    return level, levels


def pseudo_peripheral_node(adj: CSRMatrix, start: int,
                           mask: np.ndarray | None = None) -> Tuple[int, List[np.ndarray]]:
    """George–Liu pseudo-peripheral node finder.

    Repeatedly BFS from the minimum-degree vertex of the deepest level until
    eccentricity stops growing. Returns (root, its level structure).
    """
    deg = degrees(adj)
    root = start
    _, levels = bfs_levels(adj, root, mask)
    if not levels:
        return root, levels
    ecc = len(levels) - 1
    for _ in range(16):  # converges in a couple of rounds in practice
        last = levels[-1]
        cand = last[np.argmin(deg[last])]
        _, levels2 = bfs_levels(adj, int(cand), mask)
        ecc2 = len(levels2) - 1
        if ecc2 <= ecc:
            return root, levels
        root, levels, ecc = int(cand), levels2, ecc2
    return root, levels


def connected_components(adj: CSRMatrix) -> List[np.ndarray]:
    """Vertex sets of connected components (BFS flood fill)."""
    n = adj.n
    seen = np.zeros(n, dtype=bool)
    comps: List[np.ndarray] = []
    for v in range(n):
        if seen[v]:
            continue
        level, levels = bfs_levels(adj, v, mask=~seen)
        verts = np.concatenate(levels) if levels else np.array([v], dtype=np.int64)
        seen[verts] = True
        comps.append(np.sort(verts))
    return comps
