"""Sparse direct-solver substrate: containers, reordering algorithms,
symbolic analysis, numeric solvers (simplicial, skyline, multifrontal),
and the synthetic Florida-like matrix suite."""
from .csr import (CSRMatrix, bandwidth, coo_to_csr, csr_from_dense, make_spd,
                  permute_symmetric, profile, symmetrize_pattern)
from .refine import RefineInfo, refine_solve
from .reorder import LABEL_ALGORITHMS, REORDERINGS, get_reordering
from .schedule import LevelSchedule, build_schedule
from .symbolic import (SymbolicFactor, cholesky_flops, column_counts, etree,
                       fill_in, postorder, supernodes, symbolic_cholesky)

__all__ = [
    "CSRMatrix", "bandwidth", "coo_to_csr", "csr_from_dense", "make_spd",
    "permute_symmetric", "profile", "symmetrize_pattern",
    "LABEL_ALGORITHMS", "REORDERINGS", "get_reordering",
    "SymbolicFactor", "cholesky_flops", "column_counts", "etree", "fill_in",
    "postorder", "supernodes", "symbolic_cholesky",
    "LevelSchedule", "build_schedule", "RefineInfo", "refine_solve",
]
