"""Supernodal multifrontal Cholesky — the MUMPS analogue.

The multifrontal method [Duff & Reid 1983] converts sparse factorization into
a traversal of an assembly tree whose nodes are **dense frontal matrices**.
This is the TPU-native re-think of the paper's solver substrate: the
irregular sparsity is confined to host-side assembly (vectorized
scatter/extend-add index maps), while all heavy FLOPs are dense partial
factorizations of fronts — matmul-shaped work for the MXU. Three backends:

* ``numpy``   — host BLAS, front-at-a-time; used for dataset labeling
                wall-times and as the fp64 correctness reference.
* ``pallas``  — :func:`repro.kernels.ops.frontal_factor` per front (blocked
                right-looking Cholesky over 128-aligned VMEM tiles).
* ``batched`` — **level-scheduled**: fronts are grouped by assembly-tree
                level (:mod:`repro.sparse.schedule`), and every same-shape
                front of a level is partially factored in ONE
                :func:`repro.kernels.ops.frontal_factor_batch_ws` launch
                (grid over the batch dim, fused chol → tri-solve → Schur
                per front, f32 accumulate). nsup host round-trips become
                nlevels × nbuckets kernel calls.

The triangular solves are level-batched too: :func:`multifrontal_solve`
stacks each level's factors into (B, P, P)/(B, R, P) tensors once and runs
batched substitution sweeps (one LAPACK/einsum call per level-bucket)
instead of a per-front scipy loop.

Per-front cost is exactly the symbolic model of
:func:`repro.sparse.symbolic.cholesky_flops`, so measured label times and the
analytic cost model agree in ordering.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Literal, Optional, Tuple

import numpy as np
import scipy.linalg as sla

from .csr import CSRMatrix
from .schedule import FrontPlan, LevelSchedule, build_schedule
from .symbolic import SymbolicFactor, supernodes, symbolic_cholesky

__all__ = ["MultifrontalFactor", "multifrontal_cholesky", "multifrontal_solve",
           "factor_and_solve_timed"]

Backend = Literal["numpy", "pallas", "batched"]


@dataclasses.dataclass
class _Front:
    cols: Tuple[int, int]    # [c0, c1) pivot columns
    rows: np.ndarray         # global row indices of the front (sorted; first npiv are pivots)
    L11: np.ndarray          # (npiv, npiv) lower-triangular
    L21: np.ndarray          # (m - npiv, npiv)


@dataclasses.dataclass
class MultifrontalFactor:
    n: int
    fronts: List[_Front]
    sym: SymbolicFactor
    stats: dict
    schedule: Optional[LevelSchedule] = None
    dtype: np.dtype = np.float64
    _sweeps: Optional["_LevelSweeps"] = dataclasses.field(
        default=None, repr=False, compare=False)


# ---------------------------------------------------------------------------
# Host-side assembly: vectorized scatter + extend-add
# ---------------------------------------------------------------------------

def _scatter_entries(F: np.ndarray, a: CSRMatrix, fp: FrontPlan,
                     shift: int = 0) -> None:
    """Scatter A[rows, c0:c1] (lower triangle, via symmetry of the CSR rows)
    into the front workspace in one vectorized pass: global row indices map
    to local positions by ``np.searchsorted`` over the sorted front rows.
    ``shift`` displaces non-pivot rows by the pivot-padding width (the
    batched workspace layout); 0 means the dense unpadded front."""
    indptr, indices, data = a.indptr, a.indices, a.data
    c0, c1 = fp.c0, fp.c1
    start, end = int(indptr[c0]), int(indptr[c1])
    cols = indices[start:end]
    vals = data[start:end]
    colid = np.repeat(np.arange(c0, c1), np.diff(indptr[c0 : c1 + 1]))
    sel = cols >= colid            # keep the lower triangle (row ≥ col)
    loc = np.searchsorted(fp.rows, cols[sel])
    if shift:
        loc = np.where(loc >= fp.npiv, loc + shift, loc)
    F[loc, colid[sel] - c0] = vals[sel]


def _extend_add(F: np.ndarray, fp: FrontPlan, urows: np.ndarray,
                U: np.ndarray, shift: int = 0) -> None:
    """Add a child's Schur update (rows `urows`) into the front workspace."""
    idx = np.searchsorted(fp.rows, urows)
    if idx.size and (idx[-1] >= fp.rows.size
                     or not np.array_equal(fp.rows[idx], urows)):
        raise RuntimeError(
            "assembly-tree containment violated (supernode "
            f"{fp.k}: update rows not a subset of front rows)")
    if shift:
        idx = np.where(idx >= fp.npiv, idx + shift, idx)
    F[np.ix_(idx, idx)] += U


# ---------------------------------------------------------------------------
# Dense partial factorization backends (front-at-a-time)
# ---------------------------------------------------------------------------

def _partial_factor_numpy(F: np.ndarray, npiv: int):
    """Dense partial Cholesky: factor pivot block, panel solve, Schur update."""
    F11 = F[:npiv, :npiv]
    L11 = np.linalg.cholesky(F11)
    if F.shape[0] > npiv:
        L21 = sla.solve_triangular(L11, F[npiv:, :npiv].T, lower=True,
                                   trans="N").T
        S = F[npiv:, npiv:] - L21 @ L21.T
    else:
        L21 = np.empty((0, npiv), dtype=F.dtype)
        S = np.empty((0, 0), dtype=F.dtype)
    return L11, L21, S


def _partial_factor_pallas(F: np.ndarray, npiv: int):
    from repro.kernels import ops  # local import: keep numpy path jax-free
    L11, L21, S = ops.frontal_factor(F, npiv)
    return np.asarray(L11), np.asarray(L21), np.asarray(S)


# ---------------------------------------------------------------------------
# Numeric phase
# ---------------------------------------------------------------------------

def multifrontal_cholesky(
    a: CSRMatrix,
    sym: Optional[SymbolicFactor] = None,
    relax: int = 8,
    backend: Backend = "numpy",
    dtype: np.dtype | type = np.float64,
) -> MultifrontalFactor:
    """Numeric supernodal factorization of an SPD CSR matrix.

    ``dtype`` selects the front-math precision on the ``numpy`` backend
    (fp64 or fp32); the ``pallas``/``batched`` backends always accumulate in
    f32 (pair them with :mod:`repro.sparse.refine` to recover fp64-level
    residuals). The returned factor carries the :class:`LevelSchedule` used,
    so :func:`multifrontal_solve` can run level-batched sweeps.
    """
    assert a.data is not None, "numeric factorization needs values"
    if sym is None:
        sym = symbolic_cholesky(a)
    snode_ptr, snode_of = supernodes(sym, relax=relax)
    schedule = build_schedule(sym, snode_ptr, snode_of)
    eff_dtype = np.dtype(np.float32 if backend in ("pallas", "batched")
                         else dtype)

    if backend == "batched":
        fronts = _factor_batched(a, schedule)
    else:
        fronts = _factor_sequential(a, schedule, backend, eff_dtype)

    stats = dict(schedule.stats())  # nsup, nlevels, widths, occupancy, flops
    stats.update(n=a.n,
                 peak_front=max((fp.m for fp in schedule.fronts), default=0),
                 nnz_L=sym.nnz_L, fill=sym.fill, sym_flops=sym.flops,
                 backend=backend, dtype=str(eff_dtype))
    return MultifrontalFactor(a.n, fronts, sym, stats, schedule=schedule,
                              dtype=eff_dtype)


def _factor_sequential(a: CSRMatrix, schedule: LevelSchedule,
                       backend: Backend, dtype: np.dtype) -> List[_Front]:
    """Front-at-a-time postorder traversal (numpy / per-front pallas)."""
    partial = (_partial_factor_numpy if backend == "numpy"
               else _partial_factor_pallas)
    nsup = schedule.nsup
    fronts: List[_Front] = []
    pending: List[List[Tuple[np.ndarray, np.ndarray]]] = [[] for _ in range(nsup)]
    for fp in schedule.fronts:
        F = np.zeros((fp.m, fp.m), dtype=dtype)
        _scatter_entries(F, a, fp)
        for (urows, U) in pending[fp.k]:
            _extend_add(F, fp, urows, U)
        pending[fp.k] = []
        L11, L21, S = partial(F, fp.npiv)
        fronts.append(_Front((fp.c0, fp.c1), fp.rows, L11, L21))
        if fp.nrest:
            pending[fp.parent].append((fp.rows[fp.npiv :], S))
    return fronts


def _factor_batched(a: CSRMatrix, schedule: LevelSchedule) -> List[_Front]:
    """Level-scheduled factorization: per (level, bucket), assemble every
    member front into one padded f32 workspace stack and factor the stack
    in a single batched kernel launch. Pivot padding columns are decoupled
    identity columns; update-row padding is zero rows — both factor
    trivially and contribute nothing to L or the Schur complements."""
    from repro.kernels import ops

    nsup = schedule.nsup
    fronts: List[Optional[_Front]] = [None] * nsup
    pending: List[List[Tuple[np.ndarray, np.ndarray]]] = [[] for _ in range(nsup)]
    for li in range(schedule.nlevels):
        for bucket in schedule.buckets[li]:
            B, P, M = len(bucket.members), bucket.P, bucket.M
            W = np.zeros((B, M, M), dtype=np.float32)
            for bi, k in enumerate(bucket.members):
                fp = schedule.fronts[k]
                shift = P - fp.npiv
                if shift:
                    pad = np.arange(fp.npiv, P)
                    W[bi, pad, pad] = 1.0
                _scatter_entries(W[bi], a, fp, shift)
                for (urows, U) in pending[k]:
                    _extend_add(W[bi], fp, urows, U, shift)
                pending[k] = []
            Wf = np.asarray(ops.frontal_factor_batch_ws(W, P))
            for bi, k in enumerate(bucket.members):
                fp = schedule.fronts[k]
                npiv, nrest = fp.npiv, fp.nrest
                L11 = np.tril(Wf[bi, :npiv, :npiv])
                L21 = Wf[bi, P : P + nrest, :npiv]
                fronts[k] = _Front((fp.c0, fp.c1), fp.rows, L11, L21)
                if nrest:
                    S = Wf[bi, P : P + nrest, P : P + nrest]
                    pending[fp.parent].append((fp.rows[npiv:], S))
    return fronts  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# Triangular sweeps
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _SweepGroup:
    """One level-bucket's factors stacked for batched substitution."""

    L11: np.ndarray        # (B, P, P) unit-diag padded, fp64
    L11T: np.ndarray       # (B, P, P) transposed copy (backward sweep)
    L21: np.ndarray        # (B, R, P)
    piv: np.ndarray        # (B, P) global pivot indices (0 at pads)
    pmask: np.ndarray      # (B, P) bool, True at real pivots
    rest: np.ndarray       # (B, R) global update rows (0 at pads)
    rmask: np.ndarray      # (B, R) bool


@dataclasses.dataclass
class _LevelSweeps:
    levels: List[List[_SweepGroup]]


def _build_sweeps(f: MultifrontalFactor) -> _LevelSweeps:
    sched = f.schedule
    assert sched is not None
    levels: List[List[_SweepGroup]] = []
    for li in range(sched.nlevels):
        groups: List[_SweepGroup] = []
        for bucket in sched.buckets[li]:
            B, P, R = len(bucket.members), bucket.P, bucket.R
            L11 = np.zeros((B, P, P))
            diag = np.arange(P)
            L11[:, diag, diag] = 1.0
            L21 = np.zeros((B, R, P))
            piv = np.zeros((B, P), dtype=np.int64)
            pmask = np.zeros((B, P), dtype=bool)
            rest = np.zeros((B, R), dtype=np.int64)
            rmask = np.zeros((B, R), dtype=bool)
            for bi, k in enumerate(bucket.members):
                fr = f.fronts[k]
                c0, c1 = fr.cols
                npiv = c1 - c0
                nrest = fr.L21.shape[0]
                L11[bi, :npiv, :npiv] = fr.L11
                L21[bi, :nrest, :npiv] = fr.L21
                piv[bi, :npiv] = np.arange(c0, c1)
                pmask[bi, :npiv] = True
                rest[bi, :nrest] = fr.rows[npiv:]
                rmask[bi, :nrest] = True
            groups.append(_SweepGroup(
                L11, np.ascontiguousarray(L11.transpose(0, 2, 1)), L21,
                piv, pmask, rest, rmask))
        levels.append(groups)
    return _LevelSweeps(levels)


def _solve_level(f: MultifrontalFactor, b: np.ndarray) -> np.ndarray:
    """Level-batched forward/backward sweeps: one batched triangular solve
    (``np.linalg.solve`` on the stacked unit-padded factors) plus one
    batched update einsum per level-bucket, instead of a scipy call per
    front. Update scatters within a level never collide with that level's
    pivots (parents live on strictly higher levels), so bucket order is
    free and cross-front accumulation uses ``np.subtract.at``."""
    if f._sweeps is None:
        f._sweeps = _build_sweeps(f)
    sw = f._sweeps
    x = b.astype(np.float64).copy()
    # forward: L y = b, leaves upward
    for groups in sw.levels:
        for g in groups:
            xb = np.where(g.pmask, x[g.piv], 0.0)
            y = np.linalg.solve(g.L11, xb[..., None])[..., 0]
            x[g.piv[g.pmask]] = y[g.pmask]
            if g.rest.shape[1]:
                upd = np.einsum("brp,bp->br", g.L21, y)
                np.subtract.at(x, g.rest[g.rmask], upd[g.rmask])
    # backward: Lᵀ x = y, roots downward
    for groups in reversed(sw.levels):
        for g in groups:
            rhs = np.where(g.pmask, x[g.piv], 0.0)
            if g.rest.shape[1]:
                xr = np.where(g.rmask, x[g.rest], 0.0)
                rhs = rhs - np.einsum("brp,br->bp", g.L21, xr)
            y = np.linalg.solve(g.L11T, rhs[..., None])[..., 0]
            x[g.piv[g.pmask]] = y[g.pmask]
    return x


def _solve_sequential(f: MultifrontalFactor, b: np.ndarray) -> np.ndarray:
    """Per-front scipy sweeps (the pre-level-scheduling reference path)."""
    x = b.astype(np.float64).copy()
    # forward: L y = b
    for fr in f.fronts:
        c0, c1 = fr.cols
        piv = slice(c0, c1)
        y = sla.solve_triangular(fr.L11, x[piv], lower=True)
        x[piv] = y
        if fr.L21.shape[0]:
            x[fr.rows[c1 - c0 :]] -= fr.L21 @ y
    # backward: Lᵀ x = y
    for fr in reversed(f.fronts):
        c0, c1 = fr.cols
        piv = slice(c0, c1)
        rhs = x[piv]
        if fr.L21.shape[0]:
            rhs = rhs - fr.L21.T @ x[fr.rows[c1 - c0 :]]
        x[piv] = sla.solve_triangular(fr.L11.T, rhs, lower=False)
    return x


def multifrontal_solve(f: MultifrontalFactor, b: np.ndarray,
                       mode: Literal["auto", "level", "seq"] = "auto"
                       ) -> np.ndarray:
    """Solve A x = b with the supernodal factor.

    ``mode="level"`` (the default when the factor carries a schedule) runs
    the level-batched sweeps; ``"seq"`` keeps the per-front loop (reference
    and fallback). Repeated solves reuse the stacked sweep tensors cached on
    the factor.
    """
    if mode == "seq" or (mode == "auto" and f.schedule is None):
        return _solve_sequential(f, b)
    if f.schedule is None:
        raise ValueError("mode='level' needs a factor with a schedule")
    return _solve_level(f, b)


def factor_and_solve_timed(a: CSRMatrix, b: np.ndarray | None = None,
                           relax: int = 8,
                           sym: Optional[SymbolicFactor] = None,
                           backend: Backend = "numpy") -> dict:
    """Measured factor+solve wall time — the per-(matrix, ordering) label
    signal, mirroring the paper's MUMPS timings.

    Passing a precomputed ``sym`` (e.g. from a cached
    :class:`repro.core.plan.ExecutionPlan`) skips the symbolic stage
    entirely; ``t_symbolic`` is then reported as 0. ``relax`` tunes the
    supernode amalgamation and ``backend`` picks the front-math substrate,
    so labeling can time the Pallas / batched paths too.
    """
    if b is None:
        rng = np.random.default_rng(0)
        b = rng.standard_normal(a.n)
    if sym is None:
        t0 = time.perf_counter()
        sym = symbolic_cholesky(a)
        t_sym = time.perf_counter() - t0
    else:
        t_sym = 0.0
    t0 = time.perf_counter()
    f = multifrontal_cholesky(a, sym, relax=relax, backend=backend)
    t_fac = time.perf_counter() - t0
    t0 = time.perf_counter()
    x = multifrontal_solve(f, b)
    t_sol = time.perf_counter() - t0
    resid = float(np.linalg.norm(a.matvec(x) - b) / max(np.linalg.norm(b), 1e-30))
    return dict(time=t_sym + t_fac + t_sol, t_symbolic=t_sym, t_factor=t_fac,
                t_solve=t_sol, residual=resid, **f.stats)
