"""Supernodal multifrontal Cholesky — the MUMPS analogue.

The multifrontal method [Duff & Reid 1983] converts sparse factorization into
a traversal of an assembly tree whose nodes are **dense frontal matrices**.
This is the TPU-native re-think of the paper's solver substrate: the
irregular sparsity is confined to host-side assembly (vectorized
scatter/extend-add index maps), while all heavy FLOPs are dense partial
factorizations of fronts — matmul-shaped work for the MXU. Four backends:

* ``numpy``   — host BLAS, front-at-a-time; used for dataset labeling
                wall-times and as the fp64 correctness reference.
* ``pallas``  — :func:`repro.kernels.ops.frontal_factor` per front (blocked
                right-looking Cholesky over 128-aligned VMEM tiles).
* ``batched`` — **level-scheduled**: fronts are grouped by assembly-tree
                level (:mod:`repro.sparse.schedule`), and every same-shape
                front of a level is partially factored in ONE
                :func:`repro.kernels.ops.frontal_factor_batch_ws` launch
                (grid over the batch dim, fused chol → tri-solve → Schur
                per front, f32 accumulate). nsup host round-trips become
                nlevels × nbuckets kernel calls.
* ``pipelined`` — **device-resident producer/consumer**: the host only ever
                scatters A's entries into fresh workspaces (the cheap,
                irregular part); the extend-add runs on device
                (:func:`repro.kernels.ops.extend_add_batch`), so Schur
                updates never round-trip through numpy between levels.
                Kernel launches are dispatched asynchronously and the host
                races ahead assembling the next level's buckets while the
                previous level factors — the only host↔device sync is one
                drain at the end. ``stats`` records where the wall time
                went (``t_factor_assemble`` / ``t_factor_dispatch`` /
                ``t_factor_sync``) and the resulting ``overlap_efficiency``
                (host-busy fraction of the overlappable time).

The triangular solves are level-batched too: :func:`multifrontal_solve`
stacks each level's factors into (B, P, P)/(B, R, P) tensors once and runs
batched substitution sweeps per level-bucket. Three sweep modes, all
native multi-RHS (``b`` of shape ``(n,)`` or ``(n, k)``):

* ``seq``    — per-front scipy loop (fp64 reference).
* ``level``  — host sweeps: one ``np.linalg.solve`` + einsum per
               level-bucket, cross-front updates accumulated per *level*
               with one ``np.bincount`` scatter-add.
* ``device`` — the solve-phase counterpart of the pipelined backend:
               per-level factor stacks stay device-resident (reused
               directly from a pipelined factorization's workspaces, no
               drain round-trip), each level-bucket is ONE asynchronously
               dispatched jit step (gather pivots → batched Pallas
               :func:`repro.kernels.ops.tri_solve_batch` → scatter +
               ``L21`` update), and the only host↔device sync is fetching
               the solution at the end. Factors and sweeps run in f32 —
               pair with :func:`repro.sparse.refine.refine_solve_device`
               (x/r stay device-resident too) to reach fp64 residuals.

Per-front cost is exactly the symbolic model of
:func:`repro.sparse.symbolic.cholesky_flops`, so measured label times and the
analytic cost model agree in ordering.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Literal, Optional, Tuple

import numpy as np
import scipy.linalg as sla

from .csr import CSRMatrix
from .schedule import FrontPlan, LevelSchedule, build_schedule
from .symbolic import SymbolicFactor, supernodes, symbolic_cholesky

__all__ = ["MultifrontalFactor", "multifrontal_cholesky", "multifrontal_solve",
           "factor_and_solve_timed"]

Backend = Literal["numpy", "pallas", "batched", "pipelined"]

#: backends that factor fronts in f32 on device
DEVICE_BACKENDS = ("pallas", "batched", "pipelined")


def _check_deadline(ctx, stage: str) -> None:
    """Deadline checkpoint at a level boundary of the numeric phase: a
    request whose :class:`repro.core.reqctx.RequestContext` deadline has
    passed raises :class:`DeadlineExceeded` *mid-factorization* instead of
    burning the remaining levels on an answer nobody is waiting for.
    ``ctx`` is duck-typed (anything with ``expired()``/``remaining()``);
    the import is lazy to keep this module free of a core dependency."""
    if ctx is None or not ctx.expired():
        return
    from repro.core.reqctx import DeadlineExceeded

    late_ms = -(ctx.remaining() or 0.0) * 1e3
    raise DeadlineExceeded(
        f"deadline exceeded {late_ms:.1f} ms ago at {stage} — "
        f"factorization abandoned")


@dataclasses.dataclass
class _Front:
    cols: Tuple[int, int]    # [c0, c1) pivot columns
    rows: np.ndarray         # global row indices of the front (sorted; first npiv are pivots)
    L11: np.ndarray          # (npiv, npiv) lower-triangular
    L21: np.ndarray          # (m - npiv, npiv)


@dataclasses.dataclass
class MultifrontalFactor:
    n: int
    fronts: List[_Front]
    sym: SymbolicFactor
    stats: dict
    schedule: Optional[LevelSchedule] = None
    dtype: np.dtype = np.float64
    _sweeps: Optional["_LevelSweeps"] = dataclasses.field(
        default=None, repr=False, compare=False)
    # pipelined backend: the factored per-(level, bucket) workspace stacks,
    # kept device-resident so sweep="device" reads L11/L21 straight from
    # them instead of re-uploading drained host fronts
    _device_stacks: Optional[dict] = dataclasses.field(
        default=None, repr=False, compare=False)
    _dev_sweeps: Optional["_DeviceSweeps"] = dataclasses.field(
        default=None, repr=False, compare=False)


# ---------------------------------------------------------------------------
# Host-side assembly: vectorized scatter + extend-add
# ---------------------------------------------------------------------------

def _scatter_entries(F: np.ndarray, a: CSRMatrix, fp: FrontPlan,
                     shift: int = 0) -> None:
    """Scatter A[rows, c0:c1] (lower triangle, via symmetry of the CSR rows)
    into the front workspace in one vectorized pass: global row indices map
    to local positions by ``np.searchsorted`` over the sorted front rows.
    ``shift`` displaces non-pivot rows by the pivot-padding width (the
    batched workspace layout); 0 means the dense unpadded front."""
    indptr, indices, data = a.indptr, a.indices, a.data
    c0, c1 = fp.c0, fp.c1
    start, end = int(indptr[c0]), int(indptr[c1])
    cols = indices[start:end]
    vals = data[start:end]
    colid = np.repeat(np.arange(c0, c1), np.diff(indptr[c0 : c1 + 1]))
    sel = cols >= colid            # keep the lower triangle (row ≥ col)
    loc = np.searchsorted(fp.rows, cols[sel])
    if shift:
        loc = np.where(loc >= fp.npiv, loc + shift, loc)
    F[loc, colid[sel] - c0] = vals[sel]


def _extend_add(F: np.ndarray, fp: FrontPlan, urows: np.ndarray,
                U: np.ndarray, shift: int = 0) -> None:
    """Add a child's Schur update (rows `urows`) into the front workspace."""
    idx = np.searchsorted(fp.rows, urows)
    if idx.size and (idx[-1] >= fp.rows.size
                     or not np.array_equal(fp.rows[idx], urows)):
        raise RuntimeError(
            "assembly-tree containment violated (supernode "
            f"{fp.k}: update rows not a subset of front rows)")
    if shift:
        idx = np.where(idx >= fp.npiv, idx + shift, idx)
    F[np.ix_(idx, idx)] += U


# ---------------------------------------------------------------------------
# Dense partial factorization backends (front-at-a-time)
# ---------------------------------------------------------------------------

def _partial_factor_numpy(F: np.ndarray, npiv: int):
    """Dense partial Cholesky: factor pivot block, panel solve, Schur update."""
    F11 = F[:npiv, :npiv]
    L11 = np.linalg.cholesky(F11)
    if F.shape[0] > npiv:
        L21 = sla.solve_triangular(L11, F[npiv:, :npiv].T, lower=True,
                                   trans="N").T
        S = F[npiv:, npiv:] - L21 @ L21.T
    else:
        L21 = np.empty((0, npiv), dtype=F.dtype)
        S = np.empty((0, 0), dtype=F.dtype)
    return L11, L21, S


def _partial_factor_pallas(F: np.ndarray, npiv: int):
    from repro.kernels import ops  # local import: keep numpy path jax-free
    L11, L21, S = ops.frontal_factor(F, npiv)
    return np.asarray(L11), np.asarray(L21), np.asarray(S)


# ---------------------------------------------------------------------------
# Numeric phase
# ---------------------------------------------------------------------------

def multifrontal_cholesky(
    a: CSRMatrix,
    sym: Optional[SymbolicFactor] = None,
    relax: int = 8,
    backend: Backend = "numpy",
    dtype: np.dtype | type = np.float64,
    pad: str = "pow2",
    bs: Optional[int] = None,
    ctx=None,
) -> MultifrontalFactor:
    """Numeric supernodal factorization of an SPD CSR matrix.

    ``dtype`` selects the front-math precision on the ``numpy`` backend
    (fp64 or fp32); the device backends always accumulate in f32 (pair them
    with :mod:`repro.sparse.refine` to recover fp64-level residuals).
    ``pad`` and ``bs`` are the autotuned kernel-policy knobs: the bucket
    pad policy of the level schedule (``"pow2"`` / ``"mult8"``) and the
    panel block-size cap of the batched kernels (None → 32). The returned
    factor carries the :class:`LevelSchedule` used, so
    :func:`multifrontal_solve` can run level-batched sweeps.

    ``ctx`` is an optional :class:`repro.core.reqctx.RequestContext`: the
    level-scheduled backends re-check its deadline at every assembly-tree
    level boundary and abandon the factorization with
    :class:`~repro.core.reqctx.DeadlineExceeded` once it has passed —
    serving-path deadline discipline extends into the numeric solve
    instead of stopping at plan build.
    """
    assert a.data is not None, "numeric factorization needs values"
    if sym is None:
        sym = symbolic_cholesky(a)
    snode_ptr, snode_of = supernodes(sym, relax=relax)
    schedule = build_schedule(sym, snode_ptr, snode_of, pad=pad)
    eff_dtype = np.dtype(np.float32 if backend in DEVICE_BACKENDS else dtype)

    timings: dict = {}
    device_stacks = None
    _check_deadline(ctx, "factorization start")
    if backend == "batched":
        fronts, timings = _factor_batched(a, schedule, bs=bs, ctx=ctx)
    elif backend == "pipelined":
        fronts, timings, device_stacks = _factor_pipelined(a, schedule,
                                                           bs=bs, ctx=ctx)
    else:
        fronts = _factor_sequential(a, schedule, backend, eff_dtype)

    stats = dict(schedule.stats())  # nsup, nlevels, widths, occupancy, flops
    stats.update(n=a.n,
                 peak_front=max((fp.m for fp in schedule.fronts), default=0),
                 nnz_L=sym.nnz_L, fill=sym.fill, sym_flops=sym.flops,
                 backend=backend, dtype=str(eff_dtype), bs=bs, **timings)
    return MultifrontalFactor(a.n, fronts, sym, stats, schedule=schedule,
                              dtype=eff_dtype,
                              _device_stacks=device_stacks)


def _factor_sequential(a: CSRMatrix, schedule: LevelSchedule,
                       backend: Backend, dtype: np.dtype) -> List[_Front]:
    """Front-at-a-time postorder traversal (numpy / per-front pallas)."""
    partial = (_partial_factor_numpy if backend == "numpy"
               else _partial_factor_pallas)
    nsup = schedule.nsup
    fronts: List[_Front] = []
    pending: List[List[Tuple[np.ndarray, np.ndarray]]] = [[] for _ in range(nsup)]
    for fp in schedule.fronts:
        F = np.zeros((fp.m, fp.m), dtype=dtype)
        _scatter_entries(F, a, fp)
        for (urows, U) in pending[fp.k]:
            _extend_add(F, fp, urows, U)
        pending[fp.k] = []
        L11, L21, S = partial(F, fp.npiv)
        fronts.append(_Front((fp.c0, fp.c1), fp.rows, L11, L21))
        if fp.nrest:
            pending[fp.parent].append((fp.rows[fp.npiv :], S))
    return fronts


def _overlap_timings(t_assemble: float, t_dispatch: float,
                     t_sync: float) -> dict:
    """Solve-stage timing record shared by the batched/pipelined backends.

    ``overlap_efficiency`` is the host-busy fraction of the overlappable
    time — assembly seconds over assembly + device-blocked seconds. A
    backend that hides its device waits under host assembly (the pipelined
    producer/consumer loop) pushes it toward 1; a backend that blocks on
    every kernel call (batched) is bounded by how its per-bucket assembly
    and kernel times happen to interleave.
    """
    denom = t_assemble + t_sync
    return dict(t_factor_assemble=t_assemble, t_factor_dispatch=t_dispatch,
                t_factor_sync=t_sync,
                overlap_efficiency=(t_assemble / denom) if denom > 0 else 1.0)


def _assemble_bucket(a: CSRMatrix, schedule: LevelSchedule,
                     bucket) -> np.ndarray:
    """Host side of one bucket's assembly: fresh padded f32 workspace stack
    with identity pivot-pad columns and A's entries scattered in. Pivot
    padding columns are decoupled identity columns; update-row padding is
    zero rows — both factor trivially and contribute nothing to L or the
    Schur complements."""
    B, P, M = len(bucket.members), bucket.P, bucket.M
    W = np.zeros((B, M, M), dtype=np.float32)
    for bi, k in enumerate(bucket.members):
        fp = schedule.fronts[k]
        shift = P - fp.npiv
        if shift:
            pad = np.arange(fp.npiv, P)
            W[bi, pad, pad] = 1.0
        _scatter_entries(W[bi], a, fp, shift)
    return W


def _factor_batched(a: CSRMatrix, schedule: LevelSchedule,
                    bs: Optional[int] = None, ctx=None
                    ) -> Tuple[List[_Front], dict]:
    """Level-scheduled factorization: per (level, bucket), assemble every
    member front into one padded f32 workspace stack and factor the stack
    in a single batched kernel launch. Extend-add runs on the host (numpy
    scatter into the next level's workspaces) and every kernel call is a
    blocking round trip — the ``pipelined`` backend removes both."""
    from repro.kernels import ops

    pc = time.perf_counter
    nsup = schedule.nsup
    fronts: List[Optional[_Front]] = [None] * nsup
    pending: List[List[Tuple[np.ndarray, np.ndarray]]] = [[] for _ in range(nsup)]
    t_asm = t_sync = 0.0
    for li in range(schedule.nlevels):
        _check_deadline(ctx, f"batched level {li}/{schedule.nlevels}")
        for bucket in schedule.buckets[li]:
            t0 = pc()
            P = bucket.P
            W = _assemble_bucket(a, schedule, bucket)
            for bi, k in enumerate(bucket.members):
                fp = schedule.fronts[k]
                shift = P - fp.npiv
                for (urows, U) in pending[k]:
                    _extend_add(W[bi], fp, urows, U, shift)
                pending[k] = []
            t_asm += pc() - t0
            t0 = pc()
            Wf = np.asarray(ops.frontal_factor_batch_ws(W, P, bs=bs))
            t_sync += pc() - t0
            t0 = pc()
            for bi, k in enumerate(bucket.members):
                fp = schedule.fronts[k]
                npiv, nrest = fp.npiv, fp.nrest
                L11 = np.tril(Wf[bi, :npiv, :npiv])
                L21 = Wf[bi, P : P + nrest, :npiv]
                fronts[k] = _Front((fp.c0, fp.c1), fp.rows, L11, L21)
                if nrest:
                    S = Wf[bi, P : P + nrest, P : P + nrest]
                    pending[fp.parent].append((fp.rows[npiv:], S))
            t_asm += pc() - t0
    return fronts, _overlap_timings(t_asm, 0.0, t_sync)  # type: ignore[return-value]


def _route_contributions(schedule: LevelSchedule) -> dict:
    """Precompute the device extend-add routing from the schedule alone.

    Returns ``{(dst_level, dst_bucket): {(src_level, src_bucket):
    [(src_slot, dst_slot, rowmap), ...]}}`` where ``rowmap`` maps the
    source bucket's (padded) update rows to local positions in the padded
    destination workspace (−1 = inactive pad row). Grouping by source
    bucket makes every group one uniform-shape kernel launch.
    """
    loc = {}
    for li in range(schedule.nlevels):
        for bj, bucket in enumerate(schedule.buckets[li]):
            for bi, k in enumerate(bucket.members):
                loc[k] = (li, bj, bi)
    routes: dict = {}
    for fp in schedule.fronts:
        if fp.parent < 0 or fp.nrest == 0:
            continue
        sli, sbj, sbi = loc[fp.k]
        dli, dbj, dbi = loc[fp.parent]
        pfp = schedule.fronts[fp.parent]
        urows = fp.rows[fp.npiv :]
        idx = np.searchsorted(pfp.rows, urows)
        if idx.size and (idx[-1] >= pfp.rows.size
                         or not np.array_equal(pfp.rows[idx], urows)):
            raise RuntimeError(
                "assembly-tree containment violated (supernode "
                f"{fp.k}: update rows not a subset of front rows)")
        shift = schedule.buckets[dli][dbj].P - pfp.npiv
        if shift:
            idx = np.where(idx >= pfp.npiv, idx + shift, idx)
        rowmap = np.full(schedule.buckets[sli][sbj].R, -1, dtype=np.int32)
        rowmap[: fp.nrest] = idx
        (routes.setdefault((dli, dbj), {})
               .setdefault((sli, sbj), []).append((sbi, dbi, rowmap)))
    return routes


def _pad_pow2(n: int) -> int:
    return 1 << (int(n) - 1).bit_length() if n > 1 else 1


def _factor_pipelined(a: CSRMatrix, schedule: LevelSchedule,
                      bs: Optional[int] = None, ctx=None
                      ) -> Tuple[List[_Front], dict, dict]:
    """Pipelined device-resident factorization.

    Producer/consumer split: the host's only numeric work is scattering A's
    entries into fresh bucket workspaces (sparse, cheap); the extend-add
    and the partial factorization both run on device, dispatched
    asynchronously. JAX's async dispatch queues the level-*k* kernels and
    returns immediately, so the host assembles level *k+1* while the device
    factors level *k* — host work hides under kernel time. Schur updates
    stay device-resident between levels (each factored bucket stack is kept
    on device until its members' parents have consumed it via
    :func:`repro.kernels.ops.extend_add_batch`); the single blocking sync
    is the drain at the end that fetches the factored stacks for the
    host-side triangular sweeps. The factored device stacks are *also*
    returned (third element) and retained on the factor: ``sweep="device"``
    slices L11/L21 straight out of them, so device sweeps never re-upload
    the factors the drain just pulled down.
    """
    import jax.numpy as jnp

    from repro.kernels import ops

    pc = time.perf_counter
    nsup = schedule.nsup
    fronts: List[Optional[_Front]] = [None] * nsup
    routes = _route_contributions(schedule)
    dev: dict = {}             # (level, bucket) -> factored device stack
    t_asm = t_disp = t_sync = 0.0
    for li in range(schedule.nlevels):
        _check_deadline(ctx, f"pipelined dispatch level "
                             f"{li}/{schedule.nlevels}")
        for bj, bucket in enumerate(schedule.buckets[li]):
            t0 = pc()
            W = _assemble_bucket(a, schedule, bucket)
            t_asm += pc() - t0
            t0 = pc()
            w = jnp.asarray(W)
            for (sli, sbj), contribs in sorted(
                    routes.get((li, bj), {}).items()):
                # sorted destination slots: the kernel's sequential
                # accumulation contract (equal slots stay VMEM-resident)
                contribs.sort(key=lambda c: c[1])
                src = np.array([c[0] for c in contribs], dtype=np.int32)
                dst = np.array([c[1] for c in contribs], dtype=np.int32)
                rows = np.stack([c[2] for c in contribs])
                # pad the contribution count to a power of two so jit
                # shapes stay bounded; pads are inert (rowmap −1 ⇒ all-zero
                # one-hot ⇒ zero contribution) and keep dst sorted
                C, Cp = len(contribs), _pad_pow2(len(contribs))
                if Cp != C:
                    src = np.concatenate([src, np.zeros(Cp - C, np.int32)])
                    dst = np.concatenate(
                        [dst, np.full(Cp - C, dst[-1], np.int32)])
                    rows = np.concatenate(
                        [rows, np.full((Cp - C, rows.shape[1]), -1,
                                       np.int32)])
                P_src = schedule.buckets[sli][sbj].P
                u = jnp.take(dev[(sli, sbj)][:, P_src:, P_src:],
                             jnp.asarray(src), axis=0)
                w = ops.extend_add_batch(w, u, dst, rows)
            dev[(li, bj)] = ops.frontal_factor_batch_ws(w, bucket.P, bs=bs)
            t_disp += pc() - t0
    # drain: the only host↔device sync — by now the host has assembled and
    # dispatched every level, so this wait is whatever device work is left
    for li in range(schedule.nlevels):
        _check_deadline(ctx, f"pipelined drain level "
                             f"{li}/{schedule.nlevels}")
        for bj, bucket in enumerate(schedule.buckets[li]):
            t0 = pc()
            Wf = np.asarray(dev[(li, bj)])
            t_sync += pc() - t0
            t0 = pc()
            P = bucket.P
            for bi, k in enumerate(bucket.members):
                fp = schedule.fronts[k]
                L11 = np.tril(Wf[bi, : fp.npiv, : fp.npiv])
                L21 = Wf[bi, P : P + fp.nrest, : fp.npiv]
                fronts[k] = _Front((fp.c0, fp.c1), fp.rows, L11, L21)
            t_asm += pc() - t0
    return fronts, _overlap_timings(t_asm, t_disp, t_sync), dev  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# Triangular sweeps
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _SweepGroup:
    """One level-bucket's factors stacked for batched substitution."""

    L11: np.ndarray        # (B, P, P) unit-diag padded, fp64
    L11T: np.ndarray       # (B, P, P) transposed copy (backward sweep)
    L21: np.ndarray        # (B, R, P)
    piv: np.ndarray        # (B, P) global pivot indices (0 at pads)
    pmask: np.ndarray      # (B, P) bool, True at real pivots
    rest: np.ndarray       # (B, R) global update rows (0 at pads)
    rmask: np.ndarray      # (B, R) bool


@dataclasses.dataclass
class _LevelSweeps:
    levels: List[List[_SweepGroup]]


def _build_sweeps(f: MultifrontalFactor) -> _LevelSweeps:
    sched = f.schedule
    assert sched is not None
    levels: List[List[_SweepGroup]] = []
    for li in range(sched.nlevels):
        groups: List[_SweepGroup] = []
        for bucket in sched.buckets[li]:
            B, P, R = len(bucket.members), bucket.P, bucket.R
            L11 = np.zeros((B, P, P))
            diag = np.arange(P)
            L11[:, diag, diag] = 1.0
            L21 = np.zeros((B, R, P))
            piv = np.zeros((B, P), dtype=np.int64)
            pmask = np.zeros((B, P), dtype=bool)
            rest = np.zeros((B, R), dtype=np.int64)
            rmask = np.zeros((B, R), dtype=bool)
            for bi, k in enumerate(bucket.members):
                fr = f.fronts[k]
                c0, c1 = fr.cols
                npiv = c1 - c0
                nrest = fr.L21.shape[0]
                L11[bi, :npiv, :npiv] = fr.L11
                L21[bi, :nrest, :npiv] = fr.L21
                piv[bi, :npiv] = np.arange(c0, c1)
                pmask[bi, :npiv] = True
                rest[bi, :nrest] = fr.rows[npiv:]
                rmask[bi, :nrest] = True
            groups.append(_SweepGroup(
                L11, np.ascontiguousarray(L11.transpose(0, 2, 1)), L21,
                piv, pmask, rest, rmask))
        levels.append(groups)
    return _LevelSweeps(levels)


def _solve_level(f: MultifrontalFactor, x: np.ndarray) -> None:
    """Level-batched forward/backward sweeps, in place on the (n, k) fp64
    RHS block: one batched triangular solve (``np.linalg.solve`` on the
    stacked unit-padded factors) plus one batched update einsum per
    level-bucket, instead of a scipy call per front. Update scatters within
    a level never collide with that level's pivots (parents live on
    strictly higher levels), so bucket order is free and every bucket's
    cross-front updates are deferred and applied in ONE ``np.bincount``
    scatter-add per level (a dense accumulate, much faster than the
    element-at-a-time ``np.subtract.at``)."""
    if f._sweeps is None:
        f._sweeps = _build_sweeps(f)
    sw = f._sweeps
    n, k = x.shape
    colk = np.arange(k)
    # forward: L y = b, leaves upward
    for groups in sw.levels:
        acc_idx: List[np.ndarray] = []
        acc_upd: List[np.ndarray] = []
        for g in groups:
            xb = np.where(g.pmask[..., None], x[g.piv], 0.0)
            y = np.linalg.solve(g.L11, xb)
            x[g.piv[g.pmask]] = y[g.pmask]
            if g.rest.shape[1]:
                upd = np.einsum("brp,bpk->brk", g.L21, y)
                acc_idx.append(g.rest[g.rmask])
                acc_upd.append(upd[g.rmask])
        if acc_idx:
            idx = np.concatenate(acc_idx)
            upd = np.concatenate(acc_upd)
            flat = (idx[:, None] * k + colk).ravel()
            x -= np.bincount(flat, weights=upd.ravel(),
                             minlength=n * k).reshape(n, k)
    # backward: Lᵀ x = y, roots downward
    for groups in reversed(sw.levels):
        for g in groups:
            rhs = np.where(g.pmask[..., None], x[g.piv], 0.0)
            if g.rest.shape[1]:
                xr = np.where(g.rmask[..., None], x[g.rest], 0.0)
                rhs = rhs - np.einsum("brp,brk->bpk", g.L21, xr)
            y = np.linalg.solve(g.L11T, rhs)
            x[g.piv[g.pmask]] = y[g.pmask]


def _solve_sequential(f: MultifrontalFactor, x: np.ndarray) -> None:
    """Per-front scipy sweeps, in place on the (n, k) fp64 RHS block (the
    pre-level-scheduling reference path)."""
    # forward: L y = b
    for fr in f.fronts:
        c0, c1 = fr.cols
        piv = slice(c0, c1)
        y = sla.solve_triangular(fr.L11, x[piv], lower=True)
        x[piv] = y
        if fr.L21.shape[0]:
            x[fr.rows[c1 - c0 :]] -= fr.L21 @ y
    # backward: Lᵀ x = y
    for fr in reversed(f.fronts):
        c0, c1 = fr.cols
        piv = slice(c0, c1)
        rhs = x[piv]
        if fr.L21.shape[0]:
            rhs = rhs - fr.L21.T @ x[fr.rows[c1 - c0 :]]
        x[piv] = sla.solve_triangular(fr.L11.T, rhs, lower=False)


# -- device-resident sweeps --------------------------------------------------

@dataclasses.dataclass
class _DeviceSweepGroup:
    """One level-bucket's factors as device arrays for batched Pallas
    substitution. Indices are int32 with every pad slot pointing at the
    trash row ``n`` of the (n + 1, K) RHS block — no masks needed on
    device: identity pad rows in L11 and zero pad rows/cols in L21 keep
    whatever garbage the trash row holds out of every real entry."""

    L11: object            # (B, P, P) f32 device, unit-diag padded
    L21: object            # (B, R, P) f32 device
    piv: object            # (B, P) int32 device, pads -> n
    rest: object           # (B, R) int32 device, pads -> n


@dataclasses.dataclass
class _DeviceSweeps:
    levels: List[List[_DeviceSweepGroup]]


def _bucket_indices(sched: LevelSchedule, bucket, n: int
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """(B, P) pivot and (B, R) update-row index stacks for one bucket,
    pads pointed at the trash row ``n``. Built from the schedule alone —
    no drained host fronts needed."""
    B, P, R = len(bucket.members), bucket.P, bucket.R
    piv = np.full((B, P), n, dtype=np.int32)
    rest = np.full((B, R), n, dtype=np.int32)
    for bi, k in enumerate(bucket.members):
        fp = sched.fronts[k]
        piv[bi, : fp.npiv] = np.arange(fp.c0, fp.c1, dtype=np.int32)
        rest[bi, : fp.nrest] = fp.rows[fp.npiv :]
    return piv, rest


def _build_device_sweeps(f: MultifrontalFactor) -> _DeviceSweeps:
    """Stack each level-bucket's factors as device arrays.

    After a ``pipelined`` factorization the factored workspace stacks are
    still device-resident (``f._device_stacks``) and already in the padded
    bucket layout — L11/L21 are sliced straight out of them (the identity
    pivot pads factored to unit-diagonal rows, update-row pads to zero
    rows, exactly the inert padding the sweeps need). Any other backend
    uploads its host fronts once; repeated solves reuse the cached stacks.
    """
    import jax.numpy as jnp

    sched = f.schedule
    assert sched is not None
    n = f.n
    levels: List[List[_DeviceSweepGroup]] = []
    if f._device_stacks is not None:
        for li in range(sched.nlevels):
            groups: List[_DeviceSweepGroup] = []
            for bj, bucket in enumerate(sched.buckets[li]):
                W = f._device_stacks[(li, bj)]
                P = bucket.P
                piv, rest = _bucket_indices(sched, bucket, n)
                groups.append(_DeviceSweepGroup(
                    jnp.tril(W[:, :P, :P]), W[:, P:, :P],
                    jnp.asarray(piv), jnp.asarray(rest)))
            levels.append(groups)
        return _DeviceSweeps(levels)
    if f._sweeps is None:
        f._sweeps = _build_sweeps(f)
    for li, host_groups in enumerate(f._sweeps.levels):
        groups = []
        for bj, g in enumerate(host_groups):
            piv, rest = _bucket_indices(sched, sched.buckets[li][bj], n)
            groups.append(_DeviceSweepGroup(
                jnp.asarray(g.L11, jnp.float32),
                jnp.asarray(g.L21, jnp.float32),
                jnp.asarray(piv), jnp.asarray(rest)))
        levels.append(groups)
    return _DeviceSweeps(levels)


def _device_sweep_passes(f: MultifrontalFactor, x, *,
                         sweep_bs: Optional[int] = None,
                         rt: Optional[int] = None):
    """Forward + backward substitution on a device-resident (n + 1, K) f32
    RHS block. One asynchronously dispatched jit step per level-bucket; no
    host sync anywhere — callers decide when to pull the result."""
    from repro.kernels import ops

    if f._dev_sweeps is None:
        f._dev_sweeps = _build_device_sweeps(f)
    sw = f._dev_sweeps
    for groups in sw.levels:
        for g in groups:
            x = ops.sweep_forward(x, g.L11, g.L21, g.piv, g.rest,
                                  bs=sweep_bs, rt=rt)
    for groups in reversed(sw.levels):
        for g in groups:
            x = ops.sweep_backward(x, g.L11, g.L21, g.piv, g.rest,
                                   bs=sweep_bs, rt=rt)
    return x


def _solve_device(f: MultifrontalFactor, b2: np.ndarray, *,
                  sweep_bs: Optional[int] = None,
                  rt: Optional[int] = None) -> np.ndarray:
    """Device-resident sweeps for an (n, k) RHS block: upload once, one
    async dispatch per level-bucket, one sync to fetch the solution."""
    import jax.numpy as jnp

    n, k = b2.shape
    kt = k if rt is None else max(1, min(int(rt), k))
    kp = -(-k // kt) * kt          # pad K so the RHS-tile grid divides it
    xb = np.zeros((n + 1, kp), dtype=np.float32)
    xb[:n, :k] = b2
    x = _device_sweep_passes(f, jnp.asarray(xb), sweep_bs=sweep_bs, rt=kt)
    return np.asarray(x[:n, :k], dtype=np.float64)


SweepMode = Literal["auto", "level", "seq", "device"]


def multifrontal_solve(f: MultifrontalFactor, b: np.ndarray,
                       mode: SweepMode = "auto", *,
                       sweep_bs: Optional[int] = None,
                       rt: Optional[int] = None) -> np.ndarray:
    """Solve A x = b with the supernodal factor.

    ``b`` may be a single RHS ``(n,)`` or a block ``(n, k)`` — all sweep
    modes are natively multi-RHS and the result matches the input shape.
    ``mode="level"`` (the default when the factor carries a schedule) runs
    the host level-batched sweeps; ``"seq"`` keeps the per-front loop
    (reference and fallback); ``"device"`` runs the batched Pallas
    substitution kernels on device-resident factor stacks (f32 — pair
    with refinement for fp64 residuals). ``sweep_bs``/``rt`` are the
    autotuned device-sweep knobs (tri-solve panel cap and RHS tile width);
    both are ignored by the host modes. Repeated solves reuse the stacked
    sweep tensors cached on the factor.
    """
    b = np.asarray(b)
    single = b.ndim == 1
    if mode == "auto":
        mode = "seq" if f.schedule is None else "level"
    if mode in ("level", "device") and f.schedule is None:
        raise ValueError(f"mode={mode!r} needs a factor with a schedule")
    if mode == "device":
        x = _solve_device(f, b[:, None] if single else b,
                          sweep_bs=sweep_bs, rt=rt)
        return x[:, 0] if single else x
    x = np.array(b, dtype=np.float64)   # the one owned fp64 copy
    x2 = x[:, None] if single else x    # view — sweeps mutate in place
    if mode == "seq":
        _solve_sequential(f, x2)
    else:
        _solve_level(f, x2)
    return x


def factor_and_solve_timed(a: CSRMatrix, b: np.ndarray | None = None,
                           relax: int = 8,
                           sym: Optional[SymbolicFactor] = None,
                           backend: Backend = "numpy",
                           pad: str = "pow2",
                           bs: Optional[int] = None,
                           sweep: SweepMode = "auto",
                           sweep_bs: Optional[int] = None,
                           rt: Optional[int] = None) -> dict:
    """Measured factor+solve wall time — the per-(matrix, ordering) label
    signal, mirroring the paper's MUMPS timings.

    Passing a precomputed ``sym`` (e.g. from a cached
    :class:`repro.core.plan.ExecutionPlan`) skips the symbolic stage
    entirely; ``t_symbolic`` is then reported as 0. ``relax`` tunes the
    supernode amalgamation and ``backend`` picks the front-math substrate,
    so labeling can time the Pallas / batched / pipelined paths too;
    ``pad``/``bs`` are the autotuned bucket/block policy knobs and
    ``sweep``/``sweep_bs``/``rt`` the triangular-sweep mode and its
    device-kernel knobs (see :mod:`repro.autotune.solve_tuner`).
    """
    if b is None:
        rng = np.random.default_rng(0)
        b = rng.standard_normal(a.n)
    # hoist the fp64 cast out of the timed region (and out of any caller's
    # repeat loop): the sweeps get a ready-to-consume contiguous fp64 RHS
    b = np.ascontiguousarray(b, dtype=np.float64)
    if sym is None:
        t0 = time.perf_counter()
        sym = symbolic_cholesky(a)
        t_sym = time.perf_counter() - t0
    else:
        t_sym = 0.0
    t0 = time.perf_counter()
    f = multifrontal_cholesky(a, sym, relax=relax, backend=backend, pad=pad,
                              bs=bs)
    t_fac = time.perf_counter() - t0
    t0 = time.perf_counter()
    x = multifrontal_solve(f, b, mode=sweep, sweep_bs=sweep_bs, rt=rt)
    t_sol = time.perf_counter() - t0
    resid = float(np.linalg.norm(a.matvec(x) - b) / max(np.linalg.norm(b), 1e-30))
    return dict(time=t_sym + t_fac + t_sol, t_symbolic=t_sym, t_factor=t_fac,
                t_solve=t_sol, residual=resid, **f.stats)
