"""Supernodal multifrontal Cholesky — the MUMPS analogue.

The multifrontal method [Duff & Reid 1983] converts sparse factorization into
a postorder traversal of an assembly tree whose nodes are **dense frontal
matrices**. This is the TPU-native re-think of the paper's solver substrate:
the irregular sparsity is confined to host-side assembly (scatter/extend-add
index maps), while all heavy FLOPs are dense partial factorizations of
fronts — matmul-shaped work for the MXU. The dense partial factorization has
two interchangeable backends:

* ``numpy``  — host BLAS; used for dataset labeling wall-times.
* ``pallas`` — :func:`repro.kernels.ops.frontal_factor` (blocked right-looking
  Cholesky with 128-aligned VMEM tiles), validated in interpret mode on CPU.

Per-front cost is exactly the symbolic model of
:func:`repro.sparse.symbolic.cholesky_flops`, so measured label times and the
analytic cost model agree in ordering.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Literal, Optional, Tuple

import numpy as np
import scipy.linalg as sla

from .csr import CSRMatrix
from .symbolic import SymbolicFactor, supernodes, symbolic_cholesky

__all__ = ["MultifrontalFactor", "multifrontal_cholesky", "multifrontal_solve",
           "factor_and_solve_timed"]


@dataclasses.dataclass
class _Front:
    cols: Tuple[int, int]    # [c0, c1) pivot columns
    rows: np.ndarray         # global row indices of the front (sorted; first npiv are pivots)
    L11: np.ndarray          # (npiv, npiv) lower-triangular
    L21: np.ndarray          # (m - npiv, npiv)


@dataclasses.dataclass
class MultifrontalFactor:
    n: int
    fronts: List[_Front]
    sym: SymbolicFactor
    stats: dict


def _partial_factor_numpy(F: np.ndarray, npiv: int):
    """Dense partial Cholesky: factor pivot block, panel solve, Schur update."""
    F11 = F[:npiv, :npiv]
    L11 = np.linalg.cholesky(F11)
    if F.shape[0] > npiv:
        L21 = sla.solve_triangular(L11, F[npiv:, :npiv].T, lower=True,
                                   trans="N").T
        S = F[npiv:, npiv:] - L21 @ L21.T
    else:
        L21 = np.empty((0, npiv))
        S = np.empty((0, 0))
    return L11, L21, S


def _partial_factor_pallas(F: np.ndarray, npiv: int):
    from repro.kernels import ops  # local import: keep numpy path jax-free
    L11, L21, S = ops.frontal_factor(F, npiv)
    return np.asarray(L11), np.asarray(L21), np.asarray(S)


def multifrontal_cholesky(
    a: CSRMatrix,
    sym: Optional[SymbolicFactor] = None,
    relax: int = 8,
    backend: Literal["numpy", "pallas"] = "numpy",
) -> MultifrontalFactor:
    assert a.data is not None, "numeric factorization needs values"
    n = a.n
    if sym is None:
        sym = symbolic_cholesky(a)
    snode_ptr, snode_of = supernodes(sym, relax=relax)
    nsup = snode_ptr.shape[0] - 1
    Lp, Li = sym.Lp, sym.Li
    indptr, indices, data = a.indptr, a.indices, a.data
    partial = _partial_factor_numpy if backend == "numpy" else _partial_factor_pallas

    # Row structure of each supernode: union of its columns' patterns.
    fronts: List[_Front] = []
    # pending updates per supernode: list of (rows, dense update)
    pending: List[List[Tuple[np.ndarray, np.ndarray]]] = [[] for _ in range(nsup)]
    peak_front = 0
    total_front_flops = 0

    for k in range(nsup):
        c0, c1 = int(snode_ptr[k]), int(snode_ptr[k + 1])
        npiv = c1 - c0
        pats = [Li[Lp[j] : Lp[j + 1]] for j in range(c0, c1)]
        rows = np.unique(np.concatenate(pats))
        rows = rows[rows >= c0]
        # pivots first, then the remainder (np.unique sorted => already true)
        m = rows.shape[0]
        pos = {int(r): t for t, r in enumerate(rows)}
        F = np.zeros((m, m), dtype=np.float64)

        # Scatter original entries A[rows, c0:c1] (use symmetry: row j of A).
        for j in range(c0, c1):
            lo, hi = indptr[j], indptr[j + 1]
            cols_j = indices[lo:hi]
            vals_j = data[lo:hi]
            sel = cols_j >= j
            for c, v in zip(cols_j[sel], vals_j[sel]):
                ci = pos.get(int(c))
                if ci is not None:
                    F[ci, j - c0] = v

        # Extend-add children updates.
        for (urows, U) in pending[k]:
            idx = np.searchsorted(rows, urows)
            if idx.size and (idx[-1] >= rows.size
                             or not np.array_equal(rows[idx], urows)):
                raise RuntimeError(
                    "assembly-tree containment violated (supernode "
                    f"{k}: update rows not a subset of front rows)")
            F[np.ix_(idx, idx)] += U
        pending[k] = []

        peak_front = max(peak_front, m)
        total_front_flops += npiv * npiv * npiv // 3 + npiv * npiv * (m - npiv) \
            + npiv * (m - npiv) ** 2

        L11, L21, S = partial(F, npiv)
        fronts.append(_Front((c0, c1), rows, L11, L21))

        if m > npiv:
            urows = rows[npiv:]
            parent = int(snode_of[int(urows[0])])
            pending[parent].append((urows, S))

    stats = dict(n=n, nsup=nsup, peak_front=int(peak_front),
                 front_flops=int(total_front_flops),
                 nnz_L=sym.nnz_L, fill=sym.fill, sym_flops=sym.flops)
    return MultifrontalFactor(n, fronts, sym, stats)


def multifrontal_solve(f: MultifrontalFactor, b: np.ndarray) -> np.ndarray:
    """Solve A x = b with the supernodal factor (forward + backward sweeps)."""
    x = b.astype(np.float64).copy()
    # forward: L y = b
    for fr in f.fronts:
        c0, c1 = fr.cols
        piv = slice(c0, c1)
        y = sla.solve_triangular(fr.L11, x[piv], lower=True)
        x[piv] = y
        if fr.L21.shape[0]:
            x[fr.rows[c1 - c0 :]] -= fr.L21 @ y
    # backward: Lᵀ x = y
    for fr in reversed(f.fronts):
        c0, c1 = fr.cols
        piv = slice(c0, c1)
        rhs = x[piv]
        if fr.L21.shape[0]:
            rhs = rhs - fr.L21.T @ x[fr.rows[c1 - c0 :]]
        x[piv] = sla.solve_triangular(fr.L11.T, rhs, lower=False)
    return x


def factor_and_solve_timed(a: CSRMatrix, b: np.ndarray | None = None,
                           relax: int = 8,
                           sym: Optional[SymbolicFactor] = None) -> dict:
    """Measured factor+solve wall time — the per-(matrix, ordering) label
    signal, mirroring the paper's MUMPS timings.

    Passing a precomputed ``sym`` (e.g. from a cached
    :class:`repro.core.plan.ExecutionPlan`) skips the symbolic stage
    entirely; ``t_symbolic`` is then reported as 0.
    """
    if b is None:
        rng = np.random.default_rng(0)
        b = rng.standard_normal(a.n)
    if sym is None:
        t0 = time.perf_counter()
        sym = symbolic_cholesky(a)
        t_sym = time.perf_counter() - t0
    else:
        t_sym = 0.0
    t0 = time.perf_counter()
    f = multifrontal_cholesky(a, sym)
    t_fac = time.perf_counter() - t0
    t0 = time.perf_counter()
    x = multifrontal_solve(f, b)
    t_sol = time.perf_counter() - t0
    resid = float(np.linalg.norm(a.matvec(x) - b) / max(np.linalg.norm(b), 1e-30))
    return dict(time=t_sym + t_fac + t_sol, t_symbolic=t_sym, t_factor=t_fac,
                t_solve=t_sol, residual=resid, **f.stats)
