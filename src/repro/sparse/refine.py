"""Mixed-precision iterative refinement for the direct solve.

The classic trick [Wilkinson 1963; Carson & Higham 2018]: factor once in low
precision (fp32 — half the memory traffic, double the MXU rate), then recover
working-precision accuracy with a short residual-correction loop in fp64:

    x₀ = L⁻ᵀ L⁻¹ b           (low-precision factor)
    rᵢ = b − A xᵢ            (fp64 sparse matvec — cheap, O(nnz))
    xᵢ₊₁ = xᵢ + L⁻ᵀ L⁻¹ rᵢ

Each sweep multiplies the error by ~κ(A)·ε₃₂, so a handful of iterations
reaches the fp64 floor whenever κ(A) ≪ 1/ε₃₂. The loop is
residual-controlled: it stops at ``tol``, at ``max_iter``, or when progress
stalls (guards ill-conditioned systems against cycling forever).

Two drivers share those stopping rules:

* :func:`refine_solve` — host loop around caller-supplied ``matvec`` /
  ``solve`` closures (any backend, any sweep mode).
* :func:`refine_solve_device` — the device-resident loop for
  ``sweep="device"``: x, r, and the factor stacks stay in device memory,
  the fp64 residual matvec runs through the block-ELL SpMV kernel
  (:mod:`repro.kernels.spmv_bell`), and the only host↔device traffic per
  iteration is the residual-norm scalar. fp64 on device needs the x64
  context (CPU interpret / CI); on an f64-less accelerator the residual
  falls back to f32 and the loop simply stalls out earlier.

This is what makes the fp32 ``batched``/``pallas`` factorization backends of
:mod:`repro.sparse.multifrontal` usable as drop-in replacements for the fp64
numpy path: ``EngineConfig.solve_dtype = "fp32_refine"``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, List, Optional, Tuple

import numpy as np

__all__ = ["RefineInfo", "refine_solve", "refine_solve_device",
           "DEFAULT_TOL"]

DEFAULT_TOL = 1e-12
_STALL_FACTOR = 0.5   # require ≥ 2× residual reduction per sweep to continue


@dataclasses.dataclass
class RefineInfo:
    iterations: int          # correction sweeps applied (0 = first solve enough)
    residuals: List[float]   # relative residual after each evaluation
    converged: bool
    # where the solve-phase wall time went: triangular sweeps vs residual
    # evaluation (on the device loop the residual timer includes the one
    # scalar sync per iteration, where queued sweep work completes)
    t_sweep: float = 0.0
    t_residual: float = 0.0

    @property
    def final_residual(self) -> float:
        return self.residuals[-1] if self.residuals else float("inf")


def _should_stop(residuals: List[float], tol: float, iters: int,
                 max_iter: int) -> Tuple[bool, bool]:
    """(stop, converged) under the shared stopping rules: tolerance
    reached, iteration budget spent, or progress stalled (conditioning
    beyond what low-precision corrections can fix)."""
    rel = residuals[-1]
    if rel <= tol:
        return True, True
    if iters >= max_iter:
        return True, False
    if len(residuals) >= 2 and rel > _STALL_FACTOR * residuals[-2]:
        return True, False
    return False, False


def refine_solve(matvec: Callable[[np.ndarray], np.ndarray],
                 solve: Callable[[np.ndarray], np.ndarray],
                 b: np.ndarray, *,
                 tol: float = DEFAULT_TOL,
                 max_iter: int = 10) -> tuple[np.ndarray, RefineInfo]:
    """Solve A x = b to fp64 accuracy using a low-precision inner solver.

    ``matvec`` must be the fp64 operator of A; ``solve`` is the (possibly
    low-precision) factorization solve applied to an fp64 right-hand side.
    ``b`` may be ``(n,)`` or an ``(n, k)`` RHS block (both closures must
    then accept blocks; the residual norm is Frobenius over the block).
    Returns ``(x, RefineInfo)``.
    """
    pc = time.perf_counter
    b = np.asarray(b, dtype=np.float64)
    nb = float(np.linalg.norm(b))
    if nb == 0.0:
        return np.zeros_like(b), RefineInfo(0, [0.0], True)
    t0 = pc()
    x = np.asarray(solve(b), dtype=np.float64)
    t_sweep = pc() - t0
    residuals: List[float] = []
    iters = 0
    t_res = 0.0
    while True:
        t0 = pc()
        r = b - np.asarray(matvec(x), dtype=np.float64)
        rel = float(np.linalg.norm(r)) / nb
        t_res += pc() - t0
        residuals.append(rel)
        stop, ok = _should_stop(residuals, tol, iters, max_iter)
        if stop:
            return x, RefineInfo(iters, residuals, ok, t_sweep, t_res)
        t0 = pc()
        x = x + np.asarray(solve(r), dtype=np.float64)
        t_sweep += pc() - t0
        iters += 1


def _jax_x64():
    """The ``enable_x64`` context manager when this jax build has it, else
    a no-op context (residual math then runs in f32 and the stall guard
    ends the loop at the f32 floor)."""
    try:
        from jax.experimental import enable_x64
        return enable_x64()
    except ImportError:  # pragma: no cover - old jax
        import contextlib
        return contextlib.nullcontext()


@functools.cache
def _residual_dev_fn():
    """jit'd device residual step: r = b − A x (block-ELL SpMV) and ‖r‖."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.spmv_bell import bell_spmv

    @functools.partial(jax.jit, static_argnames=("interpret",))
    def step(blocks, idx, x, bp, interpret):
        r = bp - bell_spmv(blocks, idx, x, interpret=interpret)
        return r, jnp.linalg.norm(r)

    return step


def refine_solve_device(a, f, b: np.ndarray, *,
                        tol: float = DEFAULT_TOL, max_iter: int = 10,
                        sweep_bs: Optional[int] = None,
                        rt: Optional[int] = None,
                        spmv_bs: int = 8) -> tuple[np.ndarray, RefineInfo]:
    """Device-resident refinement for the ``sweep="device"`` solve path.

    ``a`` is the (permuted) fp64 :class:`repro.sparse.csr.CSRMatrix`, ``f``
    the schedule-carrying :class:`~repro.sparse.multifrontal.
    MultifrontalFactor`. The solution and residual live on device for the
    whole loop: the correction solve is the batched-Pallas sweep pass on
    the resident factor stacks, the residual matvec is the block-ELL SpMV
    kernel over fp64 blocks (converted from CSR once), and the only
    per-iteration host↔device traffic is the residual-norm scalar — the
    ``float()`` that also serves as the sync point for the level-bucket
    dispatches queued by the sweep. Stopping rules (tol / max_iter /
    stall) are shared with :func:`refine_solve`. ``b``: ``(n,)`` or
    ``(n, k)``; returns ``(x fp64 host, RefineInfo)``.
    """
    import jax.numpy as jnp

    from repro.kernels.ops import _interpret
    from repro.kernels.spmv_bell import csr_to_bell
    from repro.sparse.multifrontal import _device_sweep_passes

    pc = time.perf_counter
    b = np.asarray(b, dtype=np.float64)
    single = b.ndim == 1
    b2 = b[:, None] if single else b
    n, k = b2.shape
    nb = float(np.linalg.norm(b2))
    if nb == 0.0:
        return np.zeros_like(b), RefineInfo(0, [0.0], True)
    blocks, idx, npad = csr_to_bell(a.indptr, a.indices, a.data, n,
                                    bs=spmv_bs)
    interp = _interpret()
    residual_step = _residual_dev_fn()

    def sweep(r32):
        """f32 sweep pass on a device (n, k) block → device (n, k) f32."""
        x = jnp.zeros((n + 1, k), jnp.float32).at[:n].set(r32)
        return _device_sweep_passes(f, x, sweep_bs=sweep_bs, rt=rt)[:n]

    with _jax_x64():
        blocks_d = jnp.asarray(blocks)                   # fp64 ELL blocks
        idx_d = jnp.asarray(idx)
        bp = jnp.zeros((npad, k)).at[:n].set(jnp.asarray(b2))
        t0 = pc()
        dx = sweep(jnp.asarray(b2.astype(np.float32)))
        x = jnp.zeros((npad, k)).at[:n].set(dx.astype(bp.dtype))
        t_sweep = pc() - t0
        residuals: List[float] = []
        iters = 0
        t_res = 0.0
        while True:
            t0 = pc()
            r, nrm = residual_step(blocks_d, idx_d, x, bp, interp)
            rel = float(nrm) / nb       # the one per-iteration scalar sync
            t_res += pc() - t0
            residuals.append(rel)
            stop, ok = _should_stop(residuals, tol, iters, max_iter)
            if stop:
                break
            t0 = pc()
            dx = sweep(r[:n].astype(jnp.float32))
            x = x.at[:n].add(dx.astype(bp.dtype))
            t_sweep += pc() - t0
            iters += 1
        out = np.asarray(x[:n], dtype=np.float64)
    return (out[:, 0] if single else out,
            RefineInfo(iters, residuals, ok, t_sweep, t_res))
