"""Mixed-precision iterative refinement for the direct solve.

The classic trick [Wilkinson 1963; Carson & Higham 2018]: factor once in low
precision (fp32 — half the memory traffic, double the MXU rate), then recover
working-precision accuracy with a short residual-correction loop in fp64:

    x₀ = L⁻ᵀ L⁻¹ b           (low-precision factor)
    rᵢ = b − A xᵢ            (fp64 sparse matvec — cheap, O(nnz))
    xᵢ₊₁ = xᵢ + L⁻ᵀ L⁻¹ rᵢ

Each sweep multiplies the error by ~κ(A)·ε₃₂, so a handful of iterations
reaches the fp64 floor whenever κ(A) ≪ 1/ε₃₂. The loop is
residual-controlled: it stops at ``tol``, at ``max_iter``, or when progress
stalls (guards ill-conditioned systems against cycling forever).

This is what makes the fp32 ``batched``/``pallas`` factorization backends of
:mod:`repro.sparse.multifrontal` usable as drop-in replacements for the fp64
numpy path: ``EngineConfig.solve_dtype = "fp32_refine"``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List

import numpy as np

__all__ = ["RefineInfo", "refine_solve", "DEFAULT_TOL"]

DEFAULT_TOL = 1e-12
_STALL_FACTOR = 0.5   # require ≥ 2× residual reduction per sweep to continue


@dataclasses.dataclass
class RefineInfo:
    iterations: int          # correction sweeps applied (0 = first solve enough)
    residuals: List[float]   # relative residual after each evaluation
    converged: bool

    @property
    def final_residual(self) -> float:
        return self.residuals[-1] if self.residuals else float("inf")


def refine_solve(matvec: Callable[[np.ndarray], np.ndarray],
                 solve: Callable[[np.ndarray], np.ndarray],
                 b: np.ndarray, *,
                 tol: float = DEFAULT_TOL,
                 max_iter: int = 10) -> tuple[np.ndarray, RefineInfo]:
    """Solve A x = b to fp64 accuracy using a low-precision inner solver.

    ``matvec`` must be the fp64 operator of A; ``solve`` is the (possibly
    low-precision) factorization solve applied to an fp64 right-hand side.
    Returns ``(x, RefineInfo)``.
    """
    b = np.asarray(b, dtype=np.float64)
    nb = float(np.linalg.norm(b))
    if nb == 0.0:
        return np.zeros_like(b), RefineInfo(0, [0.0], True)
    x = np.asarray(solve(b), dtype=np.float64)
    residuals: List[float] = []
    iters = 0
    while True:
        r = b - np.asarray(matvec(x), dtype=np.float64)
        rel = float(np.linalg.norm(r)) / nb
        residuals.append(rel)
        if rel <= tol:
            return x, RefineInfo(iters, residuals, True)
        if iters >= max_iter:
            return x, RefineInfo(iters, residuals, False)
        if len(residuals) >= 2 and rel > _STALL_FACTOR * residuals[-2]:
            # stalled: conditioning beyond what fp32 corrections can fix
            return x, RefineInfo(iters, residuals, False)
        x = x + np.asarray(solve(r), dtype=np.float64)
        iters += 1
