"""Compressed-sparse-row container and structural utilities.

This is the substrate the paper's pipeline operates on: everything —
reordering, symbolic analysis, numeric factorization, feature extraction —
consumes :class:`CSRMatrix`.

Host-side structure manipulation is vectorized numpy (int32 indices);
numeric payloads convert to JAX arrays at the solver boundary
(`repro.sparse.numeric` / `repro.sparse.multifrontal`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "CSRMatrix",
    "coo_to_csr",
    "csr_from_dense",
    "bandwidth",
    "profile",
    "permute_symmetric",
    "symmetrize_pattern",
    "make_spd",
]


@dataclasses.dataclass
class CSRMatrix:
    """Square sparse matrix in CSR format.

    indptr:  (n+1,) int32
    indices: (nnz,) int32 column indices, sorted within each row
    data:    (nnz,) float64 values (may be None for pattern-only matrices)
    """

    indptr: np.ndarray
    indices: np.ndarray
    data: Optional[np.ndarray]
    shape: Tuple[int, int]
    name: str = ""
    group: str = ""

    # -- basic properties -------------------------------------------------
    @property
    def n(self) -> int:
        return self.shape[0]

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def row(self, i: int) -> np.ndarray:
        return self.indices[self.indptr[i] : self.indptr[i + 1]]

    def row_values(self, i: int) -> np.ndarray:
        assert self.data is not None
        return self.data[self.indptr[i] : self.indptr[i + 1]]

    def row_lengths(self) -> np.ndarray:
        return np.diff(self.indptr)

    def copy(self) -> "CSRMatrix":
        return CSRMatrix(
            self.indptr.copy(),
            self.indices.copy(),
            None if self.data is None else self.data.copy(),
            self.shape,
            self.name,
            self.group,
        )

    # -- conversions ------------------------------------------------------
    def to_dense(self) -> np.ndarray:
        n, m = self.shape
        out = np.zeros((n, m), dtype=np.float64)
        rows = np.repeat(np.arange(n), self.row_lengths())
        out[rows, self.indices] = 1.0 if self.data is None else self.data
        return out

    def to_coo(self) -> Tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]:
        rows = np.repeat(np.arange(self.n, dtype=np.int32), self.row_lengths())
        return rows, self.indices.copy(), None if self.data is None else self.data.copy()

    def transpose(self) -> "CSRMatrix":
        rows, cols, data = self.to_coo()
        return coo_to_csr(cols, rows, data, self.shape[::-1], self.name, self.group)

    # -- structural predicates ---------------------------------------------
    def is_structurally_symmetric(self) -> bool:
        t = self.transpose()
        return (
            np.array_equal(self.indptr, t.indptr)
            and np.array_equal(self.indices, t.indices)
        )

    def has_full_diagonal(self) -> bool:
        for i in range(self.n):
            if i not in self.row(i):
                return False
        return True

    # -- arithmetic helpers (host side; the device path lives in kernels/) --
    def matvec(self, x: np.ndarray) -> np.ndarray:
        """A @ x for a single RHS ``(n,)`` or an RHS block ``(n, k)``."""
        assert self.data is not None
        rows = np.repeat(np.arange(self.n), self.row_lengths())
        if x.ndim == 1:
            out = np.zeros(self.n, dtype=np.result_type(self.data, x))
            np.add.at(out, rows, self.data * x[self.indices])
        else:
            out = np.zeros((self.n, x.shape[1]),
                           dtype=np.result_type(self.data, x))
            np.add.at(out, rows, self.data[:, None] * x[self.indices])
        return out


def coo_to_csr(
    rows: np.ndarray,
    cols: np.ndarray,
    data: Optional[np.ndarray],
    shape: Tuple[int, int],
    name: str = "",
    group: str = "",
    sum_duplicates: bool = True,
) -> CSRMatrix:
    """Build CSR from COO triplets; sorts columns within rows, merges dups."""
    n = shape[0]
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    order = np.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    vals = None if data is None else np.asarray(data, dtype=np.float64)[order]
    if rows.size and sum_duplicates:
        keep = np.ones(rows.size, dtype=bool)
        keep[1:] = (rows[1:] != rows[:-1]) | (cols[1:] != cols[:-1])
        if not keep.all():
            if vals is not None:
                seg = np.cumsum(keep) - 1
                summed = np.zeros(int(seg[-1]) + 1, dtype=np.float64)
                np.add.at(summed, seg, vals)
                vals = summed
            rows, cols = rows[keep], cols[keep]
    indptr = np.zeros(n + 1, dtype=np.int32)
    np.add.at(indptr, rows.astype(np.int64) + 1, 1)
    indptr = np.cumsum(indptr, dtype=np.int64).astype(np.int32)
    return CSRMatrix(indptr, cols.astype(np.int32), vals, shape, name, group)


def csr_from_dense(a: np.ndarray, name: str = "", group: str = "") -> CSRMatrix:
    rows, cols = np.nonzero(a)
    return coo_to_csr(rows, cols, a[rows, cols], a.shape, name, group)


# ---------------------------------------------------------------------------
# Bandwidth / profile — the paper's two headline features (Eq. 2, Eq. 3).
# ---------------------------------------------------------------------------

def bandwidth(a: CSRMatrix) -> int:
    """Bandwidth = max_{a_ij != 0} |i - j|   (paper Eq. 2)."""
    if a.nnz == 0:
        return 0
    rows = np.repeat(np.arange(a.n, dtype=np.int64), a.row_lengths())
    return int(np.abs(rows - a.indices.astype(np.int64)).max())


def profile(a: CSRMatrix) -> int:
    """Profile = sum_i (i - min{j : a_ij != 0})   (paper Eq. 3).

    Rows with no entry left of (or on) the diagonal contribute 0, matching
    the skyline-storage interpretation the metric comes from.
    """
    total = 0
    indptr, indices = a.indptr, a.indices
    for i in range(a.n):
        lo, hi = indptr[i], indptr[i + 1]
        if hi > lo:
            jmin = int(indices[lo])  # columns sorted ascending
            if jmin < i:
                total += i - jmin
    return int(total)


# ---------------------------------------------------------------------------
# Permutation  B = P A Pᵀ  with  B[k, l] = A[perm[k], perm[l]].
# `perm` lists old indices in new order (perm[new] = old), the convention
# used by every reordering routine in repro.sparse.reorder.
# ---------------------------------------------------------------------------

def permute_symmetric(a: CSRMatrix, perm: np.ndarray) -> CSRMatrix:
    n = a.n
    perm = np.asarray(perm, dtype=np.int64)
    assert perm.shape == (n,)
    inv = np.empty(n, dtype=np.int64)
    inv[perm] = np.arange(n)
    rows, cols, data = a.to_coo()
    return coo_to_csr(inv[rows], inv[cols], data, a.shape, a.name, a.group,
                      sum_duplicates=False)


def symmetrize_pattern(a: CSRMatrix) -> CSRMatrix:
    """Pattern of A + Aᵀ (values summed where both exist)."""
    r1, c1, d1 = a.to_coo()
    rows = np.concatenate([r1, c1])
    cols = np.concatenate([c1, r1])
    data = None if d1 is None else np.concatenate([d1, d1]) * 0.5
    return coo_to_csr(rows, cols, data, a.shape, a.name, a.group)


def make_spd(a: CSRMatrix, shift: float = 1.0) -> CSRMatrix:
    """Return a symmetric positive-definite matrix with A's symmetrized
    pattern: |A|+|Aᵀ| off-diagonal, diagonally-dominant diagonal.

    This mirrors the paper's preprocessing (right-hand sides are synthetic;
    what matters for ordering studies is the *pattern*), and guarantees the
    Cholesky-based solvers succeed on every suite matrix.
    """
    s = symmetrize_pattern(a)
    rows, cols, data = s.to_coo()
    data = np.abs(data) if data is not None else np.ones(rows.shape[0])
    off = rows != cols
    rows, cols, data = rows[off], cols[off], -data[off]
    rowsum = np.zeros(s.n)
    np.add.at(rowsum, rows, -data)
    diag = rowsum + shift
    rows = np.concatenate([rows, np.arange(s.n)])
    cols = np.concatenate([cols, np.arange(s.n)])
    data = np.concatenate([data, diag])
    return coo_to_csr(rows, cols, data, a.shape, a.name, a.group)
