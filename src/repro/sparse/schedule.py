"""Level scheduling of the supernodal assembly tree.

The multifrontal factorization is a postorder traversal of the assembly
tree, but the *only* true dependency is child → parent (a parent front
extend-adds its children's Schur complements). Grouping fronts by tree
**level** — ``level(k) = 1 + max(level(children))``, leaves at 0 — yields
batches of mutually independent fronts: two fronts at the same level can
never be ancestor/descendant, so every front of a level can be partially
factored in one batched device call. That turns the numeric phase from
``nsup`` host→device round trips into ``nlevels × nbuckets`` batched
kernel launches (:func:`repro.kernels.ops.frontal_factor_batch_ws`).

Fronts within a level are **size-bucketed**: each front's pivot count and
update-row count are padded up (min ``MIN_PAD``) and fronts sharing a
padded shape form one batch. Pivot padding columns are decoupled identity
columns (they factor to 1 and contribute nothing); update-row padding is
zero rows. Bucketing bounds both the wasted FLOPs and the number of
distinct compiled kernel shapes — the trade-off between the two is the
**pad policy**:

* ``"pow2"`` (default) — next power of two: few compiled shapes, up to 4×
  padded FLOPs in the worst case.
* ``"mult8"`` — next multiple of 8: tighter occupancy (≤ ~2× waste on tiny
  fronts, far less on big ones) at the cost of more distinct shapes.

The right choice is device-dependent (compile cost vs wasted FLOPs), which
is why :mod:`repro.autotune.solve_tuner` measures it instead of hardcoding;
``occupancy`` / ``per_level_occupancy`` in :meth:`LevelSchedule.stats`
report the realized waste, per level so a bad pad choice on one wide level
is not averaged away.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from .symbolic import SymbolicFactor, supernodes

__all__ = ["FrontPlan", "Bucket", "LevelSchedule", "build_schedule",
           "front_flops", "PAD_POLICIES"]

MIN_PAD = 8

#: recognized bucket pad policies (the autotuned knob)
PAD_POLICIES = ("pow2", "mult8")


def _pad_dim(x: int, pad: str = "pow2") -> int:
    """Padded bucket dim ≥ x (0 stays 0; floor at MIN_PAD): next power of
    two under ``"pow2"``, next multiple of 8 under ``"mult8"``."""
    if x <= 0:
        return 0
    if pad == "mult8":
        return max(MIN_PAD, (int(x) + 7) // 8 * 8)
    if pad != "pow2":
        raise ValueError(f"unknown pad policy {pad!r} (want one of "
                         f"{PAD_POLICIES})")
    return max(MIN_PAD, 1 << (int(x) - 1).bit_length())


def front_flops(npiv: int, nrest: int) -> int:
    """Dense partial-factorization FLOPs of one front (chol + panel + Schur)."""
    return npiv * npiv * npiv // 3 + npiv * npiv * nrest + npiv * nrest * nrest


@dataclasses.dataclass
class FrontPlan:
    """Structure of one front, known before any numeric work."""

    k: int                   # supernode index (postorder position)
    c0: int                  # first pivot column
    c1: int                  # one past last pivot column
    rows: np.ndarray         # global row indices (sorted; first npiv = pivots)
    parent: int              # parent supernode, -1 for roots
    level: int               # assembly-tree level (leaves = 0)

    @property
    def npiv(self) -> int:
        return self.c1 - self.c0

    @property
    def m(self) -> int:
        return int(self.rows.shape[0])

    @property
    def nrest(self) -> int:
        return self.m - self.npiv

    @property
    def flops(self) -> int:
        return front_flops(self.npiv, self.nrest)


@dataclasses.dataclass
class Bucket:
    """Fronts of one level sharing a padded (pivot, rest) shape."""

    P: int                   # padded pivot dim (power of two ≥ MIN_PAD)
    R: int                   # padded update-row dim (power of two or 0)
    members: List[int]       # supernode indices

    @property
    def M(self) -> int:
        return self.P + self.R


@dataclasses.dataclass
class LevelSchedule:
    """Batched execution order for the numeric phase."""

    nsup: int
    fronts: List[FrontPlan]
    levels: List[np.ndarray]          # supernode ids per level, ascending
    buckets: List[List[Bucket]]       # per level, the size buckets
    pad: str = "pow2"                 # pad policy the buckets were built with

    @property
    def nlevels(self) -> int:
        return len(self.levels)

    def sweep_flops(self, k: int = 1) -> int:
        """FLOPs of one forward+backward triangular sweep over ``k`` RHS
        columns: per front, two npiv² triangular solves plus the L21 scatter
        and gather GEMVs (2·npiv·nrest each), per column."""
        return k * int(sum(2 * fp.npiv * fp.npiv + 4 * fp.npiv * fp.nrest
                           for fp in self.fronts))

    def stats(self) -> dict:
        widths = [len(lv) for lv in self.levels]
        # occupancy per level: true front cells / padded workspace cells of
        # that level's buckets — the global ratio hides a badly padded wide
        # level behind many well-packed small ones
        per_level: List[float] = []
        for li, lvl_buckets in enumerate(self.buckets):
            t = sum(self.fronts[int(k)].m ** 2 for k in self.levels[li])
            p = sum(b.M * b.M * len(b.members) for b in lvl_buckets)
            per_level.append(t / p if p else 1.0)
        true_cells = sum(fp.m * fp.m for fp in self.fronts)
        pad_cells = sum(b.M * b.M * len(b.members)
                        for lvl in self.buckets for b in lvl)
        nbatches = sum(len(lvl) for lvl in self.buckets)
        return dict(
            nsup=self.nsup,
            nlevels=self.nlevels,
            max_level_width=max(widths, default=0),
            mean_level_width=float(np.mean(widths)) if widths else 0.0,
            nbatches=nbatches,
            occupancy=true_cells / pad_cells if pad_cells else 1.0,
            per_level_occupancy=per_level,
            min_level_occupancy=min(per_level, default=1.0),
            pad=self.pad,
            front_flops=int(sum(fp.flops for fp in self.fronts)),
        )


def front_rows(sym: SymbolicFactor, c0: int, c1: int) -> np.ndarray:
    """Row structure of the front for pivot columns [c0, c1): the union of
    the columns' factor patterns, restricted to rows ≥ c0 (sorted, so the
    npiv pivot rows come first)."""
    Lp, Li = sym.Lp, sym.Li
    pats = [Li[Lp[j] : Lp[j + 1]] for j in range(c0, c1)]
    rows = np.unique(np.concatenate(pats))
    return rows[rows >= c0]


def build_schedule(sym: SymbolicFactor,
                   snode_ptr: np.ndarray | None = None,
                   snode_of: np.ndarray | None = None,
                   relax: int = 8, pad: str = "pow2") -> LevelSchedule:
    """Front structures + parent links + levels + size buckets.

    ``snode_ptr``/``snode_of`` may be passed to reuse an existing supernode
    partition; otherwise :func:`repro.sparse.symbolic.supernodes` is called
    with ``relax``. ``pad`` picks the bucket pad policy (see module doc).
    """
    if snode_ptr is None or snode_of is None:
        snode_ptr, snode_of = supernodes(sym, relax=relax)
    nsup = int(snode_ptr.shape[0]) - 1
    fronts: List[FrontPlan] = []
    for k in range(nsup):
        c0, c1 = int(snode_ptr[k]), int(snode_ptr[k + 1])
        rows = front_rows(sym, c0, c1)
        npiv = c1 - c0
        # parent = supernode owning the first update row (None for roots)
        parent = int(snode_of[int(rows[npiv])]) if rows.shape[0] > npiv else -1
        fronts.append(FrontPlan(k, c0, c1, rows, parent, 0))

    # levels: children always precede parents in supernode order (a parent's
    # first column is past every child pivot), so one ascending pass works
    for fp in fronts:
        if fp.parent >= 0:
            pf = fronts[fp.parent]
            pf.level = max(pf.level, fp.level + 1)
    nlevels = max((fp.level for fp in fronts), default=-1) + 1
    levels = [np.array([fp.k for fp in fronts if fp.level == li],
                       dtype=np.int64) for li in range(nlevels)]

    # size buckets per level
    buckets: List[List[Bucket]] = []
    for lv in levels:
        by_shape: Dict[Tuple[int, int], List[int]] = {}
        for k in lv:
            fp = fronts[int(k)]
            key = (_pad_dim(fp.npiv, pad), _pad_dim(fp.nrest, pad))
            by_shape.setdefault(key, []).append(int(k))
        buckets.append([Bucket(P, R, members)
                        for (P, R), members in sorted(by_shape.items())])
    return LevelSchedule(nsup, fronts, levels, buckets, pad=pad)
