"""Level scheduling of the supernodal assembly tree.

The multifrontal factorization is a postorder traversal of the assembly
tree, but the *only* true dependency is child → parent (a parent front
extend-adds its children's Schur complements). Grouping fronts by tree
**level** — ``level(k) = 1 + max(level(children))``, leaves at 0 — yields
batches of mutually independent fronts: two fronts at the same level can
never be ancestor/descendant, so every front of a level can be partially
factored in one batched device call. That turns the numeric phase from
``nsup`` host→device round trips into ``nlevels × nbuckets`` batched
kernel launches (:func:`repro.kernels.ops.frontal_factor_batch_ws`).

Fronts within a level are **size-bucketed**: each front's pivot count and
update-row count are padded up to the next power of two (min ``MIN_PAD``)
and fronts sharing a padded shape form one batch. Pivot padding columns
are decoupled identity columns (they factor to 1 and contribute nothing);
update-row padding is zero rows. Bucketing bounds both the wasted FLOPs
(< 4× in the worst case, far less in practice — see ``occupancy`` in
:meth:`LevelSchedule.stats`) and the number of distinct compiled kernel
shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from .symbolic import SymbolicFactor, supernodes

__all__ = ["FrontPlan", "Bucket", "LevelSchedule", "build_schedule",
           "front_flops"]

MIN_PAD = 8


def _pad_dim(x: int) -> int:
    """Next power of two ≥ x (0 stays 0; floor at MIN_PAD)."""
    if x <= 0:
        return 0
    return max(MIN_PAD, 1 << (int(x) - 1).bit_length())


def front_flops(npiv: int, nrest: int) -> int:
    """Dense partial-factorization FLOPs of one front (chol + panel + Schur)."""
    return npiv * npiv * npiv // 3 + npiv * npiv * nrest + npiv * nrest * nrest


@dataclasses.dataclass
class FrontPlan:
    """Structure of one front, known before any numeric work."""

    k: int                   # supernode index (postorder position)
    c0: int                  # first pivot column
    c1: int                  # one past last pivot column
    rows: np.ndarray         # global row indices (sorted; first npiv = pivots)
    parent: int              # parent supernode, -1 for roots
    level: int               # assembly-tree level (leaves = 0)

    @property
    def npiv(self) -> int:
        return self.c1 - self.c0

    @property
    def m(self) -> int:
        return int(self.rows.shape[0])

    @property
    def nrest(self) -> int:
        return self.m - self.npiv

    @property
    def flops(self) -> int:
        return front_flops(self.npiv, self.nrest)


@dataclasses.dataclass
class Bucket:
    """Fronts of one level sharing a padded (pivot, rest) shape."""

    P: int                   # padded pivot dim (power of two ≥ MIN_PAD)
    R: int                   # padded update-row dim (power of two or 0)
    members: List[int]       # supernode indices

    @property
    def M(self) -> int:
        return self.P + self.R


@dataclasses.dataclass
class LevelSchedule:
    """Batched execution order for the numeric phase."""

    nsup: int
    fronts: List[FrontPlan]
    levels: List[np.ndarray]          # supernode ids per level, ascending
    buckets: List[List[Bucket]]       # per level, the size buckets

    @property
    def nlevels(self) -> int:
        return len(self.levels)

    def stats(self) -> dict:
        widths = [len(lv) for lv in self.levels]
        true_cells = sum(fp.m * fp.m for fp in self.fronts)
        pad_cells = sum(b.M * b.M * len(b.members)
                        for lvl in self.buckets for b in lvl)
        nbatches = sum(len(lvl) for lvl in self.buckets)
        return dict(
            nsup=self.nsup,
            nlevels=self.nlevels,
            max_level_width=max(widths, default=0),
            mean_level_width=float(np.mean(widths)) if widths else 0.0,
            nbatches=nbatches,
            occupancy=true_cells / pad_cells if pad_cells else 1.0,
            front_flops=int(sum(fp.flops for fp in self.fronts)),
        )


def front_rows(sym: SymbolicFactor, c0: int, c1: int) -> np.ndarray:
    """Row structure of the front for pivot columns [c0, c1): the union of
    the columns' factor patterns, restricted to rows ≥ c0 (sorted, so the
    npiv pivot rows come first)."""
    Lp, Li = sym.Lp, sym.Li
    pats = [Li[Lp[j] : Lp[j + 1]] for j in range(c0, c1)]
    rows = np.unique(np.concatenate(pats))
    return rows[rows >= c0]


def build_schedule(sym: SymbolicFactor,
                   snode_ptr: np.ndarray | None = None,
                   snode_of: np.ndarray | None = None,
                   relax: int = 8) -> LevelSchedule:
    """Front structures + parent links + levels + size buckets.

    ``snode_ptr``/``snode_of`` may be passed to reuse an existing supernode
    partition; otherwise :func:`repro.sparse.symbolic.supernodes` is called
    with ``relax``.
    """
    if snode_ptr is None or snode_of is None:
        snode_ptr, snode_of = supernodes(sym, relax=relax)
    nsup = int(snode_ptr.shape[0]) - 1
    fronts: List[FrontPlan] = []
    for k in range(nsup):
        c0, c1 = int(snode_ptr[k]), int(snode_ptr[k + 1])
        rows = front_rows(sym, c0, c1)
        npiv = c1 - c0
        # parent = supernode owning the first update row (None for roots)
        parent = int(snode_of[int(rows[npiv])]) if rows.shape[0] > npiv else -1
        fronts.append(FrontPlan(k, c0, c1, rows, parent, 0))

    # levels: children always precede parents in supernode order (a parent's
    # first column is past every child pivot), so one ascending pass works
    for fp in fronts:
        if fp.parent >= 0:
            pf = fronts[fp.parent]
            pf.level = max(pf.level, fp.level + 1)
    nlevels = max((fp.level for fp in fronts), default=-1) + 1
    levels = [np.array([fp.k for fp in fronts if fp.level == li],
                       dtype=np.int64) for li in range(nlevels)]

    # size buckets per level
    buckets: List[List[Bucket]] = []
    for lv in levels:
        by_shape: Dict[Tuple[int, int], List[int]] = {}
        for k in lv:
            fp = fronts[int(k)]
            key = (_pad_dim(fp.npiv), _pad_dim(fp.nrest))
            by_shape.setdefault(key, []).append(int(k))
        buckets.append([Bucket(P, R, members)
                        for (P, R), members in sorted(by_shape.items())])
    return LevelSchedule(nsup, fronts, levels, buckets)
