"""Synthetic "Florida-like" sparse matrix suite.

The paper uses the first 2000 matrices of the SuiteSparse/Florida collection,
filtered to square real matrices → 936 with recorded solve times. That
download is unavailable offline, so this module generates a suite with the
same *role*: ≥936 SPD systems spanning the structural families on which
different reordering algorithms win —

* 2D/3D grid Laplacians (FEM-like; nested dissection territory),
* long-thin grids and paths/rings (bandwidth/RCM territory),
* banded random matrices and randomly-permuted banded matrices (RCM recovers
  the band; fill-reducers don't),
* Erdős–Rényi random graphs and small-world rings (AMD territory),
* scale-free / preferential-attachment graphs (hub elimination: AMD/QAMD),
* block-arrow matrices (min-degree trivially optimal, RCM pathological),
* random planar triangulations (FEM meshes; ND/SCOTCH),
* circuit-like rectangular patterns symmetrized (irregular; mixed winners).

Every generator returns an SPD :class:`CSRMatrix` via :func:`make_spd`, so
all solvers succeed and orderings are compared on identical numerics, like
the paper's synthetic right-hand-side protocol.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterator, List

import numpy as np

from .csr import CSRMatrix, coo_to_csr, make_spd

__all__ = ["generate_suite", "GENERATORS", "suite_summary"]


def _sym(rows, cols, n, name, group) -> CSRMatrix:
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    a = coo_to_csr(np.concatenate([rows, cols]), np.concatenate([cols, rows]),
                   None, (n, n), name, group)
    return make_spd(a)


# --- generators -------------------------------------------------------------

def grid2d(p: int, q: int, name: str) -> CSRMatrix:
    idx = np.arange(p * q).reshape(p, q)
    r = [idx[:-1, :].ravel(), idx[:, :-1].ravel()]
    c = [idx[1:, :].ravel(), idx[:, 1:].ravel()]
    return _sym(np.concatenate(r), np.concatenate(c), p * q, name, "grid2d")


def grid3d(p: int, q: int, r_: int, name: str) -> CSRMatrix:
    idx = np.arange(p * q * r_).reshape(p, q, r_)
    r = [idx[:-1].ravel(), idx[:, :-1].ravel(), idx[:, :, :-1].ravel()]
    c = [idx[1:].ravel(), idx[:, 1:].ravel(), idx[:, :, 1:].ravel()]
    return _sym(np.concatenate(r), np.concatenate(c), p * q * r_, name, "grid3d")


def banded(n: int, band: int, density: float, rng, name: str) -> CSRMatrix:
    rows, cols = [], []
    for d in range(1, band + 1):
        m = n - d
        keep = rng.random(m) < density
        i = np.nonzero(keep)[0]
        rows.append(i)
        cols.append(i + d)
    return _sym(np.concatenate(rows), np.concatenate(cols), n, name, "banded")


def permuted_banded(n: int, band: int, density: float, rng, name: str) -> CSRMatrix:
    a = banded(n, band, density, rng, name)
    perm = rng.permutation(n)
    from .csr import permute_symmetric
    b = permute_symmetric(a, perm)
    b.name, b.group = name, "permuted-banded"
    return b


def erdos(n: int, avg_deg: float, rng, name: str) -> CSRMatrix:
    m = int(n * avg_deg / 2)
    rows = rng.integers(0, n, m)
    cols = rng.integers(0, n, m)
    keep = rows != cols
    return _sym(rows[keep], cols[keep], n, name, "random")


def smallworld(n: int, k: int, extra: float, rng, name: str) -> CSRMatrix:
    i = np.arange(n)
    rows = [np.concatenate([i] * k)]
    cols = [np.concatenate([(i + d) % n for d in range(1, k + 1)])]
    m = int(n * extra)
    rows.append(rng.integers(0, n, m))
    cols.append(rng.integers(0, n, m))
    r, c = np.concatenate(rows), np.concatenate(cols)
    keep = r != c
    return _sym(r[keep], c[keep], n, name, "smallworld")


def scalefree(n: int, m_attach: int, rng, name: str) -> CSRMatrix:
    """Barabási–Albert preferential attachment."""
    targets = list(range(m_attach))
    repeated: List[int] = list(range(m_attach))
    rows, cols = [], []
    for v in range(m_attach, n):
        for t in set(targets):
            rows.append(v)
            cols.append(t)
            repeated.extend([v, t])
        targets = [repeated[rng.integers(0, len(repeated))] for _ in range(m_attach)]
    return _sym(np.array(rows), np.array(cols), n, name, "scalefree")


def block_arrow(nblocks: int, bs: int, border: int, rng, name: str) -> CSRMatrix:
    n = nblocks * bs + border
    rows, cols = [], []
    for b in range(nblocks):
        base = b * bs
        i = np.arange(bs - 1) + base
        rows.append(i)
        cols.append(i + 1)
        # couple each block to the border
        bi = rng.integers(0, bs, max(1, bs // 2)) + base
        bj = rng.integers(nblocks * bs, n, max(1, bs // 2))
        rows.append(bi)
        cols.append(bj)
    i = np.arange(border - 1) + nblocks * bs
    rows.append(i)
    cols.append(i + 1)
    return _sym(np.concatenate(rows), np.concatenate(cols), n, name, "block-arrow")


def triangulation(npts: int, rng, name: str) -> CSRMatrix:
    from scipy.spatial import Delaunay
    pts = rng.random((npts, 2))
    tri = Delaunay(pts)
    s = tri.simplices
    rows = np.concatenate([s[:, 0], s[:, 1], s[:, 2]])
    cols = np.concatenate([s[:, 1], s[:, 2], s[:, 0]])
    return _sym(rows, cols, npts, name, "fem-tri")


def circuit_like(n: int, nnz_per_row: int, rng, name: str) -> CSRMatrix:
    """Asymmetric random pattern with a few dense rows, symmetrized —
    mimics circuit-simulation matrices (the lhr/ASIC-style entries)."""
    m = n * nnz_per_row
    rows = rng.integers(0, n, m)
    cols = np.minimum(rng.geometric(p=min(0.5, 8.0 / n), size=m) +
                      rng.integers(0, n, m), n - 1) % n
    ndense = max(1, n // 200)
    drows = rng.integers(0, n, ndense)
    extra_r = np.repeat(drows, n // 20)
    extra_c = rng.integers(0, n, extra_r.size)
    r = np.concatenate([rows, extra_r])
    c = np.concatenate([cols, extra_c])
    keep = r != c
    return _sym(r[keep], c[keep], n, name, "circuit")


def path_ring(n: int, ring: bool, name: str) -> CSRMatrix:
    i = np.arange(n - 1)
    rows, cols = [i], [i + 1]
    if ring:
        rows.append(np.array([n - 1]))
        cols.append(np.array([0]))
    return _sym(np.concatenate(rows), np.concatenate(cols), n, name, "path-ring")


GENERATORS: Dict[str, Callable] = {
    "grid2d": grid2d, "grid3d": grid3d, "banded": banded,
    "permuted-banded": permuted_banded, "random": erdos,
    "smallworld": smallworld, "scalefree": scalefree,
    "block-arrow": block_arrow, "fem-tri": triangulation,
    "circuit": circuit_like, "path-ring": path_ring,
}


def generate_suite(count: int = 960, seed: int = 0,
                   size_scale: float = 1.0) -> Iterator[CSRMatrix]:
    """Yield `count` matrices cycling over families with varied parameters.

    ``size_scale`` shrinks every instance (used by tests to run the full
    pipeline in seconds).
    """
    rng = np.random.default_rng(seed)
    k = 0
    while k < count:
        fam = k % 12
        s = 1 + (k // 12) % 8  # size tier 1..8
        sc = size_scale
        if fam == 0:
            p = max(3, int((6 + 7 * s) * sc))
            a = grid2d(p, p, f"grid2d_{k}")
        elif fam == 1:
            p = max(3, int((4 + 2 * s) * sc))
            a = grid3d(p, p, max(2, p // 2), f"grid3d_{k}")
        elif fam == 2:
            n = max(32, int((150 + 350 * s) * sc))
            a = banded(n, int(rng.integers(2, 6 + 3 * s)),
                       float(rng.uniform(0.4, 0.95)), rng, f"banded_{k}")
        elif fam == 3:
            n = max(32, int((150 + 300 * s) * sc))
            a = permuted_banded(n, int(rng.integers(2, 5 + 2 * s)),
                                float(rng.uniform(0.5, 0.95)), rng, f"pbanded_{k}")
        elif fam == 4:
            n = max(32, int((120 + 280 * s) * sc))
            a = erdos(n, float(rng.uniform(2.0, 5.0)), rng, f"random_{k}")
        elif fam == 5:
            n = max(32, int((150 + 300 * s) * sc))
            a = smallworld(n, int(rng.integers(1, 4)),
                           float(rng.uniform(0.05, 0.4)), rng, f"smallworld_{k}")
        elif fam == 6:
            n = max(32, int((120 + 260 * s) * sc))
            a = scalefree(n, int(rng.integers(1, 4)), rng, f"scalefree_{k}")
        elif fam == 7:
            nb = max(2, int(3 + s))
            a = block_arrow(nb, max(8, int(25 * sc * s)),
                            max(4, int(10 * sc * s)), rng, f"arrow_{k}")
        elif fam == 8:
            n = max(32, int((150 + 350 * s) * sc))
            a = triangulation(n, rng, f"femtri_{k}")
        elif fam == 9:
            n = max(48, int((150 + 300 * s) * sc))
            a = circuit_like(n, int(rng.integers(2, 5)), rng, f"circuit_{k}")
        elif fam == 10:
            n = max(32, int((200 + 500 * s) * sc))
            a = path_ring(n, bool(k % 2), f"pathring_{k}")
        else:
            # long thin grid: RCM/banded-solver friendly
            p = max(2, int(4 * sc))
            q = max(16, int((60 + 150 * s) * sc))
            a = grid2d(p, q, f"thin_{k}")
            a.group = "thin-grid"
        yield a
        k += 1


def suite_summary(mats: List[CSRMatrix]) -> dict:
    import collections
    by_group = collections.Counter(m.group for m in mats)
    return dict(count=len(mats), groups=dict(by_group),
                n_min=min(m.n for m in mats), n_max=max(m.n for m in mats),
                nnz_max=max(m.nnz for m in mats))
