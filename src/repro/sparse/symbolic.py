"""Symbolic Cholesky analysis: elimination tree, factor pattern, column
counts, fill-in and factorization FLOPs — all without numeric work.

These quantities are the *cost model* behind the paper's experiments: a
reordering is good exactly when it makes ``nnz(L)`` / factor FLOPs small
(fill-reducing orderings) or the envelope small (bandwidth-reducing
orderings + skyline solvers).

Algorithms:
* ``etree``          — Liu's elimination-tree algorithm with path compression.
* ``postorder``      — DFS postorder of the etree.
* ``column_counts``  — row-subtree traversal (O(|L|)): exact nnz per column
                       of the Cholesky factor.
* ``symbolic_cholesky`` — full factor pattern per column (CSC of L).
* ``supernodes``     — fundamental supernodes + relaxed amalgamation for the
                       multifrontal solver.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from .csr import CSRMatrix

__all__ = [
    "etree", "postorder", "column_counts", "fill_in", "cholesky_flops",
    "symbolic_cholesky", "supernodes", "SymbolicFactor",
]


def _lower_rows(a: CSRMatrix):
    """Iterate (i, cols<i) for the strict lower triangle, rows ascending."""
    indptr, indices = a.indptr, a.indices
    for i in range(a.n):
        row = indices[indptr[i] : indptr[i + 1]]
        yield i, row[row < i]


def etree(a: CSRMatrix) -> np.ndarray:
    """Elimination tree of a symmetric matrix (parent[j] = -1 for roots)."""
    n = a.n
    parent = np.full(n, -1, dtype=np.int64)
    ancestor = np.full(n, -1, dtype=np.int64)
    for i, cols in _lower_rows(a):
        for j in cols:
            j = int(j)
            # Walk up with path compression until reaching i's subtree.
            while j != -1 and j < i:
                nxt = ancestor[j]
                ancestor[j] = i
                if nxt == -1:
                    parent[j] = i
                j = int(nxt)
    return parent


def postorder(parent: np.ndarray) -> np.ndarray:
    """Postorder of the forest given by `parent` (children visited first)."""
    n = parent.shape[0]
    # children lists
    head = np.full(n, -1, dtype=np.int64)
    nxt = np.full(n, -1, dtype=np.int64)
    for v in range(n - 1, -1, -1):
        p = parent[v]
        if p >= 0:
            nxt[v] = head[p]
            head[p] = v
    out = np.empty(n, dtype=np.int64)
    k = 0
    stack: List[int] = []
    for root in range(n):
        if parent[root] != -1:
            continue
        stack.append(root)
        while stack:
            v = stack[-1]
            c = head[v]
            if c == -1:
                stack.pop()
                out[k] = v
                k += 1
            else:
                head[v] = nxt[c]
                stack.append(int(c))
    assert k == n
    return out


def column_counts(a: CSRMatrix, parent: np.ndarray | None = None) -> np.ndarray:
    """nnz of each column of L **including** the diagonal.

    Row-subtree method: the pattern of L's row i is the union of etree paths
    from each j (A_ij ≠ 0, j < i) up toward i. Each first visit of a column
    j on such a path contributes one entry L_ij. O(|L|) total.
    """
    n = a.n
    if parent is None:
        parent = etree(a)
    counts = np.ones(n, dtype=np.int64)  # the diagonal
    mark = np.full(n, -1, dtype=np.int64)
    for i, cols in _lower_rows(a):
        mark[i] = i
        for j in cols:
            j = int(j)
            while j != -1 and mark[j] != i:
                mark[j] = i
                counts[j] += 1
                j = int(parent[j])
    return counts


def fill_in(a: CSRMatrix) -> int:
    """Number of factor entries that are NOT in the lower triangle of A."""
    counts = column_counts(a)
    nnz_lower = sum(cols.size for _, cols in _lower_rows(a)) + a.n
    return int(counts.sum()) - nnz_lower


def cholesky_flops(a: CSRMatrix, counts: np.ndarray | None = None) -> int:
    """Factorization FLOPs: Σ_j (1 sqrt + c_j div + c_j(c_j+1) update),
    with c_j = off-diagonal count of column j."""
    if counts is None:
        counts = column_counts(a)
    c = counts.astype(np.int64) - 1
    return int((1 + c + c * (c + 1)).sum())


@dataclasses.dataclass
class SymbolicFactor:
    parent: np.ndarray          # etree
    counts: np.ndarray          # per-column nnz of L (incl. diagonal)
    Lp: np.ndarray              # CSC indptr of L pattern
    Li: np.ndarray              # CSC row indices of L pattern (diag first)
    flops: int
    fill: int

    @property
    def nnz_L(self) -> int:
        return int(self.Li.shape[0])


def symbolic_cholesky(a: CSRMatrix) -> SymbolicFactor:
    """Full column-wise pattern of L (rows sorted ascending per column)."""
    n = a.n
    parent = etree(a)
    counts = column_counts(a, parent)
    Lp = np.zeros(n + 1, dtype=np.int64)
    Lp[1:] = np.cumsum(counts)
    Li = np.empty(int(Lp[-1]), dtype=np.int64)
    fill_ptr = Lp[:-1].copy()
    # diagonal entries first
    Li[fill_ptr] = np.arange(n)
    fill_ptr += 1
    mark = np.full(n, -1, dtype=np.int64)
    for i, cols in _lower_rows(a):
        mark[i] = i
        for j in cols:
            j = int(j)
            while j != -1 and mark[j] != i:
                mark[j] = i
                Li[fill_ptr[j]] = i
                fill_ptr[j] += 1
                j = int(parent[j])
    # sort rows within each column
    for j in range(n):
        Li[Lp[j] : Lp[j + 1]] = np.sort(Li[Lp[j] : Lp[j + 1]])
    nnz_lower = sum(c.size for _, c in _lower_rows(a)) + n
    fl = cholesky_flops(a, counts)
    return SymbolicFactor(parent, counts, Lp, Li, fl, int(counts.sum()) - nnz_lower)


def supernodes(sym: SymbolicFactor, relax: int = 8,
               max_size: int = 256) -> Tuple[np.ndarray, np.ndarray]:
    """Partition columns into supernodes for the multifrontal solver.

    A *fundamental* supernode extends column j to j+1 when parent[j] = j+1
    and count[j] = count[j+1] + 1 (identical pattern below). Relaxed
    amalgamation additionally merges a child whose pattern is "close enough"
    (≤ `relax` extra rows), which trades a little fill for far fewer fronts —
    exactly MUMPS's amalgamation knob.

    Returns (snode_ptr, snode_of): snode_ptr[k]..snode_ptr[k+1] are the
    columns of supernode k (contiguous), snode_of[j] = k.
    """
    n = sym.parent.shape[0]
    if n == 0:
        return np.zeros(1, dtype=np.int64), np.empty(0, dtype=np.int64)
    Lp, Li = sym.Lp, sym.Li
    starts = [0]
    # Cumulative amalgamation state for the open supernode: the dense front
    # treats every pivot column as having the union pattern, so we merge only
    # while the *explicit zeros* this padding introduces stay a small
    # fraction of the true entries (CHOLMOD-style relaxed supernodes).
    true_sum = int(sym.counts[0])   # true factor entries in the open snode
    carried = 0                     # union rows not in the newest pattern
    for j in range(1, n):
        s = starts[-1]
        q = j - s  # columns already in the open snode
        new_snode = True
        if sym.parent[j - 1] == j and q < max_size:
            pat_prev = Li[Lp[j - 1] : Lp[j]]
            pat_j = Li[Lp[j] : Lp[j + 1]]
            extra = int(np.setdiff1d(pat_prev[1:], pat_j,
                                     assume_unique=True).size)
            if extra <= relax:
                u = int(sym.counts[j]) + carried + extra  # union size below j
                width = q + u
                dense = (q + 1) * width - q * (q + 1) // 2
                t_sum = true_sum + int(sym.counts[j])
                if dense - t_sum <= max(64, int(0.25 * t_sum)):
                    new_snode = False
                    true_sum = t_sum
                    carried += extra
        if new_snode:
            starts.append(j)
            true_sum = int(sym.counts[j])
            carried = 0
    snode_ptr = np.array(starts + [n], dtype=np.int64)
    snode_of = np.empty(n, dtype=np.int64)
    for k in range(snode_ptr.size - 1):
        snode_of[snode_ptr[k] : snode_ptr[k + 1]] = k
    return snode_ptr, snode_of
