"""Reordering algorithm registry.

The seven algorithms of the paper's Table 2 plus the natural (identity)
ordering. The four *label* algorithms used by the selector are
``rcm``, ``amd``, ``nd``, ``scotch`` (one per category, as in the paper).

Every entry maps ``CSRMatrix -> perm`` with ``perm[new] = old``.
"""
from __future__ import annotations

from typing import Callable, Dict, List

import numpy as np

from ..csr import CSRMatrix
from .amd import amd_order, amf_order, md_order, qamd_order
from .nd import nd_order
from .hybrid import scotch_order
from .rcm import cm_order, rcm_order

__all__ = [
    "REORDERINGS",
    "LABEL_ALGORITHMS",
    "CATEGORY_OF",
    "get_reordering",
    "natural_order",
    "cm_order", "rcm_order", "md_order", "amd_order", "qamd_order",
    "amf_order", "nd_order", "scotch_order",
]


def natural_order(a: CSRMatrix) -> np.ndarray:
    return np.arange(a.n, dtype=np.int64)


REORDERINGS: Dict[str, Callable[[CSRMatrix], np.ndarray]] = {
    "natural": natural_order,
    "cm": cm_order,
    "rcm": rcm_order,
    "md": md_order,
    "amd": amd_order,
    "qamd": qamd_order,
    "amf": amf_order,
    "nd": nd_order,
    "scotch": scotch_order,
}

# The paper's four predictive labels (one per Table 2 category).
LABEL_ALGORITHMS: List[str] = ["amd", "scotch", "nd", "rcm"]

# Table 2: category per algorithm.
CATEGORY_OF: Dict[str, str] = {
    "rcm": "bandwidth-reduction", "cm": "bandwidth-reduction",
    "amd": "fill-in-reduction", "md": "fill-in-reduction",
    "qamd": "fill-in-reduction", "amf": "fill-in-reduction",
    "nd": "graph-based",
    "scotch": "hybrid",
    "natural": "identity",
}


def get_reordering(name: str) -> Callable[[CSRMatrix], np.ndarray]:
    try:
        return REORDERINGS[name]
    except KeyError:
        raise KeyError(
            f"unknown reordering {name!r}; available: {sorted(REORDERINGS)}")
