"""Reordering algorithm registry.

The seven algorithms of the paper's Table 2 plus the natural (identity)
ordering, registered in :data:`repro.engine.REORDERING_REGISTRY` with their
Table-2 category as metadata. The four *label* algorithms used by the
selector are ``rcm``, ``amd``, ``nd``, ``scotch`` (one per category, as in
the paper).

Every entry maps ``CSRMatrix -> perm`` with ``perm[new] = old``. The legacy
``REORDERINGS`` dict is now the registry itself (``Mapping``-compatible);
third-party orderings plug in with::

    from repro.engine import register_reordering

    @register_reordering("my_order", category="fill-in-reduction")
    def my_order(a): ...
"""
from __future__ import annotations

from typing import Callable, List, Mapping

import numpy as np

from repro.engine.registry import REORDERING_REGISTRY, register_reordering

from ..csr import CSRMatrix
from .amd import amd_order, amf_order, md_order, qamd_order
from .nd import nd_order
from .hybrid import scotch_order
from .rcm import cm_order, rcm_order

__all__ = [
    "REORDERINGS",
    "REORDERING_REGISTRY",
    "register_reordering",
    "LABEL_ALGORITHMS",
    "CATEGORY_OF",
    "get_reordering",
    "natural_order",
    "cm_order", "rcm_order", "md_order", "amd_order", "qamd_order",
    "amf_order", "nd_order", "scotch_order",
]


@register_reordering("natural", category="identity")
def natural_order(a: CSRMatrix) -> np.ndarray:
    return np.arange(a.n, dtype=np.int64)


for _name, _fn, _cat in [
    ("cm", cm_order, "bandwidth-reduction"),
    ("rcm", rcm_order, "bandwidth-reduction"),
    ("md", md_order, "fill-in-reduction"),
    ("amd", amd_order, "fill-in-reduction"),
    ("qamd", qamd_order, "fill-in-reduction"),
    ("amf", amf_order, "fill-in-reduction"),
    ("nd", nd_order, "graph-based"),
    ("scotch", scotch_order, "hybrid"),
]:
    register_reordering(_name, category=_cat)(_fn)
del _name, _fn, _cat

REORDERINGS = REORDERING_REGISTRY

# The paper's four predictive labels (one per Table 2 category).
LABEL_ALGORITHMS: List[str] = ["amd", "scotch", "nd", "rcm"]


class _CategoryView(Mapping):
    """Live Table-2 category view over the registry metadata (legacy name;
    late-registered orderings appear here too)."""

    def __getitem__(self, name):
        return REORDERING_REGISTRY.metadata(name).get("category",
                                                      "uncategorized")

    def __iter__(self):
        return iter(REORDERING_REGISTRY)

    def __len__(self):
        return len(REORDERING_REGISTRY)


CATEGORY_OF = _CategoryView()


def get_reordering(name: str) -> Callable[[CSRMatrix], np.ndarray]:
    """Resolve a reordering by name.

    Unknown names raise :class:`repro.engine.RegistryLookupError` (a
    ``KeyError`` subclass) with did-you-mean suggestions and *no* chained
    internal traceback.
    """
    return REORDERING_REGISTRY[name]
