"""Minimum-degree family orderings on the quotient (element) graph.

Implements the fill-reducing family the paper labels "AMD, AMF, QAMD"
(Table 2):

* ``md``   — exact external-degree minimum degree [Tinney & Walker 1967].
* ``amd``  — approximate minimum degree [Amestoy, Davis & Duff 1996]: the
  degree bound  d_i ≤ |A_i| + |L_p \\ i| + Σ_{e∈E_i, e≠p} |L_e \\ L_p|
  is maintained instead of the exact external degree.
* ``qamd`` — AMD with aggressive element absorption (elements whose boundary
  is contained in the new element are absorbed even when not adjacent to the
  pivot), MUMPS's QAMD flavour.
* ``amf``  — approximate minimum fill: pivots scored by the fill estimate
  d·(d−1)/2 − Σ_e C(|L_e ∩ adj|, 2) instead of the degree.

All use the quotient-graph representation: each uneliminated variable ``i``
keeps a set of variable neighbours ``A[i]`` and a set of element neighbours
``E[i]``; each eliminated pivot becomes an element ``p`` with boundary
``L[p]``. Elimination never forms explicit cliques, so memory stays O(nnz).

Returns ``perm`` with ``perm[new] = old``.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Set

import numpy as np

from ..csr import CSRMatrix
from ..graph import adjacency

__all__ = ["md_order", "amd_order", "qamd_order", "amf_order"]


def _quotient_md(a: CSRMatrix, *, approximate: bool, aggressive: bool,
                 min_fill: bool) -> np.ndarray:
    adj = adjacency(a)
    n = adj.n
    if n == 0:
        return np.empty(0, dtype=np.int64)

    A: List[Set[int]] = [set(adj.row(i).tolist()) for i in range(n)]
    E: List[Set[int]] = [set() for _ in range(n)]
    L: Dict[int, Set[int]] = {}          # element boundaries
    alive = np.ones(n, dtype=bool)
    absorbed: Set[int] = set()

    def exact_external_degree(i: int) -> int:
        reach: Set[int] = set(A[i])
        for e in E[i]:
            reach |= L[e]
        reach.discard(i)
        return len(reach)

    def fill_score(i: int) -> float:
        """Approximate new fill created by eliminating i (AMF)."""
        d = deg[i]
        score = d * (d - 1) / 2.0
        for e in E[i]:
            c = len(L[e] & A[i]) + len(L[e]) - 1
            score -= c * (c - 1) / 4.0  # heuristic discount for existing cliques
        return max(score, 0.0)

    deg = np.array([len(A[i]) for i in range(n)], dtype=np.int64)
    heap: List = []
    stamp = np.zeros(n, dtype=np.int64)  # lazy-invalidation counter
    for i in range(n):
        key = fill_score(i) if min_fill else deg[i]
        heapq.heappush(heap, (key, i, 0))

    order = np.empty(n, dtype=np.int64)
    for k in range(n):
        # Pop the minimum-key live entry.
        while True:
            key, p, s = heapq.heappop(heap)
            if alive[p] and s == stamp[p]:
                break
        alive[p] = False
        order[k] = p

        # Boundary of the new element p.
        Lp: Set[int] = set(A[p])
        for e in E[p]:
            Lp |= L[e]
            absorbed.add(e)
        Lp.discard(p)
        Lp = {i for i in Lp if alive[i]}

        # Absorb p's elements everywhere they appear.
        dead = E[p]
        if aggressive:
            # Aggressive absorption: also kill elements fully covered by Lp.
            for i in list(Lp):
                for e in list(E[i]):
                    if e not in dead and L[e] <= (Lp | {p}):
                        dead = dead | {e}
                        absorbed.add(e)
        L[p] = Lp
        E[p] = set()
        A[p] = set()

        lp1 = len(Lp) - 1
        for i in Lp:
            A[i] -= Lp
            A[i].discard(p)
            E[i] -= dead
            E[i].add(p)
            if min_fill:
                deg[i] = len(A[i]) + lp1 + sum(len(L[e] - Lp) for e in E[i] if e != p)
                key = fill_score(i)
            elif approximate:
                # AMD bound: |A_i| + |Lp \ i| + Σ_{e≠p} |L_e \ Lp|.
                d = len(A[i]) + lp1
                for e in E[i]:
                    if e != p:
                        d += len(L[e]) - len(L[e] & Lp)
                deg[i] = min(d, n - k - 1)
                key = deg[i]
            else:
                deg[i] = exact_external_degree(i)
                key = deg[i]
            stamp[i] += 1
            heapq.heappush(heap, (key, i, int(stamp[i])))

        for e in dead:
            L.pop(e, None)
    return order


def md_order(a: CSRMatrix) -> np.ndarray:
    return _quotient_md(a, approximate=False, aggressive=False, min_fill=False)


def amd_order(a: CSRMatrix) -> np.ndarray:
    return _quotient_md(a, approximate=True, aggressive=False, min_fill=False)


def qamd_order(a: CSRMatrix) -> np.ndarray:
    return _quotient_md(a, approximate=True, aggressive=True, min_fill=False)


def amf_order(a: CSRMatrix) -> np.ndarray:
    return _quotient_md(a, approximate=True, aggressive=False, min_fill=True)
