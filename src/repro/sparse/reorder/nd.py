"""Nested dissection [George 1973] via recursive vertex-separator bisection.

The partitioner is METIS-flavoured but self-contained:

1. pick a pseudo-peripheral root, build the BFS level structure;
2. split at the level that balances the two halves (edge separator);
3. convert to a vertex separator by taking the smaller boundary side;
4. a boundary-refinement pass shrinks the separator greedily
   (Fiduccia–Mattheyses-style single moves, gain = separator-size delta);
5. recurse on the two parts; separator vertices are numbered LAST.

Leaves smaller than ``leaf_size`` are ordered by the supplied leaf ordering
(natural for pure ND; AMD for the SCOTCH-like hybrid in ``hybrid.py``).

Returns ``perm`` with ``perm[new] = old``.
"""
from __future__ import annotations

from typing import Callable, List, Optional

import numpy as np

from ..csr import CSRMatrix, coo_to_csr
from ..graph import adjacency, bfs_levels, connected_components, pseudo_peripheral_node

__all__ = ["nd_order", "nd_order_with_leaf"]


def _subgraph(adj: CSRMatrix, verts: np.ndarray):
    """Induced subgraph; returns (sub_adj, local→global map)."""
    gmap = verts
    lmap = -np.ones(adj.n, dtype=np.int64)
    lmap[verts] = np.arange(verts.size)
    rows_out, cols_out = [], []
    indptr, indices = adj.indptr, adj.indices
    for li, v in enumerate(verts):
        nbr = indices[indptr[v] : indptr[v + 1]]
        keep = lmap[nbr] >= 0
        if keep.any():
            nb = lmap[nbr[keep]]
            rows_out.append(np.full(nb.size, li, dtype=np.int64))
            cols_out.append(nb)
    if rows_out:
        rows = np.concatenate(rows_out)
        cols = np.concatenate(cols_out)
    else:
        rows = cols = np.empty(0, dtype=np.int64)
    sub = coo_to_csr(rows, cols, None, (verts.size, verts.size),
                     sum_duplicates=False)
    return sub, gmap


def _vertex_separator(adj: CSRMatrix) -> Optional[tuple]:
    """Bisect one connected graph; returns (part0, part1, sep) local ids."""
    n = adj.n
    if n < 2:
        return None
    root, levels = pseudo_peripheral_node(adj, 0)
    if len(levels) < 3:
        # Graph is (almost) a clique / too shallow to dissect.
        return None
    sizes = np.array([lv.size for lv in levels])
    cum = np.cumsum(sizes)
    # Choose split level t: vertices in levels < t go to part0.
    t = int(np.searchsorted(cum, n / 2.0)) + 1
    t = max(1, min(t, len(levels) - 1))
    level_of = np.full(n, -1, dtype=np.int64)
    for d, lv in enumerate(levels):
        level_of[lv] = d
    part0_mask = (level_of >= 0) & (level_of < t)
    part1_mask = level_of >= t

    indptr, indices = adj.indptr, adj.indices
    # Boundary candidates on each side of the cut.
    cand0 = []
    for v in np.nonzero(part0_mask)[0]:
        nbr = indices[indptr[v] : indptr[v + 1]]
        if part1_mask[nbr].any():
            cand0.append(v)
    cand1 = []
    for v in np.nonzero(part1_mask)[0]:
        nbr = indices[indptr[v] : indptr[v + 1]]
        if part0_mask[nbr].any():
            cand1.append(v)
    sep = np.array(cand0 if len(cand0) <= len(cand1) else cand1, dtype=np.int64)

    in_sep = np.zeros(n, dtype=bool)
    in_sep[sep] = True

    # Greedy refinement: drop separator vertices whose neighbourhood touches
    # only one side (they can join that side), repeat until fixpoint.
    changed = True
    while changed:
        changed = False
        for v in np.nonzero(in_sep)[0]:
            nbr = indices[indptr[v] : indptr[v + 1]]
            nbr = nbr[~in_sep[nbr]]
            touches0 = part0_mask[nbr].any()
            touches1 = part1_mask[nbr].any()
            if not (touches0 and touches1):
                in_sep[v] = False
                if touches1:
                    part0_mask[v], part1_mask[v] = False, True
                else:
                    part1_mask[v], part0_mask[v] = False, True
                changed = True
    part0_mask &= ~in_sep
    part1_mask &= ~in_sep
    p0 = np.nonzero(part0_mask)[0]
    p1 = np.nonzero(part1_mask)[0]
    s = np.nonzero(in_sep)[0]
    if p0.size == 0 or p1.size == 0:
        return None
    return p0, p1, s


def nd_order_with_leaf(a: CSRMatrix, leaf_order: Callable[[CSRMatrix], np.ndarray],
                       leaf_size: int = 64, max_depth: int = 64) -> np.ndarray:
    adj = adjacency(a)
    out: List[int] = []

    def recurse(sub: CSRMatrix, gmap: np.ndarray, depth: int) -> np.ndarray:
        if sub.n <= leaf_size or depth >= max_depth:
            return gmap[leaf_order(sub)]

        def descend(local_verts: np.ndarray, d: int) -> np.ndarray:
            child, lmap = _subgraph(sub, local_verts)
            return recurse(child, gmap[lmap], d)  # compose local→global

        comps = connected_components(sub)
        if len(comps) > 1:
            return np.concatenate([descend(c, depth) for c in comps])
        cut = _vertex_separator(sub)
        if cut is None:
            return gmap[leaf_order(sub)]
        p0, p1, s = cut
        pieces = [descend(p0, depth + 1), descend(p1, depth + 1)]
        if s.size:
            pieces.append(gmap[s])  # separator numbered last
        return np.concatenate(pieces)

    perm = recurse(adj, np.arange(adj.n, dtype=np.int64), 0)
    assert perm.size == adj.n
    return perm


def nd_order(a: CSRMatrix, leaf_size: int = 64) -> np.ndarray:
    """Pure nested dissection: natural order inside the leaves."""
    return nd_order_with_leaf(a, lambda s: np.arange(s.n, dtype=np.int64),
                              leaf_size=leaf_size)
