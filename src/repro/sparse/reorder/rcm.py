"""Cuthill–McKee and Reverse Cuthill–McKee bandwidth-reducing orderings.

CM [Cuthill & McKee 1969]: BFS from a pseudo-peripheral node, visiting the
children of each vertex in order of increasing degree. RCM [Liu & Sherman
1976] reverses the CM numbering, which provably never increases (and usually
decreases) the envelope/profile.

Returns `perm` with ``perm[new] = old`` — apply with
:func:`repro.sparse.csr.permute_symmetric`.
"""
from __future__ import annotations

import numpy as np

from ..csr import CSRMatrix
from ..graph import adjacency, degrees, pseudo_peripheral_node

__all__ = ["cm_order", "rcm_order"]


def cm_order(a: CSRMatrix) -> np.ndarray:
    adj = adjacency(a)
    n = adj.n
    deg = degrees(adj)
    visited = np.zeros(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    pos = 0
    indptr, indices = adj.indptr, adj.indices

    # Process vertices in min-degree order so each component starts from a
    # low-degree seed (then refined to pseudo-peripheral).
    seeds = np.argsort(deg, kind="stable")
    for seed in seeds:
        if visited[seed]:
            continue
        root, _ = pseudo_peripheral_node(adj, int(seed), mask=~visited)
        # BFS with degree-sorted children.
        queue = [root]
        visited[root] = True
        head = 0
        while head < len(queue):
            v = queue[head]
            head += 1
            order[pos] = v
            pos += 1
            nbr = indices[indptr[v] : indptr[v + 1]]
            nbr = nbr[~visited[nbr]]
            if nbr.size:
                nbr = nbr[np.argsort(deg[nbr], kind="stable")]
                visited[nbr] = True
                queue.extend(int(u) for u in nbr)
    assert pos == n
    return order


def rcm_order(a: CSRMatrix) -> np.ndarray:
    return cm_order(a)[::-1].copy()
