"""SCOTCH/PORD-style hybrid ordering: nested dissection on top, minimum
degree in the leaves (halo-AMD flavour).

This is the "hybrid algorithms combining fill-in reduction and graph-based
methods" category of the paper's Table 2. Real SCOTCH runs ND until the
subgraphs are small, then switches to (halo-)AMD; we do exactly that with our
own ND and AMD.
"""
from __future__ import annotations

import numpy as np

from ..csr import CSRMatrix
from .amd import amd_order
from .nd import nd_order_with_leaf

__all__ = ["scotch_order"]


def scotch_order(a: CSRMatrix, leaf_size: int = 200) -> np.ndarray:
    return nd_order_with_leaf(a, amd_order, leaf_size=leaf_size)
