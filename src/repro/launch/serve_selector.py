"""Reorder-selection serving: async plan pipeline + legacy sync front-end.

    PYTHONPATH=src python -m repro.launch.serve_selector \
        --requests 256 --batch 16 --path device --model random_forest

Simulates the production traffic pattern the ROADMAP targets: a stream of
matrices (with repeat structures, as real workloads re-solve the same
pattern) hits an :class:`AsyncPlanServer`. Warm structures are answered at
submit time straight from the two-tier plan cache (no featurization, no
classifier, no symbolic analysis); misses flow through a deadline-based
micro-batching queue and the three cold stages —

    feature-batch → device inference → plan build

— where the batcher thread runs the padded-CSR featurizer + on-device
classifier (forest inference included, via ``forest_jnp``) over each
micro-batch, and a pool of build workers runs reorder + symbolic analysis
per structure and installs the finished :class:`ExecutionPlan` in the
cache. Per-request latency is recorded end-to-end (submit → plan ready),
and the cache's disk tier under ``artifacts/plan_cache/`` means a restarted
server starts warm.

:class:`SelectorServer` — the PR-1 synchronous, name-only front-end — is
kept for callers that only want the algorithm label.

The micro-batching pipeline itself lives in
:mod:`repro.core.dispatch` (:class:`~repro.core.dispatch.PlanDispatcher`);
:class:`AsyncPlanServer` is its in-process name, and the RPC front-end
(:mod:`repro.launch.rpc`) puts a socket protocol in front of the same
core for out-of-process clients.

The demo entrypoint drives everything through :class:`repro.engine
.SolverEngine` (``engine.train(ds)`` → ``engine.serve()``), whose
model-fingerprint cache versioning guarantees a retrained selector never
replays plans persisted by its predecessor.
"""
from __future__ import annotations

import argparse
import collections
import time
from typing import Dict, List, Sequence

from repro.core.dispatch import PlanDispatcher
from repro.core.plan_cache import PlanCache, matrix_fingerprint
from repro.core.selector import ReorderSelector
from repro.sparse.csr import CSRMatrix

__all__ = ["SelectorServer", "AsyncPlanServer", "main"]


class SelectorServer:
    """Batched, cached front-end around a trained :class:`ReorderSelector`.

    ``handle(mats)`` answers a request batch: fingerprint every matrix,
    serve repeats from the LRU cache, group the misses into padded batches
    of ``batch_size`` for the selector, and install the fresh plans.
    Duplicate structures *within* one request batch are featurized once.
    """

    def __init__(self, selector: ReorderSelector, *, batch_size: int = 16,
                 cache_capacity: int = 4096, path: str = "device",
                 use_pallas: bool = False):
        self.selector = selector
        self.batch_size = batch_size
        self.cache = PlanCache(cache_capacity)
        self.path = path
        self.use_pallas = use_pallas
        self.select_seconds = 0.0
        self.requests = 0

    def handle(self, mats: Sequence[CSRMatrix]) -> List[str]:
        self.requests += len(mats)
        keys = [matrix_fingerprint(m) for m in mats]
        plans: List[str] = [None] * len(mats)  # type: ignore[list-item]
        miss_idx: List[int] = []
        pending: Dict[str, List[int]] = {}
        for i, key in enumerate(keys):
            hit = self.cache.get(key)
            if hit is not None:
                plans[i] = hit
            elif key in pending:
                pending[key].append(i)  # intra-batch duplicate: one featurize
            else:
                pending[key] = [i]
                miss_idx.append(i)
        # size-tiered batching: chunking a size-sorted miss list keeps the
        # padded (N, E) of each device batch near its members' true sizes
        miss_idx.sort(key=lambda i: (mats[i].nnz, mats[i].n))
        for lo in range(0, len(miss_idx), self.batch_size):
            chunk = miss_idx[lo : lo + self.batch_size]
            batch_mats = [mats[i] for i in chunk]
            if self.path == "device":
                # pad partial chunks to batch_size (repeating a member) so
                # the batch dim stays one jit bucket; extra results are
                # dropped. The host path has no shape buckets — padding
                # there would just featurize the filler for nothing.
                batch_mats += [batch_mats[0]] * (self.batch_size - len(chunk))
            names, dt = self.selector.select_batch(
                batch_mats, path=self.path, use_pallas=self.use_pallas)
            self.select_seconds += dt
            for i, name in zip(chunk, names):
                self.cache.put(keys[i], name)
                for j in pending[keys[i]]:
                    plans[j] = name
        return plans

    def stats(self) -> dict:
        s = self.cache.stats()
        s.update(requests=self.requests, select_seconds=self.select_seconds)
        return s


# ---------------------------------------------------------------------------
# Async plan pipeline — the in-process face of the dispatch core
# ---------------------------------------------------------------------------

class AsyncPlanServer(PlanDispatcher):
    """In-process async plan server.

    This is :class:`repro.core.dispatch.PlanDispatcher` under its serving
    name — the full deadline micro-batching pipeline (warm-path futures,
    batcher thread, in-flight dedup, plan-build worker pool) with requests
    submitted by direct method call. The RPC front-end
    (:class:`repro.launch.rpc.PlanRPCServer`) wraps this same class to
    serve out-of-process clients; keeping the name alive preserves every
    existing import and ``SolverEngine.serve()`` contract.
    """


# ---------------------------------------------------------------------------
# entrypoint
# ---------------------------------------------------------------------------

def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--requests", type=int, default=256)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--cache", type=int, default=512)
    p.add_argument("--cache-dir", default=None,
                   help="persistent plan-cache dir (default "
                        "artifacts/plan_cache; pass '' to stay in-memory)")
    p.add_argument("--max-disk-mb", type=float, default=None,
                   help="disk-tier byte budget (LRU-by-mtime eviction)")
    p.add_argument("--max-disk-entries", type=int, default=None,
                   help="disk-tier file-count cap")
    p.add_argument("--max-wait-ms", type=float, default=5.0)
    p.add_argument("--build-workers", type=int, default=2)
    p.add_argument("--path", choices=["host", "device"], default="device")
    p.add_argument("--use-pallas", action="store_true")
    p.add_argument("--model", default="random_forest")
    p.add_argument("--distinct", type=int, default=48,
                   help="distinct structures in the request stream")
    p.add_argument("--campaign-count", type=int, default=36)
    p.add_argument("--campaign-scale", type=float, default=0.35)
    p.add_argument("--seed", type=int, default=7)
    args = p.parse_args()

    import numpy as np

    from repro.core.labeling import load_or_build
    from repro.core.plan_cache import DEFAULT_CACHE_DIR
    from repro.engine import EngineConfig, SolverEngine
    from repro.sparse.dataset import generate_suite

    # one facade: config → train → serve. The engine versions the plan
    # cache with the fitted model's fingerprint, so a retrained selector
    # never serves plans persisted by its predecessor — no manual
    # version= bump here or anywhere.
    cache_dir = (args.cache_dir if args.cache_dir is not None
                 else DEFAULT_CACHE_DIR)
    engine = SolverEngine(EngineConfig(
        model=args.model, cache_dir=cache_dir or None,
        cache_capacity=args.cache,
        cache_max_disk_bytes=(int(args.max_disk_mb * 2**20)
                              if args.max_disk_mb else None),
        cache_max_disk_entries=args.max_disk_entries,
        path=args.path, use_pallas=args.use_pallas, batch_size=args.batch,
        max_wait_ms=args.max_wait_ms, build_workers=args.build_workers,
        fast_grids=True, cv=3, seed=0))
    ds = load_or_build(cache_dir="artifacts", count=args.campaign_count,
                       seed=args.seed, size_scale=args.campaign_scale,
                       repeats=1, verbose=True)
    rep = engine.train(ds)
    print(f"[serve-selector] model={args.model} "
          f"test_acc={rep['test_accuracy']:.2f} "
          f"fingerprint={engine.fingerprint[:16]}")

    pool = list(generate_suite(count=args.distinct, seed=args.seed + 1,
                               size_scale=0.4))
    rng = np.random.default_rng(args.seed)
    # zipf-ish popularity: a few hot structures dominate, like real traffic
    pop = 1.0 / (1.0 + np.arange(len(pool)))
    pop /= pop.sum()
    stream = rng.choice(len(pool), size=args.requests, p=pop)

    server = engine.serve()
    # warm the jit/kernel compile outside the timed region, then zero the
    # metrics so the report reflects steady-state serving (on a later run
    # with a persistent cache dir this warm-up is just a disk hit)
    server.handle([pool[0]])
    server.reset_stats()

    t0 = time.perf_counter()
    futs = [server.submit(pool[i]) for i in stream]
    plans = [f.result(timeout=300) for f in futs]
    wall = time.perf_counter() - t0
    server.close()

    s = server.stats()
    print(f"[serve-selector] path={args.path} pallas={args.use_pallas} "
          f"batch={args.batch} wait={args.max_wait_ms}ms "
          f"workers={args.build_workers} "
          f"disk={'off' if not cache_dir else cache_dir}")
    print(f"[serve-selector] {args.requests} requests in {wall*1e3:.0f} ms "
          f"→ {args.requests / wall:.0f} plans/sec end-to-end")
    print(f"[serve-selector] cache: {s['hits']} hits / {s['misses']} misses "
          f"(hit rate {s['hit_rate']:.2f}), {s['evictions']} evictions, "
          f"size {s['size']}/{s['capacity']}"
          + (f", disk {s['disk_hits']} hits / {s['disk_entries']} entries"
             if "disk_hits" in s else ""))
    print(f"[serve-selector] latency: p50 {s.get('p50_ms', 0.0):.2f} ms, "
          f"p99 {s.get('p99_ms', 0.0):.2f} ms "
          f"({s['warm_hits']} warm submits)")
    print(f"[serve-selector] cold stages: select {s['select_calls']} calls "
          f"{s['select_seconds']*1e3:.0f} ms, "
          f"{s['plans_built']} plans built {s['build_seconds']*1e3:.0f} ms")
    if s.get("max_disk_bytes") or s.get("max_disk_entries"):
        print(f"[serve-selector] disk budget: {s['disk_bytes']} bytes / "
              f"{s['disk_entries']} files, {s['disk_evictions']} evictions")
    dist = collections.Counter(pl.algorithm for pl in plans)
    print(f"[serve-selector] plan distribution: {dict(sorted(dist.items()))}")


if __name__ == "__main__":
    main()
