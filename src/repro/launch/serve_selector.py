"""Reorder-selection serving loop: request batching + fingerprint plan cache.

    PYTHONPATH=src python -m repro.launch.serve_selector \
        --requests 256 --batch 16 --path device --model logistic_regression

Simulates the production traffic pattern the ROADMAP targets: a stream of
matrices (with repeat structures, as real workloads re-solve the same
pattern) hits a :class:`SelectorServer`, which answers cache hits instantly
and featurizes+classifies the misses in padded device batches. Prints
throughput, cache statistics, and the per-path breakdown.

The selector itself is trained once on a miniature labeling campaign
(cached under ``artifacts/``) so the entrypoint is self-contained and runs
in seconds on a laptop; point ``--campaign-count/--campaign-scale`` at a
bigger campaign for a production model.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Sequence, Tuple

from repro.core.plan_cache import PlanCache, matrix_fingerprint
from repro.core.selector import ReorderSelector
from repro.sparse.csr import CSRMatrix

__all__ = ["SelectorServer", "main"]


class SelectorServer:
    """Batched, cached front-end around a trained :class:`ReorderSelector`.

    ``handle(mats)`` answers a request batch: fingerprint every matrix,
    serve repeats from the LRU cache, group the misses into padded batches
    of ``batch_size`` for the selector, and install the fresh plans.
    Duplicate structures *within* one request batch are featurized once.
    """

    def __init__(self, selector: ReorderSelector, *, batch_size: int = 16,
                 cache_capacity: int = 4096, path: str = "device",
                 use_pallas: bool = False):
        self.selector = selector
        self.batch_size = batch_size
        self.cache = PlanCache(cache_capacity)
        self.path = path
        self.use_pallas = use_pallas
        self.select_seconds = 0.0
        self.requests = 0

    def handle(self, mats: Sequence[CSRMatrix]) -> List[str]:
        self.requests += len(mats)
        keys = [matrix_fingerprint(m) for m in mats]
        plans: List[str] = [None] * len(mats)  # type: ignore[list-item]
        miss_idx: List[int] = []
        pending: Dict[str, List[int]] = {}
        for i, key in enumerate(keys):
            hit = self.cache.get(key)
            if hit is not None:
                plans[i] = hit
            elif key in pending:
                pending[key].append(i)  # intra-batch duplicate: one featurize
            else:
                pending[key] = [i]
                miss_idx.append(i)
        # size-tiered batching: chunking a size-sorted miss list keeps the
        # padded (N, E) of each device batch near its members' true sizes
        miss_idx.sort(key=lambda i: (mats[i].nnz, mats[i].n))
        for lo in range(0, len(miss_idx), self.batch_size):
            chunk = miss_idx[lo : lo + self.batch_size]
            batch_mats = [mats[i] for i in chunk]
            if self.path == "device":
                # pad partial chunks to batch_size (repeating a member) so
                # the batch dim stays one jit bucket; extra results are
                # dropped. The host path has no shape buckets — padding
                # there would just featurize the filler for nothing.
                batch_mats += [batch_mats[0]] * (self.batch_size - len(chunk))
            names, dt = self.selector.select_batch(
                batch_mats, path=self.path, use_pallas=self.use_pallas)
            self.select_seconds += dt
            for i, name in zip(chunk, names):
                self.cache.put(keys[i], name)
                for j in pending[keys[i]]:
                    plans[j] = name
        return plans

    def stats(self) -> dict:
        s = self.cache.stats()
        s.update(requests=self.requests, select_seconds=self.select_seconds)
        return s


def _train_small_selector(model_name: str, count: int, scale: float,
                          seed: int) -> Tuple[ReorderSelector, dict]:
    from repro.core.labeling import load_or_build
    from repro.core.selector import train_selector

    ds = load_or_build(cache_dir="artifacts", count=count, seed=seed,
                       size_scale=scale, repeats=1, verbose=True)
    return train_selector(ds, model_name, "standard", fast=True, cv=3)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--requests", type=int, default=256)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--cache", type=int, default=512)
    p.add_argument("--path", choices=["host", "device"], default="device")
    p.add_argument("--use-pallas", action="store_true")
    p.add_argument("--model", default="logistic_regression")
    p.add_argument("--distinct", type=int, default=48,
                   help="distinct structures in the request stream")
    p.add_argument("--campaign-count", type=int, default=36)
    p.add_argument("--campaign-scale", type=float, default=0.35)
    p.add_argument("--seed", type=int, default=7)
    args = p.parse_args()

    import numpy as np

    from repro.sparse.dataset import generate_suite

    sel, rep = _train_small_selector(args.model, args.campaign_count,
                                     args.campaign_scale, args.seed)
    print(f"[serve-selector] model={args.model} "
          f"test_acc={rep['test_accuracy']:.2f}")

    pool = list(generate_suite(count=args.distinct, seed=args.seed + 1,
                               size_scale=0.4))
    rng = np.random.default_rng(args.seed)
    # zipf-ish popularity: a few hot structures dominate, like real traffic
    pop = 1.0 / (1.0 + np.arange(len(pool)))
    pop /= pop.sum()
    stream = rng.choice(len(pool), size=args.requests, p=pop)

    server = SelectorServer(sel, batch_size=args.batch,
                            cache_capacity=args.cache, path=args.path,
                            use_pallas=args.use_pallas)
    # warm the jit/kernel compile outside the timed region
    server.handle([pool[0]])

    t0 = time.perf_counter()
    plans = []
    for lo in range(0, len(stream), args.batch):
        req = [pool[i] for i in stream[lo : lo + args.batch]]
        plans.extend(server.handle(req))
    wall = time.perf_counter() - t0

    s = server.stats()
    print(f"[serve-selector] path={args.path} pallas={args.use_pallas} "
          f"batch={args.batch}")
    print(f"[serve-selector] {args.requests} requests in {wall*1e3:.0f} ms "
          f"→ {args.requests / wall:.0f} matrices/sec end-to-end")
    print(f"[serve-selector] cache: {s['hits']} hits / {s['misses']} misses "
          f"(hit rate {s['hit_rate']:.2f}), {s['evictions']} evictions, "
          f"size {s['size']}/{s['capacity']}")
    print(f"[serve-selector] selector time on misses: "
          f"{s['select_seconds']*1e3:.0f} ms")
    dist = {a: plans.count(a) for a in sorted(set(plans))}
    print(f"[serve-selector] plan distribution: {dist}")


if __name__ == "__main__":
    main()
