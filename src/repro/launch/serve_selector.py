"""Reorder-selection serving: async plan pipeline + legacy sync front-end.

    PYTHONPATH=src python -m repro.launch.serve_selector \
        --requests 256 --batch 16 --path device --model random_forest

Simulates the production traffic pattern the ROADMAP targets: a stream of
matrices (with repeat structures, as real workloads re-solve the same
pattern) hits an :class:`AsyncPlanServer`. Warm structures are answered at
submit time straight from the two-tier plan cache (no featurization, no
classifier, no symbolic analysis); misses flow through a deadline-based
micro-batching queue and the three cold stages —

    feature-batch → device inference → plan build

— where the batcher thread runs the padded-CSR featurizer + on-device
classifier (forest inference included, via ``forest_jnp``) over each
micro-batch, and a pool of build workers runs reorder + symbolic analysis
per structure and installs the finished :class:`ExecutionPlan` in the
cache. Per-request latency is recorded end-to-end (submit → plan ready),
and the cache's disk tier under ``artifacts/plan_cache/`` means a restarted
server starts warm.

:class:`SelectorServer` — the PR-1 synchronous, name-only front-end — is
kept for callers that only want the algorithm label.

The demo entrypoint drives everything through :class:`repro.engine
.SolverEngine` (``engine.train(ds)`` → ``engine.serve()``), whose
model-fingerprint cache versioning guarantees a retrained selector never
replays plans persisted by its predecessor.
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Sequence

from repro.core.plan import ExecutionPlan, PlanBuilder
from repro.core.plan_cache import PlanCache, matrix_fingerprint
from repro.core.selector import ReorderSelector
from repro.sparse.csr import CSRMatrix

__all__ = ["SelectorServer", "AsyncPlanServer", "main"]

_SENTINEL = object()


class SelectorServer:
    """Batched, cached front-end around a trained :class:`ReorderSelector`.

    ``handle(mats)`` answers a request batch: fingerprint every matrix,
    serve repeats from the LRU cache, group the misses into padded batches
    of ``batch_size`` for the selector, and install the fresh plans.
    Duplicate structures *within* one request batch are featurized once.
    """

    def __init__(self, selector: ReorderSelector, *, batch_size: int = 16,
                 cache_capacity: int = 4096, path: str = "device",
                 use_pallas: bool = False):
        self.selector = selector
        self.batch_size = batch_size
        self.cache = PlanCache(cache_capacity)
        self.path = path
        self.use_pallas = use_pallas
        self.select_seconds = 0.0
        self.requests = 0

    def handle(self, mats: Sequence[CSRMatrix]) -> List[str]:
        self.requests += len(mats)
        keys = [matrix_fingerprint(m) for m in mats]
        plans: List[str] = [None] * len(mats)  # type: ignore[list-item]
        miss_idx: List[int] = []
        pending: Dict[str, List[int]] = {}
        for i, key in enumerate(keys):
            hit = self.cache.get(key)
            if hit is not None:
                plans[i] = hit
            elif key in pending:
                pending[key].append(i)  # intra-batch duplicate: one featurize
            else:
                pending[key] = [i]
                miss_idx.append(i)
        # size-tiered batching: chunking a size-sorted miss list keeps the
        # padded (N, E) of each device batch near its members' true sizes
        miss_idx.sort(key=lambda i: (mats[i].nnz, mats[i].n))
        for lo in range(0, len(miss_idx), self.batch_size):
            chunk = miss_idx[lo : lo + self.batch_size]
            batch_mats = [mats[i] for i in chunk]
            if self.path == "device":
                # pad partial chunks to batch_size (repeating a member) so
                # the batch dim stays one jit bucket; extra results are
                # dropped. The host path has no shape buckets — padding
                # there would just featurize the filler for nothing.
                batch_mats += [batch_mats[0]] * (self.batch_size - len(chunk))
            names, dt = self.selector.select_batch(
                batch_mats, path=self.path, use_pallas=self.use_pallas)
            self.select_seconds += dt
            for i, name in zip(chunk, names):
                self.cache.put(keys[i], name)
                for j in pending[keys[i]]:
                    plans[j] = name
        return plans

    def stats(self) -> dict:
        s = self.cache.stats()
        s.update(requests=self.requests, select_seconds=self.select_seconds)
        return s


# ---------------------------------------------------------------------------
# Async plan pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _PlanRequest:
    mat: CSRMatrix
    key: str
    future: "Future[ExecutionPlan]"
    t_submit: float


class AsyncPlanServer:
    """Request queue → deadline micro-batches → staged cold path.

    * ``submit`` fingerprints the matrix; a cache hit resolves the returned
      future immediately (the warm path never enters the queue), a miss is
      enqueued.
    * One **batcher** thread collects misses until ``batch_size`` requests
      are waiting or the oldest has aged ``max_wait_ms``, deduplicates by
      fingerprint, re-checks the cache (a sibling batch may have built the
      plan meanwhile), and runs the selector's padded feature-batch +
      device inference over the remaining structures.
    * ``build_workers`` **builder** threads take per-structure (matrix,
      algorithm) items, run reorder + symbolic analysis, install the plan
      in the shared (thread-safe) cache, and resolve every future waiting
      on that fingerprint — so plan builds for one micro-batch overlap the
      next micro-batch's inference.
    """

    def __init__(self, builder: PlanBuilder, *, batch_size: int = 16,
                 max_wait_ms: float = 5.0, build_workers: int = 2,
                 latency_window: int = 100_000):
        assert builder.selector is not None, "cold path needs a selector"
        self.builder = builder
        self.cache = builder.cache
        self.batch_size = batch_size
        self.max_wait = max_wait_ms / 1e3
        self.requests = 0
        self._queue: "queue.Queue" = queue.Queue()
        self._build_queue: "queue.Queue" = queue.Queue()
        self._lat_lock = threading.Lock()
        # bounded: a long-running server keeps a sliding window, not every
        # latency ever observed (percentiles stay O(window))
        self._latencies: "collections.deque[float]" = collections.deque(
            maxlen=latency_window)
        self._warm = 0
        # keys whose plan build is in flight → requests waiting on it, so a
        # later micro-batch joins the pending build instead of duplicating
        # the selection + build work (guarded by _inflight_lock; builders
        # cache.put *before* popping, so a racer either finds the in-flight
        # entry or peeks the finished plan — never neither)
        self._inflight_lock = threading.Lock()
        self._inflight: Dict[str, List[_PlanRequest]] = {}
        # serializes enqueue-vs-shutdown so no request can land behind the
        # sentinel with a forever-pending future
        self._close_lock = threading.Lock()
        self._closed = False
        self._batcher = threading.Thread(target=self._batch_loop,
                                         name="plan-batcher", daemon=True)
        self._builders = [threading.Thread(target=self._build_loop,
                                           name=f"plan-builder-{i}",
                                           daemon=True)
                          for i in range(max(1, build_workers))]
        self._batcher.start()
        for t in self._builders:
            t.start()

    # -- client surface ------------------------------------------------------
    def submit(self, mat: CSRMatrix) -> "Future[ExecutionPlan]":
        with self._lat_lock:
            self.requests += 1
        t0 = time.perf_counter()
        key = matrix_fingerprint(mat)
        fut: "Future[ExecutionPlan]" = Future()
        plan = self.cache.get(key)
        if plan is not None:
            self._record(t0)
            with self._lat_lock:
                self._warm += 1
            fut.set_result(plan)
            return fut
        with self._close_lock:
            if self._closed:
                raise RuntimeError("server closed")
            self._queue.put(_PlanRequest(mat, key, fut, t0))
        return fut

    def handle(self, mats: Sequence[CSRMatrix],
               timeout: float = 120.0) -> List[ExecutionPlan]:
        futs = [self.submit(m) for m in mats]
        return [f.result(timeout=timeout) for f in futs]

    def close(self, timeout: float = 30.0) -> None:
        with self._close_lock:
            if self._closed:
                return
            self._closed = True
            self._queue.put(_SENTINEL)
        self._batcher.join(timeout)
        for t in self._builders:
            t.join(timeout)

    def reset_stats(self) -> None:
        """Zero the serving metrics (latency window, warm/request counts,
        builder + cache counters) — e.g. after an untimed jit warm-up, so
        the reported numbers reflect steady-state serving only."""
        with self._lat_lock:
            self._latencies.clear()
            self._warm = 0
            self.requests = 0
        self.builder.reset_stats()  # resets the cache counters too

    def stats(self) -> dict:
        s = self.builder.stats()
        with self._lat_lock:
            lats = list(self._latencies)
            warm = self._warm
            requests = self.requests
        s.update(requests=requests, warm_hits=warm)
        if lats:
            import numpy as np

            arr = np.asarray(lats)
            s.update(p50_ms=float(np.percentile(arr, 50) * 1e3),
                     p99_ms=float(np.percentile(arr, 99) * 1e3),
                     mean_ms=float(arr.mean() * 1e3))
        return s

    def _record(self, t_submit: float) -> None:
        with self._lat_lock:
            self._latencies.append(time.perf_counter() - t_submit)

    # -- stage 1: micro-batcher (feature-batch + device inference) -----------
    def _batch_loop(self) -> None:
        stop = False
        while not stop:
            item = self._queue.get()
            if item is _SENTINEL:
                break
            batch: List[_PlanRequest] = [item]
            deadline = time.perf_counter() + self.max_wait
            while len(batch) < self.batch_size:
                remain = deadline - time.perf_counter()
                if remain <= 0:
                    break
                try:
                    nxt = self._queue.get(timeout=remain)
                except queue.Empty:
                    break
                if nxt is _SENTINEL:
                    stop = True
                    break
                batch.append(nxt)
            self._dispatch(batch)
        self._build_queue.put(_SENTINEL)

    def _dispatch(self, batch: List[_PlanRequest]) -> None:
        groups: Dict[str, List[_PlanRequest]] = {}
        for r in batch:
            groups.setdefault(r.key, []).append(r)
        todo: List[str] = []
        for key, reqs in groups.items():
            with self._inflight_lock:
                pending = self._inflight.get(key)
                if pending is not None:
                    pending.extend(reqs)  # join the build already in flight
                    continue
                plan = self.cache.peek(key)  # a sibling may have built it
                if plan is None:
                    self._inflight[key] = reqs
                    todo.append(key)
            if plan is not None:
                for r in reqs:
                    self._record(r.t_submit)
                    r.future.set_result(plan)
        if not todo:
            return
        try:
            names = self.builder.select_names(
                [self._inflight[key][0].mat for key in todo])
        except Exception as exc:  # selector failure fails the whole batch
            for key in todo:
                with self._inflight_lock:
                    reqs = self._inflight.pop(key, [])
                for r in reqs:
                    r.future.set_exception(exc)
            return
        for key, name in zip(todo, names):
            self._build_queue.put((key, name))

    # -- stage 2: plan build (reorder + symbolic) ----------------------------
    def _build_loop(self) -> None:
        while True:
            item = self._build_queue.get()
            if item is _SENTINEL:
                self._build_queue.put(_SENTINEL)  # release sibling workers
                return
            key, name = item
            mat = self._inflight[key][0].mat  # entry exists until we pop it
            try:
                plan = self.builder.build(mat, algorithm=name,
                                          fingerprint=key)
            except Exception as exc:
                with self._inflight_lock:
                    reqs = self._inflight.pop(key, [])
                for r in reqs:
                    r.future.set_exception(exc)
                continue
            try:
                self.cache.put(key, plan)  # put, *then* pop (see _inflight)
            except Exception:
                # a disk-tier write failure must not fail the waiters: the
                # build succeeded and the memory tier is already populated
                pass
            with self._inflight_lock:
                reqs = self._inflight.pop(key, [])
            for r in reqs:
                self._record(r.t_submit)
                r.future.set_result(plan)


# ---------------------------------------------------------------------------
# entrypoint
# ---------------------------------------------------------------------------

def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--requests", type=int, default=256)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--cache", type=int, default=512)
    p.add_argument("--cache-dir", default=None,
                   help="persistent plan-cache dir (default "
                        "artifacts/plan_cache; pass '' to stay in-memory)")
    p.add_argument("--max-disk-mb", type=float, default=None,
                   help="disk-tier byte budget (LRU-by-mtime eviction)")
    p.add_argument("--max-disk-entries", type=int, default=None,
                   help="disk-tier file-count cap")
    p.add_argument("--max-wait-ms", type=float, default=5.0)
    p.add_argument("--build-workers", type=int, default=2)
    p.add_argument("--path", choices=["host", "device"], default="device")
    p.add_argument("--use-pallas", action="store_true")
    p.add_argument("--model", default="random_forest")
    p.add_argument("--distinct", type=int, default=48,
                   help="distinct structures in the request stream")
    p.add_argument("--campaign-count", type=int, default=36)
    p.add_argument("--campaign-scale", type=float, default=0.35)
    p.add_argument("--seed", type=int, default=7)
    args = p.parse_args()

    import numpy as np

    from repro.core.labeling import load_or_build
    from repro.core.plan_cache import DEFAULT_CACHE_DIR
    from repro.engine import EngineConfig, SolverEngine
    from repro.sparse.dataset import generate_suite

    # one facade: config → train → serve. The engine versions the plan
    # cache with the fitted model's fingerprint, so a retrained selector
    # never serves plans persisted by its predecessor — no manual
    # version= bump here or anywhere.
    cache_dir = (args.cache_dir if args.cache_dir is not None
                 else DEFAULT_CACHE_DIR)
    engine = SolverEngine(EngineConfig(
        model=args.model, cache_dir=cache_dir or None,
        cache_capacity=args.cache,
        cache_max_disk_bytes=(int(args.max_disk_mb * 2**20)
                              if args.max_disk_mb else None),
        cache_max_disk_entries=args.max_disk_entries,
        path=args.path, use_pallas=args.use_pallas, batch_size=args.batch,
        max_wait_ms=args.max_wait_ms, build_workers=args.build_workers,
        fast_grids=True, cv=3, seed=0))
    ds = load_or_build(cache_dir="artifacts", count=args.campaign_count,
                       seed=args.seed, size_scale=args.campaign_scale,
                       repeats=1, verbose=True)
    rep = engine.train(ds)
    print(f"[serve-selector] model={args.model} "
          f"test_acc={rep['test_accuracy']:.2f} "
          f"fingerprint={engine.fingerprint[:16]}")

    pool = list(generate_suite(count=args.distinct, seed=args.seed + 1,
                               size_scale=0.4))
    rng = np.random.default_rng(args.seed)
    # zipf-ish popularity: a few hot structures dominate, like real traffic
    pop = 1.0 / (1.0 + np.arange(len(pool)))
    pop /= pop.sum()
    stream = rng.choice(len(pool), size=args.requests, p=pop)

    server = engine.serve()
    # warm the jit/kernel compile outside the timed region, then zero the
    # metrics so the report reflects steady-state serving (on a later run
    # with a persistent cache dir this warm-up is just a disk hit)
    server.handle([pool[0]])
    server.reset_stats()

    t0 = time.perf_counter()
    futs = [server.submit(pool[i]) for i in stream]
    plans = [f.result(timeout=300) for f in futs]
    wall = time.perf_counter() - t0
    server.close()

    s = server.stats()
    print(f"[serve-selector] path={args.path} pallas={args.use_pallas} "
          f"batch={args.batch} wait={args.max_wait_ms}ms "
          f"workers={args.build_workers} "
          f"disk={'off' if not cache_dir else cache_dir}")
    print(f"[serve-selector] {args.requests} requests in {wall*1e3:.0f} ms "
          f"→ {args.requests / wall:.0f} plans/sec end-to-end")
    print(f"[serve-selector] cache: {s['hits']} hits / {s['misses']} misses "
          f"(hit rate {s['hit_rate']:.2f}), {s['evictions']} evictions, "
          f"size {s['size']}/{s['capacity']}"
          + (f", disk {s['disk_hits']} hits / {s['disk_entries']} entries"
             if "disk_hits" in s else ""))
    print(f"[serve-selector] latency: p50 {s.get('p50_ms', 0.0):.2f} ms, "
          f"p99 {s.get('p99_ms', 0.0):.2f} ms "
          f"({s['warm_hits']} warm submits)")
    print(f"[serve-selector] cold stages: select {s['select_calls']} calls "
          f"{s['select_seconds']*1e3:.0f} ms, "
          f"{s['plans_built']} plans built {s['build_seconds']*1e3:.0f} ms")
    if s.get("max_disk_bytes") or s.get("max_disk_entries"):
        print(f"[serve-selector] disk budget: {s['disk_bytes']} bytes / "
              f"{s['disk_entries']} files, {s['disk_evictions']} evictions")
    dist = collections.Counter(pl.algorithm for pl in plans)
    print(f"[serve-selector] plan distribution: {dict(sorted(dist.items()))}")


if __name__ == "__main__":
    main()
