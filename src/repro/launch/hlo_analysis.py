"""Loop-aware static analysis of post-optimization HLO text.

``compiled.cost_analysis()`` visits each while-loop body ONCE (measured: a
scan of 8 layers reports 1/8 of the unrolled FLOPs) and exposes no
collective statistics. This module parses ``compiled.as_text()`` into
computations with per-computation symbol tables (operand shapes are not
inlined in this XLA's text format), walks the call graph from the entry,
multiplies while bodies by their ``known_trip_count`` annotation (recorded
for jax.lax.scan), and accumulates:

* ``dot_flops``        — 2·|result|·|contracted| per dot (the MXU term).
* ``collective_bytes`` — per-device wire bytes per collective kind
                         (all-reduce counted 2× for the ring reduce+bcast).
* ``touched_bytes``    — post-fusion boundary bytes (operands+results of
                         top-level ops) — the HBM-traffic proxy.
* amplification ratios (with-trips / without-trips) to loop-correct
  cost_analysis numbers as a cross-check.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloStats"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"[\(,]\s*%?([\w.\-]+)")
# Operand entry with an optional inline type annotation, e.g.
#   dot(f32[64,128]{1,0} %lhs, f32[128,128]{1,0} %rhs)
# Newer XLA text inlines operand types; older text is name-only.
_OPERAND_TYPED_RE = re.compile(
    r"(?:([a-z0-9]+\[[0-9,]*\](?:\{[0-9,]*\})?)\s+)?%([\w.\-]+)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_MEM_OPS = {"fusion", "dot", "copy", "dynamic-slice", "dynamic-update-slice",
            "transpose", "concatenate", "pad", "slice", "reduce", "convert",
            "broadcast", "reshape", "gather", "scatter", "sort", "iota",
            "convolution", "reduce-window", "select-and-scatter",
            "custom-call"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    result_type: str   # text before the op name (may be a tuple type)
    rhs: str           # full right-hand side


@dataclasses.dataclass
class HloStats:
    dot_flops: float
    dot_bytes: float
    collective_bytes: Dict[str, float]
    collective_counts: Dict[str, float]
    touched_bytes: float
    flops_amplification: float
    bytes_amplification: float
    n_while_loops: int
    unknown_trip_loops: int

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def to_json(self) -> dict:
        return dict(dot_flops=self.dot_flops,
                    dot_bytes=self.dot_bytes,
                    collective_bytes=dict(self.collective_bytes),
                    collective_counts=dict(self.collective_counts),
                    total_collective_bytes=self.total_collective_bytes,
                    touched_bytes=self.touched_bytes,
                    flops_amplification=self.flops_amplification,
                    bytes_amplification=self.bytes_amplification,
                    n_while_loops=self.n_while_loops,
                    unknown_trip_loops=self.unknown_trip_loops)


_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_PARAM_RE = re.compile(r"%?([\w.\-]+)\s*:\s*([a-z0-9]+\[[0-9,]*\]|\([^)]*\))")


def _split_computations(text: str):
    comps: Dict[str, List[_Op]] = {}
    symtab: Dict[str, Dict[str, str]] = {}
    entry: Optional[str] = None
    current: Optional[str] = None
    for line in text.splitlines():
        hdr = _HDR_RE.match(line)
        if hdr and "=" not in line.split("(")[0]:
            current = hdr.group(1)
            comps[current] = []
            symtab[current] = {}
            if line.lstrip().startswith("ENTRY"):
                entry = current
            # parameters declared in the header
            for pname, ptype in _PARAM_RE.findall(line):
                symtab[current][pname] = ptype
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        d = _DEF_RE.match(line)
        if not d:
            continue
        name, rhs = d.group(1), d.group(2)
        # op kind: first identifier followed by '(' after the result type
        km = re.search(r"\}?\s([a-z][a-z0-9\-]*)\(", " " + rhs)
        kind = km.group(1) if km else ""
        result_type = rhs.split(kind + "(")[0] if kind else rhs
        symtab[current][name] = result_type
        comps[current].append(_Op(name, kind, result_type, rhs))
    return comps, symtab, entry


def analyze_hlo(text: str) -> HloStats:
    comps, symtab, entry = _split_computations(text)
    if entry is None:
        # fallback: last computation
        entry = list(comps)[-1]

    stats = dict(dot=0.0, touched=0.0, dot_bytes=0.0)
    noloop = dict(dot=0.0, touched=0.0)
    coll_bytes: Dict[str, float] = defaultdict(float)
    coll_counts: Dict[str, float] = defaultdict(float)
    counters = dict(n_while=0, unknown=0)

    def operand_types(rhs: str, kind: str, table: Dict[str, str]) -> List[str]:
        """Resolved type strings of an op's operands.

        Prefers the inline type annotation when the text format carries one
        (``dot(f32[64,128]{1,0} %lhs, ...)``); falls back to the computation
        symbol table for name-only formats. Without this, the name regex used
        to match the *type* token ("f32") as an operand name, so shape
        lookups came back empty and dot contraction dims collapsed to 1.
        """
        inner = rhs.split(kind + "(", 1)[1] if kind + "(" in rhs else ""
        # cut at the closing paren of the operand list (operands hold no parens)
        inner = inner.split(")")[0]
        typed = _OPERAND_TYPED_RE.findall(inner)
        if typed:
            return [t if t else table.get(name, "") for t, name in typed]
        # name-only dialect without % prefixes
        return [table.get(m.group(1), "")
                for m in _OPERAND_RE.finditer("(" + inner)]

    def walk(comp: str, mult: float, depth: int):
        if comp not in comps or depth > 64:
            return
        table = symtab[comp]
        for op in comps[comp]:
            if op.kind == "while":
                counters["n_while"] += 1
                t = _TRIP_RE.search(op.rhs)
                trips = float(t.group(1)) if t else 1.0
                if not t:
                    counters["unknown"] += 1
                bm = re.search(r"body=%?([\w.\-]+)", op.rhs)
                if bm:
                    walk(bm.group(1), mult * trips, depth + 1)
                continue
            if op.kind in ("call", "conditional"):
                for cm in re.finditer(
                        r"(?:to_apply|branch_computations=\{|calls=)"
                        r"%?([\w.\-]+)", op.rhs):
                    walk(cm.group(1), mult, depth + 1)
            if op.kind == "dot":
                rdims = _first_dims(op.result_type)
                rn = 1
                for d in rdims:
                    rn *= d
                contract = 1
                otypes = operand_types(op.rhs, "dot", table)
                cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rhs)
                if otypes and cdims and cdims.group(1):
                    ldims = _first_dims(otypes[0])
                    for ci in cdims.group(1).split(","):
                        ci = int(ci)
                        if ci < len(ldims):
                            contract *= ldims[ci]
                f = 2.0 * rn * contract
                stats["dot"] += mult * f
                noloop["dot"] += f
                # matmul-boundary HBM traffic: lhs + rhs + result bytes
                # (the fusion-safe floor of true traffic — see §Roofline)
                db = _shape_bytes(op.result_type)
                for otype in otypes:
                    db += _shape_bytes(otype)
                stats["dot_bytes"] += mult * db
            if op.kind in _COLLECTIVES:
                b = _shape_bytes(op.result_type)
                if op.kind == "reduce-scatter":
                    otypes = operand_types(op.rhs, op.kind, table)
                    if otypes and otypes[0]:
                        b = _shape_bytes(otypes[0])
                factor = 2.0 if op.kind == "all-reduce" else 1.0
                coll_bytes[op.kind] += mult * factor * b
                coll_counts[op.kind] += mult
            if op.kind in _MEM_OPS:
                b = _shape_bytes(op.result_type)
                for otype in operand_types(op.rhs, op.kind, table):
                    b += _shape_bytes(otype)
                stats["touched"] += mult * b
                noloop["touched"] += b

    walk(entry, 1.0, 0)
    flops_amp = stats["dot"] / noloop["dot"] if noloop["dot"] else 1.0
    bytes_amp = (stats["touched"] / noloop["touched"]
                 if noloop["touched"] else 1.0)
    return HloStats(stats["dot"], stats["dot_bytes"], dict(coll_bytes),
                    dict(coll_counts), stats["touched"], flops_amp,
                    bytes_amp, counters["n_while"], counters["unknown"])
