"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --shape train_4k --steps 200 [--smoke] [--devices N] [--fsdp] \
        [--grad-compression] [--ckpt-dir DIR]

``--devices N`` requests N host platform devices (set before jax init) and
builds an N-device (data, model) mesh; with the default 1 there is no mesh
and the single-device path runs. ``--smoke`` swaps in the reduced config and
a small shape so the driver runs end-to-end on a laptop CPU.
"""
import argparse
import os


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama3.2-1b")
    p.add_argument("--shape", default="train_4k")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--devices", type=int, default=1)
    p.add_argument("--data-par", type=int, default=0,
                   help="data axis size (default devices//model_par)")
    p.add_argument("--model-par", type=int, default=1)
    p.add_argument("--fsdp", action="store_true")
    p.add_argument("--grad-compression", action="store_true")
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--seq-len", type=int, default=0)
    p.add_argument("--batch", type=int, default=0)
    args = p.parse_args()

    if args.devices > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax  # noqa: E402 — after XLA_FLAGS

    from repro.configs import get_config, get_smoke_config
    from repro.distributed.sharding import ExecutionPlan
    from repro.models.config import SHAPES, ShapeSpec
    from repro.train import Trainer, TrainerConfig

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    if args.smoke:
        shape = ShapeSpec("smoke_train", args.seq_len or 128,
                          args.batch or 8, "train")
    else:
        base = SHAPES[args.shape]
        shape = ShapeSpec(base.name, args.seq_len or base.seq_len,
                          args.batch or base.global_batch, base.kind)

    mesh = None
    data_axes = ("data",)
    if args.devices > 1:
        mp = args.model_par
        dp = args.data_par or args.devices // mp
        assert dp * mp == args.devices, "data_par × model_par must = devices"
        mesh = jax.make_mesh((dp, mp), ("data", "model"))

    plan = ExecutionPlan(fsdp_params=args.fsdp,
                         grad_compression=args.grad_compression)
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                         total_steps=args.steps,
                         warmup_steps=max(args.steps // 20, 5))
    trainer = Trainer(cfg, shape, tcfg, mesh=mesh, plan=plan,
                      data_axes=data_axes)
    trainer.run_with_restart(args.steps)
    print("[train] done")


if __name__ == "__main__":
    main()
