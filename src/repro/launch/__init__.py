"""Launchers: production mesh, multi-pod dry-run, train/serve CLIs.

NOTE: do not import `repro.launch.dryrun` from library code — it sets
XLA_FLAGS at import time (by design: it must run as its own process).
"""
from .mesh import make_production_mesh, mesh_axes

__all__ = ["make_production_mesh", "mesh_axes"]
