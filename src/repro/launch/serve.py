"""Serving launcher: prefill a batch of requests, then batched decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --prompt-len 64 --decode-steps 16 --batch 4

Demonstrates the full KV-cache path (prefill → N decode steps) with greedy
sampling and reports per-phase latency. ``--devices N`` builds an N-device
mesh with the cache sharded per `repro.distributed.sharding.cache_specs`.
"""
import argparse
import os
import time


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-1.7b")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--devices", type=int, default=1)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--decode-steps", type=int, default=16)
    args = p.parse_args()

    if args.devices > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax  # noqa: E402
    import jax.numpy as jnp  # noqa: E402
    import numpy as np  # noqa: E402

    from repro.configs import get_config, get_smoke_config
    from repro.models import decode_step, init_params, prefill

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    max_seq = args.prompt_len + args.decode_steps
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.PRNGKey(0))

    if cfg.input_mode == "tokens":
        batch = {"tokens": jnp.asarray(rng.integers(
            0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)}
    else:
        batch = {"embeds": jnp.asarray(rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)), jnp.float32)}

    pf = jax.jit(lambda p, b: prefill(cfg, p, b, max_seq=max_seq))
    dc = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))

    t0 = time.perf_counter()
    logits, cache = pf(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(args.decode_steps):
        if cfg.input_mode != "tokens":
            tok_in = jnp.zeros((args.batch, 1, cfg.d_model), jnp.float32)
        else:
            tok_in = tok
        logits, cache = dc(params, cache, tok_in)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        toks.append(np.asarray(tok[:, 0]))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    print(f"[serve] {cfg.name}: prefill({args.batch}×{args.prompt_len}) "
          f"{t_prefill*1e3:.0f} ms; {args.decode_steps} decode steps "
          f"{t_decode*1e3:.0f} ms "
          f"({t_decode/args.decode_steps*1e3:.1f} ms/tok)")
    print("[serve] sampled tokens (seq 0):", [int(t[0]) for t in toks])


if __name__ == "__main__":
    main()
