"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS *before* any jax
initialization and only then calls this.

Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — 'pod' extends the
data-parallel dimension across the inter-pod (DCN/optical) boundary; batch
shards over ('pod', 'data').
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_axes"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes(multi_pod: bool = False):
    """(data_axes, model_axis) for a production mesh."""
    return (("pod", "data") if multi_pod else ("data",)), "model"
