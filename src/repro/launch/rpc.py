"""RPC front-end for the plan-serving plane: length-prefixed socket protocol.

    PYTHONPATH=src python -m repro.launch.rpc --port 7077   # serve
    PYTHONPATH=src python -m repro.launch.rpc --smoke       # CI round trip

The :class:`AsyncPlanServer` pipeline was in-process only — a plan request
had to originate in the serving process itself. This module puts a real
(stdlib-only) transport in front of the same dispatch core so separate
processes — other hosts' solver jobs, load generators, sibling replicas —
submit matrices over a socket and get :class:`ExecutionPlan`s back:

    client                      server
    ------                      ------
    frame{op: plan, csr}  --->  PlanRPCServer (accept/conn threads)
                                  └→ AsyncPlanServer.submit (micro-batching,
                                     sharded featurize→infer, build pool,
                                     replica-shared two-tier cache)
    frame{ok, plan}       <---  future resolves

**Framing.** Every message is a 4-byte big-endian length followed by a
pickle payload — the classic length-prefixed protocol, trivially
implementable from any language with a pickle bridge and robust under
partial reads (``_recv_exact`` loops). Requests and responses are plain
dicts; matrices travel as their CSR arrays, plans as pickled
:class:`ExecutionPlan` objects.

**Trust boundary.** Payloads are pickles, so the server must only listen
where clients are trusted (localhost or a private service mesh) — the same
trust model as the shared plan-cache directory, whose entries are also
pickles. This is infrastructure RPC, not a public API gateway.

Ops: ``ping`` (liveness + server identity), ``plan`` (one matrix → plan),
``plan_batch`` (many), ``select`` (names only, no plan build), ``stats``,
``metrics`` (structured-metrics snapshot), ``shutdown`` (drain and stop
the listener).

**Request identity.** ``plan``/``plan_batch`` requests carry optional
``request_id`` (``request_ids`` for batches), ``deadline_ms`` and
``priority`` fields; the server mints a
:class:`repro.core.reqctx.RequestContext` from them (or from nothing) and
threads it through the dispatch pipeline, so every response echoes the
request id and reports ``spans_ms`` — per-stage wall time (queue, select,
build, cache, …) measured by the layers themselves. Error responses are
*structured*: ``{ok: False, error, error_type, op, request_id}``, and the
client re-raises serving errors by type — a shed request raises
:class:`~repro.core.reqctx.DeadlineExceeded` client-side, a backpressure
rejection :class:`~repro.core.reqctx.QueueFull`, a shutdown race
:class:`~repro.core.reqctx.DispatcherClosed`; anything else is an
:class:`RPCError`. A malformed frame (unpicklable payload, hostile length
prefix) is answered with a structured error frame before the connection is
dropped — the stream has no boundary to resync to, but the peer at least
learns why.
"""
from __future__ import annotations

import argparse
import pickle
import socket
import socketserver
import struct
import threading
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.reqctx import SERVING_ERRORS, RequestContext, ServingError
from repro.sparse.csr import CSRMatrix

__all__ = ["PlanRPCServer", "PlanRPCClient", "RPCError", "error_frame",
           "raise_from_frame", "main"]

_LEN = struct.Struct(">I")
MAX_FRAME = 1 << 30  # 1 GiB: rejects garbage/hostile length prefixes


class RPCError(RuntimeError):
    """Server-side failure surfaced to the client (message carried over).

    ``error_type`` holds the server-side exception class name,
    ``request_id`` the request the failure belongs to (both may be None
    for protocol-level failures)."""

    def __init__(self, message: str, *, error_type: Optional[str] = None,
                 request_id: Optional[str] = None):
        super().__init__(message)
        self.error_type = error_type
        self.request_id = request_id


def error_frame(exc_or_msg, *, op: Optional[str] = None,
                request_id: Optional[str] = None) -> Dict[str, Any]:
    """Structured error response: always carries op + request id (possibly
    None) so the client can attribute the failure, and the server-side
    type name so typed serving errors survive the wire."""
    if isinstance(exc_or_msg, BaseException):
        etype = type(exc_or_msg).__name__
        msg = f"{etype}: {exc_or_msg}"
    else:
        etype = "RPCError"
        msg = str(exc_or_msg)
    return {"ok": False, "error": msg, "error_type": etype,
            "op": op, "request_id": request_id}


def raise_from_frame(resp: Dict[str, Any]) -> None:
    """Client side: re-raise a typed serving error by wire name, or an
    :class:`RPCError` carrying the structured fields."""
    etype = resp.get("error_type")
    msg = resp.get("error", "unknown server error")
    cls = SERVING_ERRORS.get(etype or "")
    if cls is not None:
        raise cls(msg)
    raise RPCError(msg, error_type=etype, request_id=resp.get("request_id"))


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("peer closed mid-frame"
                                  if buf else "peer closed")
        buf.extend(chunk)
    return bytes(buf)


def send_frame(sock: socket.socket, obj: Any) -> None:
    payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(_LEN.pack(len(payload)) + payload)


def recv_frame(sock: socket.socket) -> Any:
    (n,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    if n > MAX_FRAME:
        raise RPCError(f"frame of {n} bytes exceeds MAX_FRAME")
    return pickle.loads(_recv_exact(sock, n))


# ---------------------------------------------------------------------------
# CSR wire format — plain arrays, no class pickling on the request path
# ---------------------------------------------------------------------------

def matrix_to_wire(m: CSRMatrix) -> Dict[str, Any]:
    return {"n": int(m.n),
            "indptr": np.asarray(m.indptr, np.int32),
            "indices": np.asarray(m.indices, np.int32),
            "data": None if m.data is None else np.asarray(m.data),
            "name": m.name}


def matrix_from_wire(d: Dict[str, Any]) -> CSRMatrix:
    n = int(d["n"])
    return CSRMatrix(np.asarray(d["indptr"], np.int32),
                     np.asarray(d["indices"], np.int32),
                     None if d.get("data") is None else np.asarray(d["data"]),
                     (n, n), name=str(d.get("name", "")))


# ---------------------------------------------------------------------------
# server
# ---------------------------------------------------------------------------

class PlanRPCServer:
    """Socket front-end over an :class:`AsyncPlanServer`/dispatch core.

    One accept loop, one handler thread per connection (requests on a
    connection are answered in order; concurrency comes from concurrent
    connections, which all feed the same micro-batching queue — exactly
    the fan-in the deadline batcher exists for). ``port=0`` binds an
    ephemeral port, published as ``self.port`` (the launcher prints it).

    ``own_dispatcher=True`` (the default when constructed by
    ``SolverEngine.serve(rpc=True)``) makes ``close()`` shut the dispatch
    core down too; with ``False`` the caller keeps the core for further
    in-process use.
    """

    def __init__(self, dispatcher, host: str = "127.0.0.1", port: int = 0,
                 *, own_dispatcher: bool = True, backlog: int = 128):
        self.dispatcher = dispatcher
        self.own_dispatcher = own_dispatcher
        # the RPC layer reports into the same registry as the dispatch
        # core it fronts — one snapshot covers transport + pipeline
        self.metrics = getattr(dispatcher, "metrics", None)
        self._sock = socket.create_server((host, port), backlog=backlog)
        self.host, self.port = self._sock.getsockname()[:2]
        self._closed = threading.Event()
        self._conns_lock = threading.Lock()
        self._conns: List[socket.socket] = []
        self.started_unix = time.time()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="rpc-accept", daemon=True)
        self._accept_thread.start()

    # -- lifecycle -----------------------------------------------------------
    def close(self, timeout: float = 30.0) -> None:
        if self._closed.is_set():
            return
        self._closed.set()
        try:
            self._sock.close()
        except OSError:
            pass
        with self._conns_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        self._accept_thread.join(timeout)
        if self.own_dispatcher:
            self.dispatcher.close(timeout)

    def serve_forever(self, poll_s: float = 0.2) -> None:
        """Block the calling thread until ``close()`` (the CLI uses this;
        embedders just keep the object around)."""
        while not self._closed.is_set():
            time.sleep(poll_s)

    # -- loops ---------------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._closed.is_set():
            try:
                conn, _addr = self._sock.accept()
            except OSError:
                if self._closed.is_set():
                    break  # listener closed by close()
                # transient accept failure (EMFILE under an fd burst,
                # ECONNABORTED from a mid-handshake RST): the listener is
                # still good — back off briefly and keep accepting rather
                # than silently never answering another client
                time.sleep(0.05)
                continue
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._conns_lock:
                self._conns.append(conn)
            if self.metrics is not None:
                self.metrics.counter("rpc.connections").inc()
            threading.Thread(target=self._serve_conn, args=(conn,),
                             name="rpc-conn", daemon=True).start()

    def _count_request(self) -> None:
        if self.metrics is not None:
            self.metrics.counter("rpc.requests").inc()

    def _count_error(self) -> None:
        if self.metrics is not None:
            self.metrics.counter("rpc.errors").inc()

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while not self._closed.is_set():
                try:
                    req = recv_frame(conn)
                except (ConnectionError, OSError):
                    return
                except Exception as exc:
                    # non-protocol peer (port scanner, HTTP probe) or a
                    # corrupt/hostile frame: answer with a structured
                    # error frame so a real-but-buggy client learns *why*,
                    # then drop the connection — there is no frame
                    # boundary to resync to, so the stream is unusable
                    self._count_error()
                    try:
                        send_frame(conn, error_frame(
                            f"malformed frame: {type(exc).__name__}: {exc}"))
                    except (ConnectionError, OSError):
                        pass
                    return
                self._count_request()
                try:
                    resp = self._handle(req)
                except Exception as exc:  # never kill the conn on one op
                    self._count_error()
                    rid = (req.get("request_id")
                           if isinstance(req, dict) else None)
                    op = req.get("op") if isinstance(req, dict) else None
                    resp = error_frame(exc, op=op, request_id=rid)
                try:
                    send_frame(conn, resp)
                except (ConnectionError, OSError):
                    return
                if isinstance(req, dict) and req.get("op") == "shutdown":
                    # the response frame is on the wire (sendall returned)
                    # — only now is it safe to tear the listener down
                    threading.Thread(target=self.close,
                                     name="rpc-shutdown",
                                     daemon=True).start()
                    return
        finally:
            with self._conns_lock:
                if conn in self._conns:
                    self._conns.remove(conn)
            try:
                conn.close()
            except OSError:
                pass

    # -- op handlers ---------------------------------------------------------
    @staticmethod
    def _mint_ctx(req: Dict[str, Any],
                  request_id: Optional[str] = None) -> RequestContext:
        """Context from the wire fields (all optional): ``request_id`` /
        ``deadline_ms`` / ``priority``. The deadline clock starts *here*,
        at the serving edge — network transit is the client's budget."""
        return RequestContext.mint(
            request_id=request_id or req.get("request_id"),
            deadline_ms=req.get("deadline_ms"),
            priority=int(req.get("priority", 0)))

    def _handle(self, req: Any) -> Dict[str, Any]:
        if not isinstance(req, dict) or "op" not in req:
            return error_frame("malformed request (no op)")
        op = req["op"]
        timeout = float(req.get("timeout", 120.0))
        if op == "ping":
            return {"ok": True, "pong": time.time(),
                    "uptime_s": time.time() - self.started_unix}
        if op == "plan":
            mat = matrix_from_wire(req["matrix"])
            ctx = self._mint_ctx(req)
            t0 = time.perf_counter()
            try:
                plan = self.dispatcher.submit(mat, ctx).result(
                    timeout=timeout)
            except ServingError as exc:
                self._count_error()
                return error_frame(exc, op=op, request_id=ctx.request_id)
            return {"ok": True, "plan": plan,
                    "request_id": ctx.request_id,
                    "spans_ms": ctx.spans_ms(),
                    "server_ms": (time.perf_counter() - t0) * 1e3}
        if op == "plan_batch":
            mats = [matrix_from_wire(d) for d in req["matrices"]]
            rids = req.get("request_ids") or [None] * len(mats)
            ctxs = [self._mint_ctx(req, request_id=r) for r in rids]
            futs, errors = [], {}
            for i, (m, c) in enumerate(zip(mats, ctxs)):
                try:
                    futs.append(self.dispatcher.submit(m, c))
                except ServingError as exc:
                    futs.append(None)
                    errors[i] = exc
            plans: List[Any] = []
            for i, f in enumerate(futs):
                if f is None:
                    plans.append(None)
                    continue
                try:
                    plans.append(f.result(timeout=timeout))
                except ServingError as exc:
                    plans.append(None)
                    errors[i] = exc
            if errors:
                self._count_error()
            return {"ok": True, "plans": plans,
                    "request_ids": [c.request_id for c in ctxs],
                    "spans_ms": [c.spans_ms() for c in ctxs],
                    "errors": {i: error_frame(e, op=op,
                                              request_id=ctxs[i].request_id)
                               for i, e in errors.items()}}
        if op == "select":
            mats = [matrix_from_wire(d) for d in req["matrices"]]
            names = self.dispatcher.builder.select_names(mats)
            return {"ok": True, "algorithms": names}
        if op == "stats":
            return {"ok": True, "stats": self.dispatcher.stats()}
        if op == "metrics":
            snap = (self.metrics.snapshot()
                    if self.metrics is not None else {})
            return {"ok": True, "metrics": snap}
        if op == "shutdown":
            # teardown is deferred to _serve_conn AFTER the response is
            # sent — closing here would race conn.shutdown() against our
            # own reply and the client could see ECONNRESET instead of ok
            return {"ok": True}
        return {"ok": False, "error": f"unknown op {op!r}"}


# ---------------------------------------------------------------------------
# client
# ---------------------------------------------------------------------------

class PlanRPCClient:
    """Blocking client for :class:`PlanRPCServer` (one socket, in-order).

    Usable from any process with network reach to the server — no jax, no
    trained model, no cache directory needed on the client side::

        with PlanRPCClient("127.0.0.1", port) as c:
            plan = c.plan(matrix)          # ExecutionPlan, cold or warm
            names = c.select([m1, m2])     # algorithm names only
            print(c.stats()["hit_rate"])

    ``connect_retries`` retries the initial TCP connect (a just-spawned
    server may not be listening yet). Not thread-safe; use one client per
    thread (connections are cheap, and the server batches across them).
    """

    def __init__(self, host: str, port: int, *, timeout: float = 120.0,
                 connect_retries: int = 20, retry_delay_s: float = 0.25):
        self.timeout = timeout
        last: Optional[Exception] = None
        for _ in range(max(1, connect_retries)):
            try:
                self._sock = socket.create_connection((host, port),
                                                      timeout=timeout)
                break
            except OSError as exc:
                last = exc
                time.sleep(retry_delay_s)
        else:
            raise ConnectionError(
                f"could not reach plan server at {host}:{port}: {last}")
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)

    # -- plumbing ------------------------------------------------------------
    def _call(self, op: str, **payload) -> Dict[str, Any]:
        payload["op"] = op
        payload.setdefault("timeout", self.timeout)
        # optional request fields default to absent, not None-on-the-wire
        for k in ("deadline_ms", "request_id", "request_ids", "priority"):
            if payload.get(k) is None:
                payload.pop(k, None)
        send_frame(self._sock, payload)
        resp = recv_frame(self._sock)
        if not resp.get("ok"):
            raise_from_frame(resp)
        return resp

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass

    def __enter__(self) -> "PlanRPCClient":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -- ops -----------------------------------------------------------------
    def ping(self) -> Dict[str, Any]:
        return self._call("ping")

    def plan(self, mat: CSRMatrix, *, deadline_ms: Optional[float] = None,
             priority: Optional[int] = None,
             request_id: Optional[str] = None):
        """One matrix → its :class:`ExecutionPlan` (server-cached).

        ``deadline_ms``/``priority``/``request_id`` ride the wire into the
        server-side :class:`RequestContext`; a shed request raises
        :class:`~repro.core.reqctx.DeadlineExceeded`, a backpressure
        rejection :class:`~repro.core.reqctx.QueueFull`."""
        return self.plan_detailed(mat, deadline_ms=deadline_ms,
                                  priority=priority,
                                  request_id=request_id)["plan"]

    def plan_detailed(self, mat: CSRMatrix, *,
                      deadline_ms: Optional[float] = None,
                      priority: Optional[int] = None,
                      request_id: Optional[str] = None) -> Dict[str, Any]:
        """Full ``plan`` response: plan + ``request_id`` + per-stage
        ``spans_ms`` + ``server_ms`` (the RequestContext's telemetry)."""
        return self._call("plan", matrix=matrix_to_wire(mat),
                          deadline_ms=deadline_ms, priority=priority,
                          request_id=request_id)

    def plan_with_timing(self, mat: CSRMatrix):
        """(plan, server-side milliseconds) — the smoke test uses the
        server time to show warm ≪ cold independent of network jitter."""
        r = self._call("plan", matrix=matrix_to_wire(mat))
        return r["plan"], r["server_ms"]

    def plan_batch(self, mats: Sequence[CSRMatrix], *,
                   deadline_ms: Optional[float] = None,
                   priority: Optional[int] = None) -> List:
        """Plans for a batch. Raises the first typed serving error if any
        member was shed/rejected; ``plan_batch_detailed`` returns partial
        results instead."""
        r = self.plan_batch_detailed(mats, deadline_ms=deadline_ms,
                                     priority=priority)
        errs = r.get("errors") or {}
        if errs:
            raise_from_frame(next(iter(errs.values())))
        return r["plans"]

    def plan_batch_detailed(self, mats: Sequence[CSRMatrix], *,
                            deadline_ms: Optional[float] = None,
                            priority: Optional[int] = None,
                            request_ids: Optional[Sequence[str]] = None
                            ) -> Dict[str, Any]:
        """Full ``plan_batch`` response: ``plans`` (None where a member
        failed), ``request_ids``, per-request ``spans_ms``, and ``errors``
        (index → structured error frame)."""
        return self._call("plan_batch",
                          matrices=[matrix_to_wire(m) for m in mats],
                          deadline_ms=deadline_ms, priority=priority,
                          request_ids=(list(request_ids)
                                       if request_ids else None))

    def select(self, mats: Sequence[CSRMatrix]) -> List[str]:
        return self._call("select",
                          matrices=[matrix_to_wire(m)
                                    for m in mats])["algorithms"]

    def stats(self) -> Dict[str, Any]:
        return self._call("stats")["stats"]

    def metrics(self) -> Dict[str, Any]:
        """Structured-metrics snapshot (counters/gauges/histograms) of the
        server's registry — transport and pipeline in one dict."""
        return self._call("metrics")["metrics"]

    def shutdown(self) -> None:
        self._call("shutdown")


# ---------------------------------------------------------------------------
# entrypoint: serve a trained engine over RPC / run the CI smoke
# ---------------------------------------------------------------------------

def _train_tiny_engine(args):
    from repro.core.labeling import load_or_build
    from repro.engine import EngineConfig, SolverEngine

    engine = SolverEngine(EngineConfig(
        model=args.model, cache_dir=args.cache_dir or None,
        serving_devices=args.devices, batch_size=args.batch,
        fast_grids=True, cv=3, seed=0))
    ds = load_or_build(cache_dir="artifacts", count=args.campaign_count,
                       seed=7, size_scale=args.campaign_scale, repeats=1,
                       verbose=False)
    rep = engine.train(ds)
    print(f"[rpc] model={args.model} test_acc={rep['test_accuracy']:.2f} "
          f"fingerprint={engine.fingerprint[:16]}")
    return engine


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0,
                   help="0 binds an ephemeral port (printed)")
    p.add_argument("--bundle", default=None,
                   help="serve this SelectorBundle instead of training")
    p.add_argument("--model", default="decision_tree")
    p.add_argument("--devices", type=int, default=None,
                   help="serving-mesh device count (None: degenerate 1)")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--cache-dir", default="artifacts/plan_cache")
    p.add_argument("--campaign-count", type=int, default=12)
    p.add_argument("--campaign-scale", type=float, default=0.25)
    p.add_argument("--smoke", action="store_true",
                   help="serve, then run a cold+warm round trip from a "
                        "separate client process and exit nonzero on "
                        "failure (the CI leg)")
    args = p.parse_args()

    from repro.engine import EngineConfig, SolverEngine

    if args.bundle:
        engine = SolverEngine.load(args.bundle, EngineConfig(
            cache_dir=args.cache_dir or None, serving_devices=args.devices,
            batch_size=args.batch))
    else:
        engine = _train_tiny_engine(args)

    server = engine.serve(rpc=True, host=args.host, port=args.port)
    print(f"[rpc] serving on {server.host}:{server.port} "
          f"(mesh devices: {args.devices or 1})", flush=True)

    if args.smoke:
        rc = _smoke(server)
        server.close()
        raise SystemExit(rc)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        server.close()


def _smoke(server: PlanRPCServer) -> int:
    """Cold + warm request from a *separate client process* (the
    acceptance criterion): the child connects over TCP, plans the same
    structure twice, and asserts the second hit is served from cache."""
    import json
    import subprocess
    import sys

    child = (
        "import json, sys\n"
        "import numpy as np\n"
        "from repro.launch.rpc import PlanRPCClient\n"
        "from repro.sparse.dataset import grid2d\n"
        "port = int(sys.argv[1])\n"
        "m = grid2d(9, 9, 'smoke')\n"
        "with PlanRPCClient('127.0.0.1', port) as c:\n"
        "    pong = c.ping()\n"
        "    plan_cold, ms_cold = c.plan_with_timing(m)\n"
        "    plan_warm, ms_warm = c.plan_with_timing(m)\n"
        "    stats = c.stats()\n"
        "assert plan_cold.algorithm == plan_warm.algorithm\n"
        "assert np.array_equal(plan_cold.perm, plan_warm.perm)\n"
        "assert stats['warm_hits'] >= 1, stats\n"
        "print(json.dumps({'cold_ms': ms_cold, 'warm_ms': ms_warm,\n"
        "                  'algorithm': plan_cold.algorithm,\n"
        "                  'warm_hits': stats['warm_hits']}))\n"
    )
    r = subprocess.run([sys.executable, "-c", child, str(server.port)],
                       capture_output=True, text=True, timeout=300)
    if r.returncode != 0:
        print(f"[rpc-smoke] FAIL\n{r.stdout}\n{r.stderr}")
        return 1
    out = json.loads(r.stdout.strip().splitlines()[-1])
    print(f"[rpc-smoke] OK cold {out['cold_ms']:.1f} ms → warm "
          f"{out['warm_ms']:.2f} ms ({out['algorithm']}, "
          f"{out['warm_hits']} warm hits)")
    return 0


if __name__ == "__main__":
    main()
