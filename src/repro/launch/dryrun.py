import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell on
the production mesh with 512 placeholder host devices, and extract the
roofline inputs from the compiled artifact.

MUST be run as its own process (the XLA_FLAGS line above executes before any
other import — jax locks the device count on first init). One cell per
invocation keeps compile memory bounded and lets the sweep be resumable:

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k [--multi-pod] [--plan-json '{"fsdp_params": true}']
    PYTHONPATH=src python -m repro.launch.dryrun --all   # full sweep

Per cell it writes ``artifacts/dryrun/<mesh>/<arch>__<shape>[__tag].json``
holding memory_analysis, cost_analysis, loop-corrected dot FLOPs, and
per-kind collective bytes (see repro.launch.hlo_analysis). §Roofline in
EXPERIMENTS.md is generated from these artifacts by benchmarks/roofline.py.
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.distributed.meshctx import MeshContext, mesh_context
from repro.distributed.sharding import (ExecutionPlan, batch_specs,
                                        cache_specs, opt_state_spec_for,
                                        param_specs, to_shardings)
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh, mesh_axes
from repro.models.config import SHAPES, ModelConfig, ShapeSpec
from repro.models.transformer import (decode_step, init_cache, init_params,
                                      loss_fn, prefill)
from repro.train.data import input_specs
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

# v5e hardware constants for the roofline terms
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # B/s per chip
ICI_BW = 50e9              # B/s per link (per-device wire bytes / this)


def cell_is_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """Returns a skip reason or None."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("skipped: pure full-attention arch — 500k-token decode is "
                "reserved for sub-quadratic (SSM/hybrid) archs per the "
                "assignment (see DESIGN.md §Arch-applicability)")
    return None


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, data_axes,
               model_axis, plan: ExecutionPlan):
    """Returns (fn, example_args, in_shardings, donate) for the cell."""
    cfg = plan.apply(cfg)
    if plan.pure_dp:
        # flat DP/FSDP over every mesh axis: batch shards over all of them
        data_axes = tuple(dict.fromkeys(tuple(data_axes) + (model_axis,)))
    n_model = int(mesh.shape[model_axis])
    attn_tp = cfg.num_heads % n_model == 0
    attn_dp = (tuple(data_axes) + (model_axis,)
               if (not attn_tp and not plan.pure_dp
                   and plan.attn_batch_reshard) else None)
    ctx = MeshContext(mesh, tuple(data_axes), model_axis,
                      attn_dp_axes=attn_dp,
                      shard_activation_ckpt=plan.shard_activation_ckpt)
    with mesh_context(ctx):
        pshape = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = param_specs(pshape, cfg, plan, model_axis=model_axis,
                         data_axes=tuple(data_axes),
                         n_model=int(mesh.shape[model_axis]))
    pshard = to_shardings(pspecs, mesh)
    batch_sds = input_specs(cfg, shape)

    if shape.kind == "train":
        oshape = jax.eval_shape(init_opt_state, pshape)
        from jax.sharding import PartitionSpec as P
        ospecs = dict(master=jax.tree_util.tree_map(
            lambda s, l: opt_state_spec_for(s, l.shape, tuple(data_axes), mesh),
            pspecs, oshape["master"],
            is_leaf=lambda x: isinstance(x, P)))
        ospecs["m"] = ospecs["master"]
        ospecs["v"] = ospecs["master"]
        ospecs["count"] = P()
        oshard = to_shardings(ospecs, mesh)
        bshard = to_shardings(batch_specs(cfg, shape, tuple(data_axes)), mesh)
        ocfg = AdamWConfig()

        def train_step(params, opt, batch, step):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
            params, opt, om = adamw_update(grads, opt, params, ocfg, 1.0)
            return params, opt, dict(loss=loss, **metrics, **om)

        args = (pshape, oshape, batch_sds, jnp.int32(0))
        in_sh = (pshard, oshard, bshard, None)
        return train_step, args, in_sh, (0, 1), ctx

    if shape.kind == "prefill":
        bshard = to_shardings(batch_specs(cfg, shape, tuple(data_axes)), mesh)

        def prefill_step(params, batch):
            return prefill(cfg, params, batch, max_seq=shape.seq_len)

        args = (pshape, batch_sds)
        return prefill_step, args, (pshard, bshard), (), ctx

    # decode: one token against a seq_len cache
    n_data_sz = 1
    for ax in data_axes:
        n_data_sz *= mesh.shape[ax]
    batch_sharded = (shape.global_batch % n_data_sz == 0
                     and shape.global_batch >= n_data_sz)
    if plan.seq_shard_decode and not batch_sharded:
        heads_on_model = cfg.num_kv_heads % n_model == 0
        seq_axes = (tuple(data_axes) if heads_on_model
                    else tuple(data_axes) + (model_axis,))
        ctx = dataclasses.replace(ctx, decode_seq_axes=seq_axes)
    with mesh_context(ctx):
        cache_shape = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    cshard = to_shardings(
        cache_specs(cfg, shape, mesh, model_axis=model_axis,
                    data_axes=tuple(data_axes)), mesh)
    tok_sds = batch_sds["tokens" if cfg.input_mode == "tokens" else "embeds"]
    n_data = 1
    for ax in data_axes:
        n_data *= mesh.shape[ax]
    from jax.sharding import NamedSharding, PartitionSpec as P
    da = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    tok_spec = P(da, *([None] * (len(tok_sds.shape) - 1))) \
        if shape.global_batch >= n_data else P(*([None] * len(tok_sds.shape)))
    tshard = NamedSharding(mesh, tok_spec)

    def serve_step(params, cache, tokens):
        return decode_step(cfg, params, cache, tokens)

    args = (pshape, cache_shape, tok_sds)
    return serve_step, args, (pshard, cshard, tshard), (1,), ctx


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             plan: ExecutionPlan = ExecutionPlan(), out_dir="artifacts/dryrun",
             tag: str = "", verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
    out_path = os.path.join(
        out_dir, mesh_name,
        f"{arch}__{shape_name}{('__' + tag) if tag else ''}.json")

    record: dict = dict(arch=arch, shape=shape_name, mesh=mesh_name,
                        plan=dataclasses.asdict(plan),
                        model_params=cfg.param_count(),
                        active_params=cfg.active_param_count())
    skip = cell_is_applicable(cfg, shape)
    if skip:
        record["status"] = skip
        with open(out_path, "w") as f:
            json.dump(record, f, indent=2)
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: {skip}")
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    data_axes, model_axis = mesh_axes(multi_pod)
    n_chips = mesh.devices.size

    t0 = time.perf_counter()
    try:
        fn, args, in_sh, donate, ctx = build_cell(cfg, shape, mesh,
                                                  data_axes, model_axis, plan)
        with mesh_context(ctx):
            jitted = jax.jit(fn, in_shardings=in_sh,
                             donate_argnums=donate or None)
            lowered = jitted.lower(*args)
            t_lower = time.perf_counter() - t0
            t0 = time.perf_counter()
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0

        ma = compiled.memory_analysis()
        print(ma)                      # proves it fits
        ca = compiled.cost_analysis()
        if isinstance(ca, list):       # older JAX: one dict per device
            ca = ca[0] if ca else {}
        print({k: ca[k] for k in ("flops", "bytes accessed")
               if k in ca})           # FLOPs/bytes for §Roofline
        hlo = analyze_hlo(compiled.as_text())

        per_dev_bytes = dict(
            argument=int(ma.argument_size_in_bytes),
            output=int(ma.output_size_in_bytes),
            temp=int(ma.temp_size_in_bytes),
            alias=int(ma.alias_size_in_bytes),
            code=int(ma.generated_code_size_in_bytes),
        )
        resident = (per_dev_bytes["argument"] + per_dev_bytes["temp"]
                    - per_dev_bytes["alias"])
        # loop-corrected per-device numbers (analyzer counts per-device HLO)
        dot_flops_dev = hlo.dot_flops
        ca_flops_corrected = float(ca.get("flops", 0.0)
                                   ) * hlo.flops_amplification
        # HBM traffic proxy: matmul-boundary bytes (lhs+rhs+out per dot).
        # cost_analysis "bytes accessed" counts every unfused CPU op —
        # converts alone inflate it ~30× vs what a TPU fusion would touch.
        bytes_dev = hlo.dot_bytes
        coll_dev = hlo.total_collective_bytes

        # steps/tokens accounting for MODEL_FLOPS
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                       else 1)
        n_active = cfg.active_param_count()
        mult = 6 if shape.kind == "train" else 2
        model_flops = mult * n_active * tokens

        compute_s = dot_flops_dev / PEAK_FLOPS
        memory_s = bytes_dev / HBM_BW
        collective_s = coll_dev / ICI_BW
        terms = dict(compute_s=compute_s, memory_s=memory_s,
                     collective_s=collective_s)
        bottleneck = max(terms, key=terms.get)

        record.update(
            status="ok",
            t_lower_s=round(t_lower, 1), t_compile_s=round(t_compile, 1),
            n_chips=int(n_chips),
            memory=per_dev_bytes, resident_bytes=int(resident),
            fits_hbm=bool(resident < 16e9),
            cost_analysis=dict(flops=float(ca.get("flops", 0.0)),
                               bytes_accessed=float(ca.get("bytes accessed", 0.0)),
                               transcendentals=float(ca.get("transcendentals", 0.0))),
            hlo=hlo.to_json(),
            per_device=dict(dot_flops=dot_flops_dev,
                            ca_flops_corrected=ca_flops_corrected,
                            bytes=bytes_dev, dot_bytes=hlo.dot_bytes,
                            ca_bytes_corrected=float(
                                ca.get("bytes accessed", 0.0))
                            * hlo.bytes_amplification,
                            collective_bytes=coll_dev),
            roofline=dict(**terms, bottleneck=bottleneck,
                          model_flops=model_flops,
                          hlo_flops_global=dot_flops_dev * n_chips,
                          useful_flops_ratio=(
                              model_flops / (dot_flops_dev * n_chips)
                              if dot_flops_dev else 0.0)),
        )
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: OK "
                  f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s, "
                  f"resident {resident/1e9:.2f} GB/dev, "
                  f"bottleneck {bottleneck})")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        record["status"] = f"FAILED: {type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: FAILED {e}")

    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_NAMES)
    p.add_argument("--shape", choices=list(SHAPES))
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--all", action="store_true",
                   help="run every (arch × shape) for the selected mesh")
    p.add_argument("--plan-json", default="",
                   help='ExecutionPlan overrides, e.g. \'{"fsdp_params":true}\'')
    p.add_argument("--tag", default="", help="artifact suffix for perf exps")
    p.add_argument("--out-dir", default="artifacts/dryrun")
    args = p.parse_args()

    plan = ExecutionPlan(**json.loads(args.plan_json)) if args.plan_json \
        else ExecutionPlan()

    if args.all:
        for arch in ARCH_NAMES:
            for shape_name in SHAPES:
                run_cell(arch, shape_name, args.multi_pod, plan,
                         args.out_dir, args.tag)
        return
    assert args.arch and args.shape, "--arch/--shape or --all required"
    run_cell(args.arch, args.shape, args.multi_pod, plan, args.out_dir,
             args.tag)


if __name__ == "__main__":
    main()
