"""Dense frontal-matrix factorization kernels for the multifrontal solver.

The multifrontal method reduces sparse Cholesky to *partial factorizations*
of dense fronts — `repro.sparse.multifrontal` builds the assembly tree and
calls :func:`repro.kernels.ops.frontal_factor`, which orchestrates three
Pallas kernels over 128-aligned VMEM tiles:

* ``chol_tile``     — unblocked Cholesky of one diagonal tile (the only
                      sequential piece; O(bs) fori_loop steps on a VMEM tile).
* ``tri_inv_tile``  — forward-substitution inverse of the tile's L factor,
                      turning the panel triangular-solve into a matmul.
* ``matmul_nt``     — tiled C ± A·Bᵀ with f32 VMEM accumulator; carries both
                      the panel solve (W·L⁻ᵀ) and the Schur update
                      (S −= L21·L21ᵀ), i.e. all the MXU FLOPs.
* ``frontal_factor_batch`` — the level-scheduled workhorse: a grid over the
                      batch dim where each program runs the *whole* blocked
                      right-looking partial factorization of one front
                      (chol tile → panel tri-solve → Schur rank-bs update,
                      fused, f32 accumulate) entirely in VMEM. One launch
                      factors every same-shape front of an assembly-tree
                      level — no per-front host round trips.
* ``tri_solve_batch`` — the level-scheduled *substitution* workhorse: one
                      grid program runs the whole blocked forward (``L y =
                      b``) or backward (``Lᵀ x = y``) substitution of one
                      front's RHS slab, reusing ``tri_inv_tile``'s block
                      inverse so every panel step is matmul-shaped. The RHS
                      dim is tiled by the grid (multi-RHS solves stream
                      column slabs through the same factor block), which is
                      what makes ``sweep="device"`` in
                      :func:`repro.sparse.multifrontal.multifrontal_solve`
                      one async kernel dispatch per level-bucket.
* ``extend_add_batch`` — the on-device extend-add: accumulates a stack of
                      child Schur update blocks into parent front workspaces
                      from a precomputed row map. The irregular scatter is
                      expressed as two MXU matmuls per child (``Eᵀ U E``
                      with a one-hot embedding ``E`` built in-kernel from
                      the row map), the destination slot is a scalar-prefetch
                      index driving the output BlockSpec, and the workspace
                      stack is aliased in/out so sequential grid steps
                      accumulate. This is what lets the ``pipelined``
                      backend keep update matrices device-resident between
                      assembly-tree levels.

This is the TPU-native adaptation of the paper's MUMPS substrate: the
irregular sparse assembly stays on the host, the dense front math is
systolic-friendly tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["chol_tile", "tri_inv_tile", "matmul_nt", "frontal_factor_batch",
           "extend_add_batch", "tri_solve_batch"]


# ---------------------------------------------------------------------------
# Shared single-tile bodies (used by both the tile kernels and the batched
# front kernel; operate on jnp values, lower triangle authoritative)
# ---------------------------------------------------------------------------

def _chol_block(a: jax.Array) -> jax.Array:
    """Unblocked right-looking Cholesky of one (bs, bs) f32 block value."""
    bs = a.shape[0]
    i = jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)

    def step(j, a):
        ajj = jax.lax.dynamic_slice(a, (j, j), (1, 1))[0, 0]
        d = jnp.sqrt(ajj)
        colj = jax.lax.dynamic_slice(a, (0, j), (bs, 1))[:, 0]
        l = jnp.where(i == j, d, jnp.where(i > j, colj / d, 0.0))
        trailing = (i[:, None] > j) & (i[None, :] > j)
        a = a - jnp.where(trailing, l[:, None] * l[None, :], 0.0)
        a = jax.lax.dynamic_update_slice(a, l[:, None], (0, j))
        return a

    return jnp.tril(jax.lax.fori_loop(0, bs, step, a))


def _tri_inv_block(L: jax.Array) -> jax.Array:
    """Inverse of a lower-triangular (bs, bs) f32 block (row-by-row)."""
    bs = L.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)

    def step(r, y):
        lrow = jax.lax.dynamic_slice(L, (r, 0), (1, bs))
        d = jax.lax.dynamic_slice(L, (r, r), (1, 1))[0, 0]
        lrow = jnp.where(cols < r, lrow, 0.0)
        erow = (cols == r).astype(jnp.float32)
        yrow = (erow - jnp.dot(lrow, y, preferred_element_type=jnp.float32)) / d
        return jax.lax.dynamic_update_slice(y, yrow, (r, 0))

    return jax.lax.fori_loop(0, bs, step, jnp.zeros((bs, bs), jnp.float32))


# ---------------------------------------------------------------------------
# Diagonal-tile Cholesky (single block, right-looking, masked updates)
# ---------------------------------------------------------------------------

def _chol_kernel(a_ref, l_ref):
    a = a_ref[...].astype(jnp.float32)
    l_ref[...] = _chol_block(a).astype(l_ref.dtype)


def chol_tile(a: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Cholesky of one (bs, bs) SPD tile; returns lower-triangular L."""
    bs = a.shape[0]
    assert a.shape == (bs, bs)
    return pl.pallas_call(
        _chol_kernel,
        in_specs=[pl.BlockSpec((bs, bs), lambda: (0, 0))],
        out_specs=pl.BlockSpec((bs, bs), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((bs, bs), a.dtype),
        interpret=interpret,
    )(a)


# ---------------------------------------------------------------------------
# Triangular inverse of a tile (L Y = I, row-by-row forward substitution)
# ---------------------------------------------------------------------------

def _tri_inv_kernel(l_ref, y_ref):
    L = l_ref[...].astype(jnp.float32)
    y_ref[...] = _tri_inv_block(L).astype(y_ref.dtype)


def tri_inv_tile(l: jax.Array, *, interpret: bool = False) -> jax.Array:
    bs = l.shape[0]
    return pl.pallas_call(
        _tri_inv_kernel,
        in_specs=[pl.BlockSpec((bs, bs), lambda: (0, 0))],
        out_specs=pl.BlockSpec((bs, bs), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((bs, bs), l.dtype),
        interpret=interpret,
    )(l)


# ---------------------------------------------------------------------------
# Tiled C = beta*C_in + alpha * A @ Bᵀ  (the MXU workhorse)
# ---------------------------------------------------------------------------

def _matmul_nt_kernel(a_ref, b_ref, c_ref, o_ref, acc_ref, *,
                      k_blocks: int, alpha: float, beta: float):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = beta * c_ref[...].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    acc_ref[...] += alpha * jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == k_blocks - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_nt(a: jax.Array, b: jax.Array, c: jax.Array, *,
              alpha: float = 1.0, beta: float = 1.0,
              bm: int = 128, bn: int = 128, bk: int = 128,
              interpret: bool = False) -> jax.Array:
    """Returns beta*c + alpha * a @ bᵀ. Shapes: a (M,K), b (N,K), c (M,N);
    all dims must be multiples of the tile sizes (ops.py pads)."""
    m, k = a.shape
    n = b.shape[0]
    assert b.shape[1] == k and c.shape == (m, n)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k)
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_matmul_nt_kernel, k_blocks=k // bk,
                               alpha=alpha, beta=beta)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b, c)


# ---------------------------------------------------------------------------
# Batched partial factorization: one grid program = one whole front
# ---------------------------------------------------------------------------

def _frontal_batch_kernel(f_ref, o_ref, *, npanels: int, bs: int):
    """Blocked right-looking partial Cholesky of one (M, M) front workspace.

    Factors the leading ``npanels * bs`` columns; the trailing block ends up
    holding the Schur complement. Panel loop is a static unroll (npanels is
    a bucket constant), each panel fusing chol-tile → panel tri-solve (via
    the tile inverse, i.e. a matmul) → rank-bs Schur update, all on the f32
    VMEM-resident workspace. Lower triangle is authoritative throughout.
    """
    W = f_ref[...][0].astype(jnp.float32)
    M = W.shape[0]
    for t in range(npanels):
        lo = t * bs
        ltt = _chol_block(W[lo : lo + bs, lo : lo + bs])
        W = jax.lax.dynamic_update_slice(W, ltt, (lo, lo))
        below = M - lo - bs
        if below == 0:
            continue
        inv = _tri_inv_block(ltt)
        panel = W[lo + bs :, lo : lo + bs]
        lpanel = jax.lax.dot_general(
            panel, inv, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        W = jax.lax.dynamic_update_slice(W, lpanel, (lo + bs, lo))
        trail = W[lo + bs :, lo + bs :] - jax.lax.dot_general(
            lpanel, lpanel, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        W = jax.lax.dynamic_update_slice(W, trail, (lo + bs, lo + bs))
    o_ref[...] = W[None].astype(o_ref.dtype)


def _extend_add_kernel(dst_ref, u_ref, rows_ref, w_ref, o_ref):
    """Accumulate one child update into its parent front workspace.

    The scatter ``W[rows, rows] += U`` is recast as ``W += Eᵀ U E`` with
    ``E[i, j] = (rows[i] == j)`` — two matmuls, no gather/scatter lowering
    needed. Row-map entries of ``-1`` (child padding, or a padded
    contribution slot) produce an all-zero one-hot row, so they contribute
    nothing. ``o_ref`` aliases the workspace stack; the TPU grid is
    sequential, so contributions sorted by destination slot accumulate
    (equal slots stay VMEM-resident between consecutive steps).
    """
    del w_ref  # aliased with o_ref — the accumulation target
    U = u_ref[...][0].astype(jnp.float32)             # (R, R)
    rows = rows_ref[...][0]                           # (R,) int32
    R = U.shape[0]
    M = o_ref.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (R, M), 1)
    E = (rows[:, None] == iota).astype(jnp.float32)   # (R, M) one-hot
    UE = jax.lax.dot_general(U, E, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    contrib = jax.lax.dot_general(E, UE, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    o_ref[...] += contrib[None].astype(o_ref.dtype)


def extend_add_batch(w: jax.Array, u: jax.Array, dst: jax.Array,
                     rows: jax.Array, *, interpret: bool = False
                     ) -> jax.Array:
    """On-device extend-add: scatter-accumulate child Schur updates into
    parent front workspaces.

    ``w``: (B, M, M) f32 parent workspaces (host-scattered A entries +
    identity pads). ``u``: (C, R, R) f32 child update blocks (typically the
    trailing Schur block of a previously factored, still device-resident
    bucket). ``dst``: (C,) int32 destination batch slot per child, sorted
    ascending (the accumulation-ordering contract). ``rows``: (C, R) int32
    local row positions in the (padded) parent front; ``-1`` marks inactive
    rows. Returns the updated workspace stack (``w`` is consumed via
    aliasing).
    """
    B, M, M2 = w.shape
    C, R, R2 = u.shape
    assert M == M2 and R == R2 and dst.shape == (C,) and rows.shape == (C, R)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C,),
        in_specs=[
            pl.BlockSpec((1, R, R), lambda c, dst: (c, 0, 0)),
            pl.BlockSpec((1, R), lambda c, dst: (c, 0)),
            pl.BlockSpec((1, M, M), lambda c, dst: (dst[c], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, M, M), lambda c, dst: (dst[c], 0, 0)),
    )
    return pl.pallas_call(
        _extend_add_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, M, M), w.dtype),
        input_output_aliases={3: 0},  # w (4th operand incl. prefetch) → out
        interpret=interpret,
    )(dst, u, rows, w)


def _tri_solve_batch_kernel(l_ref, b_ref, o_ref, *, npanels: int, bs: int,
                            lower: bool):
    """Blocked triangular substitution of one (P, K) RHS slab.

    ``lower=True`` solves ``L X = B`` top-down; ``lower=False`` solves
    ``Lᵀ X = B`` bottom-up (``l_ref`` always holds the *lower* factor — the
    transpose lives in the contraction dims, not in memory). Each panel
    step inverts the (bs, bs) diagonal block via :func:`_tri_inv_block`
    and applies it as a matmul, so the only sequential work is the
    fori_loop inside the tiny block inverse. The panel loop is a static
    unroll (npanels is a bucket constant). Unit-diagonal padding rows in
    the factor are decoupled identity rows: they pass their RHS entries
    through untouched, which is what lets padded slots carry garbage
    ("trash row" gathers) without contaminating real rows.
    """
    L = l_ref[...][0].astype(jnp.float32)           # (P, P)
    X = b_ref[...][0].astype(jnp.float32)           # (P, K)
    P, K = X.shape
    if lower:
        for t in range(npanels):
            lo = t * bs
            ltt = jax.lax.dynamic_slice(L, (lo, lo), (bs, bs))
            inv = _tri_inv_block(ltt)
            xp = jax.lax.dot_general(
                inv, jax.lax.dynamic_slice(X, (lo, 0), (bs, K)),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            X = jax.lax.dynamic_update_slice(X, xp, (lo, 0))
            below = P - lo - bs
            if below:
                pan = jax.lax.dynamic_slice(L, (lo + bs, lo), (below, bs))
                tail = jax.lax.dynamic_slice(X, (lo + bs, 0), (below, K))
                tail = tail - jax.lax.dot_general(
                    pan, xp, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
                X = jax.lax.dynamic_update_slice(X, tail, (lo + bs, 0))
    else:
        for t in range(npanels - 1, -1, -1):
            lo = t * bs
            ltt = jax.lax.dynamic_slice(L, (lo, lo), (bs, bs))
            inv = _tri_inv_block(ltt)
            rhs = jax.lax.dynamic_slice(X, (lo, 0), (bs, K))
            below = P - lo - bs
            if below:
                pan = jax.lax.dynamic_slice(L, (lo + bs, lo), (below, bs))
                tail = jax.lax.dynamic_slice(X, (lo + bs, 0), (below, K))
                rhs = rhs - jax.lax.dot_general(
                    pan, tail, (((0,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
            xp = jax.lax.dot_general(           # (L_tt)⁻ᵀ rhs = invᵀ @ rhs
                inv, rhs, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            X = jax.lax.dynamic_update_slice(X, xp, (lo, 0))
    o_ref[...] = X[None].astype(o_ref.dtype)


def tri_solve_batch(l: jax.Array, x: jax.Array, *, bs: int,
                    kt: int | None = None, lower: bool = True,
                    interpret: bool = False) -> jax.Array:
    """Batched blocked triangular substitution over a stack of fronts.

    ``l``: (B, P, P) lower factors (unit-diagonal identity padding beyond
    each front's true pivot count). ``x``: (B, P, K) RHS slabs. Solves
    ``L Y = X`` (``lower=True``) or ``Lᵀ Y = X`` per batch member in one
    launch: the grid is (B, K // kt), so each program owns one front's
    (P, kt) RHS tile — ``kt`` (default: the whole K) is the RHS-tile policy
    knob that turns multi-RHS solves into independent column slabs.
    """
    B, P, P2 = l.shape
    K = x.shape[2]
    kt = K if kt is None else kt
    assert P == P2 and x.shape == (B, P, K), (l.shape, x.shape)
    assert P % bs == 0 and K % kt == 0, (P, bs, K, kt)
    kernel = functools.partial(_tri_solve_batch_kernel, npanels=P // bs,
                               bs=bs, lower=lower)
    return pl.pallas_call(
        kernel,
        grid=(B, K // kt),
        in_specs=[
            pl.BlockSpec((1, P, P), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, P, kt), lambda b, j: (b, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, P, kt), lambda b, j: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, P, K), x.dtype),
        interpret=interpret,
    )(l, x)


def frontal_factor_batch(w: jax.Array, npiv: int, *, bs: int,
                         interpret: bool = False) -> jax.Array:
    """Batched partial Cholesky over a stack of front workspaces.

    ``w``: (B, M, M) f32, each front laid out with its (identity-padded)
    pivot block in the leading ``npiv`` columns. Returns the factored
    workspaces: tril of the leading block is L11, rows below it in the
    pivot columns are L21, and the trailing block is the Schur complement
    (lower triangle authoritative).
    """
    B, M, M2 = w.shape
    assert M == M2 and 0 < npiv <= M and npiv % bs == 0, (w.shape, npiv, bs)
    kernel = functools.partial(_frontal_batch_kernel,
                               npanels=npiv // bs, bs=bs)
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, M, M), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, M, M), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, M, M), w.dtype),
        interpret=interpret,
    )(w)
