"""Dense frontal-matrix factorization kernels for the multifrontal solver.

The multifrontal method reduces sparse Cholesky to *partial factorizations*
of dense fronts — `repro.sparse.multifrontal` builds the assembly tree and
calls :func:`repro.kernels.ops.frontal_factor`, which orchestrates three
Pallas kernels over 128-aligned VMEM tiles:

* ``chol_tile``     — unblocked Cholesky of one diagonal tile (the only
                      sequential piece; O(bs) fori_loop steps on a VMEM tile).
* ``tri_inv_tile``  — forward-substitution inverse of the tile's L factor,
                      turning the panel triangular-solve into a matmul.
* ``matmul_nt``     — tiled C ± A·Bᵀ with f32 VMEM accumulator; carries both
                      the panel solve (W·L⁻ᵀ) and the Schur update
                      (S −= L21·L21ᵀ), i.e. all the MXU FLOPs.

This is the TPU-native adaptation of the paper's MUMPS substrate: the
irregular sparse assembly stays on the host, the dense front math is
systolic-friendly tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["chol_tile", "tri_inv_tile", "matmul_nt"]


# ---------------------------------------------------------------------------
# Diagonal-tile Cholesky (single block, right-looking, masked updates)
# ---------------------------------------------------------------------------

def _chol_kernel(a_ref, l_ref):
    a = a_ref[...].astype(jnp.float32)
    bs = a.shape[0]
    i = jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)

    def step(j, a):
        ajj = jax.lax.dynamic_slice(a, (j, j), (1, 1))[0, 0]
        d = jnp.sqrt(ajj)
        colj = jax.lax.dynamic_slice(a, (0, j), (bs, 1))[:, 0]
        l = jnp.where(i == j, d, jnp.where(i > j, colj / d, 0.0))
        trailing = (i[:, None] > j) & (i[None, :] > j)
        a = a - jnp.where(trailing, l[:, None] * l[None, :], 0.0)
        a = jax.lax.dynamic_update_slice(a, l[:, None], (0, j))
        return a

    a = jax.lax.fori_loop(0, bs, step, a)
    l_ref[...] = jnp.tril(a).astype(l_ref.dtype)


def chol_tile(a: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Cholesky of one (bs, bs) SPD tile; returns lower-triangular L."""
    bs = a.shape[0]
    assert a.shape == (bs, bs)
    return pl.pallas_call(
        _chol_kernel,
        in_specs=[pl.BlockSpec((bs, bs), lambda: (0, 0))],
        out_specs=pl.BlockSpec((bs, bs), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((bs, bs), a.dtype),
        interpret=interpret,
    )(a)


# ---------------------------------------------------------------------------
# Triangular inverse of a tile (L Y = I, row-by-row forward substitution)
# ---------------------------------------------------------------------------

def _tri_inv_kernel(l_ref, y_ref):
    L = l_ref[...].astype(jnp.float32)
    bs = L.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)

    def step(r, y):
        lrow = jax.lax.dynamic_slice(L, (r, 0), (1, bs))
        d = jax.lax.dynamic_slice(L, (r, r), (1, 1))[0, 0]
        lrow = jnp.where(cols < r, lrow, 0.0)
        erow = (cols == r).astype(jnp.float32)
        yrow = (erow - jnp.dot(lrow, y, preferred_element_type=jnp.float32)) / d
        return jax.lax.dynamic_update_slice(y, yrow, (r, 0))

    y = jax.lax.fori_loop(0, bs, step, jnp.zeros((bs, bs), jnp.float32))
    y_ref[...] = y.astype(y_ref.dtype)


def tri_inv_tile(l: jax.Array, *, interpret: bool = False) -> jax.Array:
    bs = l.shape[0]
    return pl.pallas_call(
        _tri_inv_kernel,
        in_specs=[pl.BlockSpec((bs, bs), lambda: (0, 0))],
        out_specs=pl.BlockSpec((bs, bs), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((bs, bs), l.dtype),
        interpret=interpret,
    )(l)


# ---------------------------------------------------------------------------
# Tiled C = beta*C_in + alpha * A @ Bᵀ  (the MXU workhorse)
# ---------------------------------------------------------------------------

def _matmul_nt_kernel(a_ref, b_ref, c_ref, o_ref, acc_ref, *,
                      k_blocks: int, alpha: float, beta: float):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = beta * c_ref[...].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    acc_ref[...] += alpha * jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == k_blocks - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_nt(a: jax.Array, b: jax.Array, c: jax.Array, *,
              alpha: float = 1.0, beta: float = 1.0,
              bm: int = 128, bn: int = 128, bk: int = 128,
              interpret: bool = False) -> jax.Array:
    """Returns beta*c + alpha * a @ bᵀ. Shapes: a (M,K), b (N,K), c (M,N);
    all dims must be multiples of the tile sizes (ops.py pads)."""
    m, k = a.shape
    n = b.shape[0]
    assert b.shape[1] == k and c.shape == (m, n)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k)
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_matmul_nt_kernel, k_blocks=k // bk,
                               alpha=alpha, beta=beta)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b, c)
