"""Flash attention (online-softmax streaming) Pallas TPU kernel.

Grid layout: ``(batch×heads, q_blocks, kv_blocks)`` with the kv axis minor —
TPU grids execute sequentially over the minor dimension, so the running
(max, sum, acc) statistics live in VMEM scratch across kv iterations and the
output block is written once, on the last kv step.

Tiling: q/k/v blocks of (block_q/block_kv, head_dim) in VMEM; head_dim is
expected MXU-aligned (128 for every assigned architecture). The f32
accumulator keeps softmax numerics independent of the bf16 inputs.

The public entry points (GQA handling, padding, causal/decode modes) are in
:mod:`repro.kernels.ops`; the pure-jnp oracle is
:func:`repro.kernels.ref.attention_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  sm_scale: float, causal: bool, block_q: int, block_kv: int,
                  kv_blocks: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    kpos = ki * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = kpos < kv_len  # padded keys never contribute
    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        mask = mask & (qpos >= kpos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[:, 0] = l_ref[:, 0] * alpha + p.sum(axis=1)
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, sm_scale: float | None = None,
                    block_q: int = 128, block_kv: int = 128,
                    kv_len: int | None = None,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, D); k, v: (BH, Skv, D). Sq/Skv must be multiples of the
    block sizes (callers pad; `kv_len` masks the padding)."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    assert sq % block_q == 0 and skv % block_kv == 0, (sq, skv)
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    if kv_len is None:
        kv_len = skv
    kv_blocks = skv // block_kv
    grid = (bh, sq // block_q, kv_blocks)
    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, block_q=block_q,
        block_kv=block_kv, kv_blocks=kv_blocks, kv_len=kv_len)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
