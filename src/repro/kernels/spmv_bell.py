"""Block-ELLPACK SpMV Pallas kernel.

TPU-native sparse matvec: the matrix is stored as dense (bs×bs) blocks in an
ELL layout — every block-row holds exactly ``max_k`` blocks (zero-padded) and
a scalar-prefetched index vector names each block's column block. Scalar
prefetch feeds the x-block index_map, so the gather happens in the pipeline's
address generation rather than as vector gather ops (the standard Pallas TPU
sparse idiom). Used for on-device iterative refinement and batched feature
extraction in the serving example.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["bell_spmv", "csr_to_bell"]


def csr_to_bell(indptr: np.ndarray, indices: np.ndarray, data: np.ndarray,
                n: int, bs: int = 8) -> Tuple[np.ndarray, np.ndarray, int]:
    """Convert CSR to block-ELL: (blocks (R, K, bs, bs), idx (R, K), n_pad)."""
    npad = ((n + bs - 1) // bs) * bs
    nrb = npad // bs
    # bucket nonzeros into (row_block, col_block)
    rows = np.repeat(np.arange(n), np.diff(indptr))
    rb, cb = rows // bs, indices // bs
    keys = rb * nrb + cb
    order = np.argsort(keys, kind="stable")
    rows_s, cols_s, data_s, keys_s = rows[order], indices[order], data[order], keys[order]
    uniq, starts = np.unique(keys_s, return_index=True)
    starts = np.append(starts, keys_s.size)
    per_row: list[list[tuple[int, np.ndarray]]] = [[] for _ in range(nrb)]
    for u, s0, s1 in zip(uniq, starts[:-1], starts[1:]):
        r, c = int(u) // nrb, int(u) % nrb
        blk = np.zeros((bs, bs))
        blk[rows_s[s0:s1] - r * bs, cols_s[s0:s1] - c * bs] = data_s[s0:s1]
        per_row[r].append((c, blk))
    max_k = max(1, max(len(p) for p in per_row))
    blocks = np.zeros((nrb, max_k, bs, bs))
    idx = np.zeros((nrb, max_k), dtype=np.int32)
    for r, plist in enumerate(per_row):
        for k, (c, blk) in enumerate(plist):
            blocks[r, k] = blk
            idx[r, k] = c
    return blocks, idx, npad


def _bell_kernel(idx_ref, blocks_ref, x_ref, o_ref, *, max_k: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Accumulate in the output dtype: f32 normally, f64 when the caller runs
    # under the x64 context (device-resident refinement residuals).
    acc = jnp.float64 if o_ref.dtype == jnp.float64 else jnp.float32
    blk = blocks_ref[0, 0].astype(acc)                # (bs, bs)
    xb = x_ref[...].astype(acc)                       # (bs, kk)
    o_ref[...] += jnp.dot(blk, xb, preferred_element_type=acc
                          ).astype(o_ref.dtype)


def bell_spmv(blocks: jax.Array, idx: jax.Array, x: jax.Array, *,
              interpret: bool = False) -> jax.Array:
    """y = A @ x with A in block-ELL form.

    x: ``(n_pad,)`` or an RHS block ``(n_pad, k)``; the result matches x's
    shape and dtype (fp64 in/out when running under ``enable_x64``).
    """
    nrb, max_k, bs, _ = blocks.shape
    single = x.ndim == 1
    x2 = x[:, None] if single else x
    kk = x2.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nrb, max_k),
        in_specs=[
            pl.BlockSpec((1, 1, bs, bs), lambda r, k, idx_ref: (r, k, 0, 0)),
            pl.BlockSpec((bs, kk), lambda r, k, idx_ref: (idx_ref[r, k], 0)),
        ],
        out_specs=pl.BlockSpec((bs, kk), lambda r, k, idx_ref: (r, 0)),
        scratch_shapes=[],
    )
    out = pl.pallas_call(
        functools.partial(_bell_kernel, max_k=max_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nrb * bs, kk), x.dtype),
        interpret=interpret,
    )(idx, blocks, x2)
    return out[:, 0] if single else out
