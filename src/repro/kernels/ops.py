"""Public jit'd wrappers around the Pallas kernels.

On CPU hosts (this container, unit tests) the kernels execute in
``interpret=True`` mode — the kernel body runs as traced JAX ops, which
validates BlockSpec indexing and numerics exactly. On TPU the same calls
compile through Mosaic. `_interpret()` picks automatically.

The LM model code keeps an XLA (einsum) attention path for CPU dry-runs and
uses :func:`attention` on real TPU — see `repro.models.layers.Attention`.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .flash_attention import flash_attention
from .frontal_cholesky import (chol_tile, extend_add_batch as
                               _extend_add_batch_kernel, frontal_factor_batch
                               as _frontal_factor_batch_kernel, matmul_nt,
                               tri_inv_tile, tri_solve_batch as
                               _tri_solve_batch_kernel)
from .spmv_bell import bell_spmv, csr_to_bell

__all__ = ["attention", "frontal_factor", "frontal_factor_batch",
           "frontal_factor_batch_ws", "extend_add_batch", "pick_block_size",
           "spmv", "matmul_nt_padded", "tri_solve_batch", "rhs_tile",
           "sweep_forward", "sweep_backward"]


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv"))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, block_q: int = 128,
              block_kv: int = 128) -> jax.Array:
    """GQA flash attention. q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D).

    Repeats KV heads to match Q heads, pads sequences to block multiples
    (padded keys are masked via kv_len), and restores the original shape.
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qf = _pad_to(q.reshape(b * hq, sq, d), 1, block_q)
    kf = _pad_to(k.reshape(b * hq, skv, d), 1, block_kv)
    vf = _pad_to(v.reshape(b * hq, skv, d), 1, block_kv)
    out = flash_attention(qf, kf, vf, causal=causal, block_q=block_q,
                          block_kv=block_kv, kv_len=skv,
                          interpret=_interpret())
    return out[:, :sq].reshape(b, hq, sq, d)


def matmul_nt_padded(a: jax.Array, b: jax.Array, c: jax.Array, *,
                     alpha: float = 1.0, beta: float = 1.0,
                     bs: int = 128) -> jax.Array:
    """beta*c + alpha*a@bᵀ for arbitrary shapes (zero-pads to tiles)."""
    m, n = c.shape
    ap = _pad_to(_pad_to(a, 0, bs), 1, bs)
    bp = _pad_to(_pad_to(b, 0, bs), 1, bs)
    cp = _pad_to(_pad_to(c, 0, bs), 1, bs)
    out = matmul_nt(ap, bp, cp, alpha=alpha, beta=beta, bm=bs, bn=bs, bk=bs,
                    interpret=_interpret())
    return out[:m, :n]


def frontal_factor(f: jax.Array, npiv: int, *, bs: int = 128
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Partial Cholesky of a frontal matrix (lower triangle of `f` is read).

    Returns (L11, L21, S) like :func:`repro.kernels.ref.partial_cholesky_ref`.
    Layout: the pivot block is padded to a tile multiple with identity
    columns (decoupled, factor to 1.0, contribute nothing), so tile loops
    stay 128-aligned regardless of npiv.
    """
    f = jnp.asarray(f, jnp.float32)
    m = f.shape[0]
    nrest = m - npiv
    P = ((npiv + bs - 1) // bs) * bs
    Rp = ((nrest + bs - 1) // bs) * bs if nrest else 0
    M = P + Rp
    interp = _interpret()

    W = jnp.zeros((M, M), jnp.float32)
    W = W.at[:npiv, :npiv].set(jnp.tril(f[:npiv, :npiv]))
    if P > npiv:
        pad_idx = jnp.arange(npiv, P)
        W = W.at[pad_idx, pad_idx].set(1.0)
    if nrest:
        W = W.at[P : P + nrest, :npiv].set(f[npiv:, :npiv])
        W = W.at[P : P + nrest, P : P + nrest].set(jnp.tril(f[npiv:, npiv:]))

    for t in range(P // bs):
        lo = t * bs
        tile = jax.lax.dynamic_slice(W, (lo, lo), (bs, bs))
        ltt = chol_tile(tile, interpret=interp)
        W = jax.lax.dynamic_update_slice(W, ltt, (lo, lo))
        rows_below = M - lo - bs
        if rows_below == 0:
            continue
        inv = tri_inv_tile(ltt, interpret=interp)
        panel = jax.lax.dynamic_slice(W, (lo + bs, lo), (rows_below, bs))
        lpanel = matmul_nt(panel, inv, jnp.zeros_like(panel), alpha=1.0,
                           beta=0.0, bm=bs, bn=bs, bk=bs, interpret=interp)
        W = jax.lax.dynamic_update_slice(W, lpanel, (lo + bs, lo))
        trail = jax.lax.dynamic_slice(W, (lo + bs, lo + bs),
                                      (rows_below, rows_below))
        trail = matmul_nt(lpanel, lpanel, trail, alpha=-1.0, beta=1.0,
                          bm=bs, bn=bs, bk=bs, interpret=interp)
        W = jax.lax.dynamic_update_slice(W, trail, (lo + bs, lo + bs))

    L11 = jnp.tril(W[:npiv, :npiv])
    L21 = W[P : P + nrest, :npiv]
    S = W[P : P + nrest, P : P + nrest]
    S = jnp.tril(S) + jnp.tril(S, -1).T  # lower is authoritative
    return L11, L21, S


@functools.partial(jax.jit, static_argnames=("npiv", "bs", "interpret"))
def _factor_batch_ws_jit(w, npiv, bs, interpret):
    return _frontal_factor_batch_kernel(w, npiv, bs=bs, interpret=interpret)


def pick_block_size(npiv: int, bs: int | None = None) -> int:
    """Largest panel width ≤ ``bs`` (default 32) that divides ``npiv``.

    Bucketed pivot dims are multiples of 8 (pow2 ≥ 8 under the default pad
    policy, next-multiple-of-8 under ``mult8``), so the descent over
    divisors terminates at 8 at the latest; tiny fronts (npiv < 8) run
    unblocked. 32 keeps the sequential chol-tile loop short while the
    rank-bs updates stay matmul-shaped."""
    cap = 32 if bs is None else max(1, int(bs))
    if npiv <= cap:
        return npiv
    for cand in range(cap, 0, -1):
        if npiv % cand == 0:
            return cand
    return npiv


_batch_block = pick_block_size  # back-compat alias


def frontal_factor_batch_ws(w: jax.Array, npiv: int, *,
                            bs: int | None = None) -> jax.Array:
    """Level-scheduled entry point: factor the leading ``npiv`` columns of
    every (M, M) front workspace in the (B, M, M) stack ``w`` in ONE kernel
    launch (grid over B). Calls jit-cache per (B, M, npiv, bs) — bucketed
    shapes are powers of two, so a handful of compilations cover a whole
    factorization. ``bs`` is a *cap* on the panel width (the autotuned
    policy knob); the effective width is the largest divisor of ``npiv``
    not exceeding it. Returns the factored workspaces (see
    :func:`repro.kernels.frontal_cholesky.frontal_factor_batch`)."""
    bs = pick_block_size(npiv, bs)
    return _factor_batch_ws_jit(jnp.asarray(w, jnp.float32), npiv, bs,
                                _interpret())


@functools.partial(jax.jit, static_argnames=("interpret",))
def _extend_add_jit(w, u, dst, rows, interpret):
    return _extend_add_batch_kernel(w, u, dst, rows, interpret=interpret)


# donation realizes the kernel-level workspace aliasing as a true in-place
# update on TPU; CPU (interpret/test) has no donation support and would
# warn on every compile, so it gets the plain variant
_extend_add_jit_donated = jax.jit(_extend_add_jit.__wrapped__,
                                  static_argnames=("interpret",),
                                  donate_argnums=(0,))


def extend_add_batch(w: jax.Array, u: jax.Array, dst, rows) -> jax.Array:
    """On-device extend-add (see
    :func:`repro.kernels.frontal_cholesky.extend_add_batch`): accumulate the
    child update stack ``u`` (C, R, R) into the parent workspace stack ``w``
    (B, M, M) at slots ``dst`` (sorted ascending) and local rows ``rows``
    (-1 = inactive). ``w`` is donated on TPU — callers must treat it as
    consumed. Calls jit-cache per (B, M, C, R) shape."""
    interp = _interpret()
    fn = _extend_add_jit if interp else _extend_add_jit_donated
    return fn(jnp.asarray(w, jnp.float32), jnp.asarray(u, jnp.float32),
              jnp.asarray(dst, jnp.int32), jnp.asarray(rows, jnp.int32),
              interp)


def frontal_factor_batch(fs: jax.Array, npiv: int, *, bs: int | None = None
                         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batched analogue of :func:`frontal_factor` for a uniform stack.

    ``fs``: (B, m, m) SPD fronts sharing one pivot count. Pads the pivot
    block to a tile multiple with decoupled identity columns (like
    ``frontal_factor``), factors the stack in one launch, and returns
    (L11, L21, S) with shapes (B, npiv, npiv) / (B, m-npiv, npiv) /
    (B, m-npiv, m-npiv).
    """
    fs = jnp.asarray(fs, jnp.float32)
    b, m, _ = fs.shape
    nrest = m - npiv
    if bs is None:
        P = max(8, 1 << (npiv - 1).bit_length())
        bs = _batch_block(P)
    else:
        P = ((npiv + bs - 1) // bs) * bs
    M = P + nrest
    W = jnp.zeros((b, M, M), jnp.float32)
    W = W.at[:, :npiv, :npiv].set(jnp.tril(fs[:, :npiv, :npiv]))
    if P > npiv:
        pad_idx = jnp.arange(npiv, P)
        W = W.at[:, pad_idx, pad_idx].set(1.0)
    if nrest:
        W = W.at[:, P:, :npiv].set(fs[:, npiv:, :npiv])
        W = W.at[:, P:, P:].set(jnp.tril(fs[:, npiv:, npiv:]))
    W = frontal_factor_batch_ws(W, P, bs=bs)
    L11 = jnp.tril(W[:, :npiv, :npiv])
    L21 = W[:, P:, :npiv]
    S = W[:, P:, P:]
    S = jnp.tril(S) + jnp.swapaxes(jnp.tril(S, -1), 1, 2)
    return L11, L21, S


@functools.partial(jax.jit, static_argnames=("bs", "kt", "lower",
                                             "interpret"))
def _tri_solve_jit(l, x, bs, kt, lower, interpret):
    return _tri_solve_batch_kernel(l, x, bs=bs, kt=kt, lower=lower,
                                   interpret=interpret)


def rhs_tile(k: int, rt: int | None = None) -> int:
    """Effective RHS-tile width: ``rt`` when it divides the RHS count,
    else the whole slab (one tile). The autotuned ``rt`` policy knob only
    kicks in when the caller's padded RHS width actually tiles by it."""
    if rt is None or k <= 0:
        return max(k, 1)
    rt = max(1, int(rt))
    return rt if k % rt == 0 else k


def tri_solve_batch(l: jax.Array, x: jax.Array, *, bs: int | None = None,
                    rt: int | None = None, lower: bool = True) -> jax.Array:
    """Batched blocked triangular substitution (see
    :func:`repro.kernels.frontal_cholesky.tri_solve_batch`).

    ``l``: (B, P, P) lower factors, ``x``: (B, P, K) RHS slabs; solves
    ``L Y = X`` or ``Lᵀ Y = X``. ``bs`` caps the panel width (same
    divisor-descent policy as the factor kernels); ``rt`` tiles the RHS
    dim (K is zero-padded up to a multiple). Calls jit-cache per
    (B, P, K, bs, kt) — bucketed P's are powers of two, so a handful of
    compilations cover a whole sweep schedule.
    """
    l = jnp.asarray(l, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    B, P, _ = l.shape
    K = x.shape[2]
    bse = pick_block_size(P, bs)
    if rt is not None and K % max(1, int(rt)):
        x = _pad_to(x, 2, max(1, int(rt)))
    kt = rhs_tile(x.shape[2], rt)
    out = _tri_solve_jit(l, x, bse, kt, lower, _interpret())
    return out[:, :, :K] if out.shape[2] != K else out


@functools.partial(jax.jit, static_argnames=("bs", "kt", "interpret"))
def _sweep_fwd_jit(x, l11, l21, piv, rest, bs, kt, interpret):
    k = x.shape[1]
    xb = jnp.take(x, piv, axis=0)                         # (B, P, k)
    y = _tri_solve_batch_kernel(l11, xb, bs=bs, kt=kt, lower=True,
                                interpret=interpret)
    x = x.at[piv.reshape(-1)].set(y.reshape(-1, k))
    if l21.shape[1]:
        upd = jnp.einsum("brp,bpk->brk", l21, y)
        x = x.at[rest.reshape(-1)].add(-upd.reshape(-1, k))
    return x


@functools.partial(jax.jit, static_argnames=("bs", "kt", "interpret"))
def _sweep_bwd_jit(x, l11, l21, piv, rest, bs, kt, interpret):
    k = x.shape[1]
    rhs = jnp.take(x, piv, axis=0)                        # (B, P, k)
    if l21.shape[1]:
        xr = jnp.take(x, rest, axis=0)                    # (B, R, k)
        rhs = rhs - jnp.einsum("brp,brk->bpk", l21, xr)
    y = _tri_solve_batch_kernel(l11, rhs, bs=bs, kt=kt, lower=False,
                                interpret=interpret)
    return x.at[piv.reshape(-1)].set(y.reshape(-1, k))


def sweep_forward(x: jax.Array, l11: jax.Array, l21: jax.Array,
                  piv: jax.Array, rest: jax.Array, *, bs: int | None = None,
                  rt: int | None = None) -> jax.Array:
    """One level-bucket's forward-substitution step on a device-resident
    RHS block.

    ``x``: (n + 1, K) f32 — the solution-in-progress with a trailing
    "trash row" that every padded index points at (garbage in, garbage
    confined: identity pad rows in ``l11`` and zero pad rows/cols in
    ``l21`` keep it inert). Gathers the bucket's pivot rows, runs the
    batched :func:`tri_solve_batch` lower sweep, scatters the solved
    pivots back, and scatter-subtracts the ``L21 y`` cross-front updates —
    all inside one jit, dispatched asynchronously.
    """
    return _sweep_fwd_jit(x, l11, l21, piv, rest,
                          pick_block_size(l11.shape[1], bs),
                          rhs_tile(x.shape[1], rt), _interpret())


def sweep_backward(x: jax.Array, l11: jax.Array, l21: jax.Array,
                   piv: jax.Array, rest: jax.Array, *, bs: int | None = None,
                   rt: int | None = None) -> jax.Array:
    """One level-bucket's backward-substitution step (``Lᵀ x = y``):
    gathers pivot and update rows, subtracts the ``L21ᵀ`` coupling, runs
    the batched upper sweep, and scatters the solved pivots back."""
    return _sweep_bwd_jit(x, l11, l21, piv, rest,
                          pick_block_size(l11.shape[1], bs),
                          rhs_tile(x.shape[1], rt), _interpret())


def spmv(indptr: np.ndarray, indices: np.ndarray, data: np.ndarray,
         x: np.ndarray, *, bs: int = 8) -> np.ndarray:
    """CSR SpMV through the block-ELL kernel (host-side layout conversion)."""
    n = x.shape[0]
    blocks, idx, npad = csr_to_bell(indptr, indices, data, n, bs)
    xp = np.zeros(npad, dtype=np.float32)
    xp[:n] = x
    y = bell_spmv(jnp.asarray(blocks, jnp.float32), jnp.asarray(idx),
                  jnp.asarray(xp), interpret=_interpret())
    return np.asarray(y)[:n]
