"""Pallas reduction kernels for batched CSR structure statistics.

The batched feature extractor (`repro.core.features.extract_features_batch_jnp`)
needs two flat reductions over a padded ``(B, E)`` entry batch — bandwidth
(max |i−j|) and profile (sum of first-column offsets) — and three over the
``(B, N)`` row batch — max/min row count and the squared deviation sum
behind nnz_std. Both are the serving hot loop: every request pays them
once per matrix, so they run as Pallas grid reductions here (VPU tiles, one
accumulator row per matrix) instead of XLA segment ops.

Layout: grid ``(B, num_tiles)``; each step reduces one ``(1, tile)`` slice
and folds it into a ``(1, 128)`` accumulator row for matrix ``b`` — the
leading lanes carry the statistics (max/min/sum folds), the rest stay zero.
The ``@pl.when(t == 0)`` init makes the output revisit-safe, the same idiom as
`spmv_bell`. On CPU hosts the kernels execute in ``interpret=True`` mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["entry_stats", "row_stats", "LANES"]

LANES = 128            # accumulator row width (TPU lane count)
_ROW_MIN_INIT = 3.4e38  # ~f32 max: min-accumulator identity


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _pad_tiles(x: jnp.ndarray, tile: int) -> jnp.ndarray:
    pad = (-x.shape[1]) % tile
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x


def _lane_select(vals) -> jnp.ndarray:
    """(1, LANES) row holding scalar ``vals[i]`` in lane i, 0 elsewhere."""
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, LANES), 1)
    out = jnp.zeros((1, LANES), jnp.float32)
    for i, v in enumerate(vals):
        out = jnp.where(lanes == i, v, out)
    return out


def _entry_kernel(rows_ref, cols_ref, valid_ref, first_ref, out_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    r = rows_ref[...].astype(jnp.int32)
    c = cols_ref[...].astype(jnp.int32)
    valid = valid_ref[...] != 0
    first = first_ref[...] != 0

    absd = jnp.where(valid, jnp.abs(r - c), 0)
    bw = absd.max().astype(jnp.float32)
    prof = jnp.where(first & (c < r), r - c, 0).sum().astype(jnp.float32)

    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, LANES), 1)
    tile_row = _lane_select([bw, prof])
    cur = out_ref[...]
    # lane 0 folds by max, lane 1 by sum
    out_ref[...] = jnp.where(lanes == 0, jnp.maximum(cur, tile_row),
                             cur + tile_row)


def _row_kernel(row_nnz_ref, row_valid_ref, mean_ref, out_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = _lane_select([0.0, jnp.float32(_ROW_MIN_INIT), 0.0])

    cnt = row_nnz_ref[...].astype(jnp.float32)
    valid = row_valid_ref[...] != 0
    mean = mean_ref[...].astype(jnp.float32)  # (1, 1) per-matrix mean

    mx = jnp.where(valid, cnt, 0.0).max()
    mn = jnp.where(valid, cnt, _ROW_MIN_INIT).min()
    dev = jnp.where(valid, cnt - mean, 0.0)
    sq = (dev * dev).sum()

    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, LANES), 1)
    tile_row = _lane_select([mx, mn, sq])
    cur = out_ref[...]
    # lane 0 folds by max, lane 1 by min, lane 2 by sum
    out_ref[...] = jnp.where(
        lanes == 0, jnp.maximum(cur, tile_row),
        jnp.where(lanes == 1, jnp.minimum(cur, tile_row), cur + tile_row))


def entry_stats(rows, cols, valid, first, *, tile: int = 512,
                interpret=None):
    """Per-matrix [bandwidth, profile] over a padded entry batch.

    rows/cols: (B, E) int32; valid/first: (B, E) int32 masks (0/1).
    Returns (B, 2) float32.
    """
    if interpret is None:
        interpret = _interpret()
    rows = _pad_tiles(jnp.asarray(rows, jnp.int32), tile)
    cols = _pad_tiles(jnp.asarray(cols, jnp.int32), tile)
    valid = _pad_tiles(jnp.asarray(valid, jnp.int32), tile)
    first = _pad_tiles(jnp.asarray(first, jnp.int32), tile)
    b, e = rows.shape
    grid = (b, e // tile)
    spec = pl.BlockSpec((1, tile), lambda i, t: (i, t))
    out = pl.pallas_call(
        _entry_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=pl.BlockSpec((1, LANES), lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, LANES), jnp.float32),
        interpret=interpret,
    )(rows, cols, valid, first)
    return out[:, :2]


def row_stats(row_nnz, row_valid, mean, *, tile: int = 512, interpret=None):
    """Per-matrix [max, min, Σ(x−mean)²] of valid per-row nonzero counts.

    row_nnz/row_valid: (B, N) int32; mean: (B,) float32 (= nnz/n, computed
    by the caller so the deviation sum is single-pass).
    Returns (B, 3) float32.
    """
    if interpret is None:
        interpret = _interpret()
    row_nnz = _pad_tiles(jnp.asarray(row_nnz, jnp.int32), tile)
    row_valid = _pad_tiles(jnp.asarray(row_valid, jnp.int32), tile)
    b, npad = row_nnz.shape
    mean2 = jnp.asarray(mean, jnp.float32).reshape(b, 1)
    grid = (b, npad // tile)
    spec = pl.BlockSpec((1, tile), lambda i, t: (i, t))
    out = pl.pallas_call(
        _row_kernel,
        grid=grid,
        in_specs=[spec, spec, pl.BlockSpec((1, 1), lambda i, t: (i, 0))],
        out_specs=pl.BlockSpec((1, LANES), lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, LANES), jnp.float32),
        interpret=interpret,
    )(row_nnz, row_valid, mean2)
    return out[:, :3]
