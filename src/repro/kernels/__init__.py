"""Pallas TPU kernels for the framework's compute hot spots.

* ``flash_attention``  — streaming softmax attention (LM substrate).
* ``frontal_cholesky`` — dense-front partial factorization tiles
                         (multifrontal sparse solver).
* ``spmv_bell``        — block-ELL SpMV with scalar-prefetch gather.

``ops`` holds the jit'd public wrappers (interpret-mode on CPU);
``ref`` holds the pure-jnp oracles the tests assert against.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
