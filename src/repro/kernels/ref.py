"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref", "partial_cholesky_ref", "matmul_nt_ref",
           "bell_spmv_ref"]


def attention_ref(q, k, v, *, causal: bool = True,
                  sm_scale: float | None = None,
                  kv_len: int | None = None):
    """q: (BH, Sq, D); k/v: (BH, Skv, D) — plain softmax attention."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    mask = jnp.ones((sq, skv), dtype=bool)
    if kv_len is not None:
        mask = mask & (jnp.arange(skv)[None, :] < kv_len)
    if causal:
        mask = mask & (jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :])
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def partial_cholesky_ref(f, npiv: int):
    """Dense partial factorization oracle: returns (L11, L21, S)."""
    f = jnp.asarray(f, dtype=jnp.float32)
    f11 = f[:npiv, :npiv]
    # symmetrize from the lower triangle (fronts only fill the lower part)
    f11 = jnp.tril(f11) + jnp.tril(f11, -1).T
    l11 = jnp.linalg.cholesky(f11)
    f21 = f[npiv:, :npiv]
    l21 = jax.scipy.linalg.solve_triangular(l11, f21.T, lower=True).T
    f22 = f[npiv:, npiv:]
    f22 = jnp.tril(f22) + jnp.tril(f22, -1).T
    s = f22 - l21 @ l21.T
    return l11, l21, s


def matmul_nt_ref(a, b, c, *, alpha: float = 1.0, beta: float = 1.0):
    return beta * jnp.asarray(c, jnp.float32) + alpha * (
        jnp.asarray(a, jnp.float32) @ jnp.asarray(b, jnp.float32).T)


def bell_spmv_ref(blocks, idx, x):
    """Oracle for block-ELL SpMV: densify and multiply."""
    nrb, max_k, bs, _ = blocks.shape
    n = nrb * bs
    a = jnp.zeros((n, n), dtype=jnp.float32)
    for r in range(nrb):
        for k in range(max_k):
            c = int(idx[r, k])
            a = a.at[r * bs:(r + 1) * bs, c * bs:(c + 1) * bs].add(
                jnp.asarray(blocks[r, k], jnp.float32))
    return a @ jnp.asarray(x, jnp.float32)
