"""``repro.engine`` — the single production API for the paper's deliverable.

One facade covers the whole lifecycle::

    from repro.engine import EngineConfig, SolverEngine

    engine = SolverEngine(EngineConfig(model="random_forest"))
    engine.train(dataset)              # grid-search + refit, fingerprinted
    name, dt = engine.select(A)        # algorithm name for one matrix
    plan = engine.plan(A)              # cached ExecutionPlan (two-tier)
    result = engine.solve(A, b)        # plan + numeric factor + solve
    server = engine.serve()            # AsyncPlanServer bound to the engine
    engine.save("selector.bundle")     # versioned SelectorBundle artifact
    engine = SolverEngine.load("selector.bundle")

Underneath: four capability registries (reorderings, models, scalers,
feature sets — decorator-registered, metadata-carrying, shared with the
legacy dict names), versioned :class:`SelectorBundle` persistence instead
of raw pickles, and model/scaler ``fingerprint()``s that the engine threads
into the plan cache as its version — retraining automatically invalidates
every previously persisted plan.

The registry surface imports eagerly (stdlib-only); the facade classes load
lazily on first attribute access so ``import repro.engine`` is cheap and
core modules can import the registries without cycles.
"""
from .registry import (FEATURE_SET_REGISTRY, MODEL_REGISTRY,
                       REORDERING_REGISTRY, SCALER_REGISTRY,
                       DuplicateNameError, FeatureSet, Registry,
                       RegistryEntry, RegistryError, RegistryLookupError,
                       get_feature_set, register_feature_set, register_model,
                       register_reordering, register_scaler)

__all__ = [
    # registries
    "Registry", "RegistryEntry", "RegistryError", "DuplicateNameError",
    "RegistryLookupError", "FeatureSet",
    "REORDERING_REGISTRY", "MODEL_REGISTRY", "SCALER_REGISTRY",
    "FEATURE_SET_REGISTRY",
    "register_reordering", "register_model", "register_scaler",
    "register_feature_set", "get_feature_set",
    # fingerprints
    "fingerprint_state", "component_fingerprint", "combine_fingerprints",
    # facade (lazy)
    "EngineConfig", "SolverEngine", "EngineError",
    "SelectorBundle", "BundleValidationError", "BUNDLE_SCHEMA_VERSION",
]

_LAZY = {
    "fingerprint_state": "repro.engine.fingerprint",
    "component_fingerprint": "repro.engine.fingerprint",
    "combine_fingerprints": "repro.engine.fingerprint",
    "EngineConfig": "repro.engine.config",
    "SolverEngine": "repro.engine.core",
    "EngineError": "repro.engine.core",
    "SelectorBundle": "repro.engine.bundle",
    "BundleValidationError": "repro.engine.bundle",
    "BUNDLE_SCHEMA_VERSION": "repro.engine.bundle",
}


def __getattr__(name):  # PEP 562: facade classes resolve on first touch
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    value = getattr(importlib.import_module(mod), name)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
