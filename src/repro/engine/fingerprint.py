"""Deterministic fingerprints of fitted state.

A *fingerprint* is a short stable hash of everything that determines a
component's input→output behaviour: class identity, hyperparameters, and
fitted state. The engine threads the fingerprint of its (model, scaler,
feature set, algorithm list) into the plan cache as the cache version, so
retraining — which changes the fitted state, hence the fingerprint —
automatically makes every previously persisted plan invisible. No manual
``TwoTierPlanCache(version=...)`` bump, no stale plans served by a freshly
retrained selector (the ROADMAP hazard this closes).

Hashing canonicalizes recursively: dicts by sorted key, sequences in
order, arrays as dtype + shape + raw bytes (jax arrays are pulled to host
first), scalars/strings by repr. Anything unrecognized falls back to
``pickle.dumps`` — deterministic for the plain object graphs that appear in
model state.
"""
from __future__ import annotations

import hashlib
import numbers
import pickle
from typing import Any

__all__ = ["canonical_bytes", "fingerprint_state", "component_fingerprint",
           "combine_fingerprints"]

_DIGEST_SIZE = 16


def _update(h, obj: Any) -> None:
    import numpy as np

    if obj is None:
        h.update(b"\x00none")
    elif isinstance(obj, (bool, numbers.Integral)):
        h.update(b"\x01int" + repr(int(obj)).encode())
    elif isinstance(obj, numbers.Real):
        h.update(b"\x02flt" + repr(float(obj)).encode())
    elif isinstance(obj, str):
        h.update(b"\x03str" + obj.encode())
    elif isinstance(obj, bytes):
        h.update(b"\x04byt" + obj)
    elif isinstance(obj, dict):
        h.update(b"\x05map" + repr(len(obj)).encode())
        for k in sorted(obj, key=repr):
            _update(h, k)
            _update(h, obj[k])
    elif isinstance(obj, (list, tuple)):
        h.update(b"\x06seq" + repr(len(obj)).encode())
        for v in obj:
            _update(h, v)
    else:
        arr = None
        if isinstance(obj, np.ndarray):
            arr = obj
        elif hasattr(obj, "__array__") and hasattr(obj, "dtype"):
            arr = np.asarray(obj)  # jax arrays land here (host transfer)
        if arr is not None:
            h.update(b"\x07arr" + str(arr.dtype).encode()
                     + repr(arr.shape).encode())
            h.update(np.ascontiguousarray(arr).tobytes())
        else:
            h.update(b"\x08pkl" + pickle.dumps(obj, protocol=4))


def canonical_bytes(obj: Any) -> bytes:
    """Canonical byte digest of a (possibly nested) state object."""
    h = hashlib.blake2b(digest_size=_DIGEST_SIZE)
    _update(h, obj)
    return h.digest()


def fingerprint_state(obj: Any) -> str:
    """Hex fingerprint of a state object (nested dicts / arrays / scalars)."""
    return canonical_bytes(obj).hex()


def component_fingerprint(component: Any) -> str:
    """Fingerprint of a model or scaler: class + params + fitted state.

    Components expose ``state()`` (fitted arrays) and optionally ``params``
    (hyperparameters); both enter the hash along with the class name, so
    two fits with different data *or* different hyperparameters never
    collide, and an unfitted component has a well-defined fingerprint too.
    """
    return fingerprint_state({
        "class": type(component).__name__,
        "params": getattr(component, "params", {}),
        "state": component.state() if hasattr(component, "state") else {},
    })


def combine_fingerprints(**parts: Any) -> str:
    """One fingerprint over named parts (model/scaler/features/algorithms)."""
    return fingerprint_state({k: v for k, v in sorted(parts.items())})
