"""Capability registries — the engine's plugin points.

Everything the selection pipeline composes is a *named capability*:
reordering algorithms, classifier families, feature scalers, and feature
sets. Each lives in a :class:`Registry` — an ordered, metadata-carrying
mapping with decorator registration — so third-party orderings, models, or
extended feature sets plug in without editing core modules:

    from repro.engine import register_reordering

    @register_reordering("my_order", category="fill-in-reduction")
    def my_order(a):          # CSRMatrix -> perm, perm[new] = old
        ...

The legacy dict names (``repro.sparse.reorder.REORDERINGS``,
``repro.core.ml.MODEL_ZOO``, ``repro.core.scaling.SCALERS``) are now these
registries — :class:`Registry` implements the ``Mapping`` protocol, so
``ZOO[name]``, ``sorted(ZOO)`` and friends keep working, and every lookup
failure raises the same :class:`RegistryLookupError` with
did-you-mean suggestions instead of a bare, chained ``KeyError``.

This module is dependency-free (stdlib only) on purpose: core modules
import it at definition time, and nothing here imports back into
``repro.*``.
"""
from __future__ import annotations

import dataclasses
import difflib
from typing import (Any, Callable, Dict, Iterator, List, Mapping, Optional,
                    Sequence)

__all__ = [
    "Registry", "RegistryEntry", "RegistryError", "DuplicateNameError",
    "RegistryLookupError", "FeatureSet",
    "REORDERING_REGISTRY", "MODEL_REGISTRY", "SCALER_REGISTRY",
    "FEATURE_SET_REGISTRY",
    "register_reordering", "register_model", "register_scaler",
    "register_feature_set", "get_feature_set",
]


class RegistryError(Exception):
    """Base class for registry failures."""


class DuplicateNameError(RegistryError, ValueError):
    """A name was registered twice without ``overwrite=True``."""


class RegistryLookupError(RegistryError, KeyError):
    """Unknown name, across *all* registries — one error type, with
    suggestions, so callers of any capability lookup handle one thing.

    Subclasses ``KeyError`` so legacy ``except KeyError`` call sites keep
    working.
    """

    def __init__(self, kind: str, name: Any, known: Sequence[str]):
        self.kind = kind
        self.name = name
        self.known = sorted(known)
        msg = f"unknown {kind} {name!r}; available: {self.known}"
        if isinstance(name, str) and self.known:
            close = difflib.get_close_matches(name, self.known, n=3)
            if close:
                msg += f" — did you mean {' / '.join(map(repr, close))}?"
        super().__init__(msg)

    def __str__(self) -> str:  # KeyError.__str__ repr()s the message
        return self.args[0]


def _same_provenance(a: Any, b: Any) -> bool:
    """True when ``b`` is a reload of ``a``: same definition site (module +
    qualname for classes/functions; FeatureSets compare their extractors)."""
    if isinstance(a, FeatureSet) and isinstance(b, FeatureSet):
        return a.name == b.name and _same_provenance(a.extract, b.extract)
    qa = (getattr(a, "__module__", None), getattr(a, "__qualname__", None))
    qb = (getattr(b, "__module__", None), getattr(b, "__qualname__", None))
    return None not in qa and qa == qb


@dataclasses.dataclass(frozen=True)
class RegistryEntry:
    """One registered capability: the object plus its metadata."""

    name: str
    obj: Any
    metadata: Dict[str, Any] = dataclasses.field(default_factory=dict)


class Registry(Mapping):
    """Ordered name → capability mapping with decorator registration.

    ``registry[name]`` returns the registered object (class or callable);
    ``registry.spec(name)`` returns the full :class:`RegistryEntry` with
    metadata (e.g. ``category``, ``device_capable``, ``symmetric_only``).
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: "Dict[str, RegistryEntry]" = {}

    # -- registration --------------------------------------------------------
    def register(self, name: str, obj: Any = None, *, overwrite: bool = False,
                 **metadata: Any):
        """Register ``obj`` under ``name``; usable as a decorator.

        ``@registry.register("x", category="y")`` decorates a class or
        function; ``registry.register("x", obj)`` registers directly.
        Re-registering a taken name raises :class:`DuplicateNameError`
        unless ``overwrite=True``. Re-registering the *same* object — or a
        fresh object with the same module + qualname, which is what
        ``importlib.reload`` produces — replaces silently, so reloads and
        re-imports stay harmless while genuinely conflicting names fail.
        """

        def _add(target):
            prior = self._entries.get(name)
            if (prior is not None and prior.obj is not target
                    and not overwrite
                    and not _same_provenance(prior.obj, target)):
                raise DuplicateNameError(
                    f"{self.kind} {name!r} is already registered "
                    f"(to {prior.obj!r}); pass overwrite=True to replace it")
            self._entries[name] = RegistryEntry(name, target, dict(metadata))
            return target

        if obj is None:
            return _add
        return _add(obj)

    def unregister(self, name: str) -> None:
        self._entries.pop(name, None)

    # -- lookup --------------------------------------------------------------
    def __getitem__(self, name: str) -> Any:
        try:
            return self._entries[name].obj
        except KeyError:
            # `from None`: the internal KeyError is noise — the caller
            # should see one clean frame, not a chained traceback
            raise RegistryLookupError(self.kind, name, self._entries) from None

    def spec(self, name: str) -> RegistryEntry:
        if name not in self._entries:
            raise RegistryLookupError(self.kind, name, self._entries)
        return self._entries[name]

    def metadata(self, name: str) -> Dict[str, Any]:
        return dict(self.spec(name).metadata)

    def name_of(self, obj: Any) -> str:
        """Reverse lookup: the name ``obj`` (or its class) is registered
        under — how bundles record which registry entry rebuilds them."""
        cls = obj if isinstance(obj, type) else type(obj)
        for e in self._entries.values():
            if e.obj is obj or e.obj is cls:
                return e.name
        raise RegistryLookupError(self.kind, getattr(cls, "__name__", obj),
                                  self._entries)

    # -- Mapping protocol (legacy dict compatibility) ------------------------
    def __iter__(self) -> Iterator[str]:
        return iter(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, name: object) -> bool:
        return name in self._entries

    def __repr__(self) -> str:
        return f"Registry({self.kind!r}, {sorted(self._entries)})"


# ---------------------------------------------------------------------------
# Feature sets
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FeatureSet:
    """A named feature schema plus its extraction paths.

    ``names`` is the schema (order matters — it is persisted in bundles and
    validated on load). ``extract`` maps one matrix to a ``(d,)`` vector;
    ``extract_batch`` maps a sequence to ``(B, d)`` on the host;
    ``extract_batch_jnp`` (optional) consumes a padded CSR batch on device —
    feature sets without one transparently fall back to the host path.
    """

    name: str
    names: Sequence[str]
    extract: Callable
    extract_batch: Optional[Callable] = None
    extract_batch_jnp: Optional[Callable] = None

    @property
    def dim(self) -> int:
        return len(self.names)

    def batch(self, mats) -> Any:
        if self.extract_batch is not None:
            return self.extract_batch(mats)
        import numpy as np
        return np.stack([self.extract(m) for m in mats])

    @property
    def device_capable(self) -> bool:
        return self.extract_batch_jnp is not None


# ---------------------------------------------------------------------------
# The four registries + their decorator front-ends
# ---------------------------------------------------------------------------

REORDERING_REGISTRY = Registry("reordering")
MODEL_REGISTRY = Registry("model")
SCALER_REGISTRY = Registry("scaler")
FEATURE_SET_REGISTRY = Registry("feature set")


def register_reordering(name: str, *, category: str = "uncategorized",
                        symmetric_only: bool = True,
                        device_capable: bool = False, **metadata):
    """Decorator: register a ``CSRMatrix -> perm`` callable."""
    return REORDERING_REGISTRY.register(
        name, category=category, symmetric_only=symmetric_only,
        device_capable=device_capable, **metadata)


def register_model(name: str, *, device_capable: bool = False, **metadata):
    """Decorator: register a :class:`BaseClassifier` subclass.

    ``device_capable`` marks families whose fitted instances expose
    ``forward_jnp`` (inference fuses into the serving jit).
    """
    return MODEL_REGISTRY.register(name, device_capable=device_capable,
                                   **metadata)


def register_scaler(name: str, **metadata):
    """Decorator: register a scaler class (fit/transform/state/load_state)."""
    return SCALER_REGISTRY.register(name, **metadata)


def register_feature_set(name: str, *, names: Sequence[str],
                         extract: Optional[Callable] = None,
                         extract_batch: Optional[Callable] = None,
                         extract_batch_jnp: Optional[Callable] = None,
                         **metadata):
    """Register a feature schema + extractors; decorator over ``extract``.

    Called with ``extract=``, registers immediately; without it, returns a
    decorator for the single-matrix extractor.
    """

    def _add(extract_fn):
        fs = FeatureSet(name, list(names), extract_fn, extract_batch,
                        extract_batch_jnp)
        FEATURE_SET_REGISTRY.register(name, fs,
                                      device_capable=fs.device_capable,
                                      dim=fs.dim, **metadata)
        return extract_fn

    if extract is None:
        return _add
    _add(extract)
    return FEATURE_SET_REGISTRY[name]


def get_feature_set(name: str) -> FeatureSet:
    """The registered :class:`FeatureSet`, importing the default providers
    first so lookups work before any explicit ``repro.core`` import."""
    import repro.core.features  # noqa: F401  (registers paper12/extended19)
    return FEATURE_SET_REGISTRY[name]
