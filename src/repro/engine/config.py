"""Engine configuration: one dataclass for the whole serving stack."""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Optional, Sequence

from repro.core.plan_cache import DEFAULT_CACHE_DIR

__all__ = ["EngineConfig", "DEFAULT_CACHE_DIR"]


@dataclasses.dataclass
class EngineConfig:
    """Everything a :class:`SolverEngine` composes, in one place.

    Capability fields (``model``, ``scaling``, ``feature_set``,
    ``algorithms``) are *registry names*, so swapping any of them — or a
    third-party registration — is a config edit, not a code edit.
    """

    # capability selection (registry names)
    model: str = "random_forest"
    scaling: str = "standard"
    feature_set: str = "paper12"
    # None → adopt the label set of the training dataset / loaded bundle;
    # set it to *assert* the labels (train() rejects a dataset whose
    # algorithm list disagrees)
    algorithms: Optional[Sequence[str]] = None

    # plan cache: dir=None/"" keeps it in-memory; byte/entry budgets bound
    # the disk tier (LRU-by-mtime eviction)
    cache_dir: Optional[str] = DEFAULT_CACHE_DIR
    cache_capacity: int = 4096
    cache_max_disk_bytes: Optional[int] = None
    cache_max_disk_entries: Optional[int] = None

    # featurization / inference path
    path: str = "device"          # "device" (padded CSR batch) or "host"
    use_pallas: bool = False
    batch_size: int = 16
    # serving-mesh width: the featurize→infer shard_map splits each device
    # micro-batch over this many devices (None: leave the process-wide
    # serving mesh alone — degenerate 1-device mesh unless a launcher set
    # one). Installing it is process-global (see SolverEngine).
    serving_devices: Optional[int] = None

    # async serving
    max_wait_ms: float = 5.0
    build_workers: int = 2
    # backpressure + deadlines (the RequestContext spine): max_queue=None
    # keeps the dispatch queue unbounded; setting it makes submit raise a
    # typed QueueFull once the backlog reaches it. default_deadline_ms
    # stamps a deadline on requests that arrive without one — expired
    # requests are shed with DeadlineExceeded at dequeue time instead of
    # occupying a build worker (warm cache hits still succeed).
    max_queue: Optional[int] = None
    default_deadline_ms: Optional[float] = None
    # structured metrics: every serving layer (dispatch, cache tiers, mesh
    # inference, RPC) reports into the engine's MetricsRegistry; a path
    # here additionally streams shed/reject events as JSON lines
    metrics_jsonl: Optional[str] = None

    # RPC front-end (SolverEngine.serve(rpc=True)): bind address. Port 0
    # binds an ephemeral port, published on the returned server object.
    rpc_host: str = "127.0.0.1"
    rpc_port: int = 0

    # numeric solve: backend picks the front-math substrate ("numpy" host
    # BLAS, "pallas" per-front kernels, "batched" level-scheduled batched
    # kernels, "pipelined" level-scheduled with async dispatch + on-device
    # extend-add — see repro.sparse.schedule / .multifrontal); solve_dtype
    # picks the precision mode ("fp64", "fp32", or "fp32_refine" = fp32
    # factorization + fp64 iterative refinement; the f32-only device
    # backends promote "fp64" to "fp32_refine" automatically, with a
    # warning at config time so the promotion is never silent)
    solver: str = "multifrontal"  # or "simplicial"
    backend: str = "numpy"
    solve_dtype: str = "fp64"
    # triangular-sweep substrate for the solve phase: "auto" (level sweeps
    # when the factor has a schedule), "seq" per-front reference, "level"
    # host level-batched, "device" batched Pallas substitution kernels on
    # device-resident factor stacks (f32 — pairs with refinement exactly
    # like the device factor backends)
    sweep: str = "auto"
    # autotuned bucket/block policy (repro.autotune.solve_tuner): when
    # autotune_solve is True the engine loads (or measures, on first use)
    # the per-device-kind SolvePolicy from autotune_dir and threads its
    # bs/pad through execute_plan; False leaves the kernel defaults
    autotune_solve: bool = False
    autotune_dir: str = os.path.join("artifacts", "autotune")

    # training
    fast_grids: bool = False
    cv: int = 5
    test_size: float = 0.2
    seed: int = 0

    # bundle lifecycle (repro.lifecycle): where the versioned bundle
    # registry lives, the promotion-gate thresholds promote() applies by
    # default, and the shadow evaluator's mirror-queue bound (a full queue
    # drops observations rather than slowing the serving path)
    bundle_dir: str = os.path.join("artifacts", "bundles")
    promote_min_accuracy: float = 0.5
    promote_min_shadow_requests: int = 10
    promote_min_win_rate: float = 0.5
    shadow_max_queue: int = 512

    def __post_init__(self) -> None:
        if self.path not in ("host", "device"):
            raise ValueError(f"path must be 'host' or 'device', "
                             f"got {self.path!r}")
        if self.backend not in ("numpy", "pallas", "batched", "pipelined"):
            raise ValueError(f"backend must be 'numpy', 'pallas', 'batched' "
                             f"or 'pipelined', got {self.backend!r}")
        if self.solve_dtype not in ("fp64", "fp32", "fp32_refine"):
            raise ValueError(f"solve_dtype must be 'fp64', 'fp32' or "
                             f"'fp32_refine', got {self.solve_dtype!r}")
        if self.sweep not in ("auto", "seq", "level", "device"):
            raise ValueError(f"sweep must be 'auto', 'seq', 'level' or "
                             f"'device', got {self.sweep!r}")
        if (self.solve_dtype == "fp64"
                and (self.backend in ("pallas", "batched", "pipelined")
                     or self.sweep == "device")):
            what = (f"backend {self.backend!r} factors"
                    if self.backend != "numpy" or self.sweep != "device"
                    else "sweep 'device' solves")
            warnings.warn(
                f"{what} in fp32; solve_dtype "
                f"'fp64' will run as 'fp32_refine' (fp32 factorization + "
                f"fp64 iterative refinement). Set solve_dtype="
                f"'fp32_refine' explicitly to silence this.",
                UserWarning, stacklevel=2)
