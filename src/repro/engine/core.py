""":class:`SolverEngine` — matrix in, best reordering (and solve) out.

The facade composes the registries, the selector pipeline, the
ExecutionPlan builder/cache, and the async server behind one object with
one configuration. The key invariant it owns: **the plan cache is always
versioned by the fingerprint of the fitted model/scaler**. ``train()`` (or
``load()``) computes the fingerprint and rebuilds the cache front-end with
it, so a refit makes every previously persisted plan invisible — no manual
``TwoTierPlanCache(version=...)`` bump anywhere, and a stale plan can never
be served by a newer model.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .bundle import SelectorBundle
from .config import EngineConfig
from .registry import get_feature_set

__all__ = ["SolverEngine", "EngineError"]


class EngineError(RuntimeError):
    """Engine misuse: untrained access, config/selector mismatch, etc."""


class SolverEngine:
    """One API for train → select → plan → solve → serve → save/load.

    Build one from a config and train it, attach an existing fitted
    selector, or load a persisted :class:`SelectorBundle`::

        engine = SolverEngine(EngineConfig(model="random_forest"))
        engine.train(dataset)
        engine.solve(A, b)
        engine.save("selector.bundle")
        engine = SolverEngine.load("selector.bundle")
    """

    def __init__(self, config: Optional[EngineConfig] = None,
                 selector=None):
        self.config = config if config is not None else EngineConfig()
        self._selector = None
        self._fingerprint: Optional[str] = None
        self._builder = None
        self.last_report: Optional[Dict[str, Any]] = None
        if selector is not None:
            self.attach(selector)

    # -- selector lifecycle --------------------------------------------------
    @property
    def selector(self):
        if self._selector is None:
            raise EngineError("engine has no trained selector yet — call "
                              "train(dataset), attach(selector), or "
                              "SolverEngine.load(path)")
        return self._selector

    @property
    def is_trained(self) -> bool:
        return self._selector is not None

    def attach(self, selector) -> "SolverEngine":
        """Adopt a fitted ``ReorderSelector`` (feature set must match)."""
        fs = getattr(selector, "feature_set", "paper12")
        if fs != self.config.feature_set:
            raise EngineError(
                f"selector was trained on feature set {fs!r} but the engine "
                f"is configured for {self.config.feature_set!r}")
        self._selector = selector
        self.refresh_fingerprint()
        return self

    def train(self, dataset, **overrides) -> Dict[str, Any]:
        """Grid-search + refit on a :class:`LabeledDataset`; returns the
        evaluation report. Any ``train_selector`` keyword can be overridden
        per call (e.g. ``grid=...``); the new fit gets a new fingerprint,
        which re-versions the plan cache automatically."""
        from repro.core.selector import train_selector

        cfg = self.config
        if (cfg.algorithms is not None
                and list(cfg.algorithms) != list(dataset.algorithms)):
            raise EngineError(
                f"config asserts algorithms {list(cfg.algorithms)} but the "
                f"dataset was labeled over {list(dataset.algorithms)} — "
                "relabel the dataset or drop the config assertion")
        kwargs: Dict[str, Any] = dict(
            model_name=cfg.model, scaling=cfg.scaling,
            feature_set=cfg.feature_set, fast=cfg.fast_grids, cv=cfg.cv,
            test_size=cfg.test_size, seed=cfg.seed)
        kwargs.update(overrides)
        self._selector, report = train_selector(dataset, **kwargs)
        self.last_report = report
        self.refresh_fingerprint()
        return report

    # -- fingerprint → cache version -----------------------------------------
    @property
    def fingerprint(self) -> Optional[str]:
        """Fingerprint of the fitted (model, scaler, features, algorithms);
        ``None`` while untrained. This exact value versions the plan cache."""
        return self._fingerprint

    def refresh_fingerprint(self) -> Optional[str]:
        """Recompute the fingerprint from the live selector and, if it
        changed, rebuild the cache front-end under the new version.
        ``train``/``attach``/``load`` call this; call it yourself only after
        mutating the fitted model out of band."""
        if self._selector is None:
            return None
        fp = SelectorBundle.from_selector(self._selector).fingerprint
        if fp != self._fingerprint:
            self._fingerprint = fp
            self._builder = None  # rebuilt lazily under the new version
        return fp

    @property
    def cache_version(self) -> str:
        if self._fingerprint is None:
            raise EngineError("no fingerprint before training")
        return f"sel-{self._fingerprint[:16]}"

    def _get_builder(self):
        if self._builder is None:
            from repro.core.plan import PlanBuilder
            from repro.core.plan_cache import PlanCache, TwoTierPlanCache

            cfg = self.config
            if cfg.cache_dir:
                cache = TwoTierPlanCache(
                    cfg.cache_capacity, cfg.cache_dir,
                    version=self.cache_version,
                    max_disk_bytes=cfg.cache_max_disk_bytes,
                    max_disk_entries=cfg.cache_max_disk_entries)
            else:
                cache = PlanCache(cfg.cache_capacity)
            self._builder = PlanBuilder(
                self.selector, cache, path=cfg.path,
                use_pallas=cfg.use_pallas, batch_size=cfg.batch_size)
        return self._builder

    @property
    def builder(self):
        """The fingerprint-versioned :class:`PlanBuilder` (cache included)."""
        return self._get_builder()

    # -- selection -----------------------------------------------------------
    def select(self, a) -> Tuple[str, float]:
        """(algorithm name, prediction seconds) for one matrix."""
        return self.selector.select(a)

    def select_batch(self, mats: Sequence) -> List[str]:
        """Algorithm names for a batch via the configured path."""
        names, _ = self.selector.select_batch(
            mats, path=self.config.path, use_pallas=self.config.use_pallas)
        return names

    # -- planning ------------------------------------------------------------
    def plan(self, a):
        """Cached :class:`ExecutionPlan` for one matrix."""
        plan, _ = self._get_builder().get_or_build(a)
        return plan

    def plan_batch(self, mats: Sequence) -> List:
        """Plans for a request batch (hits skip every cold stage)."""
        return self._get_builder().plan_batch(mats)

    # -- solving -------------------------------------------------------------
    def solve(self, a, b: Optional[np.ndarray] = None) -> Dict[str, Any]:
        """Plan (cached) + numeric factor + solve; returns the result dict
        of :func:`repro.core.plan.execute_plan` (x, timings, residual)."""
        from repro.core.plan import execute_plan

        return execute_plan(a, self.plan(a), b, solver=self.config.solver,
                            backend=self.config.backend)

    def solve_batch(self, mats: Sequence,
                    bs: Optional[Sequence[Optional[np.ndarray]]] = None
                    ) -> List[Dict[str, Any]]:
        plans = self.plan_batch(mats)
        from repro.core.plan import execute_plan

        if bs is None:
            bs = [None] * len(mats)
        return [execute_plan(a, p, b, solver=self.config.solver,
                             backend=self.config.backend)
                for a, p, b in zip(mats, plans, bs)]

    # -- serving -------------------------------------------------------------
    def serve(self, **overrides):
        """A fresh :class:`AsyncPlanServer` bound to this engine's builder
        (and therefore to its fingerprint-versioned cache). Keyword
        overrides pass through (``batch_size``, ``max_wait_ms``,
        ``build_workers``)."""
        from repro.launch.serve_selector import AsyncPlanServer

        cfg = self.config
        kwargs = dict(batch_size=cfg.batch_size,
                      max_wait_ms=cfg.max_wait_ms,
                      build_workers=cfg.build_workers)
        kwargs.update(overrides)
        return AsyncPlanServer(self._get_builder(), **kwargs)

    # -- persistence ---------------------------------------------------------
    def save(self, path: str, meta: Optional[Dict[str, Any]] = None) -> str:
        """Persist the fitted selector as a versioned SelectorBundle."""
        meta = dict(meta or {})
        if self.last_report is not None:
            meta.setdefault("test_accuracy",
                            self.last_report.get("test_accuracy"))
        return SelectorBundle.from_selector(self.selector,
                                            meta=meta).save(path)

    @classmethod
    def load(cls, path: str, config: Optional[EngineConfig] = None
             ) -> "SolverEngine":
        """Rebuild an engine from a bundle (validating it), adopting the
        bundle's feature set when no config is given. A config whose
        ``feature_set`` disagrees with the bundle is rejected — serving a
        model on features it was not trained on is never right. The
        capability fields (model / scaling / algorithms) are synced to what
        the bundle actually serves, so ``stats()`` and a later ``train()``
        never misreport the live pipeline; a passed config contributes the
        cache/serving/solve knobs."""
        import dataclasses

        bundle = SelectorBundle.load(path)
        if config is None:
            config = EngineConfig(feature_set=bundle.feature_set)
        elif config.feature_set != bundle.feature_set:
            raise EngineError(
                f"bundle {path!r} was trained on feature set "
                f"{bundle.feature_set!r} but the engine config asks for "
                f"{config.feature_set!r}")
        config = dataclasses.replace(config, model=bundle.model_name,
                                     scaling=bundle.scaler_name,
                                     algorithms=list(bundle.algorithms))
        engine = cls(config)
        engine.attach(bundle.to_selector())
        return engine

    # -- introspection -------------------------------------------------------
    def feature_set(self):
        return get_feature_set(self.config.feature_set)

    def stats(self) -> Dict[str, Any]:
        s = (self._get_builder().stats() if self._selector is not None
             else {})
        s.update(fingerprint=self._fingerprint,
                 model=self.config.model, scaling=self.config.scaling,
                 feature_set=self.config.feature_set)
        return s

    def __repr__(self) -> str:
        fp = self._fingerprint[:12] if self._fingerprint else "untrained"
        return (f"SolverEngine(model={self.config.model!r}, "
                f"features={self.config.feature_set!r}, fingerprint={fp})")
