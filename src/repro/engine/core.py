""":class:`SolverEngine` — matrix in, best reordering (and solve) out.

The facade composes the registries, the selector pipeline, the
ExecutionPlan builder/cache, and the async server behind one object with
one configuration. The key invariant it owns: **the plan cache is always
versioned by the fingerprint of the fitted model/scaler**. ``train()`` (or
``load()``) computes the fingerprint and rebuilds the cache front-end with
it, so a refit makes every previously persisted plan invisible — no manual
``TwoTierPlanCache(version=...)`` bump anywhere, and a stale plan can never
be served by a newer model.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .bundle import SelectorBundle
from .config import EngineConfig
from .registry import get_feature_set

__all__ = ["SolverEngine", "EngineError"]


class EngineError(RuntimeError):
    """Engine misuse: untrained access, config/selector mismatch, etc."""


def _dataset_provenance(ds) -> Dict[str, Any]:
    """Plain-data description of a LabeledDataset for bundle schema v2."""
    labels = np.asarray(ds.labels)
    return dict(
        kind=type(ds).__name__,
        n_samples=int(np.asarray(ds.features).shape[0]),
        algorithms=list(ds.algorithms),
        feature_set=getattr(ds, "feature_set", "paper12"),
        groups=sorted(set(getattr(ds, "groups", []))),
        dim_range=[int(np.min(ds.dims)), int(np.max(ds.dims))],
        nnz_range=[int(np.min(ds.nnzs)), int(np.max(ds.nnzs))],
        label_counts={alg: int((labels == i).sum())
                      for i, alg in enumerate(ds.algorithms)},
    )


class SolverEngine:
    """One API for train → select → plan → solve → serve → save/load.

    Build one from a config and train it, attach an existing fitted
    selector, or load a persisted :class:`SelectorBundle`::

        engine = SolverEngine(EngineConfig(model="random_forest"))
        engine.train(dataset)
        engine.solve(A, b)
        engine.save("selector.bundle")
        engine = SolverEngine.load("selector.bundle")
    """

    def __init__(self, config: Optional[EngineConfig] = None,
                 selector=None):
        self.config = config if config is not None else EngineConfig()
        self._selector = None
        self._fingerprint: Optional[str] = None
        self._builder = None
        self._metrics = None  # lazily built (sink config lives on config)
        self._solve_policy = None  # lazily resolved (may autotune once)
        self.last_report: Optional[Dict[str, Any]] = None
        # dataset provenance of the last train() — persisted into bundle
        # schema v2 by save() (None for attach()/load()-built engines)
        self.last_provenance: Optional[Dict[str, Any]] = None
        # bundle lifecycle: the bundle the live selector came from (so a
        # re-registration at the next promote reuses its report card
        # instead of a stale last_report), the shadow evaluator mirroring
        # the serving path, and the registry handle
        self._attached_bundle: Optional[SelectorBundle] = None
        self._shadow = None
        self._registry = None
        self._promote_lock = threading.Lock()
        if selector is not None:
            self.attach(selector)

    # -- selector lifecycle --------------------------------------------------
    @property
    def selector(self):
        if self._selector is None:
            raise EngineError("engine has no trained selector yet — call "
                              "train(dataset), attach(selector), or "
                              "SolverEngine.load(path)")
        return self._selector

    @property
    def is_trained(self) -> bool:
        return self._selector is not None

    def attach(self, selector) -> "SolverEngine":
        """Adopt a fitted ``ReorderSelector`` (feature set must match)."""
        fs = getattr(selector, "feature_set", "paper12")
        if fs != self.config.feature_set:
            raise EngineError(
                f"selector was trained on feature set {fs!r} but the engine "
                f"is configured for {self.config.feature_set!r}")
        self._selector = selector
        self._attached_bundle = None  # promote()/load() re-set it after
        self.refresh_fingerprint()
        return self

    def train(self, dataset, **overrides) -> Dict[str, Any]:
        """Grid-search + refit on a :class:`LabeledDataset`; returns the
        evaluation report. Any ``train_selector`` keyword can be overridden
        per call (e.g. ``grid=...``); the new fit gets a new fingerprint,
        which re-versions the plan cache automatically."""
        from repro.core.selector import train_selector

        cfg = self.config
        if (cfg.algorithms is not None
                and list(cfg.algorithms) != list(dataset.algorithms)):
            raise EngineError(
                f"config asserts algorithms {list(cfg.algorithms)} but the "
                f"dataset was labeled over {list(dataset.algorithms)} — "
                "relabel the dataset or drop the config assertion")
        kwargs: Dict[str, Any] = dict(
            model_name=cfg.model, scaling=cfg.scaling,
            feature_set=cfg.feature_set, fast=cfg.fast_grids, cv=cfg.cv,
            test_size=cfg.test_size, seed=cfg.seed)
        kwargs.update(overrides)
        self._selector, report = train_selector(dataset, **kwargs)
        self.last_report = report
        self.last_provenance = _dataset_provenance(dataset)
        self._attached_bundle = None  # the fit is newer than any bundle
        self.refresh_fingerprint()
        return report

    # -- fingerprint → cache version -----------------------------------------
    @property
    def fingerprint(self) -> Optional[str]:
        """Fingerprint of the fitted (model, scaler, features, algorithms);
        ``None`` while untrained. This exact value versions the plan cache."""
        return self._fingerprint

    def refresh_fingerprint(self) -> Optional[str]:
        """Recompute the fingerprint from the live selector and, if it
        changed, rebuild the cache front-end under the new version.
        ``train``/``attach``/``load`` call this; call it yourself only after
        mutating the fitted model out of band."""
        if self._selector is None:
            return None
        fp = SelectorBundle.from_selector(self._selector).fingerprint
        if fp != self._fingerprint:
            self._fingerprint = fp
            self._builder = None  # rebuilt lazily under the new version
        return fp

    @property
    def cache_version(self) -> str:
        if self._fingerprint is None:
            raise EngineError("no fingerprint before training")
        return f"sel-{self._fingerprint[:16]}"

    @property
    def metrics(self):
        """The engine's :class:`repro.core.metrics.MetricsRegistry` — one
        registry per engine, shared by the cache tiers, the plan builder
        (mesh inference), the dispatcher, and the RPC front-end, so
        ``metrics.snapshot()`` is the whole serving stack in one dict."""
        if self._metrics is None:
            from repro.core.metrics import JSONLSink, MetricsRegistry

            self._metrics = MetricsRegistry()
            if self.config.metrics_jsonl:
                self._metrics.add_sink(JSONLSink(self.config.metrics_jsonl))
        return self._metrics

    def _get_builder(self):
        if self._builder is None:
            from repro.core.plan import PlanBuilder
            from repro.core.plan_cache import PlanCache, TwoTierPlanCache

            cfg = self.config
            if cfg.cache_dir:
                cache = TwoTierPlanCache(
                    cfg.cache_capacity, cfg.cache_dir,
                    version=self.cache_version,
                    max_disk_bytes=cfg.cache_max_disk_bytes,
                    max_disk_entries=cfg.cache_max_disk_entries,
                    metrics=self.metrics)
            else:
                cache = PlanCache(cfg.cache_capacity, metrics=self.metrics)
            self._builder = PlanBuilder(
                self.selector, cache, path=cfg.path,
                use_pallas=cfg.use_pallas, batch_size=cfg.batch_size,
                metrics=self.metrics)
        return self._builder

    @property
    def builder(self):
        """The fingerprint-versioned :class:`PlanBuilder` (cache included)."""
        return self._get_builder()

    # -- selection -----------------------------------------------------------
    def select(self, a) -> Tuple[str, float]:
        """(algorithm name, prediction seconds) for one matrix."""
        return self.selector.select(a)

    def select_batch(self, mats: Sequence) -> List[str]:
        """Algorithm names for a batch via the configured path (sharded
        over the configured serving mesh on the device path)."""
        self._ensure_serving_mesh()
        names, _ = self.selector.select_batch(
            mats, path=self.config.path, use_pallas=self.config.use_pallas)
        return names

    # -- planning ------------------------------------------------------------
    def plan(self, a, ctx=None):
        """Cached :class:`ExecutionPlan` for one matrix. Mints a
        :class:`repro.core.reqctx.RequestContext` when the caller did not
        bring one; either way the context accumulates per-stage spans
        (cache/select/reorder/symbolic) for this request."""
        from repro.core.reqctx import RequestContext

        self._ensure_serving_mesh()
        if ctx is None:
            ctx = RequestContext.mint(
                deadline_ms=self.config.default_deadline_ms)
        plan, _ = self._get_builder().get_or_build(a, ctx=ctx)
        if self._shadow is not None:
            # mirror the decision to the shadow candidate — off the hot
            # path (O(enqueue), never raises), after the real plan is in
            # hand, so the client-visible response is untouched
            self._shadow.observe(a, plan.algorithm, key=plan.fingerprint)
        return plan

    def plan_batch(self, mats: Sequence) -> List:
        """Plans for a request batch (hits skip every cold stage)."""
        self._ensure_serving_mesh()
        return self._get_builder().plan_batch(mats)

    # -- solving -------------------------------------------------------------
    @property
    def solve_policy(self):
        """The :class:`repro.autotune.solve_tuner.SolvePolicy` this engine
        applies to the numeric backends. With ``autotune_solve`` off this
        is the conservative default (kernel defaults, pow2 padding) unless
        a tuned record for this device kind is already persisted in
        ``autotune_dir``; with it on, the first access runs the tuner once
        (persisting the result) and every later engine just loads it."""
        if self._solve_policy is None:
            from repro.autotune.solve_tuner import get_policy

            cfg = self.config
            self._solve_policy = get_policy(
                cfg.autotune_dir, backend=cfg.backend,
                autotune=cfg.autotune_solve)
        return self._solve_policy

    def _solve_kwargs(self) -> Dict[str, Any]:
        cfg = self.config
        pol = self.solve_policy
        return dict(solver=cfg.solver, backend=cfg.backend,
                    solve_dtype=cfg.solve_dtype, pad=pol.pad, bs=pol.bs,
                    sweep=cfg.sweep,
                    sweep_bs=getattr(pol, "sweep_bs", None),
                    rt=getattr(pol, "rt", None),
                    metrics=self.metrics)

    def solve(self, a, b: Optional[np.ndarray] = None,
              ctx=None) -> Dict[str, Any]:
        """Plan (cached) + numeric factor + solve; returns the result dict
        of :func:`repro.core.plan.execute_plan` (x, timings, residual).
        One :class:`RequestContext` spans planning *and* the numeric tail,
        so the result carries the request id and ``ctx.spans`` tells the
        whole story (cache → … → factor.assemble/factor.device → solve);
        the same spans land in the engine metrics as ``stage.*``
        histograms. The tuned solve policy (``solve_policy``) supplies the
        bucket pad and kernel block knobs."""
        from repro.core.plan import execute_plan
        from repro.core.reqctx import RequestContext

        if ctx is None:
            ctx = RequestContext.mint(
                deadline_ms=self.config.default_deadline_ms)
        return execute_plan(a, self.plan(a, ctx=ctx), b, ctx=ctx,
                            **self._solve_kwargs())

    def solve_batch(self, mats: Sequence,
                    bs: Optional[Sequence[Optional[np.ndarray]]] = None
                    ) -> List[Dict[str, Any]]:
        plans = self.plan_batch(mats)
        from repro.core.plan import execute_plan

        if bs is None:
            bs = [None] * len(mats)
        kw = self._solve_kwargs()
        return [execute_plan(a, p, b, **kw)
                for a, p, b in zip(mats, plans, bs)]

    # -- serving -------------------------------------------------------------
    def _ensure_serving_mesh(self) -> None:
        """Install the configured serving mesh (``serving_devices``) if it
        is not already active. Process-global by design — the serving mesh
        is device topology, not per-engine state — and a no-op when the
        config leaves ``serving_devices`` unset (the degenerate 1-device
        mesh, or whatever the launcher installed, stays active)."""
        nd = self.config.serving_devices
        if nd is None:
            return
        from repro.distributed.meshctx import (get_serving_mesh,
                                               make_serving_mesh,
                                               set_serving_mesh)

        if get_serving_mesh().num_devices != nd:
            set_serving_mesh(make_serving_mesh(nd))

    def serve(self, *, rpc: bool = False, host: Optional[str] = None,
              port: Optional[int] = None, **overrides):
        """A fresh server bound to this engine's builder (and therefore to
        its fingerprint-versioned, replica-shareable cache).

        ``rpc=False`` (default) returns the in-process
        :class:`AsyncPlanServer`; ``rpc=True`` additionally binds the
        length-prefixed socket front-end (:class:`repro.launch.rpc
        .PlanRPCServer`) on ``(host, port)`` — defaulting to the config's
        ``rpc_host``/``rpc_port`` — and returns it (its ``close()`` shuts
        the pipeline down too; the bound port is ``server.port``). Keyword
        overrides pass through to the pipeline (``batch_size``,
        ``max_wait_ms``, ``build_workers``)."""
        from repro.launch.serve_selector import AsyncPlanServer

        self._ensure_serving_mesh()
        cfg = self.config
        kwargs = dict(batch_size=cfg.batch_size,
                      max_wait_ms=cfg.max_wait_ms,
                      build_workers=cfg.build_workers,
                      max_queue=cfg.max_queue,
                      default_deadline_ms=cfg.default_deadline_ms,
                      metrics=self.metrics,
                      # late start_shadow()/stop_shadow() are picked up
                      # live: the dispatcher re-reads the provider on
                      # every mirrored decision
                      shadow=lambda: self._shadow)
        kwargs.update(overrides)
        server = AsyncPlanServer(self._get_builder(), **kwargs)
        if not rpc:
            return server
        from repro.launch.rpc import PlanRPCServer

        try:
            return PlanRPCServer(
                server, host=cfg.rpc_host if host is None else host,
                port=cfg.rpc_port if port is None else port,
                own_dispatcher=True)
        except BaseException:
            # a failed bind (port in use, bad host) must not leak the
            # already-running batcher/builder threads — e.g. a caller
            # retrying ports in a loop would accumulate a pool per attempt
            server.close()
            raise

    # -- bundle lifecycle: shadow → promote → rollback -----------------------
    @property
    def registry(self):
        """The :class:`repro.lifecycle.registry.BundleRegistry` rooted at
        ``config.bundle_dir`` — the durable side of promote/rollback."""
        if (self._registry is None
                or self._registry.root != self.config.bundle_dir):
            from repro.lifecycle.registry import BundleRegistry

            self._registry = BundleRegistry(self.config.bundle_dir)
        return self._registry

    @property
    def shadow(self):
        """The active :class:`repro.lifecycle.shadow.ShadowEvaluator`, or
        None. While set, every ``plan()``/``solve()`` decision (and every
        decision of servers built by ``serve()``) is mirrored to it."""
        return self._shadow

    def start_shadow(self, candidate):
        """Shadow-serve a candidate next to the incumbent.

        ``candidate`` is a :class:`SelectorBundle`, a path to one, or a
        fitted ``ReorderSelector``. Replaces any active shadow. The
        evaluator reports into this engine's metrics (``shadow.*``) and
        its ``stats()`` are the online evidence ``promote()`` gates on."""
        from repro.lifecycle.shadow import ShadowEvaluator

        self.stop_shadow()
        self._shadow = ShadowEvaluator(
            candidate, metrics=self.metrics,
            max_queue=self.config.shadow_max_queue)
        return self._shadow

    def stop_shadow(self, timeout: float = 10.0
                    ) -> Optional[Dict[str, Any]]:
        """Detach and stop the shadow evaluator; its final ``stats()``
        (after draining the mirror queue), or None if none was active."""
        shadow, self._shadow = self._shadow, None
        if shadow is None:
            return None
        shadow.drain(timeout)
        shadow.close(timeout)
        return shadow.stats()

    def promote(self, candidate=None, *, gate=None,
                source: Optional[str] = None) -> Dict[str, Any]:
        """Gated atomic swap of the serving bundle.

        ``candidate`` defaults to the bundle the active shadow evaluator
        is scoring. The gate (``PromotionGate.from_config(self.config)``
        unless one is passed) checks the candidate's report card and — if
        the shadow evaluator is scoring this exact candidate — its online
        win rate; :class:`repro.lifecycle.promote.NotPromotable` /
        :class:`GateRejected` abort with nothing changed. On pass: the
        incumbent and the candidate are registered (lineage edge incumbent
        → candidate), the registry's serving pointer moves, the engine
        adopts the candidate, and — via the fingerprint → cache-version
        plumbing — every plan built under the incumbent becomes invisible
        (restored intact by :meth:`rollback`). Returns the gate decision
        extended with ``version``/``previous_version``."""
        from repro.lifecycle.promote import PromotionGate, evaluate_gate

        with self._promote_lock:
            shadow = self._shadow
            if candidate is None:
                if shadow is None or shadow.bundle is None:
                    raise EngineError(
                        "promote() has no candidate: pass a SelectorBundle "
                        "(or path), or start_shadow() with a bundle first")
                candidate = shadow.bundle
            elif isinstance(candidate, str):
                candidate = SelectorBundle.load(candidate)
            candidate.validate()
            if gate is None:
                gate = PromotionGate.from_config(self.config)
            shadow_stats = None
            if (shadow is not None and shadow.candidate_fingerprint
                    == candidate.fingerprint):
                shadow.drain(10.0)  # settle the scorecard before gating
                shadow_stats = shadow.stats()
            decision = evaluate_gate(candidate, gate, shadow_stats)

            reg = self.registry
            incumbent = self._current_bundle()
            inc_entry = None
            if incumbent is not None:
                inc_entry = reg.register(incumbent, source="incumbent")
                if reg.serving_version() is None:
                    # first promotion ever: record that the incumbent
                    # *was* serving, so rollback has a target
                    reg.mark_serving(inc_entry["version"])
            cand_entry = reg.register(
                candidate, source=source or "promote",
                parent=None if inc_entry is None else inc_entry["version"])
            entry = reg.mark_serving(cand_entry["version"])
            self._adopt_bundle(candidate)
            self.stop_shadow()
            self.metrics.emit("lifecycle.promote",
                              version=entry["version"],
                              fingerprint=candidate.fingerprint)
            return dict(decision, version=entry["version"],
                        previous_version=(None if inc_entry is None
                                          else inc_entry["version"]))

    def rollback(self) -> Dict[str, Any]:
        """Swap the serving bundle back to the registry's ``previous``
        version. The engine re-adopts that bundle, and the fingerprint →
        cache-version plumbing makes its previously persisted plans
        visible again (nothing was deleted at promote time). Returns the
        restored registry entry."""
        with self._promote_lock:
            entry = self.registry.rollback()
            self._adopt_bundle(self.registry.load(entry["version"]))
            self.metrics.emit("lifecycle.rollback",
                              version=entry["version"],
                              fingerprint=entry["fingerprint"])
            return entry

    def _adopt_bundle(self, bundle: SelectorBundle) -> None:
        """Make ``bundle`` the serving state: sync the capability fields,
        attach its selector (which re-versions the plan cache off the new
        fingerprint), and remember the bundle for later registration."""
        import dataclasses

        if bundle.feature_set != self.config.feature_set:
            raise EngineError(
                f"bundle was trained on feature set "
                f"{bundle.feature_set!r} but the engine is configured for "
                f"{self.config.feature_set!r}")
        self.config = dataclasses.replace(
            self.config, model=bundle.model_name,
            scaling=bundle.scaler_name, algorithms=list(bundle.algorithms))
        self.attach(bundle.to_selector())
        self._attached_bundle = bundle
        # last_report described the *previous* fit; the adopted bundle's
        # own report card travels with it
        self.last_report = None
        self.last_provenance = None

    # -- persistence ---------------------------------------------------------
    def _report_card(self) -> Optional[Dict[str, Any]]:
        """The schema-v2 report card of the last ``train()``, or None for
        an attach()/load()-built engine (whose quality was not measured
        here)."""
        if self.last_report is None:
            return None
        rep = self.last_report
        conf = rep.get("confusion")
        return dict(
            test_accuracy=rep.get("test_accuracy"),
            cv_score=rep.get("cv_score"),
            best_params=rep.get("best_params"),
            per_algorithm_recall=rep.get("per_algorithm_recall"),
            confusion=(np.asarray(conf).tolist()
                       if conf is not None else None),
            test_support=rep.get("test_support"),
        )

    def _current_bundle(self) -> Optional[SelectorBundle]:
        """The serving state as a bundle: the attached bundle when the live
        selector still matches it (so its report card survives), else a
        fresh snapshot carrying this engine's training report (if any)."""
        if self._selector is None:
            return None
        if (self._attached_bundle is not None
                and self._attached_bundle.fingerprint == self._fingerprint):
            return self._attached_bundle
        return SelectorBundle.from_selector(
            self.selector, report_card=self._report_card(),
            provenance=self.last_provenance)

    def save(self, path: str, meta: Optional[Dict[str, Any]] = None) -> str:
        """Persist the fitted selector as a versioned SelectorBundle.

        When the engine trained the selector itself, the bundle carries the
        schema-v2 training-report card (test accuracy, per-algorithm
        recall, confusion matrix) and the dataset provenance — an
        attach()/load()-built engine saves a bundle with both ``None``."""
        meta = dict(meta or {})
        report_card = self._report_card()
        if report_card is not None:
            meta.setdefault("test_accuracy", report_card["test_accuracy"])
        return SelectorBundle.from_selector(
            self.selector, meta=meta, report_card=report_card,
            provenance=self.last_provenance).save(path)

    @classmethod
    def load(cls, path: str, config: Optional[EngineConfig] = None
             ) -> "SolverEngine":
        """Rebuild an engine from a bundle (validating it), adopting the
        bundle's feature set when no config is given. A config whose
        ``feature_set`` disagrees with the bundle is rejected — serving a
        model on features it was not trained on is never right. The
        capability fields (model / scaling / algorithms) are synced to what
        the bundle actually serves, so ``stats()`` and a later ``train()``
        never misreport the live pipeline; a passed config contributes the
        cache/serving/solve knobs."""
        import dataclasses

        bundle = SelectorBundle.load(path)
        if config is None:
            config = EngineConfig(feature_set=bundle.feature_set)
        elif config.feature_set != bundle.feature_set:
            raise EngineError(
                f"bundle {path!r} was trained on feature set "
                f"{bundle.feature_set!r} but the engine config asks for "
                f"{config.feature_set!r}")
        config = dataclasses.replace(config, model=bundle.model_name,
                                     scaling=bundle.scaler_name,
                                     algorithms=list(bundle.algorithms))
        engine = cls(config)
        engine.attach(bundle.to_selector())
        engine._attached_bundle = bundle  # keep its report card for
        return engine                     # registration at promote time

    # -- introspection -------------------------------------------------------
    def feature_set(self):
        return get_feature_set(self.config.feature_set)

    def stats(self) -> Dict[str, Any]:
        s = (self._get_builder().stats() if self._selector is not None
             else {})
        s.update(fingerprint=self._fingerprint,
                 model=self.config.model, scaling=self.config.scaling,
                 feature_set=self.config.feature_set)
        return s

    def __repr__(self) -> str:
        fp = self._fingerprint[:12] if self._fingerprint else "untrained"
        return (f"SolverEngine(model={self.config.model!r}, "
                f"features={self.config.feature_set!r}, fingerprint={fp})")
