"""Versioned selector artifacts: :class:`SelectorBundle`.

A bundle replaces raw ``ReorderSelector`` pickles as the persistence format
for trained selectors. Instead of pickling live objects (whose class layout
silently drifts between revisions), a bundle is a *schema-versioned
envelope of plain data*:

    schema version + feature schema (set name + ordered feature names)
    + algorithm list + model (registry name, hyperparameters, fitted state
    via ``state()``) + scaler (registry name, fitted state) + fingerprint

Loading validates everything before any object is built: the schema
version, that the model/scaler/feature-set names resolve in their
registries, that the stored feature names match the registered feature
set's schema, and that the stored fingerprint matches the recomputed one
(corruption check). Legacy ``ReorderSelector.save`` pickles still load,
behind a :class:`DeprecationWarning` shim.

**Schema v2** adds two *descriptive* sections — ``report_card`` (held-out
test accuracy, per-algorithm recall, confusion matrix) and ``provenance``
(what dataset the selector was trained on) — so a bundle answers "how good
is this selector and where did it come from" without the training run.
Both are deliberately excluded from the fingerprint: they describe the
fitted behaviour, they don't change it, so a v1 bundle re-saved with a
card keeps its cache version. v1 bundles (no such sections) still load,
with both set to ``None``.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import time
import warnings
from typing import Any, Dict, List, Optional

from .fingerprint import fingerprint_state
from .registry import (FEATURE_SET_REGISTRY, MODEL_REGISTRY, SCALER_REGISTRY,
                       get_feature_set)

__all__ = ["SelectorBundle", "BundleValidationError",
           "BUNDLE_SCHEMA_VERSION"]

BUNDLE_SCHEMA_VERSION = 2

_MAGIC = "repro.engine.SelectorBundle"


class BundleValidationError(RuntimeError):
    """A bundle failed load-time validation (schema / registry / schema
    mismatch / corruption)."""


def _ensure_default_registrations() -> None:
    """Bundles resolve by registry name; make sure the in-tree providers
    have registered before lookups (third-party entries must already be
    imported by the caller, exactly like any plugin system)."""
    import repro.core.features  # noqa: F401
    import repro.core.ml  # noqa: F401
    import repro.core.scaling  # noqa: F401
    import repro.sparse.reorder  # noqa: F401


@dataclasses.dataclass
class SelectorBundle:
    """Schema-versioned, fingerprinted, registry-resolvable selector state."""

    model_name: str
    model_params: Dict[str, Any]
    model_state: Dict[str, Any]
    scaler_name: str
    scaler_state: Dict[str, Any]
    feature_set: str
    feature_names: List[str]
    algorithms: List[str]
    fingerprint: str = ""
    schema_version: int = BUNDLE_SCHEMA_VERSION
    created_unix: float = 0.0
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # -- schema v2: descriptive sections (fingerprint-exempt) ---------------
    # training-report card: {test_accuracy, cv_score, best_params,
    # per_algorithm_recall: {alg: recall}, confusion: [[...]] (rows =
    # true algorithm, cols = predicted, over the held-out split),
    # test_support: {alg: count}}. None on v1 bundles and untrained saves.
    report_card: Optional[Dict[str, Any]] = None
    # dataset provenance: {n_samples, algorithms, feature_set, groups,
    # dim_range, nnz_range, label_counts}. None on v1 bundles.
    provenance: Optional[Dict[str, Any]] = None

    # -- identity ------------------------------------------------------------
    def compute_fingerprint(self) -> str:
        """Deterministic hash of everything behaviour-relevant. Computable
        from the envelope alone (no live objects), so a loaded bundle can be
        integrity-checked and the engine can version its plan cache off the
        same value it would get from the live selector."""
        return fingerprint_state({
            "model_name": self.model_name,
            "model_params": self.model_params,
            "model_state": self.model_state,
            "scaler_name": self.scaler_name,
            "scaler_state": self.scaler_state,
            "feature_set": self.feature_set,
            "feature_names": list(self.feature_names),
            "algorithms": list(self.algorithms),
        })

    # -- conversion ----------------------------------------------------------
    @classmethod
    def from_selector(cls, selector, meta: Optional[Dict[str, Any]] = None,
                      report_card: Optional[Dict[str, Any]] = None,
                      provenance: Optional[Dict[str, Any]] = None
                      ) -> "SelectorBundle":
        """Snapshot a fitted :class:`repro.core.selector.ReorderSelector`.

        ``report_card``/``provenance`` are the v2 descriptive sections
        (``SolverEngine.save`` fills them from its last training run);
        omitted, the bundle is still a valid v2 envelope with both None.
        """
        _ensure_default_registrations()
        fs_name = getattr(selector, "feature_set", "paper12")
        fs = get_feature_set(fs_name)
        b = cls(
            model_name=MODEL_REGISTRY.name_of(selector.model),
            model_params=dict(getattr(selector.model, "params", {})),
            model_state=selector.model.state(),
            scaler_name=SCALER_REGISTRY.name_of(selector.scaler),
            scaler_state=selector.scaler.state(),
            feature_set=fs_name,
            feature_names=list(fs.names),
            algorithms=list(selector.algorithms),
            created_unix=time.time(),
            meta=dict(meta or {}),
            report_card=report_card,
            provenance=provenance,
        )
        b.fingerprint = b.compute_fingerprint()
        return b

    def to_selector(self):
        """Rebuild a ready-to-serve ``ReorderSelector`` (validates first)."""
        from repro.core.selector import ReorderSelector

        self.validate()
        model = MODEL_REGISTRY[self.model_name](**self.model_params)
        model.load_state(self.model_state)
        scaler = SCALER_REGISTRY[self.scaler_name]()
        scaler.load_state(self.scaler_state)
        return ReorderSelector(model, scaler, list(self.algorithms),
                               feature_set=self.feature_set)

    # -- validation ----------------------------------------------------------
    def validate(self) -> "SelectorBundle":
        _ensure_default_registrations()
        if self.schema_version > BUNDLE_SCHEMA_VERSION:
            raise BundleValidationError(
                f"bundle schema v{self.schema_version} is newer than this "
                f"build understands (v{BUNDLE_SCHEMA_VERSION})")
        for registry, name in ((MODEL_REGISTRY, self.model_name),
                               (SCALER_REGISTRY, self.scaler_name),
                               (FEATURE_SET_REGISTRY, self.feature_set)):
            if name not in registry:
                raise BundleValidationError(
                    f"bundle references unknown {registry.kind} {name!r}; "
                    f"available: {sorted(registry)}")
        fs = FEATURE_SET_REGISTRY[self.feature_set]
        if list(self.feature_names) != list(fs.names):
            raise BundleValidationError(
                f"bundle feature schema does not match registered feature "
                f"set {self.feature_set!r}: bundle has "
                f"{list(self.feature_names)}, registry has {list(fs.names)}")
        if self.fingerprint and self.fingerprint != self.compute_fingerprint():
            raise BundleValidationError(
                "bundle fingerprint mismatch — the payload was modified "
                "after save (or the file is corrupt)")
        if self.report_card is not None:
            conf = self.report_card.get("confusion")
            k = len(self.algorithms)
            if conf is not None and (len(conf) != k
                                     or any(len(row) != k for row in conf)):
                raise BundleValidationError(
                    f"report card confusion matrix is not {k}x{k} for "
                    f"algorithms {list(self.algorithms)}")
        return self

    def describe(self) -> Dict[str, Any]:
        """Compact plain-data summary (what the bundle registry indexes):
        identity + capability names + the headline quality numbers, never
        the fitted state."""
        return dict(
            fingerprint=self.fingerprint,
            schema_version=self.schema_version,
            model=self.model_name,
            scaler=self.scaler_name,
            feature_set=self.feature_set,
            algorithms=list(self.algorithms),
            created_unix=self.created_unix,
            test_accuracy=(self.report_card or {}).get("test_accuracy"),
            n_samples=(self.provenance or {}).get("n_samples"),
        )

    # -- persistence ---------------------------------------------------------
    def save(self, path: str) -> str:
        payload = dataclasses.asdict(self)
        envelope = {"magic": _MAGIC,
                    "schema_version": self.schema_version,
                    "bundle": payload}
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            pickle.dump(envelope, f, protocol=pickle.HIGHEST_PROTOCOL)
        os.replace(tmp, path)
        return path

    @classmethod
    def from_envelope(cls, obj: Dict[str, Any]) -> "SelectorBundle":
        """Validated bundle from an already-unpickled envelope dict (the
        single dispatch point shared with the deprecated
        ``ReorderSelector.load`` shim — no file is read twice)."""
        fields = {f.name for f in dataclasses.fields(cls)}
        payload = {k: v for k, v in obj["bundle"].items() if k in fields}
        return cls(**payload).validate()

    @classmethod
    def load(cls, path: str) -> "SelectorBundle":
        with open(path, "rb") as f:
            obj = pickle.load(f)
        if isinstance(obj, dict) and obj.get("magic") == _MAGIC:
            return cls.from_envelope(obj)
        # legacy shim: a raw pickled ReorderSelector (pre-bundle format)
        from repro.core.selector import ReorderSelector

        if isinstance(obj, ReorderSelector):
            warnings.warn(
                f"{path} is a legacy raw ReorderSelector pickle; re-save it "
                "as a SelectorBundle via SolverEngine.save() / "
                "SelectorBundle.from_selector()", DeprecationWarning,
                stacklevel=2)
            return cls.from_selector(obj).validate()
        raise BundleValidationError(
            f"{path} is neither a SelectorBundle envelope nor a legacy "
            f"ReorderSelector pickle (got {type(obj).__name__})")
