"""Transport-agnostic plan dispatch: the serving plane's batching core.

:class:`PlanDispatcher` is the deadline micro-batching pipeline that used
to live inside ``repro.launch.serve_selector.AsyncPlanServer`` (which is
now a thin alias). Extracting it decouples *how requests arrive* from *how
they are served*: the in-process async server, the RPC front-end
(:mod:`repro.launch.rpc`), and tests all push requests into the same core
and get back futures of :class:`repro.core.plan.ExecutionPlan`.

Every request travels as a :class:`repro.core.reqctx.RequestContext` —
minted at ``submit`` when the caller did not bring one — which carries its
identity, priority, absolute deadline, and per-stage span timings through
every layer. On that spine the dispatcher implements the production
serving disciplines:

* **Admission control** — ``max_queue`` bounds the dispatch queue; a
  submit against a full queue raises :class:`~repro.core.reqctx.QueueFull`
  immediately (backpressure to the caller) instead of growing an unbounded
  backlog.
* **Deadline shedding** — a request whose deadline passed is failed with
  :class:`~repro.core.reqctx.DeadlineExceeded` at *dequeue time*: the
  batcher drops it before featurization, and a build worker re-checks the
  waiters before reorder+symbolic so an expired request never occupies a
  build worker. Warm cache hits are served even with an expired deadline —
  the answer is already in hand.
* **Priority batching** — the queue is a priority queue (higher
  ``ctx.priority`` first, FIFO within a priority), so latency-critical
  requests jump the backlog under load.
* **Structured metrics** — every stage reports into a
  :class:`repro.core.metrics.MetricsRegistry` (``dispatch.*`` counters and
  gauges, ``stage.*`` latency histograms); ``stats()`` is derived from the
  same instruments, so the three formerly divergent hand-rolled stats
  dicts now share one source of truth.

Pipeline shape:

* ``submit`` fingerprints the matrix; a cache hit resolves the returned
  future immediately (the warm path never enters the queue), a miss is
  admitted (or rejected) into the priority queue.
* One **batcher** thread collects misses until ``batch_size`` requests are
  waiting or the oldest has aged ``max_wait_ms``, sheds expired requests,
  deduplicates by fingerprint, re-checks the cache (a sibling batch may
  have built the plan meanwhile), and runs the selector's padded
  feature-batch + device inference — which shard_maps over the active
  serving mesh — over the remaining structures.
* ``build_workers`` **builder** threads take per-structure (matrix,
  algorithm) items, prune expired waiters, run reorder + symbolic
  analysis, install the plan in the shared (thread-safe, possibly
  replica-shared two-tier) cache, and resolve every future waiting on that
  fingerprint — so plan builds for one micro-batch overlap the next
  micro-batch's inference.

``close()`` fails every queued and in-flight request with
:class:`~repro.core.reqctx.DispatcherClosed` — clients see a typed error,
never a future that hangs forever.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.metrics import MetricsRegistry
from repro.core.plan import ExecutionPlan, PlanBuilder
from repro.core.plan_cache import matrix_fingerprint
from repro.core.reqctx import (DeadlineExceeded, DispatcherClosed, QueueFull,
                               RequestContext)
from repro.sparse.csr import CSRMatrix

__all__ = ["PlanDispatcher"]

_SENTINEL = object()


class _PlanRequest:
    """One queued request: the matrix, its context, and its future."""

    __slots__ = ("mat", "key", "ctx", "future", "t_enqueue")

    def __init__(self, mat: CSRMatrix, key: str, ctx: RequestContext,
                 future: "Future[ExecutionPlan]"):
        self.mat = mat
        self.key = key
        self.ctx = ctx
        self.future = future
        self.t_enqueue = time.perf_counter()


class PlanDispatcher:
    """Request queue → deadline micro-batches → staged cold path.

    See the module docstring for the pipeline shape and serving
    disciplines. Thread-safe: any number of front-end threads (in-process
    callers, RPC connection handlers) may ``submit`` concurrently.

    ``max_queue=None`` keeps the queue unbounded (the pre-backpressure
    behavior); ``default_deadline_ms`` stamps a deadline on requests whose
    minted context has none (caller-supplied contexts are never altered).
    """

    def __init__(self, builder: PlanBuilder, *, batch_size: int = 16,
                 max_wait_ms: float = 5.0, build_workers: int = 2,
                 latency_window: int = 100_000,
                 max_queue: Optional[int] = None,
                 default_deadline_ms: Optional[float] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 shadow=None):
        assert builder.selector is not None, "cold path needs a selector"
        self.builder = builder
        # shadow mirror (repro.lifecycle.shadow.ShadowEvaluator, or a
        # zero-arg provider returning one/None so the engine can start and
        # stop shadowing while this dispatcher is live): every resolved
        # decision — warm hit or fresh selection — is mirrored to the
        # candidate off the hot path; never consulted for the response
        self._shadow = shadow
        self.cache = builder.cache
        self.batch_size = batch_size
        self.max_wait = max_wait_ms / 1e3
        self.max_queue = max_queue
        self.default_deadline_ms = default_deadline_ms
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        self._c_requests = m.counter("dispatch.requests")
        self._c_warm = m.counter("dispatch.warm_hits")
        self._c_shed = m.counter("dispatch.shed")
        self._c_rejected = m.counter("dispatch.rejected")
        self._c_closed = m.counter("dispatch.closed_rejects")
        self._c_errors = m.counter("dispatch.errors")
        self._g_depth = m.gauge("dispatch.queue_depth")
        self._g_inflight = m.gauge("dispatch.inflight_keys")
        self._h_latency = m.histogram("dispatch.latency_s", latency_window)
        self._h_queue = m.histogram("stage.queue_s", latency_window)
        self._h_select = m.histogram("stage.select_s", latency_window)
        self._h_build = m.histogram("stage.build_s", latency_window)
        # priority queue entries: (-priority, seq, request-or-sentinel) —
        # higher priority first, FIFO within a priority via the sequence
        # number (which also keeps requests themselves out of comparisons)
        self._seq = itertools.count()
        self._queue: "queue.PriorityQueue" = queue.PriorityQueue(
            maxsize=max_queue or 0)
        self._build_queue: "queue.Queue" = queue.Queue()
        # keys whose plan build is in flight → requests waiting on it, so a
        # later micro-batch joins the pending build instead of duplicating
        # the selection + build work (guarded by _inflight_lock; builders
        # cache.put *before* popping, so a racer either finds the in-flight
        # entry or peeks the finished plan — never neither)
        self._inflight_lock = threading.Lock()
        self._inflight: Dict[str, List[_PlanRequest]] = {}
        # serializes enqueue-vs-shutdown so no request can land behind the
        # sentinel with a forever-pending future
        self._close_lock = threading.Lock()
        self._closed = False
        self._batcher = threading.Thread(target=self._batch_loop,
                                         name="plan-batcher", daemon=True)
        self._builders = [threading.Thread(target=self._build_loop,
                                           name=f"plan-builder-{i}",
                                           daemon=True)
                          for i in range(max(1, build_workers))]
        self._batcher.start()
        for t in self._builders:
            t.start()

    def set_shadow(self, shadow) -> None:
        """Install (or clear, with None) the shadow mirror at runtime."""
        self._shadow = shadow

    def _mirror(self, mat: CSRMatrix, algorithm: str, key: str) -> None:
        """Hand one resolved decision to the shadow evaluator, if any.
        ``observe`` is O(enqueue) and never raises — the mirror can only
        drop observations, never slow or fail the serving path."""
        shadow = self._shadow
        if callable(shadow) and not hasattr(shadow, "observe"):
            shadow = shadow()
        if shadow is not None:
            shadow.observe(mat, algorithm, key=key)

    # -- client surface ------------------------------------------------------
    def submit(self, mat: CSRMatrix,
               ctx: Optional[RequestContext] = None
               ) -> "Future[ExecutionPlan]":
        """Future of the plan for ``mat``; the request's context rides on
        the returned future as ``fut.ctx`` (span timings, identity).

        Raises :class:`QueueFull` (queue at ``max_queue``) or
        :class:`DispatcherClosed` at admission; a deadline that expires
        *later* fails the future with :class:`DeadlineExceeded` instead.
        """
        if ctx is None:
            ctx = RequestContext.mint(deadline_ms=self.default_deadline_ms)
        self._c_requests.inc()
        fut: "Future[ExecutionPlan]" = Future()
        fut.ctx = ctx  # type: ignore[attr-defined]
        with ctx.span("cache"):
            ctx.fingerprint = key = matrix_fingerprint(mat)
            plan = self.cache.get(key)
        if plan is not None:
            # the warm path serves even expired deadlines: the answer is
            # already in hand, failing it would only hurt the client
            self._c_warm.inc()
            self._finish(ctx)
            fut.set_result(plan)
            self._mirror(mat, plan.algorithm, key)
            return fut
        if ctx.expired():
            self._shed(_PlanRequest(mat, key, ctx, fut))
            return fut
        with self._close_lock:
            if self._closed:
                self._c_closed.inc()
                raise DispatcherClosed("dispatcher is closed")
            entry = (-ctx.priority, next(self._seq),
                     _PlanRequest(mat, key, ctx, fut))
            try:
                self._queue.put_nowait(entry)
            except queue.Full:
                self._c_rejected.inc()
                self.metrics.emit("dispatch.reject",
                                  request_id=ctx.request_id,
                                  fingerprint=key, depth=self._queue.qsize())
                raise QueueFull(
                    f"dispatch queue at capacity ({self.max_queue}); "
                    f"request {ctx.request_id} rejected") from None
        self._g_depth.set(self._queue.qsize())
        return fut

    def handle(self, mats: Sequence[CSRMatrix], timeout: float = 120.0,
               ctxs: Optional[Sequence[RequestContext]] = None
               ) -> List[ExecutionPlan]:
        if ctxs is None:
            ctxs = [None] * len(mats)  # type: ignore[list-item]
        futs = [self.submit(m, c) for m, c in zip(mats, ctxs)]
        return [f.result(timeout=timeout) for f in futs]

    def close(self, timeout: float = 30.0) -> None:
        """Drain and stop. Every request still queued or waiting on an
        unstarted build is failed with :class:`DispatcherClosed` — clients
        get a typed error, never a hung future."""
        with self._close_lock:
            if self._closed:
                return
            self._closed = True
            # fail everything still in the queue (nothing new can land:
            # submit checks _closed under this same lock)
            pending: List[_PlanRequest] = []
            while True:
                try:
                    entry = self._queue.get_nowait()
                except queue.Empty:
                    break
                if entry[2] is not _SENTINEL:
                    pending.append(entry[2])
            self._queue.put((float("inf"), next(self._seq), _SENTINEL))
        exc = DispatcherClosed("dispatcher closed before the request "
                               "was served")
        for r in pending:
            self._c_closed.inc()
            self._fail(r, exc)
        self._batcher.join(timeout)
        for t in self._builders:
            t.join(timeout)
        # builds already queued were finished by the workers before their
        # sentinel; anything still in _inflight had no build queued — fail
        # those waiters too rather than leaving them pending forever
        with self._inflight_lock:
            leftovers = [r for reqs in self._inflight.values() for r in reqs]
            self._inflight.clear()
        for r in leftovers:
            self._c_closed.inc()
            self._fail(r, exc)
        self._g_depth.set(0)
        self._g_inflight.set(0)

    def reset_stats(self) -> None:
        """Zero the serving metrics (latency windows, counters, builder +
        cache counters) — e.g. after an untimed jit warm-up, so the
        reported numbers reflect steady-state serving only."""
        self.metrics.reset()
        self.builder.reset_stats()  # resets the cache counters too

    def stats(self) -> dict:
        s = self.builder.stats()
        s.update(requests=self._c_requests.value,
                 warm_hits=self._c_warm.value,
                 shed=self._c_shed.value,
                 rejected=self._c_rejected.value,
                 closed_rejects=self._c_closed.value,
                 errors=self._c_errors.value,
                 queue_depth=self._queue.qsize(),
                 max_queue=self.max_queue)
        with self._inflight_lock:
            s["inflight_keys"] = len(self._inflight)
        lat = self._h_latency.summary()
        if lat["count"]:
            s.update(p50_ms=lat["p50"] * 1e3, p99_ms=lat["p99"] * 1e3,
                     mean_ms=lat["mean"] * 1e3)
        for stage, h in (("queue", self._h_queue),
                         ("select", self._h_select),
                         ("build", self._h_build)):
            hs = h.summary()
            if hs["count"]:
                s[f"stage_{stage}_p50_ms"] = hs["p50"] * 1e3
                s[f"stage_{stage}_p99_ms"] = hs["p99"] * 1e3
        return s

    # -- request completion helpers ------------------------------------------
    def _finish(self, ctx: RequestContext) -> None:
        """Record end-to-end latency and the total span."""
        dt = ctx.elapsed()
        ctx.add_span("total", dt - ctx.spans.get("total", 0.0))
        self._h_latency.observe(dt)

    def _fail(self, r: _PlanRequest, exc: BaseException) -> None:
        self._finish(r.ctx)
        if not r.future.set_running_or_notify_cancel():
            return  # client cancelled; nothing to deliver
        r.future.set_exception(exc)

    def _shed(self, r: _PlanRequest) -> None:
        self._c_shed.inc()
        self.metrics.emit("dispatch.shed", request_id=r.ctx.request_id,
                          fingerprint=r.key,
                          late_by_ms=-(r.ctx.remaining() or 0.0) * 1e3)
        self._fail(r, DeadlineExceeded(
            f"request {r.ctx.request_id} missed its deadline by "
            f"{-(r.ctx.remaining() or 0.0) * 1e3:.1f} ms"))

    def _resolve(self, r: _PlanRequest, plan: ExecutionPlan) -> None:
        self._finish(r.ctx)
        if not r.future.set_running_or_notify_cancel():
            return
        r.future.set_result(plan)

    # -- stage 1: micro-batcher (feature-batch + device inference) -----------
    def _take(self, timeout: Optional[float]) -> object:
        """One queue entry → request (shedding expired ones) or sentinel;
        raises queue.Empty on timeout."""
        while True:
            if timeout is None:
                entry = self._queue.get()
            else:
                entry = self._queue.get(timeout=timeout)
            self._g_depth.set(self._queue.qsize())
            item = entry[2]
            if item is _SENTINEL:
                return _SENTINEL
            r: _PlanRequest = item
            waited = time.perf_counter() - r.t_enqueue
            r.ctx.add_span("queue", waited)
            self._h_queue.observe(waited)
            if r.ctx.expired():
                # deadline shedding at dequeue: the client stopped waiting,
                # so spend nothing further on this request
                self._shed(r)
                continue
            return r

    def _batch_loop(self) -> None:
        stop = False
        while not stop:
            try:
                item = self._take(None)
            except queue.Empty:  # pragma: no cover - blocking get
                continue
            if item is _SENTINEL:
                break
            batch: List[_PlanRequest] = [item]
            deadline = time.perf_counter() + self.max_wait
            while len(batch) < self.batch_size:
                remain = deadline - time.perf_counter()
                if remain <= 0:
                    break
                try:
                    nxt = self._take(remain)
                except queue.Empty:
                    break
                if nxt is _SENTINEL:
                    stop = True
                    break
                batch.append(nxt)
            self._dispatch(batch)
        self._build_queue.put(_SENTINEL)

    def _dispatch(self, batch: List[_PlanRequest]) -> None:
        groups: Dict[str, List[_PlanRequest]] = {}
        for r in batch:
            groups.setdefault(r.key, []).append(r)
        todo: List[str] = []
        for key, reqs in groups.items():
            with self._inflight_lock:
                pending = self._inflight.get(key)
                if pending is not None:
                    pending.extend(reqs)  # join the build already in flight
                    continue
                plan = self.cache.peek(key)  # a sibling may have built it
                if plan is None:
                    self._inflight[key] = reqs
                    todo.append(key)
            if plan is not None:
                for r in reqs:
                    self._resolve(r, plan)
        self._g_inflight.set(len(self._inflight))
        if not todo:
            return
        t0 = time.perf_counter()
        try:
            names = self.builder.select_names(
                [self._inflight[key][0].mat for key in todo])
        except Exception as exc:  # selector failure fails the whole batch
            self._c_errors.inc()
            for key in todo:
                with self._inflight_lock:
                    reqs = self._inflight.pop(key, [])
                for r in reqs:
                    self._fail(r, exc)
            return
        dt = time.perf_counter() - t0
        self._h_select.observe(dt)
        for key in todo:
            # selection ran once over the whole micro-batch; attribute its
            # wall time to every member (it gated each of them equally)
            with self._inflight_lock:
                reqs = list(self._inflight.get(key, ()))
            for r in reqs:
                r.ctx.add_span("select", dt)
        for key, name in zip(todo, names):
            with self._inflight_lock:
                reqs = self._inflight.get(key)
                rep = reqs[0].mat if reqs else None
            if rep is not None:
                self._mirror(rep, name, key)
            self._build_queue.put((key, name))

    # -- stage 2: plan build (reorder + symbolic) ----------------------------
    def _prune_expired(self, key: str) -> Tuple[List[_PlanRequest], bool]:
        """Shed expired waiters for ``key``. Returns (shed, any_live):
        when no waiter is still live, the key is popped from _inflight and
        the build is skipped entirely — an expired request never occupies
        a build worker."""
        with self._inflight_lock:
            reqs = self._inflight.get(key)
            if not reqs:
                self._inflight.pop(key, None)
                return [], False
            live = [r for r in reqs if not r.ctx.expired()]
            dead = [r for r in reqs if r.ctx.expired()]
            if live:
                self._inflight[key] = live
            else:
                self._inflight.pop(key, None)
        return dead, bool(live)

    def _build_loop(self) -> None:
        while True:
            item = self._build_queue.get()
            if item is _SENTINEL:
                self._build_queue.put(_SENTINEL)  # release sibling workers
                return
            key, name = item
            dead, any_live = self._prune_expired(key)
            for r in dead:
                self._shed(r)
            if not any_live:
                continue  # every waiter expired: no build worker consumed
            mat = self._inflight[key][0].mat  # entry exists until we pop it
            rep_ctx = self._inflight[key][0].ctx  # per-stage reorder/symbolic
            t0 = time.perf_counter()
            try:
                plan = self.builder.build(mat, algorithm=name,
                                          fingerprint=key, ctx=rep_ctx)
            except Exception as exc:
                self._c_errors.inc()
                with self._inflight_lock:
                    reqs = self._inflight.pop(key, [])
                for r in reqs:
                    self._fail(r, exc)
                continue
            dt = time.perf_counter() - t0
            self._h_build.observe(dt)
            try:
                self.cache.put(key, plan)  # put, *then* pop (see _inflight)
            except Exception:
                # a disk-tier write failure must not fail the waiters: the
                # build succeeded and the memory tier is already populated
                pass
            with self._inflight_lock:
                reqs = self._inflight.pop(key, [])
            self._g_inflight.set(len(self._inflight))
            for r in reqs:
                r.ctx.add_span("build", dt)
                self._resolve(r, plan)
