"""Transport-agnostic plan dispatch: the serving plane's batching core.

:class:`PlanDispatcher` is the deadline micro-batching pipeline that used
to live inside ``repro.launch.serve_selector.AsyncPlanServer`` (which is
now a thin alias). Extracting it decouples *how requests arrive* from *how
they are served*: the in-process async server, the RPC front-end
(:mod:`repro.launch.rpc`), and tests all push :class:`CSRMatrix` requests
into the same core and get back futures of
:class:`repro.core.plan.ExecutionPlan`.

Pipeline shape (unchanged from the original server):

* ``submit`` fingerprints the matrix; a cache hit resolves the returned
  future immediately (the warm path never enters the queue), a miss is
  enqueued.
* One **batcher** thread collects misses until ``batch_size`` requests are
  waiting or the oldest has aged ``max_wait_ms``, deduplicates by
  fingerprint, re-checks the cache (a sibling batch may have built the
  plan meanwhile), and runs the selector's padded feature-batch + device
  inference — which shard_maps over the active serving mesh, so the cold
  stage scales with devices — over the remaining structures.
* ``build_workers`` **builder** threads take per-structure (matrix,
  algorithm) items, run reorder + symbolic analysis, install the plan in
  the shared (thread-safe, possibly replica-shared two-tier) cache, and
  resolve every future waiting on that fingerprint — so plan builds for
  one micro-batch overlap the next micro-batch's inference.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Sequence

from repro.core.plan import ExecutionPlan, PlanBuilder
from repro.core.plan_cache import matrix_fingerprint
from repro.sparse.csr import CSRMatrix

__all__ = ["PlanDispatcher"]

_SENTINEL = object()


@dataclasses.dataclass
class _PlanRequest:
    mat: CSRMatrix
    key: str
    future: "Future[ExecutionPlan]"
    t_submit: float


class PlanDispatcher:
    """Request queue → deadline micro-batches → staged cold path.

    See the module docstring for the pipeline shape. Thread-safe: any
    number of front-end threads (in-process callers, RPC connection
    handlers) may ``submit`` concurrently.
    """

    def __init__(self, builder: PlanBuilder, *, batch_size: int = 16,
                 max_wait_ms: float = 5.0, build_workers: int = 2,
                 latency_window: int = 100_000):
        assert builder.selector is not None, "cold path needs a selector"
        self.builder = builder
        self.cache = builder.cache
        self.batch_size = batch_size
        self.max_wait = max_wait_ms / 1e3
        self.requests = 0
        self._queue: "queue.Queue" = queue.Queue()
        self._build_queue: "queue.Queue" = queue.Queue()
        self._lat_lock = threading.Lock()
        # bounded: a long-running server keeps a sliding window, not every
        # latency ever observed (percentiles stay O(window))
        self._latencies: "collections.deque[float]" = collections.deque(
            maxlen=latency_window)
        self._warm = 0
        # keys whose plan build is in flight → requests waiting on it, so a
        # later micro-batch joins the pending build instead of duplicating
        # the selection + build work (guarded by _inflight_lock; builders
        # cache.put *before* popping, so a racer either finds the in-flight
        # entry or peeks the finished plan — never neither)
        self._inflight_lock = threading.Lock()
        self._inflight: Dict[str, List[_PlanRequest]] = {}
        # serializes enqueue-vs-shutdown so no request can land behind the
        # sentinel with a forever-pending future
        self._close_lock = threading.Lock()
        self._closed = False
        self._batcher = threading.Thread(target=self._batch_loop,
                                         name="plan-batcher", daemon=True)
        self._builders = [threading.Thread(target=self._build_loop,
                                           name=f"plan-builder-{i}",
                                           daemon=True)
                          for i in range(max(1, build_workers))]
        self._batcher.start()
        for t in self._builders:
            t.start()

    # -- client surface ------------------------------------------------------
    def submit(self, mat: CSRMatrix) -> "Future[ExecutionPlan]":
        with self._lat_lock:
            self.requests += 1
        t0 = time.perf_counter()
        key = matrix_fingerprint(mat)
        fut: "Future[ExecutionPlan]" = Future()
        plan = self.cache.get(key)
        if plan is not None:
            self._record(t0)
            with self._lat_lock:
                self._warm += 1
            fut.set_result(plan)
            return fut
        with self._close_lock:
            if self._closed:
                raise RuntimeError("server closed")
            self._queue.put(_PlanRequest(mat, key, fut, t0))
        return fut

    def handle(self, mats: Sequence[CSRMatrix],
               timeout: float = 120.0) -> List[ExecutionPlan]:
        futs = [self.submit(m) for m in mats]
        return [f.result(timeout=timeout) for f in futs]

    def close(self, timeout: float = 30.0) -> None:
        with self._close_lock:
            if self._closed:
                return
            self._closed = True
            self._queue.put(_SENTINEL)
        self._batcher.join(timeout)
        for t in self._builders:
            t.join(timeout)

    def reset_stats(self) -> None:
        """Zero the serving metrics (latency window, warm/request counts,
        builder + cache counters) — e.g. after an untimed jit warm-up, so
        the reported numbers reflect steady-state serving only."""
        with self._lat_lock:
            self._latencies.clear()
            self._warm = 0
            self.requests = 0
        self.builder.reset_stats()  # resets the cache counters too

    def stats(self) -> dict:
        s = self.builder.stats()
        with self._lat_lock:
            lats = list(self._latencies)
            warm = self._warm
            requests = self.requests
        s.update(requests=requests, warm_hits=warm)
        if lats:
            import numpy as np

            arr = np.asarray(lats)
            s.update(p50_ms=float(np.percentile(arr, 50) * 1e3),
                     p99_ms=float(np.percentile(arr, 99) * 1e3),
                     mean_ms=float(arr.mean() * 1e3))
        return s

    def _record(self, t_submit: float) -> None:
        with self._lat_lock:
            self._latencies.append(time.perf_counter() - t_submit)

    # -- stage 1: micro-batcher (feature-batch + device inference) -----------
    def _batch_loop(self) -> None:
        stop = False
        while not stop:
            item = self._queue.get()
            if item is _SENTINEL:
                break
            batch: List[_PlanRequest] = [item]
            deadline = time.perf_counter() + self.max_wait
            while len(batch) < self.batch_size:
                remain = deadline - time.perf_counter()
                if remain <= 0:
                    break
                try:
                    nxt = self._queue.get(timeout=remain)
                except queue.Empty:
                    break
                if nxt is _SENTINEL:
                    stop = True
                    break
                batch.append(nxt)
            self._dispatch(batch)
        self._build_queue.put(_SENTINEL)

    def _dispatch(self, batch: List[_PlanRequest]) -> None:
        groups: Dict[str, List[_PlanRequest]] = {}
        for r in batch:
            groups.setdefault(r.key, []).append(r)
        todo: List[str] = []
        for key, reqs in groups.items():
            with self._inflight_lock:
                pending = self._inflight.get(key)
                if pending is not None:
                    pending.extend(reqs)  # join the build already in flight
                    continue
                plan = self.cache.peek(key)  # a sibling may have built it
                if plan is None:
                    self._inflight[key] = reqs
                    todo.append(key)
            if plan is not None:
                for r in reqs:
                    self._record(r.t_submit)
                    r.future.set_result(plan)
        if not todo:
            return
        try:
            names = self.builder.select_names(
                [self._inflight[key][0].mat for key in todo])
        except Exception as exc:  # selector failure fails the whole batch
            for key in todo:
                with self._inflight_lock:
                    reqs = self._inflight.pop(key, [])
                for r in reqs:
                    r.future.set_exception(exc)
            return
        for key, name in zip(todo, names):
            self._build_queue.put((key, name))

    # -- stage 2: plan build (reorder + symbolic) ----------------------------
    def _build_loop(self) -> None:
        while True:
            item = self._build_queue.get()
            if item is _SENTINEL:
                self._build_queue.put(_SENTINEL)  # release sibling workers
                return
            key, name = item
            mat = self._inflight[key][0].mat  # entry exists until we pop it
            try:
                plan = self.builder.build(mat, algorithm=name,
                                          fingerprint=key)
            except Exception as exc:
                with self._inflight_lock:
                    reqs = self._inflight.pop(key, [])
                for r in reqs:
                    r.future.set_exception(exc)
                continue
            try:
                self.cache.put(key, plan)  # put, *then* pop (see _inflight)
            except Exception:
                # a disk-tier write failure must not fail the waiters: the
                # build succeeded and the memory tier is already populated
                pass
            with self._inflight_lock:
                reqs = self._inflight.pop(key, [])
            for r in reqs:
                self._record(r.t_submit)
                r.future.set_result(plan)
