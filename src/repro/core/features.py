"""The paper's 12 matrix features (Table 3).

| feature    | description                      |
|------------|----------------------------------|
| dimension  | number of rows (square matrix)   |
| nnz        | number of nonzeros               |
| nnz_ratio  | nnz / n²                         |
| nnz_max    | max nonzeros per row             |
| nnz_min    | min nonzeros per row             |
| nnz_avg    | mean nonzeros per row            |
| nnz_std    | std of nonzeros per row          |
| degree_max | max node degree (symmetrized graph, no diagonal) |
| degree_min | min node degree                  |
| degree_avg | mean node degree                 |
| bandwidth  | max |i−j| over nonzeros (Eq. 2)  |
| profile    | Σᵢ (i − min{j : aᵢⱼ≠0}) (Eq. 3)  |

`extract_features` is the host (numpy) path used by the selector pipeline;
`extract_features_jnp` is a device path over a dense/padded representation
used by tests to cross-validate and by the serving example to batch feature
extraction on accelerator.
"""
from __future__ import annotations

import numpy as np

from repro.sparse.csr import CSRMatrix, bandwidth, profile
from repro.sparse.graph import adjacency, degrees

__all__ = ["FEATURE_NAMES", "EXTENDED_FEATURE_NAMES", "extract_features",
           "extract_features_batch", "extract_features_extended",
           "extract_features_jnp"]

FEATURE_NAMES = [
    "dimension", "nnz", "nnz_ratio", "nnz_max", "nnz_min", "nnz_avg",
    "nnz_std", "degree_max", "degree_min", "degree_avg", "bandwidth",
    "profile",
]

# Beyond-paper feature set (EXPERIMENTS.md §Perf, paper-side hillclimb):
# normalized/shape-aware derivatives that separate "banded" from "scale-free"
# structure far better than the raw Table-3 features.
EXTENDED_FEATURE_NAMES = FEATURE_NAMES + [
    "bandwidth_ratio",     # bandwidth / n
    "profile_ratio",       # profile / (n · bandwidth)
    "degree_std",          # spread of the degree distribution
    "degree_skew",         # hub indicator (scale-free vs mesh)
    "mean_absdist",        # mean |i−j| over nonzeros (band localization)
    "diag_dominance",      # fraction of nonzeros on ±1% band
    "row_nnz_cv",          # coefficient of variation of row counts
]


def extract_features(a: CSRMatrix) -> np.ndarray:
    n = a.n
    row_nnz = a.row_lengths().astype(np.float64)
    adj = adjacency(a)
    deg = degrees(adj).astype(np.float64)
    nnz = float(a.nnz)
    feats = np.array([
        float(n),
        nnz,
        nnz / float(n) ** 2,
        float(row_nnz.max()) if n else 0.0,
        float(row_nnz.min()) if n else 0.0,
        float(row_nnz.mean()) if n else 0.0,
        float(row_nnz.std()) if n else 0.0,
        float(deg.max()) if n else 0.0,
        float(deg.min()) if n else 0.0,
        float(deg.mean()) if n else 0.0,
        float(bandwidth(a)),
        float(profile(a)),
    ], dtype=np.float64)
    return feats


def extract_features_batch(mats) -> np.ndarray:
    return np.stack([extract_features(m) for m in mats])


def extract_features_extended(a: CSRMatrix) -> np.ndarray:
    """Paper features + 7 beyond-paper structure descriptors."""
    base = extract_features(a)
    n = max(a.n, 1)
    bw = max(base[FEATURE_NAMES.index("bandwidth")], 1.0)
    prof = base[FEATURE_NAMES.index("profile")]
    row_nnz = a.row_lengths().astype(np.float64)
    adj = adjacency(a)
    deg = degrees(adj).astype(np.float64)
    dstd = float(deg.std())
    dmean = max(float(deg.mean()), 1e-12)
    skew = (float(((deg - deg.mean()) ** 3).mean()) / max(dstd, 1e-12) ** 3
            if dstd > 0 else 0.0)
    rows = np.repeat(np.arange(a.n, dtype=np.int64), a.row_lengths())
    absdist = np.abs(rows - a.indices.astype(np.int64))
    near = float((absdist <= max(1, n // 100)).mean()) if a.nnz else 1.0
    ext = np.array([
        bw / n,
        prof / (n * bw),
        dstd,
        skew,
        float(absdist.mean()) if a.nnz else 0.0,
        near,
        float(row_nnz.std() / max(row_nnz.mean(), 1e-12)),
    ], dtype=np.float64)
    return np.concatenate([base, ext])


def extract_features_jnp(dense):
    """Device-side feature extraction from a dense (n, n) array.

    Used for cross-validation of the host path and for batched on-device
    extraction in the serving example (vmap over a padded batch).
    """
    import jax.numpy as jnp

    a = jnp.asarray(dense)
    n = a.shape[0]
    mask = (a != 0)
    row_nnz = mask.sum(axis=1).astype(jnp.float32)
    nnz = row_nnz.sum()
    # symmetrized off-diagonal degrees
    sym = mask | mask.T
    sym = sym & ~jnp.eye(n, dtype=bool)
    deg = sym.sum(axis=1).astype(jnp.float32)
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    dist = jnp.where(mask, jnp.abs(i - j), 0)
    bw = dist.max()
    # profile: i - min column with nonzero, counted only when it is < i
    first = jnp.where(mask, j, n).min(axis=1)
    prof = jnp.where(first < i[:, 0], i[:, 0] - first, 0).sum()
    return jnp.stack([
        jnp.float32(n), nnz, nnz / jnp.float32(n) ** 2,
        row_nnz.max(), row_nnz.min(), row_nnz.mean(), row_nnz.std(),
        deg.max(), deg.min(), deg.mean(),
        bw.astype(jnp.float32), prof.astype(jnp.float32),
    ])
