"""The paper's 12 matrix features (Table 3).

| feature    | description                      |
|------------|----------------------------------|
| dimension  | number of rows (square matrix)   |
| nnz        | number of nonzeros               |
| nnz_ratio  | nnz / n²                         |
| nnz_max    | max nonzeros per row             |
| nnz_min    | min nonzeros per row             |
| nnz_avg    | mean nonzeros per row            |
| nnz_std    | std of nonzeros per row          |
| degree_max | max node degree (symmetrized graph, no diagonal) |
| degree_min | min node degree                  |
| degree_avg | mean node degree                 |
| bandwidth  | max |i−j| over nonzeros (Eq. 2)  |
| profile    | Σᵢ (i − min{j : aᵢⱼ≠0}) (Eq. 3)  |

`extract_features` is the host (numpy) path used by the selector pipeline.
Two device paths exist:

* `extract_features_batch_jnp` — the serving path: CSR-native over a padded
  ``(indptr, indices)`` batch, all 12 features via segment reductions (plus
  an optional Pallas kernel for the bandwidth/profile/row-stats inner
  loops). Never materializes a dense ``(n, n)`` array, so it scales to the
  full suite on device.
* `extract_features_jnp` — legacy dense-(n, n) path, kept only for
  cross-validation on tiny matrices.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import numpy as np

from repro.engine.registry import register_feature_set
from repro.sparse.csr import CSRMatrix, bandwidth, profile
from repro.sparse.graph import adjacency, degrees

__all__ = ["FEATURE_NAMES", "EXTENDED_FEATURE_NAMES", "extract_features",
           "extract_features_batch", "extract_features_extended",
           "extract_features_jnp", "CSRBatch", "pad_csr_batch",
           "extract_features_batch_jnp"]

FEATURE_NAMES = [
    "dimension", "nnz", "nnz_ratio", "nnz_max", "nnz_min", "nnz_avg",
    "nnz_std", "degree_max", "degree_min", "degree_avg", "bandwidth",
    "profile",
]

# Beyond-paper feature set (EXPERIMENTS.md §Perf, paper-side hillclimb):
# normalized/shape-aware derivatives that separate "banded" from "scale-free"
# structure far better than the raw Table-3 features.
EXTENDED_FEATURE_NAMES = FEATURE_NAMES + [
    "bandwidth_ratio",     # bandwidth / n
    "profile_ratio",       # profile / (n · bandwidth)
    "degree_std",          # spread of the degree distribution
    "degree_skew",         # hub indicator (scale-free vs mesh)
    "mean_absdist",        # mean |i−j| over nonzeros (band localization)
    "diag_dominance",      # fraction of nonzeros on ±1% band
    "row_nnz_cv",          # coefficient of variation of row counts
]


def extract_features(a: CSRMatrix) -> np.ndarray:
    n = a.n
    row_nnz = a.row_lengths().astype(np.float64)
    adj = adjacency(a)
    deg = degrees(adj).astype(np.float64)
    nnz = float(a.nnz)
    feats = np.array([
        float(n),
        nnz,
        nnz / float(n) ** 2,
        float(row_nnz.max()) if n else 0.0,
        float(row_nnz.min()) if n else 0.0,
        float(row_nnz.mean()) if n else 0.0,
        float(row_nnz.std()) if n else 0.0,
        float(deg.max()) if n else 0.0,
        float(deg.min()) if n else 0.0,
        float(deg.mean()) if n else 0.0,
        float(bandwidth(a)),
        float(profile(a)),
    ], dtype=np.float64)
    return feats


def extract_features_batch(mats) -> np.ndarray:
    return np.stack([extract_features(m) for m in mats])


def extract_features_extended(a: CSRMatrix) -> np.ndarray:
    """Paper features + 7 beyond-paper structure descriptors."""
    base = extract_features(a)
    n = max(a.n, 1)
    bw = max(base[FEATURE_NAMES.index("bandwidth")], 1.0)
    prof = base[FEATURE_NAMES.index("profile")]
    row_nnz = a.row_lengths().astype(np.float64)
    adj = adjacency(a)
    deg = degrees(adj).astype(np.float64)
    dstd = float(deg.std())
    dmean = max(float(deg.mean()), 1e-12)
    skew = (float(((deg - deg.mean()) ** 3).mean()) / max(dstd, 1e-12) ** 3
            if dstd > 0 else 0.0)
    rows = np.repeat(np.arange(a.n, dtype=np.int64), a.row_lengths())
    absdist = np.abs(rows - a.indices.astype(np.int64))
    near = float((absdist <= max(1, n // 100)).mean()) if a.nnz else 1.0
    ext = np.array([
        bw / n,
        prof / (n * bw),
        dstd,
        skew,
        float(absdist.mean()) if a.nnz else 0.0,
        near,
        float(row_nnz.std() / max(row_nnz.mean(), 1e-12)),
    ], dtype=np.float64)
    return np.concatenate([base, ext])


class CSRBatch(NamedTuple):
    """Padded batch of CSR patterns — the wire format of the serving path.

    indptr:  (B, N+1) int32, rows past n[b] padded with nnz[b]
    indices: (B, E)   int32, entries past nnz[b] padded with 0
    n:       (B,)     int32 true dimensions
    nnz:     (B,)     int32 true nonzero counts
    """

    indptr: np.ndarray
    indices: np.ndarray
    n: np.ndarray
    nnz: np.ndarray


def _next_pow2(x: int) -> int:
    return 1 << max(3, (x - 1).bit_length())


def pad_csr_batch(mats: Sequence[CSRMatrix], n_max: Optional[int] = None,
                  nnz_max: Optional[int] = None,
                  bucket: bool = False) -> CSRBatch:
    """Pack matrices of ragged sizes into one padded CSR buffer batch.

    ``bucket=True`` rounds the padded dims up to powers of two so a stream
    of similarly-sized batches hits a handful of jit/kernel shape buckets
    instead of recompiling per batch (the serving path uses this).
    """
    assert len(mats) > 0
    nmax = max(m.n for m in mats) if n_max is None else n_max
    emax = max(max(m.nnz for m in mats), 1) if nnz_max is None else nnz_max
    if bucket:
        nmax, emax = _next_pow2(nmax), _next_pow2(emax)
    b = len(mats)
    indptr = np.zeros((b, nmax + 1), np.int32)
    indices = np.zeros((b, emax), np.int32)
    n = np.zeros(b, np.int32)
    nnz = np.zeros(b, np.int32)
    for i, m in enumerate(mats):
        indptr[i, : m.n + 1] = m.indptr
        indptr[i, m.n + 1 :] = m.nnz
        indices[i, : m.nnz] = m.indices
        n[i], nnz[i] = m.n, m.nnz
    return CSRBatch(indptr, indices, n, nnz)


_BATCH_JIT_CACHE: dict = {}


def _build_sharded_featurizer(sm, use_pallas: bool,
                              interpret: Optional[bool]):
    """jit(shard_map(featurize)) over the serving mesh's batch axis.

    Each shard runs the full segment-reduction featurizer (Pallas inner
    loops included) on its B/ndev slice of the padded batch — the features
    of one matrix never depend on another, so the split is exact, not an
    approximation. Ragged batches are padded up to a multiple of the device
    count by replicating row 0 (filler results are sliced off), which keeps
    every shard the same static shape. A 1-device mesh runs this very same
    code as its degenerate case.
    """
    import jax
    import jax.numpy as jnp

    from repro.distributed.compat import shard_map

    nd = sm.num_devices
    spec = sm.spec()

    def local(indptr, indices, n, nnz):
        return _extract_features_batch_impl(
            CSRBatch(indptr, indices, n, nnz), use_pallas=use_pallas,
            interpret=interpret)

    mapped = shard_map(local, mesh=sm.mesh, in_specs=(spec,) * 4,
                       out_specs=spec, check_vma=False)

    @jax.jit
    def run(indptr, indices, n, nnz):
        b = indptr.shape[0]
        pad = (-b) % nd
        if pad:
            indptr = jnp.concatenate([indptr,
                                      jnp.repeat(indptr[:1], pad, axis=0)])
            indices = jnp.concatenate([indices,
                                       jnp.repeat(indices[:1], pad, axis=0)])
            n = jnp.concatenate([n, jnp.repeat(n[:1], pad)])
            nnz = jnp.concatenate([nnz, jnp.repeat(nnz[:1], pad)])
        return mapped(indptr, indices, n, nnz)[:b]

    return run


def extract_features_batch_jnp(batch: CSRBatch, *, use_pallas: bool = False,
                               interpret: Optional[bool] = None,
                               jit: bool = True, mesh=None):
    """All 12 Table-3 features for a padded CSR batch, on device(s).

    Pure segment reductions over ``(indptr, indices)`` — per-entry row ids by
    binary search on indptr, degrees of the symmetrized graph by
    scatter-add + a vectorized reciprocal-edge membership search (sorted row
    segments), bandwidth/profile/row-stats as flat masked reductions. Memory
    is O(B·(N+E)); no dense (n, n) array exists at any point.

    The batch axis is sharded over the active serving mesh
    (:func:`repro.distributed.meshctx.get_serving_mesh`, or ``mesh=`` to
    override) with shard_map: each device featurizes its slice of the batch
    independently, so throughput scales with the mesh and the result is
    element-wise identical to the 1-device run. There is no separate
    single-device code path — that is just the degenerate 1-device mesh.

    ``use_pallas=True`` routes the three entry reductions and three row
    reductions through `repro.kernels.csr_stats` *per shard* (interpret
    mode on CPU). The whole extraction compiles as one jit per padded shape
    (pair with ``pad_csr_batch(..., bucket=True)`` to bound the number of
    buckets). ``jit=False`` runs the raw unsharded impl — it exists for
    composing into an outer trace, not for serving. Returns a (B, 12)
    float32 jax array ordered like FEATURE_NAMES.
    """
    if not jit:
        return _extract_features_batch_impl(batch, use_pallas=use_pallas,
                                            interpret=interpret)
    from repro.distributed.meshctx import get_serving_mesh

    sm = mesh if mesh is not None else get_serving_mesh()
    key = (use_pallas, interpret, sm)
    fn = _BATCH_JIT_CACHE.get(key)
    if fn is None:
        fn = _build_sharded_featurizer(sm, use_pallas, interpret)
        _BATCH_JIT_CACHE[key] = fn
    return fn(*(np.asarray(a) for a in batch))


def _extract_features_batch_impl(batch: CSRBatch, *, use_pallas: bool,
                                 interpret: Optional[bool]):
    import jax
    import jax.numpy as jnp

    indptr = jnp.asarray(batch.indptr, jnp.int32)    # (B, N+1)
    indices = jnp.asarray(batch.indices, jnp.int32)  # (B, E)
    n = jnp.asarray(batch.n, jnp.int32)
    nnz = jnp.asarray(batch.nnz, jnp.int32)
    bsz, e = indices.shape
    nmax = indptr.shape[1] - 1
    nf = n.astype(jnp.float32)
    nnzf = nnz.astype(jnp.float32)

    entry_ids = jnp.arange(e, dtype=jnp.int32)
    valid = entry_ids[None, :] < nnz[:, None]                       # (B, E)
    # row id of entry k: the i with indptr[i] <= k < indptr[i+1]
    rows = jax.vmap(
        lambda ip: jnp.searchsorted(ip, entry_ids, side="right"))(indptr)
    rows = jnp.clip(rows - 1, 0, nmax - 1).astype(jnp.int32)
    cols = jnp.clip(indices, 0, nmax - 1)
    offdiag = valid & (rows != cols)

    # first-entry-of-row mask: entry k starts its row iff indptr[rows[k]] == k
    row_start = jnp.take_along_axis(indptr, rows, axis=1)
    isfirst = valid & (row_start == entry_ids[None, :])

    row_ids = jnp.arange(nmax, dtype=jnp.int32)
    row_valid = row_ids[None, :] < n[:, None]                       # (B, N)
    row_nnz = indptr[:, 1:] - indptr[:, :-1]                        # (B, N)
    nnz_avg = nnzf / jnp.maximum(nf, 1.0)

    if use_pallas:
        from repro.kernels.csr_stats import entry_stats, row_stats

        es = entry_stats(rows, cols, valid.astype(jnp.int32),
                         isfirst.astype(jnp.int32), interpret=interpret)
        bw, prof = es[:, 0], es[:, 1]
        rs = row_stats(row_nnz, row_valid.astype(jnp.int32), nnz_avg,
                       interpret=interpret)
        nnz_max, nnz_min, nnz_sq = rs[:, 0], rs[:, 1], rs[:, 2]
        nnz_min = jnp.where(n > 0, nnz_min, 0.0)
    else:
        absd = jnp.where(valid, jnp.abs(rows - cols), 0)
        bw = absd.max(axis=1).astype(jnp.float32)
        # sum in f32: an int32 sum wraps once profile > 2^31 (n ~ 50k banded)
        prof = jnp.where(isfirst & (cols < rows), rows - cols,
                         0).astype(jnp.float32).sum(axis=1)
        cnt = row_nnz.astype(jnp.float32)
        nnz_max = jnp.where(row_valid, cnt, 0.0).max(axis=1)
        nnz_min = jnp.where(row_valid, cnt, jnp.inf).min(axis=1)
        nnz_sq = jnp.where(row_valid, (cnt - nnz_avg[:, None]) ** 2,
                           0.0).sum(axis=1)
    nnz_std = jnp.sqrt(nnz_sq / jnp.maximum(nf, 1.0))

    # degrees of the symmetrized off-diagonal graph, CSR-native:
    # deg_i = outdeg_i + indeg_i − #reciprocated edges of row i
    bidx = jnp.broadcast_to(jnp.arange(bsz)[:, None], (bsz, e))
    w = offdiag.astype(jnp.float32)
    outdeg = jnp.zeros((bsz, nmax), jnp.float32).at[bidx, rows].add(w)
    indeg = jnp.zeros((bsz, nmax), jnp.float32).at[bidx, cols].add(w)
    # reciprocal membership: binary-search row cols[k] for value rows[k]
    # (column segments are sorted) — lower_bound with a static trip count
    lo = jnp.take_along_axis(indptr, cols, axis=1)
    hi0 = jnp.take_along_axis(indptr, cols + 1, axis=1)
    hi = hi0
    for _ in range(max(1, int(np.ceil(np.log2(e + 1))) + 1)):
        mid = (lo + hi) // 2
        midv = jnp.take_along_axis(indices, jnp.clip(mid, 0, e - 1), axis=1)
        active = lo < hi
        go_right = active & (midv < rows)
        hi = jnp.where(active & ~go_right, mid, hi)
        lo = jnp.where(go_right, mid + 1, lo)
    atlo = jnp.take_along_axis(indices, jnp.clip(lo, 0, e - 1), axis=1)
    recip_flag = offdiag & (lo < hi0) & (atlo == rows)
    recip = jnp.zeros((bsz, nmax), jnp.float32).at[bidx, rows].add(
        recip_flag.astype(jnp.float32))
    deg = outdeg + indeg - recip
    deg_max = jnp.where(row_valid, deg, 0.0).max(axis=1)
    deg_min = jnp.where(row_valid, deg, jnp.inf).min(axis=1)
    deg_min = jnp.where(n > 0, deg_min, 0.0)
    deg_avg = jnp.where(row_valid, deg, 0.0).sum(axis=1) / jnp.maximum(nf, 1.0)

    return jnp.stack([
        nf, nnzf, nnzf / jnp.maximum(nf, 1.0) ** 2,
        nnz_max, jnp.where(n > 0, nnz_min, 0.0), nnz_avg, nnz_std,
        deg_max, deg_min, deg_avg, bw, prof,
    ], axis=1)


def extract_features_jnp(dense):
    """Device-side feature extraction from a dense (n, n) array.

    Used for cross-validation of the host path and for batched on-device
    extraction in the serving example (vmap over a padded batch).
    """
    import jax.numpy as jnp

    a = jnp.asarray(dense)
    n = a.shape[0]
    mask = (a != 0)
    row_nnz = mask.sum(axis=1).astype(jnp.float32)
    nnz = row_nnz.sum()
    # symmetrized off-diagonal degrees
    sym = mask | mask.T
    sym = sym & ~jnp.eye(n, dtype=bool)
    deg = sym.sum(axis=1).astype(jnp.float32)
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    dist = jnp.where(mask, jnp.abs(i - j), 0)
    bw = dist.max()
    # profile: i - min column with nonzero, counted only when it is < i
    first = jnp.where(mask, j, n).min(axis=1)
    prof = jnp.where(first < i[:, 0], i[:, 0] - first, 0).sum()
    return jnp.stack([
        jnp.float32(n), nnz, nnz / jnp.float32(n) ** 2,
        row_nnz.max(), row_nnz.min(), row_nnz.mean(), row_nnz.std(),
        deg.max(), deg.min(), deg.mean(),
        bw.astype(jnp.float32), prof.astype(jnp.float32),
    ])


# ---------------------------------------------------------------------------
# Feature-set registration — the engine resolves featurizers by name, so
# alternative schemas (here the beyond-paper extended set; elsewhere
# third-party sets via @register_feature_set) swap in without touching the
# selector. The schema (name list) is persisted in SelectorBundles and
# validated on load.
# ---------------------------------------------------------------------------

register_feature_set("paper12", names=FEATURE_NAMES,
                     extract=extract_features,
                     extract_batch=extract_features_batch,
                     extract_batch_jnp=extract_features_batch_jnp,
                     paper="Table 3")
register_feature_set("extended19", names=EXTENDED_FEATURE_NAMES,
                     extract=extract_features_extended,
                     paper="Table 3 + EXPERIMENTS feature study")
