"""Cross-process file locking for replica-shared on-disk state.

N serving replicas share one ``artifacts/plan_cache/`` disk tier. Writes
were already safe (tempfile + atomic rename), but *maintenance* was not:
two replicas running the budget-eviction sweep concurrently each list the
directory, each compute the same overage, and each delete files — together
evicting far past the budget and miscounting what they removed. The fix is
advisory ``flock``\\ s on sidecar lock files (the cache uses one to make
sweeps single-flight across replicas and another, taken shared by scans
and exclusive by the delete pass, for scan consistency).

:class:`FileLock` is intentionally minimal and stdlib-only:

* **Advisory** — every cooperating process must take it; unrelated readers
  of the files are unaffected.
* **Reentrant per instance within a process is NOT supported** — callers
  hold it for short, non-nested critical sections (one sweep, one scan).
  A per-instance thread mutex serializes threads of one process so the
  process-level flock state (which is per open-file-description) can't be
  corrupted by two threads sharing the fd.
* **Robust to crashes** — flock locks die with the process; a crashed
  replica never wedges the tier.

On platforms without ``fcntl`` (Windows), locking degrades to the
in-process mutex only — single-replica behaviour, exactly what the code
did before this module existed.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Optional

try:  # POSIX; on Windows the lock degrades to in-process only
    import fcntl
except ImportError:  # pragma: no cover - linux CI
    fcntl = None  # type: ignore[assignment]

__all__ = ["FileLock"]


class FileLock:
    """``flock``-based advisory lock with shared/exclusive modes.

    Use as a context manager::

        lock = FileLock(os.path.join(cache_dir, ".lock"))
        with lock.exclusive():          # blocking writer section
            ...
        with lock.shared():             # blocking reader section
            ...
        if lock.acquire(blocking=False):   # try-lock (exclusive)
            try: ...
            finally: lock.release()
    """

    def __init__(self, path: str):
        self.path = path
        self._fd: Optional[int] = None
        self._mutex = threading.Lock()

    # -- low-level ----------------------------------------------------------
    def _open(self) -> Optional[int]:
        if fcntl is None:
            return None
        if self._fd is None:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            # O_CREAT but never truncate: the file carries no content, only
            # its flock state; it is left behind by design (removing it
            # would race new lockers onto a different inode)
            self._fd = os.open(self.path, os.O_RDWR | os.O_CREAT, 0o644)
        return self._fd

    def _flock(self, op: int, blocking: bool) -> bool:
        fd = self._open()
        if fd is None:  # no fcntl: thread mutex already held → "acquired"
            return True
        if not blocking:
            op |= fcntl.LOCK_NB
        try:
            fcntl.flock(fd, op)
            return True
        except (BlockingIOError, InterruptedError):
            return False
        except OSError:
            # e.g. flock unsupported on this filesystem (some NFS mounts):
            # degrade to in-process locking rather than fail the cache op
            return True

    # -- public surface ------------------------------------------------------
    def acquire(self, blocking: bool = True, shared: bool = False,
                timeout: Optional[float] = None) -> bool:
        """Take the lock; returns False for a failed non-blocking try or an
        expired ``timeout``.

        ``timeout`` (seconds, with ``blocking=True``) bounds the total
        wait. Unlike a non-blocking retry loop, the thread *queues* on the
        in-process mutex — Python locks wake waiters on release, so a
        steady stream of short holders cannot starve the acquirer the way
        repeated try-locks can. The cross-process flock phase then polls
        under the held mutex (flock itself has no timeout), which also
        stops new same-process holders from barging in while we wait out
        other processes' holds.
        """
        if timeout is not None and blocking:
            deadline = time.monotonic() + timeout
            got_mutex = self._mutex.acquire(True, timeout)
        else:
            deadline = None
            got_mutex = self._mutex.acquire(blocking)
        if not got_mutex:
            return False
        op = (fcntl.LOCK_SH if shared else fcntl.LOCK_EX) if fcntl else 0
        if deadline is None:
            ok = self._flock(op, blocking)
        else:
            while True:
                ok = self._flock(op, False)
                if ok or time.monotonic() >= deadline:
                    break
                time.sleep(0.005)
        if ok:
            return True
        self._mutex.release()
        return False

    def release(self) -> None:
        try:
            if self._fd is not None and fcntl is not None:
                try:
                    fcntl.flock(self._fd, fcntl.LOCK_UN)
                except OSError:
                    # mirror of the acquire-side degrade: on filesystems
                    # where flock is unsupported (some NFS), acquire
                    # succeeded mutex-only, and unlock must not throw out
                    # of the cache's finally blocks
                    pass
        finally:
            self._mutex.release()

    # -- context managers ----------------------------------------------------
    class _Guard:
        def __init__(self, lock: "FileLock", shared: bool):
            self._lock, self._shared = lock, shared

        def __enter__(self):
            self._lock.acquire(blocking=True, shared=self._shared)
            return self._lock

        def __exit__(self, *exc):
            self._lock.release()
            return False

    def exclusive(self) -> "_Guard":
        """Blocking exclusive (writer) guard — one holder across *and*
        within processes."""
        return FileLock._Guard(self, shared=False)

    def shared(self) -> "_Guard":
        """Blocking shared (reader) guard — concurrent with other shared
        holders in other processes, excluded by any exclusive holder.
        (Within one process the thread mutex still serializes holders;
        scans are short and this keeps the fd's flock state single-owner.)
        """
        return FileLock._Guard(self, shared=True)

    def __getstate__(self):
        # fds and mutexes don't pickle; a lock travelling to another
        # process (e.g. a cache shipped through multiprocessing) re-opens
        # its own fd on first use — same path, same flock namespace
        return {"path": self.path}

    def __setstate__(self, state):
        self.path = state["path"]
        self._fd = None
        self._mutex = threading.Lock()
