"""End-to-end reordering-algorithm selector — the paper's deliverable.

``ReorderSelector`` = feature extraction → scaler → classifier → algorithm
name. ``fit_from_dataset`` trains it from a :class:`LabeledDataset`;
``select``/``predict_matrix`` run the trained pipeline on a new matrix
(the ~16 ms path of the paper's Table 5).
"""
from __future__ import annotations

import pickle
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.features import extract_features
from repro.core.labeling import LabeledDataset
from repro.core.ml import MODEL_ZOO, BaseClassifier, accuracy_score
from repro.core.model_selection import GridSearchCV, train_test_split
from repro.core.scaling import SCALERS
from repro.sparse.csr import CSRMatrix

__all__ = ["ReorderSelector", "DEFAULT_GRIDS", "train_selector"]


# Hyperparameter grids per model family (paper §3.4: "candidate values are
# usually given by empirical methods").
DEFAULT_GRIDS: Dict[str, Dict[str, Sequence]] = {
    "random_forest": {
        "criterion": ["gini"],
        "min_samples_leaf": [1, 2],
        "min_samples_split": [2, 5],
        "n_estimators": [50, 100],
    },
    "decision_tree": {
        "criterion": ["gini", "entropy"],
        "max_depth": [None, 8, 16],
        "min_samples_leaf": [1, 2, 5],
    },
    "logistic_regression": {"C": [0.1, 1.0, 10.0], "steps": [500]},
    "naive_bayes": {"var_smoothing": [1e-9, 1e-6]},
    "svm": {"C": [1.0, 10.0], "gamma": [0.1, 0.5], "kernel": ["rbf"]},
    "mlp": {"hidden_layer_sizes": [(64, 32), (128,)], "lr": [0.01]},
    "knn": {"n_neighbors": [3, 5, 9], "weights": ["uniform", "distance"]},
}

# Smaller grids for smoke-speed runs.
FAST_GRIDS: Dict[str, Dict[str, Sequence]] = {
    k: {p: v[:1] for p, v in g.items()} for k, g in DEFAULT_GRIDS.items()
}


class ReorderSelector:
    def __init__(self, model: BaseClassifier, scaler, algorithms: List[str]):
        self.model = model
        self.scaler = scaler
        self.algorithms = algorithms

    # -- inference -----------------------------------------------------------
    def predict_features(self, feats: np.ndarray) -> np.ndarray:
        feats = np.atleast_2d(feats)
        return self.model.predict(self.scaler.transform(feats))

    def select(self, a: CSRMatrix) -> Tuple[str, float]:
        """Returns (algorithm name, prediction seconds) — Table 5's columns."""
        t0 = time.perf_counter()
        feats = extract_features(a)
        idx = int(self.predict_features(feats)[0])
        return self.algorithms[idx], time.perf_counter() - t0

    def accuracy(self, feats: np.ndarray, labels: np.ndarray) -> float:
        return accuracy_score(labels, self.predict_features(feats))

    # -- persistence -----------------------------------------------------------
    def save(self, path: str) -> None:
        with open(path, "wb") as f:
            pickle.dump(self, f)

    @staticmethod
    def load(path: str) -> "ReorderSelector":
        with open(path, "rb") as f:
            obj = pickle.load(f)
        assert isinstance(obj, ReorderSelector)
        return obj


def train_selector(
    ds: LabeledDataset,
    model_name: str = "random_forest",
    scaling: str = "standard",
    test_size: float = 0.2,
    seed: int = 0,
    cv: int = 5,
    grid: Optional[Dict[str, Sequence]] = None,
    fast: bool = False,
):
    """Grid-search + refit a selector; returns (selector, report dict).

    The report carries everything the paper's evaluation needs: test
    accuracy, indices of the split, per-scenario totals (AMD / predicted /
    ideal — Table 6), and the mean speedup vs AMD (the 1.45× claim).
    """
    x, y = ds.features, ds.labels
    xtr, xte, ytr, yte, itr, ite = train_test_split(x, y, test_size, seed)
    scaler = SCALERS[scaling]().fit(xtr)
    grids = FAST_GRIDS if fast else DEFAULT_GRIDS
    gs = GridSearchCV(MODEL_ZOO[model_name](), grid or grids[model_name],
                      cv=cv, seed=seed)
    gs.fit(scaler.transform(xtr), ytr)
    sel = ReorderSelector(gs.best_model_, scaler, list(ds.algorithms))

    pred = sel.predict_features(xte)
    acc = accuracy_score(yte, pred)

    amd_idx = ds.algorithms.index("amd")
    t_amd = ds.times[ite, amd_idx].sum()
    t_pred = ds.times[ite, pred].sum()
    t_ideal = ds.times[ite].min(axis=1).sum()
    speedups = ds.times[ite, amd_idx] / np.maximum(ds.times[ite, pred], 1e-12)

    report = dict(
        model=model_name, scaling=scaling,
        best_params=gs.best_params_, cv_score=gs.best_score_,
        test_accuracy=acc,
        test_idx=ite, train_idx=itr, predictions=pred,
        time_amd=float(t_amd), time_predicted=float(t_pred),
        time_ideal=float(t_ideal),
        reduction_vs_amd=float(1.0 - t_pred / t_amd) if t_amd > 0 else 0.0,
        excess_vs_ideal=float(t_pred / t_ideal - 1.0) if t_ideal > 0 else 0.0,
        mean_speedup_vs_amd=float(speedups.mean()),
        max_speedup_vs_amd=float(speedups.max()),
    )
    return sel, report
