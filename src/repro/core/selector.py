"""End-to-end reordering-algorithm selector — the paper's deliverable.

``ReorderSelector`` = feature extraction → scaler → classifier → algorithm
name. ``fit_from_dataset`` trains it from a :class:`LabeledDataset`;
``select``/``predict_matrix`` run the trained pipeline on a new matrix
(the ~16 ms path of the paper's Table 5).

``select_batch`` is the serving path: many matrices at once, either through
the host featurizer or the CSR-native device featurizer
(`extract_features_batch_jnp`); for every zoo member with a ``forward_jnp``
(the JAX models *and* the tree/forest family, via
:mod:`repro.core.ml.forest_jnp`) the scaler transform and classifier
forward also run on device inside one jit.
"""
from __future__ import annotations

import pickle
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.features import pad_csr_batch
from repro.core.labeling import LabeledDataset
from repro.core.ml import MODEL_ZOO, BaseClassifier, accuracy_score
from repro.core.model_selection import GridSearchCV, train_test_split
from repro.core.scaling import SCALERS
from repro.engine.registry import FeatureSet, get_feature_set
from repro.sparse.csr import CSRMatrix

__all__ = ["ReorderSelector", "DEFAULT_GRIDS", "train_selector",
           "scaler_transform_jnp"]


def scaler_transform_jnp(scaler, x):
    """Device twin of ``scaler.transform`` — reads the fitted state and
    applies the affine map in jnp so it fuses into the inference jit."""
    import jax.numpy as jnp

    st = scaler.state()
    if "mean" in st:
        return ((x - jnp.asarray(st["mean"], jnp.float32))
                / jnp.asarray(st["std"], jnp.float32))
    if "min" in st:
        return ((x - jnp.asarray(st["min"], jnp.float32))
                / jnp.asarray(st["scale"], jnp.float32))
    return x


# Hyperparameter grids per model family (paper §3.4: "candidate values are
# usually given by empirical methods").
DEFAULT_GRIDS: Dict[str, Dict[str, Sequence]] = {
    "random_forest": {
        "criterion": ["gini"],
        "min_samples_leaf": [1, 2],
        "min_samples_split": [2, 5],
        "n_estimators": [50, 100],
    },
    "decision_tree": {
        "criterion": ["gini", "entropy"],
        "max_depth": [None, 8, 16],
        "min_samples_leaf": [1, 2, 5],
    },
    "logistic_regression": {"C": [0.1, 1.0, 10.0], "steps": [500]},
    "naive_bayes": {"var_smoothing": [1e-9, 1e-6]},
    "svm": {"C": [1.0, 10.0], "gamma": [0.1, 0.5], "kernel": ["rbf"]},
    "mlp": {"hidden_layer_sizes": [(64, 32), (128,)], "lr": [0.01]},
    "knn": {"n_neighbors": [3, 5, 9], "weights": ["uniform", "distance"]},
}

# Smaller grids for smoke-speed runs.
FAST_GRIDS: Dict[str, Dict[str, Sequence]] = {
    k: {p: v[:1] for p, v in g.items()} for k, g in DEFAULT_GRIDS.items()
}


class ReorderSelector:
    def __init__(self, model: BaseClassifier, scaler, algorithms: List[str],
                 feature_set: str = "paper12"):
        self.model = model
        self.scaler = scaler
        self.algorithms = algorithms
        # registry name of the feature schema this selector was trained on
        # (resolved lazily; bundles persist and validate it)
        self.feature_set = feature_set

    def _fs(self) -> FeatureSet:
        # getattr: pre-feature-set pickles lack the attribute
        return get_feature_set(getattr(self, "feature_set", "paper12"))

    # -- inference -----------------------------------------------------------
    def predict_features(self, feats: np.ndarray) -> np.ndarray:
        feats = np.atleast_2d(feats)
        return self.model.predict(self.scaler.transform(feats))

    def select(self, a: CSRMatrix) -> Tuple[str, float]:
        """Returns (algorithm name, prediction seconds) — Table 5's columns."""
        t0 = time.perf_counter()
        feats = self._fs().extract(a)
        idx = int(self.predict_features(feats)[0])
        return self.algorithms[idx], time.perf_counter() - t0

    # -- batched serving path --------------------------------------------------
    def select_batch(self, mats: Sequence[CSRMatrix], *, path: str = "host",
                     use_pallas: bool = False
                     ) -> Tuple[List[str], float]:
        """Select for a whole batch at once; returns (names, total seconds).

        ``path='host'`` runs the per-matrix numpy featurizer; ``'device'``
        packs the batch into padded CSR buffers and runs the segment-reduction
        featurizer (optionally through the Pallas csr_stats kernels). JAX
        classifiers then consume the feature batch without leaving device.
        """
        assert path in ("host", "device"), path
        t0 = time.perf_counter()
        fs = self._fs()
        if path == "device" and fs.extract_batch_jnp is not None:
            # device featurizers consume the padded-CSR wire format
            feats = fs.extract_batch_jnp(
                pad_csr_batch(mats, bucket=True), use_pallas=use_pallas)
            idx = self._predict_device(feats)
        else:  # host path, or a feature set with no device extractor
            idx = self.predict_features(fs.batch(mats))
        names = [self.algorithms[int(i)] for i in idx]
        return names, time.perf_counter() - t0

    def _fit_version(self) -> tuple:
        """Identity of the fitted state the device jit bakes in as constants.

        Refitting model or scaler assigns fresh objects, so the leaves of
        the fitted attributes change identity and the cached trace is
        invalidated. The version holds strong *references* (compared with
        ``is``), never bare ``id()``s: a freed-and-reallocated object could
        reuse an address and alias a stale trace."""
        import jax

        fitted = {k: v for k, v in vars(self.model).items()
                  if k.endswith("_")}
        leaves = jax.tree_util.tree_leaves(fitted)
        leaves += list(self.scaler.state().values())
        return tuple(leaves)

    @staticmethod
    def _same_version(a, b) -> bool:
        return (a is not None and b is not None and len(a) == len(b)
                and all(x is y for x, y in zip(a, b)))

    def _predict_device(self, feats) -> np.ndarray:
        """Label indices for an on-device (B, 12) feature batch.

        Zoo members exposing ``forward_jnp`` stay on device — scaler +
        forward + argmax shard_mapped over the active serving mesh's batch
        axis in one cached jit (rebuilt if the model, scaler, or mesh
        changes). The fitted state closes over the shard_map body as
        replicated constants, so every shard classifies its B/ndev slice
        locally and the padded feature batch never gathers onto one device;
        a 1-device mesh is the degenerate case of the same trace. That
        includes decision trees and random forests via the flattened-node
        traversal of :mod:`repro.core.ml.forest_jnp`, so the paper's
        winning model serves without a host round-trip; only KNN/NB fall
        back to host inference on transferred features.
        """
        if hasattr(self.model, "forward_jnp"):
            from repro.distributed.meshctx import get_serving_mesh

            sm = get_serving_mesh()
            version = self._fit_version()
            fn = getattr(self, "_device_fn", None)
            if (fn is None or getattr(self, "_device_fn_mesh", None) != sm
                    or not self._same_version(
                        getattr(self, "_device_fn_version", None), version)):
                import jax
                import jax.numpy as jnp

                from repro.distributed.compat import shard_map

                def infer(x):
                    z = scaler_transform_jnp(self.scaler, x)
                    return jnp.argmax(self.model.forward_jnp(z), axis=1)

                spec = sm.spec()
                mapped = shard_map(infer, mesh=sm.mesh, in_specs=(spec,),
                                   out_specs=spec, check_vma=False)
                nd = sm.num_devices

                def infer_sharded(x):
                    b = x.shape[0]
                    pad = (-b) % nd
                    if pad:  # ragged batch: filler rows, sliced off below
                        x = jnp.concatenate(
                            [x, jnp.repeat(x[:1], pad, axis=0)])
                    return mapped(x)[:b]

                fn = self._device_fn = jax.jit(infer_sharded)
                self._device_fn_version = version
                self._device_fn_mesh = sm
            return np.asarray(fn(feats))
        return self.model.predict(self.scaler.transform(np.asarray(feats)))

    def accuracy(self, feats: np.ndarray, labels: np.ndarray) -> float:
        return accuracy_score(labels, self.predict_features(feats))

    # -- persistence -----------------------------------------------------------
    def __getstate__(self):
        # jitted device closures are not picklable; rebuilt lazily on load
        return {k: v for k, v in self.__dict__.items()
                if not k.startswith("_")}

    def save(self, path: str) -> None:
        """Deprecated raw-pickle persistence — prefer the versioned,
        validated :class:`repro.engine.SelectorBundle` (which
        ``SolverEngine.save`` writes). Kept as a shim for old callers."""
        warnings.warn(
            "ReorderSelector.save/load raw pickles are deprecated; use "
            "SolverEngine.save / SelectorBundle.from_selector instead",
            DeprecationWarning, stacklevel=2)
        with open(path, "wb") as f:
            pickle.dump(self, f)

    @staticmethod
    def load(path: str) -> "ReorderSelector":
        """Deprecated twin of :meth:`save`; loads either a raw pickle or a
        SelectorBundle file (so callers migrate one side at a time)."""
        warnings.warn(
            "ReorderSelector.save/load raw pickles are deprecated; use "
            "SolverEngine.load / SelectorBundle.load instead",
            DeprecationWarning, stacklevel=2)
        with open(path, "rb") as f:
            obj = pickle.load(f)
        if isinstance(obj, ReorderSelector):
            return obj
        from repro.engine.bundle import SelectorBundle, _MAGIC

        if isinstance(obj, dict) and obj.get("magic") == _MAGIC:
            return SelectorBundle.from_envelope(obj).to_selector()
        raise TypeError(f"{path} holds {type(obj).__name__}, not a "
                        "ReorderSelector or SelectorBundle")


def train_selector(
    ds: LabeledDataset,
    model_name: str = "random_forest",
    scaling: str = "standard",
    test_size: float = 0.2,
    seed: int = 0,
    cv: int = 5,
    grid: Optional[Dict[str, Sequence]] = None,
    fast: bool = False,
    feature_set: Optional[str] = None,
):
    """Grid-search + refit a selector; returns (selector, report dict).

    ``model_name``/``scaling``/``feature_set`` are registry names (unknown
    ones raise :class:`repro.engine.RegistryLookupError` with suggestions).
    ``feature_set`` defaults to the set the dataset was featurized with.
    The report carries everything the paper's evaluation needs: test
    accuracy, indices of the split, per-scenario totals (AMD / predicted /
    ideal — Table 6), and the mean speedup vs AMD (the 1.45× claim).
    """
    fs_name = feature_set or getattr(ds, "feature_set", None) or "paper12"
    fs = get_feature_set(fs_name)
    x, y = ds.features, ds.labels
    if x.shape[1] != fs.dim:
        raise ValueError(
            f"dataset features have dim {x.shape[1]} but feature set "
            f"{fs_name!r} has {fs.dim} ({list(fs.names)})")
    xtr, xte, ytr, yte, itr, ite = train_test_split(x, y, test_size, seed)
    scaler = SCALERS[scaling]().fit(xtr)
    grids = FAST_GRIDS if fast else DEFAULT_GRIDS
    gs = GridSearchCV(MODEL_ZOO[model_name](),
                      grid or grids.get(model_name, {}), cv=cv, seed=seed)
    gs.fit(scaler.transform(xtr), ytr)
    sel = ReorderSelector(gs.best_model_, scaler, list(ds.algorithms),
                          feature_set=fs_name)

    pred = sel.predict_features(xte)
    acc = accuracy_score(yte, pred)

    # training-report card (persisted into SelectorBundle schema v2):
    # confusion matrix over the held-out split + per-algorithm recall
    k = len(ds.algorithms)
    confusion = np.zeros((k, k), dtype=np.int64)
    for t, q in zip(yte, pred):
        confusion[int(t), int(q)] += 1
    support = confusion.sum(axis=1)
    per_algorithm_recall = {
        alg: (float(confusion[i, i] / support[i]) if support[i] else None)
        for i, alg in enumerate(ds.algorithms)}

    amd_idx = ds.algorithms.index("amd")
    t_amd = ds.times[ite, amd_idx].sum()
    t_pred = ds.times[ite, pred].sum()
    t_ideal = ds.times[ite].min(axis=1).sum()
    speedups = ds.times[ite, amd_idx] / np.maximum(ds.times[ite, pred], 1e-12)

    report = dict(
        model=model_name, scaling=scaling,
        best_params=gs.best_params_, cv_score=gs.best_score_,
        test_accuracy=acc,
        confusion=confusion,
        per_algorithm_recall=per_algorithm_recall,
        test_support={alg: int(s) for alg, s in zip(ds.algorithms, support)},
        test_idx=ite, train_idx=itr, predictions=pred,
        time_amd=float(t_amd), time_predicted=float(t_pred),
        time_ideal=float(t_ideal),
        reduction_vs_amd=float(1.0 - t_pred / t_amd) if t_amd > 0 else 0.0,
        excess_vs_ideal=float(t_pred / t_ideal - 1.0) if t_ideal > 0 else 0.0,
        mean_speedup_vs_amd=float(speedups.mean()),
        max_speedup_vs_amd=float(speedups.max()),
    )
    return sel, report
