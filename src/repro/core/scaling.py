"""Feature normalization: Max-Min scaling and Standardization (paper §4.2).

Scalers register in :data:`repro.engine.SCALER_REGISTRY`; the legacy
``SCALERS`` name is that registry (``Mapping``-compatible), so
``SCALERS[name]()`` keeps working and new scalers plug in with
``@register_scaler("name")``.
"""
from __future__ import annotations

import numpy as np

from repro.engine.registry import SCALER_REGISTRY, register_scaler

__all__ = ["MinMaxScaler", "StandardScaler", "IdentityScaler", "SCALERS",
           "SCALER_REGISTRY", "register_scaler"]


@register_scaler("none")
class IdentityScaler:
    def fit(self, x: np.ndarray) -> "IdentityScaler":
        return self

    def transform(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(x, dtype=np.float64)

    def fit_transform(self, x: np.ndarray) -> np.ndarray:
        return self.fit(x).transform(x)

    def state(self) -> dict:
        return {}

    def load_state(self, state: dict) -> None:
        pass

    def fingerprint(self) -> str:
        """Stable hash of class + fitted state (see engine.fingerprint)."""
        from repro.engine.fingerprint import component_fingerprint
        return component_fingerprint(self)


@register_scaler("minmax")
class MinMaxScaler(IdentityScaler):
    def fit(self, x: np.ndarray) -> "MinMaxScaler":
        x = np.asarray(x, dtype=np.float64)
        self.min_ = x.min(axis=0)
        span = x.max(axis=0) - self.min_
        self.scale_ = np.where(span > 0, span, 1.0)
        return self

    def transform(self, x: np.ndarray) -> np.ndarray:
        return (np.asarray(x, dtype=np.float64) - self.min_) / self.scale_

    def state(self) -> dict:
        return dict(min=self.min_, scale=self.scale_)

    def load_state(self, state: dict) -> None:
        self.min_, self.scale_ = state["min"], state["scale"]


@register_scaler("standard")
class StandardScaler(IdentityScaler):
    def fit(self, x: np.ndarray) -> "StandardScaler":
        x = np.asarray(x, dtype=np.float64)
        self.mean_ = x.mean(axis=0)
        std = x.std(axis=0)
        self.std_ = np.where(std > 0, std, 1.0)
        return self

    def transform(self, x: np.ndarray) -> np.ndarray:
        return (np.asarray(x, dtype=np.float64) - self.mean_) / self.std_

    def state(self) -> dict:
        return dict(mean=self.mean_, std=self.std_)

    def load_state(self, state: dict) -> None:
        self.mean_, self.std_ = state["mean"], state["std"]


SCALERS = SCALER_REGISTRY
