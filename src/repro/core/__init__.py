# The paper's primary contribution: supervised selection of sparse matrix
# reordering algorithms. features → scaler → classifier → {AMD, SCOTCH, ND,
# RCM}, trained on argmin-solve-time labels (repro.core.labeling) over the
# matrix suite (repro.sparse.dataset), solved by the multifrontal solver
# (repro.sparse.multifrontal). The generalized form of the same idea drives
# execution-plan selection for the LM framework (repro.autotune).
from .features import FEATURE_NAMES, extract_features, extract_features_batch
from .labeling import LabeledDataset, load_or_build, run_labeling_campaign
from .ml import MODEL_ZOO, accuracy_score
from .model_selection import GridSearchCV, cross_val_score, train_test_split
from .scaling import SCALERS, MinMaxScaler, StandardScaler
from .selector import DEFAULT_GRIDS, ReorderSelector, train_selector

__all__ = [
    "FEATURE_NAMES", "extract_features", "extract_features_batch",
    "LabeledDataset", "load_or_build", "run_labeling_campaign",
    "MODEL_ZOO", "accuracy_score",
    "GridSearchCV", "cross_val_score", "train_test_split",
    "SCALERS", "MinMaxScaler", "StandardScaler",
    "DEFAULT_GRIDS", "ReorderSelector", "train_selector",
]
