"""Plan cache for the selection serving path: thread-safe LRU + disk tier.

Reordering selection is a pure function of the sparsity *structure*, so
repeat structures (the common case under heavy traffic: the same mesh
refactored each timestep, the same circuit re-solved per corner) should skip
featurization, inference, reordering, and symbolic analysis. Keys are a
structure fingerprint — ``(n, nnz, blake2b(indptr ‖ indices))`` — values are
whatever plan the caller stores (a full :class:`repro.core.plan.ExecutionPlan`
on the serving path; any picklable object works).

Two classes:

* :class:`PlanCache` — bounded in-memory LRU with hit/miss accounting.
  Thread-safe: the async server shares one instance across its batcher and
  plan-build worker threads.
* :class:`TwoTierPlanCache` — the same LRU backed by a persistent on-disk
  tier (one pickle per fingerprint under ``artifacts/plan_cache/`` by
  default). Memory evictions stay recoverable from disk, and a fresh
  process warms itself from the plans a previous one built.
"""
from __future__ import annotations

import hashlib
import os
import pickle
import tempfile
import threading
from collections import OrderedDict
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core.locking import FileLock
from repro.sparse.csr import CSRMatrix

__all__ = ["matrix_fingerprint", "PlanCache", "TwoTierPlanCache",
           "DEFAULT_CACHE_DIR"]

DEFAULT_CACHE_DIR = os.path.join("artifacts", "plan_cache")


def matrix_fingerprint(a: CSRMatrix) -> str:
    """Structure fingerprint: n, nnz, and a hash of the CSR index buffers.

    Values (``a.data``) are deliberately excluded — ordering depends only on
    the pattern, so numerically-different instances of one structure share a
    cache entry.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(a.n).tobytes())
    h.update(np.int64(a.nnz).tobytes())
    h.update(np.ascontiguousarray(a.indptr, dtype=np.int32).tobytes())
    h.update(np.ascontiguousarray(a.indices, dtype=np.int32).tobytes())
    return h.hexdigest()


class PlanCache:
    """Bounded LRU mapping fingerprint → plan, with hit/miss accounting.

    Thread-safe: memory-tier state is only touched under ``self._lock``
    (reentrant), making one instance shareable across the async server's
    worker threads; second-tier (disk) I/O deliberately runs *outside* the
    lock so it never stalls concurrent warm-path gets.
    """

    def __init__(self, capacity: int = 4096, *, metrics=None,
                 metrics_prefix: str = "cache"):
        assert capacity >= 1
        self.capacity = capacity
        self._store: "OrderedDict[str, Any]" = OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # optional structured-metrics mirror: the attribute counters stay
        # the source of truth for stats() (and the tests that assert on
        # them); when a repro.core.metrics.MetricsRegistry is supplied,
        # every count also lands in `<prefix>.*` so the serving stack's
        # one snapshot sees the cache tier too
        self._metrics = metrics
        self._metrics_prefix = metrics_prefix

    def _minc(self, name: str, n: int = 1) -> None:
        if self._metrics is not None:
            self._metrics.counter(f"{self._metrics_prefix}.{name}").inc(n)

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._store

    def get(self, key: str) -> Optional[Any]:
        with self._lock:
            if key in self._store:
                self._store.move_to_end(key)
                self.hits += 1
                self._minc("memory_hits")
                return self._store[key]
        # second-tier lookup runs WITHOUT the lock: disk reads must not
        # stall concurrent warm-path gets (no-op for the memory-only cache)
        plan = self._tier_load(key)
        with self._lock:
            if plan is not None:
                self.hits += 1
                self._tier_hit_locked()
                self._install_locked(key, plan)
                return plan
            self.misses += 1
        if plan is None:
            self._minc("misses")
        return plan

    def peek(self, key: str) -> Optional[Any]:
        """Memory-tier lookup without touching LRU order or counters (used
        by the async batcher's double-check, which must not skew stats)."""
        with self._lock:
            return self._store.get(key)

    def put(self, key: str, plan: Any) -> None:
        with self._lock:
            self._install_locked(key, plan)
        # disk write outside the lock; the tempfile+rename below is atomic,
        # so concurrent writers of one key are last-rename-wins safe, and
        # a failed write degrades to memory-only caching (never fails the
        # request whose plan is already installed above).
        self._tier_store(key, plan)

    def _install_locked(self, key: str, plan: Any) -> None:
        """Insert into the memory LRU (caller holds the lock)."""
        if key in self._store:
            self._store.move_to_end(key)
        self._store[key] = plan
        while len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.evictions += 1
            self._minc("evictions")

    # second-tier hooks — no-ops for the memory-only cache ------------------
    def _tier_load(self, key: str) -> Optional[Any]:
        """Fetch from the second tier; called WITHOUT the lock held."""
        return None

    def _tier_hit_locked(self) -> None:
        """Account a second-tier hit; called with the lock held."""

    def _tier_store(self, key: str, plan: Any) -> None:
        """Write to the second tier; called WITHOUT the lock held."""

    def reset_stats(self) -> None:
        """Zero the accounting counters (entries stay cached)."""
        with self._lock:
            self.hits = self.misses = self.evictions = 0

    def stats(self) -> Dict[str, float]:
        with self._lock:
            total = self.hits + self.misses
            return dict(size=len(self._store), capacity=self.capacity,
                        hits=self.hits, misses=self.misses,
                        evictions=self.evictions,
                        hit_rate=self.hits / total if total else 0.0)


class TwoTierPlanCache(PlanCache):
    """Memory LRU over a persistent pickle-per-key disk tier.

    ``get`` falls through memory → disk → miss; a disk hit promotes the
    plan back into the LRU (counted in ``hits`` and ``disk_hits``, so the
    base class's ``hit_rate`` reflects both tiers). ``put`` writes both
    tiers; the disk write is atomic (tempfile + rename), so a plan file is
    never observed half-written by a concurrent reader or a crashed
    process. Disk entries outlive LRU eviction *and* the process — that is
    the tier's entire point.

    The tier is **replica-shared**: any number of serving processes may
    point at one ``cache_dir`` and warm each other. Reads and atomic
    writes need no coordination; maintenance (budget-eviction sweeps,
    ``stats()``/usage scans, ``clear_disk``) is coordinated through
    sidecar cross-process :class:`repro.core.locking.FileLock`\\ s so two
    replicas can never run the eviction sweep concurrently (which would
    over-evict past the budget and miscount) and a scan never observes a
    sweep half-applied.
    """

    def __init__(self, capacity: int = 4096,
                 cache_dir: str = DEFAULT_CACHE_DIR, version: str = "v0",
                 max_disk_bytes: Optional[int] = None,
                 max_disk_entries: Optional[int] = None, *,
                 metrics=None, metrics_prefix: str = "cache"):
        super().__init__(capacity, metrics=metrics,
                         metrics_prefix=metrics_prefix)
        self.cache_dir = cache_dir
        # plans persist across process restarts, so they outlive the model
        # that chose them: ``version`` namespaces the disk entries, and a
        # new version (SolverEngine derives it from the served model's
        # fingerprint on every train/load) makes every old entry a miss
        # without touching other versions' files
        self.version = version
        # disk-tier budgets: once either is exceeded after a write, plan
        # files — across ALL versions in the dir, so orphans from retired
        # fingerprints go first — are evicted LRU-by-mtime
        self.max_disk_bytes = max_disk_bytes
        self.max_disk_entries = max_disk_entries
        os.makedirs(cache_dir, exist_ok=True)
        self.disk_hits = 0
        self.disk_writes = 0
        self.disk_errors = 0
        self.disk_evictions = 0
        # one sweeper at a time; concurrent writers skip instead of queueing
        self._evict_lock = threading.Lock()
        # cross-process coordination: N replicas share one disk tier, with
        # two sidecar flocks splitting the two concerns. `.sweep.lock`
        # (always tried non-blocking) makes the eviction sweep single-
        # flight across replicas — the loser *skips*, exactly like the
        # in-process _evict_lock. `.scan.lock` makes usage scans
        # consistent: stats take it shared, the sweep's delete pass takes
        # it exclusive with a bounded timed wait (the put path must never
        # stall indefinitely), so a scan never observes a half-applied
        # sweep. Two files, not one, because a
        # single lock cannot both let sweeps skip past a *sweeping*
        # sibling and wait behind a *scanning* one: with one lock, a
        # steady trickle of stats polls (shared holders) would starve
        # eviction forever. Plan-file reads and atomic writes take
        # neither — the hot path stays lock-free.
        self._sweep_lock = FileLock(os.path.join(cache_dir, ".sweep.lock"))
        self._scan_lock = FileLock(os.path.join(cache_dir, ".scan.lock"))

    def _path(self, key: str) -> str:
        return os.path.join(self.cache_dir, f"{key}.{self.version}.plan.pkl")

    def _tier_load(self, key: str) -> Optional[Any]:
        path = self._path(key)
        if not os.path.exists(path):
            return None
        try:
            with open(path, "rb") as f:
                plan = pickle.load(f)
        except (OSError, pickle.UnpicklingError, EOFError):
            return None  # unreadable entry ≡ miss; next put overwrites it
        try:
            # a disk hit refreshes mtime so the budget sweep's mtime order
            # is true LRU (recency of use), not FIFO (recency of write)
            os.utime(path, None)
        except OSError:
            pass
        return plan

    def _tier_hit_locked(self) -> None:
        self.disk_hits += 1
        self._minc("disk_hits")

    def _tier_store(self, key: str, plan: Any) -> None:
        try:
            fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as f:
                    pickle.dump(plan, f, protocol=pickle.HIGHEST_PROTOCOL)
                os.replace(tmp, self._path(key))
            except BaseException:
                if os.path.exists(tmp):
                    os.unlink(tmp)
                raise
        except (OSError, pickle.PicklingError):
            # disk full / unwritable dir / unpicklable plan: the memory
            # tier already holds the plan, so serving degrades gracefully
            with self._lock:
                self.disk_errors += 1
            self._minc("disk_errors")
            return
        with self._lock:
            self.disk_writes += 1
        self._minc("disk_writes")
        self._evict_disk()

    def _evict_disk(self) -> None:
        """Enforce the disk budgets: drop least-recently-written plan files
        (mtime order, every version) until within bytes *and* entries.

        Runs outside the memory-tier lock (it is pure disk maintenance);
        ``_evict_lock`` keeps it single-flight within the process and the
        tier's cross-process flock keeps it single-flight *across serving
        replicas* — both taken non-blocking, so a writer that finds a sweep
        already running (here or in a sibling replica) skips rather than
        queueing. Without the flock, two replicas each compute the same
        overage from their own listing and together delete ~2× past the
        budget while miscounting their evictions. That makes the budget a
        *soft* bound under concurrency (a file written after the running
        sweep's listdir survives until the next write triggers a sweep),
        which is the right trade for a cache: bounded drift, no writer ever
        blocked on another's sweep. A file that vanished before our unlink
        (a sibling's ``clear_disk``) is already off disk, so it leaves the
        running totals but is not counted as *our* eviction.
        """
        if self.max_disk_bytes is None and self.max_disk_entries is None:
            return
        if not self._evict_lock.acquire(blocking=False):
            return
        try:
            if not self._sweep_lock.acquire(blocking=False):
                return  # a sibling replica is sweeping this tier
            try:
                # wait out in-flight stats scans with a BOUNDED wait, not
                # an unbounded blocking flock: this runs on the put path
                # (a plan-build worker serving live requests), and flock
                # gives LOCK_EX no priority over a stream of LOCK_SH
                # holders — unbounded waiting could stall the writer
                # indefinitely behind replicas polling stats(). The
                # timeout path *queues* on the in-process mutex (so local
                # scan hammering can't starve it) and polls the flock for
                # the remainder; if the budget expires anyway, skip — the
                # next put retries the sweep.
                if not self._scan_lock.acquire(timeout=0.25):
                    return
                try:
                    self._evict_disk_locked()
                finally:
                    self._scan_lock.release()
            finally:
                self._sweep_lock.release()
        finally:
            self._evict_lock.release()

    def _evict_disk_locked(self) -> None:
        entries = []
        for f in os.listdir(self.cache_dir):
            if not f.endswith(".plan.pkl"):
                continue
            try:
                st = os.stat(os.path.join(self.cache_dir, f))
            except OSError:
                continue
            entries.append((st.st_mtime, st.st_size, f))
        entries.sort()  # oldest first
        total = sum(e[1] for e in entries)
        count = len(entries)
        evicted = 0
        for mtime, size, f in entries:
            over_bytes = (self.max_disk_bytes is not None
                          and total > self.max_disk_bytes)
            over_count = (self.max_disk_entries is not None
                          and count > self.max_disk_entries)
            if not over_bytes and not over_count:
                break
            try:
                os.unlink(os.path.join(self.cache_dir, f))
            except FileNotFoundError:
                pass  # already gone: off the budget, but not our eviction
            except OSError:
                continue  # undeletable: keep it charged against the budget
            else:
                evicted += 1
            total -= size
            count -= 1
        if evicted:
            with self._lock:
                self.disk_evictions += evicted
            self._minc("disk_evictions", evicted)

    def _suffix(self) -> str:
        return f".{self.version}.plan.pkl"

    # disk-only maintenance: no memory-tier lock involved — holding it
    # across a listdir/unlink sweep would stall warm-path gets. The scan
    # takes the tier's *shared* flock instead: concurrent with other
    # replicas' scans, excluded by a sweep, so stats never observe a
    # half-applied eviction pass.
    def _disk_usage(self) -> "Tuple[int, int]":
        """One scandir pass → (entries of *this* version, bytes of *all*
        versions). Entries are what this cache can hit; bytes are what the
        budget is charged against (orphaned versions still occupy disk)."""
        entries = 0
        total = 0
        suffix = self._suffix()
        with self._scan_lock.shared(), os.scandir(self.cache_dir) as it:
            for e in it:
                if not e.name.endswith(".plan.pkl"):
                    continue
                if e.name.endswith(suffix):
                    entries += 1
                try:
                    total += e.stat().st_size
                except OSError:
                    pass
        return entries, total

    def disk_entries(self) -> int:
        return self._disk_usage()[0]

    def disk_bytes(self) -> int:
        """Total size of plan files in the dir (all versions — what the
        byte budget is charged against)."""
        return self._disk_usage()[1]

    def clear_disk(self) -> None:
        with self._scan_lock.exclusive():
            for f in os.listdir(self.cache_dir):
                if f.endswith(self._suffix()):
                    try:
                        os.unlink(os.path.join(self.cache_dir, f))
                    except FileNotFoundError:
                        pass  # a sibling replica got there first

    def reset_stats(self) -> None:
        with self._lock:
            super().reset_stats()
            self.disk_hits = self.disk_writes = self.disk_errors = 0
            self.disk_evictions = 0

    def stats(self) -> Dict[str, float]:
        entries, nbytes = self._disk_usage()  # one scan, outside the lock
        with self._lock:
            s = super().stats()
            s.update(disk_hits=self.disk_hits, disk_writes=self.disk_writes,
                     disk_errors=self.disk_errors,
                     disk_evictions=self.disk_evictions,
                     memory_hits=self.hits - self.disk_hits,
                     disk_entries=entries, disk_bytes=nbytes,
                     max_disk_bytes=self.max_disk_bytes,
                     max_disk_entries=self.max_disk_entries)
            return s
