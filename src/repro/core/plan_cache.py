"""LRU plan cache for the selection serving path.

Reordering selection is a pure function of the sparsity *structure*, so
repeat structures (the common case under heavy traffic: the same mesh
refactored each timestep, the same circuit re-solved per corner) should skip
both featurization and inference. Keys are a structure fingerprint —
``(n, nnz, blake2b(indptr ‖ indices))`` — values are whatever plan the
caller stores (algorithm name here; a full execution plan later).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Any, Dict, Optional

import numpy as np

from repro.sparse.csr import CSRMatrix

__all__ = ["matrix_fingerprint", "PlanCache"]


def matrix_fingerprint(a: CSRMatrix) -> str:
    """Structure fingerprint: n, nnz, and a hash of the CSR index buffers.

    Values (``a.data``) are deliberately excluded — ordering depends only on
    the pattern, so numerically-different instances of one structure share a
    cache entry.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(a.n).tobytes())
    h.update(np.int64(a.nnz).tobytes())
    h.update(np.ascontiguousarray(a.indptr, dtype=np.int32).tobytes())
    h.update(np.ascontiguousarray(a.indices, dtype=np.int32).tobytes())
    return h.hexdigest()


class PlanCache:
    """Bounded LRU mapping fingerprint → plan, with hit/miss accounting."""

    def __init__(self, capacity: int = 4096):
        assert capacity >= 1
        self.capacity = capacity
        self._store: "OrderedDict[str, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: str) -> bool:
        return key in self._store

    def get(self, key: str) -> Optional[Any]:
        if key in self._store:
            self._store.move_to_end(key)
            self.hits += 1
            return self._store[key]
        self.misses += 1
        return None

    def put(self, key: str, plan: Any) -> None:
        if key in self._store:
            self._store.move_to_end(key)
        self._store[key] = plan
        while len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.evictions += 1

    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return dict(size=len(self._store), capacity=self.capacity,
                    hits=self.hits, misses=self.misses,
                    evictions=self.evictions,
                    hit_rate=self.hits / total if total else 0.0)
