"""JAX-trained classifiers: multinomial logistic regression, linear / RFF-RBF
SVM, and MLP. Full-batch Adam, jit-compiled, deterministic.

These are the differentiable members of the paper's Fig. 4 line-up. Training
sets are ~750×12, so full-batch on one device is instant; the point is that
they share the same fit/predict surface as the numpy models and run on TPU
unchanged.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .base import BaseClassifier

__all__ = ["LogisticRegression", "SVMClassifier", "MLPClassifier"]


def _adam_train(loss_fn, params, steps: int, lr: float):
    """Full-batch Adam via lax.scan (one compiled loop)."""

    @jax.jit
    def run(params):
        flat, tree = jax.tree_util.tree_flatten(params)
        m = [jnp.zeros_like(p) for p in flat]
        v = [jnp.zeros_like(p) for p in flat]

        def step(carry, i):
            flat, m, v = carry
            p = jax.tree_util.tree_unflatten(tree, flat)
            g = jax.grad(loss_fn)(p)
            gflat, _ = jax.tree_util.tree_flatten(g)
            b1, b2, eps = 0.9, 0.999, 1e-8
            t = i + 1
            new_flat, new_m, new_v = [], [], []
            for pi, gi, mi, vi in zip(flat, gflat, m, v):
                mi = b1 * mi + (1 - b1) * gi
                vi = b2 * vi + (1 - b2) * gi * gi
                mh = mi / (1 - b1 ** t)
                vh = vi / (1 - b2 ** t)
                new_flat.append(pi - lr * mh / (jnp.sqrt(vh) + eps))
                new_m.append(mi)
                new_v.append(vi)
            return (new_flat, new_m, new_v), 0.0

        (flat, _, _), _ = jax.lax.scan(step, (flat, m, v),
                                       jnp.arange(steps, dtype=jnp.float32))
        return jax.tree_util.tree_unflatten(tree, flat)

    return run(params)


class LogisticRegression(BaseClassifier):
    def __init__(self, C: float = 1.0, steps: int = 500, lr: float = 0.05,
                 random_state: int = 0):
        super().__init__(C=C, steps=steps, lr=lr, random_state=random_state)

    def fit(self, x, y):
        x = jnp.asarray(x, dtype=jnp.float32)
        y = np.asarray(y, dtype=np.int64)
        self.n_classes_ = int(y.max()) + 1
        k, d = self.n_classes_, x.shape[1]
        yj = jnp.asarray(y)
        p = self.params
        w = jnp.zeros((d, k), dtype=jnp.float32)
        b = jnp.zeros((k,), dtype=jnp.float32)

        def loss(params):
            w, b = params
            logits = x @ w + b
            ce = -jnp.take_along_axis(jax.nn.log_softmax(logits),
                                      yj[:, None], axis=1).mean()
            return ce + (0.5 / p["C"]) * (w ** 2).sum() / x.shape[0]

        self.w_, self.b_ = _adam_train(loss, (w, b), p["steps"], p["lr"])
        return self

    def forward_jnp(self, x: jnp.ndarray) -> jnp.ndarray:
        """Class scores for an on-device (B, d) batch — jit/vmap-safe."""
        return x @ self.w_ + self.b_

    def predict_proba(self, x):
        logits = self.forward_jnp(jnp.asarray(x, dtype=jnp.float32))
        return np.asarray(jax.nn.softmax(logits, axis=1))

    def predict(self, x):
        return self.predict_proba(x).argmax(axis=1)


class SVMClassifier(BaseClassifier):
    """One-vs-rest hinge-loss SVM; kernel='rbf' uses random Fourier features
    (Rahimi–Recht) so the optimization stays a linear JAX problem."""

    def __init__(self, C: float = 1.0, kernel: str = "rbf", gamma: float = 0.5,
                 n_components: int = 256, steps: int = 500, lr: float = 0.05,
                 random_state: int = 0):
        super().__init__(C=C, kernel=kernel, gamma=gamma,
                         n_components=n_components, steps=steps, lr=lr,
                         random_state=random_state)

    def _featurize(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.params["kernel"] == "linear":
            return x
        return jnp.sqrt(2.0 / self.params["n_components"]) * jnp.cos(
            x @ self.rff_w_ + self.rff_b_)

    def fit(self, x, y):
        p = self.params
        x = jnp.asarray(x, dtype=jnp.float32)
        y = np.asarray(y, dtype=np.int64)
        self.n_classes_ = int(y.max()) + 1
        d = x.shape[1]
        if p["kernel"] == "rbf":
            key = jax.random.PRNGKey(p["random_state"])
            k1, k2 = jax.random.split(key)
            self.rff_w_ = (jnp.sqrt(2.0 * p["gamma"])
                           * jax.random.normal(k1, (d, p["n_components"])))
            self.rff_b_ = jax.random.uniform(
                k2, (p["n_components"],), maxval=2 * jnp.pi)
        phi = self._featurize(x)
        # one-vs-rest targets in {-1, +1}
        t = -jnp.ones((x.shape[0], self.n_classes_), dtype=jnp.float32)
        t = t.at[jnp.arange(x.shape[0]), jnp.asarray(y)].set(1.0)
        w = jnp.zeros((phi.shape[1], self.n_classes_), dtype=jnp.float32)
        b = jnp.zeros((self.n_classes_,), dtype=jnp.float32)

        def loss(params):
            w, b = params
            margins = phi @ w + b
            hinge = jnp.maximum(0.0, 1.0 - t * margins).mean()
            return p["C"] * hinge + 0.5 * (w ** 2).sum() / phi.shape[0]

        self.w_, self.b_ = _adam_train(loss, (w, b), p["steps"], p["lr"])
        return self

    def forward_jnp(self, x: jnp.ndarray) -> jnp.ndarray:
        """One-vs-rest margins for an on-device (B, d) batch."""
        return self._featurize(x) @ self.w_ + self.b_

    def decision_function(self, x):
        return np.asarray(self.forward_jnp(jnp.asarray(x, dtype=jnp.float32)))

    def predict(self, x):
        return self.decision_function(x).argmax(axis=1)


def _mlp_forward(params, x):
    h = x
    for (w, b) in params[:-1]:
        h = jax.nn.relu(h @ w + b)
    w, b = params[-1]
    return h @ w + b


class MLPClassifier(BaseClassifier):
    def __init__(self, hidden_layer_sizes: Sequence[int] = (64, 32),
                 steps: int = 800, lr: float = 0.01, alpha: float = 1e-4,
                 random_state: int = 0):
        super().__init__(hidden_layer_sizes=tuple(hidden_layer_sizes),
                         steps=steps, lr=lr, alpha=alpha,
                         random_state=random_state)

    def fit(self, x, y):
        p = self.params
        x = jnp.asarray(x, dtype=jnp.float32)
        y = np.asarray(y, dtype=np.int64)
        self.n_classes_ = int(y.max()) + 1
        yj = jnp.asarray(y)
        sizes = [x.shape[1], *p["hidden_layer_sizes"], self.n_classes_]
        key = jax.random.PRNGKey(p["random_state"])
        params = []
        for i in range(len(sizes) - 1):
            key, sub = jax.random.split(key)
            scale = jnp.sqrt(2.0 / sizes[i])
            params.append((scale * jax.random.normal(sub, (sizes[i], sizes[i + 1])),
                           jnp.zeros((sizes[i + 1],))))

        def loss(params):
            logits = _mlp_forward(params, x)
            ce = -jnp.take_along_axis(jax.nn.log_softmax(logits),
                                      yj[:, None], axis=1).mean()
            l2 = sum((w ** 2).sum() for (w, _) in params)
            return ce + p["alpha"] * l2

        self.params_ = _adam_train(loss, params, p["steps"], p["lr"])
        return self

    def forward_jnp(self, x: jnp.ndarray) -> jnp.ndarray:
        """Logits for an on-device (B, d) batch."""
        return _mlp_forward(self.params_, x)

    def predict_proba(self, x):
        logits = self.forward_jnp(jnp.asarray(x, dtype=jnp.float32))
        return np.asarray(jax.nn.softmax(logits, axis=1))

    def predict(self, x):
        return self.predict_proba(x).argmax(axis=1)
