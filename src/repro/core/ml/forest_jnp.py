"""Device-side decision-tree / random-forest inference.

The zoo's strongest models (Fig. 4's winners) are pointer-chasing CART
trees — useless on an accelerator as-is. This module flattens a fitted
tree into dense node arrays (feature, threshold, left, right, leaf
probabilities) and evaluates a whole forest on a feature batch with
``jnp.take``-based level traversal: every sample in every tree descends one
level per step, leaves self-loop, and after ``depth`` steps each sample
sits at its leaf. No host transfer, no Python recursion — the traversal is
a ``lax.fori_loop`` of gathers, vmapped over trees, so it fuses into the
selector's inference jit next to the scaler transform.

Numerics: thresholds and leaf probabilities are evaluated in float32
(device default). Fully-grown CART leaves are pure, so forest votes are
small exact integers and the argmax agrees with the float64 host path; a
sample within float32 epsilon of a split threshold may route differently,
which is measure-zero for continuous features.
"""
from __future__ import annotations

from typing import List, NamedTuple

import numpy as np

__all__ = ["ForestArrays", "tree_to_arrays", "arrays_to_tree",
           "forest_to_arrays", "forest_forward_jnp", "forest_forward"]


class ForestArrays(NamedTuple):
    """Flattened forest: ``(T, N)`` node arrays padded to the widest tree.

    ``left``/``right`` are in-tree node indices; leaves (and padding) point
    at themselves so extra traversal steps are no-ops. ``value`` holds the
    normalized class distribution of each node's training samples (only
    leaf rows are ever gathered).
    """

    feature: np.ndarray    # (T, N) int32
    threshold: np.ndarray  # (T, N) float32
    left: np.ndarray       # (T, N) int32
    right: np.ndarray      # (T, N) int32
    value: np.ndarray      # (T, N, k) float32
    depth: int             # max levels over all trees (python int: static)


def tree_to_arrays(root, n_classes: int, normalize: bool = True):
    """DFS-flatten one linked `_Node` tree into parallel lists.

    Returns (feature, threshold, left, right, value, depth) python lists —
    the forest packer pads and stacks them. ``normalize=False`` keeps the
    raw class counts (the persistence path uses it: renormalizing is not
    bit-stable, and fingerprints must survive a save/load round trip).
    """
    feats: List[int] = []
    thrs: List[float] = []
    lefts: List[int] = []
    rights: List[int] = []
    values: List[np.ndarray] = []
    depth = 0
    # explicit stack: grid-search trees can outgrow Python's recursion limit
    stack = [(root, None, False, 0)]  # (node, parent_idx, is_right, level)
    while stack:
        node, parent, is_right, level = stack.pop()
        i = len(feats)
        depth = max(depth, level)
        is_leaf = node.left is None
        feats.append(0 if is_leaf else node.feature)
        thrs.append(np.inf if is_leaf else node.threshold)
        lefts.append(i)   # self-loop; patched below for internal nodes
        rights.append(i)
        val = np.asarray(node.value, dtype=np.float64)
        assert val.shape == (n_classes,), (val.shape, n_classes)
        values.append(val / max(float(val.sum()), 1.0) if normalize
                      else val)
        if parent is not None:
            (rights if is_right else lefts)[parent] = i
        if not is_leaf:
            # push right first so left is visited (and indexed) first
            stack.append((node.right, i, True, level + 1))
            stack.append((node.left, i, False, level + 1))
    return feats, thrs, lefts, rights, values, depth


def arrays_to_tree(feature, threshold, left, right, value):
    """Inverse of :func:`tree_to_arrays`: rebuild the linked ``_Node`` tree
    from parallel node arrays (leaves are the self-looping rows). Used by
    ``DecisionTreeClassifier.load_state`` so persisted bundles stay
    array-only. Iterative — no recursion limit to outgrow."""
    from .decision_tree import _Node

    nodes = [_Node(np.asarray(value[i], dtype=np.float64))
             for i in range(len(feature))]
    for i, node in enumerate(nodes):
        li, ri = int(left[i]), int(right[i])
        if li != i or ri != i:
            node.feature = int(feature[i])
            node.threshold = float(threshold[i])
            node.left = nodes[li]
            node.right = nodes[ri]
    return nodes[0]


def forest_to_arrays(trees, n_classes: int) -> ForestArrays:
    """Pack fitted trees (objects with ``root_``) into one padded stack."""
    flat = [tree_to_arrays(t.root_, n_classes) for t in trees]
    nmax = max(len(f[0]) for f in flat)
    T = len(flat)
    feature = np.zeros((T, nmax), dtype=np.int32)
    threshold = np.full((T, nmax), np.inf, dtype=np.float32)
    left = np.tile(np.arange(nmax, dtype=np.int32), (T, 1))
    right = left.copy()
    value = np.zeros((T, nmax, n_classes), dtype=np.float32)
    depth = 0
    for t, (f, th, lf, rg, vals, d) in enumerate(flat):
        m = len(f)
        feature[t, :m] = f
        threshold[t, :m] = th
        left[t, :m] = lf
        right[t, :m] = rg
        value[t, :m] = np.stack(vals)
        depth = max(depth, d)
    return ForestArrays(feature, threshold, left, right, value, depth)


def forest_forward_jnp(fa: ForestArrays, x):
    """Mean leaf probabilities ``(B, k)`` for a ``(B, d)`` feature batch.

    Level-synchronous traversal: ``node[b]`` descends one edge per step via
    three gathers (feature, threshold, child), vmapped over the tree axis.
    Traceable under jit; ``fa`` arrays become constants of the trace.
    """
    import jax
    import jax.numpy as jnp

    x = jnp.asarray(x, jnp.float32)
    feature = jnp.asarray(fa.feature)
    threshold = jnp.asarray(fa.threshold)
    left = jnp.asarray(fa.left)
    right = jnp.asarray(fa.right)
    value = jnp.asarray(fa.value)

    def one_tree(feat, thr, lft, rgt, val):
        def body(_, node):
            f = jnp.take(feat, node)                       # (B,)
            t = jnp.take(thr, node)
            xv = jnp.take_along_axis(x, f[:, None], axis=1)[:, 0]
            return jnp.where(xv <= t, jnp.take(lft, node),
                             jnp.take(rgt, node))

        node0 = jnp.zeros(x.shape[0], jnp.int32)
        node = jax.lax.fori_loop(0, fa.depth, body, node0)
        return jnp.take(val, node, axis=0)                 # (B, k)

    probs = jax.vmap(one_tree)(feature, threshold, left, right, value)
    return probs.mean(axis=0)


def _cached_arrays(model, trees) -> ForestArrays:
    """Flatten once per fit: keyed on the identity of the fitted roots.

    The key holds strong references to the root nodes (not their ``id``s):
    a refit frees the old roots, and a reallocated node could otherwise
    reuse an address and alias the stale arrays.
    """
    key = tuple(t.root_ for t in trees)
    cached = getattr(model, "_flat", None)
    if (cached is None or len(cached[0]) != len(key)
            or any(a is not b for a, b in zip(cached[0], key))):
        model._flat = (key, forest_to_arrays(trees, int(model.n_classes_)))
    return model._flat[1]


def forest_forward(model, x):
    """``forward_jnp`` implementation shared by the tree and forest classes.

    ``model`` is a fitted ``DecisionTreeClassifier`` (``root_``) or
    ``RandomForestClassifier`` (``trees_``).
    """
    trees = getattr(model, "trees_", None)
    if trees is None:
        trees = [model]
    return forest_forward_jnp(_cached_arrays(model, trees), x)
