"""CART decision tree (gini / entropy), vectorized split search."""
from __future__ import annotations

from typing import Optional

import numpy as np

from .base import BaseClassifier

__all__ = ["DecisionTreeClassifier"]


def _impurity(counts: np.ndarray, criterion: str) -> np.ndarray:
    """counts: (..., k) class counts → impurity per row."""
    total = counts.sum(axis=-1, keepdims=True)
    p = counts / np.maximum(total, 1)
    if criterion == "gini":
        return 1.0 - (p ** 2).sum(axis=-1)
    logp = np.where(p > 0, np.log2(np.maximum(p, 1e-12)), 0.0)
    return -(p * logp).sum(axis=-1)


class _Node:
    __slots__ = ("feature", "threshold", "left", "right", "value")

    def __init__(self, value):
        self.feature: int = -1
        self.threshold: float = 0.0
        self.left: Optional["_Node"] = None
        self.right: Optional["_Node"] = None
        self.value = value  # class-count vector


class DecisionTreeClassifier(BaseClassifier):
    def __init__(self, criterion: str = "gini", max_depth: Optional[int] = None,
                 min_samples_split: int = 2, min_samples_leaf: int = 1,
                 max_features: Optional[str] = None, random_state: int = 0):
        super().__init__(criterion=criterion, max_depth=max_depth,
                         min_samples_split=min_samples_split,
                         min_samples_leaf=min_samples_leaf,
                         max_features=max_features, random_state=random_state)

    def fit(self, x: np.ndarray, y: np.ndarray) -> "DecisionTreeClassifier":
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=np.int64)
        self.n_classes_ = int(y.max()) + 1 if y.size else 1
        self._rng = np.random.default_rng(self.params["random_state"])
        self.root_ = self._build(x, y, depth=0)
        return self

    # -- split search --------------------------------------------------------
    def _best_split(self, x, y):
        p = self.params
        n, d = x.shape
        k = self.n_classes_
        feats = np.arange(d)
        if p["max_features"] == "sqrt":
            m = max(1, int(np.sqrt(d)))
            feats = self._rng.choice(d, size=m, replace=False)
        best = (None, None, np.inf)  # feature, threshold, score
        min_leaf = p["min_samples_leaf"]
        onehot = np.zeros((n, k))
        onehot[np.arange(n), y] = 1.0
        for f in feats:
            order = np.argsort(x[:, f], kind="stable")
            xs = x[order, f]
            cum = np.cumsum(onehot[order], axis=0)  # counts left of cut i+1
            total = cum[-1]
            # candidate cuts between distinct consecutive values
            valid = np.nonzero(xs[1:] > xs[:-1])[0]  # cut after index i
            if valid.size == 0:
                continue
            nl = valid + 1
            nr = n - nl
            ok = (nl >= min_leaf) & (nr >= min_leaf)
            valid, nl, nr = valid[ok], nl[ok], nr[ok]
            if valid.size == 0:
                continue
            left_counts = cum[valid]
            right_counts = total[None, :] - left_counts
            imp = (nl * _impurity(left_counts, p["criterion"])
                   + nr * _impurity(right_counts, p["criterion"])) / n
            i = int(np.argmin(imp))
            if imp[i] < best[2]:
                thr = 0.5 * (xs[valid[i]] + xs[valid[i] + 1])
                best = (int(f), float(thr), float(imp[i]))
        return best

    def _build(self, x, y, depth):
        p = self.params
        counts = np.bincount(y, minlength=self.n_classes_).astype(np.float64)
        node = _Node(counts)
        if (y.size < p["min_samples_split"]
                or (p["max_depth"] is not None and depth >= p["max_depth"])
                or np.unique(y).size <= 1):
            return node
        parent_imp = _impurity(counts[None, :], p["criterion"])[0]
        f, thr, score = self._best_split(x, y)
        if f is None or score >= parent_imp - 1e-12:
            return node
        mask = x[:, f] <= thr
        node.feature, node.threshold = f, thr
        node.left = self._build(x[mask], y[mask], depth + 1)
        node.right = self._build(x[~mask], y[~mask], depth + 1)
        return node

    # -- persistence ----------------------------------------------------------
    def state(self) -> dict:
        """Fitted tree as flat node arrays (schema of ``tree_to_arrays``) —
        no linked ``_Node`` objects leave the process, so bundle payloads
        and fingerprints are plain deterministic arrays."""
        if not hasattr(self, "root_"):
            return {}
        from .forest_jnp import tree_to_arrays
        f, t, lf, rg, v, _ = tree_to_arrays(self.root_, self.n_classes_,
                                            normalize=False)
        return dict(n_classes_=int(self.n_classes_),
                    feature=np.asarray(f, np.int32),
                    threshold=np.asarray(t, np.float64),
                    left=np.asarray(lf, np.int32),
                    right=np.asarray(rg, np.int32),
                    value=np.asarray(v, np.float64))

    def load_state(self, state: dict) -> "DecisionTreeClassifier":
        if not state:
            return self
        from .forest_jnp import arrays_to_tree
        self.n_classes_ = int(state["n_classes_"])
        self.root_ = arrays_to_tree(state["feature"], state["threshold"],
                                    state["left"], state["right"],
                                    state["value"])
        return self

    # -- inference ------------------------------------------------------------
    def _leaf_counts(self, x: np.ndarray) -> np.ndarray:
        out = np.empty((x.shape[0], self.n_classes_))
        for i, row in enumerate(x):
            node = self.root_
            while node.left is not None:
                node = node.left if row[node.feature] <= node.threshold else node.right
            out[i] = node.value
        return out

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        c = self._leaf_counts(np.asarray(x, dtype=np.float64))
        return c / np.maximum(c.sum(axis=1, keepdims=True), 1)

    def predict(self, x: np.ndarray) -> np.ndarray:
        return self.predict_proba(x).argmax(axis=1)

    def forward_jnp(self, x):
        """Device scores (B, k): leaf probabilities via flattened-node
        traversal (:mod:`repro.core.ml.forest_jnp`); jit-traceable."""
        from .forest_jnp import forest_forward
        return forest_forward(self, x)
