"""Random forest: bagged CART trees with sqrt-feature subsampling.

The paper's winning model (Fig. 4 / Table 4: gini, min_samples_leaf=1,
min_samples_split=5, n_estimators=100).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .base import BaseClassifier
from .decision_tree import DecisionTreeClassifier

__all__ = ["RandomForestClassifier"]


class RandomForestClassifier(BaseClassifier):
    def __init__(self, n_estimators: int = 100, criterion: str = "gini",
                 max_depth: Optional[int] = None, min_samples_split: int = 2,
                 min_samples_leaf: int = 1, bootstrap: bool = True,
                 random_state: int = 0):
        super().__init__(n_estimators=n_estimators, criterion=criterion,
                         max_depth=max_depth,
                         min_samples_split=min_samples_split,
                         min_samples_leaf=min_samples_leaf,
                         bootstrap=bootstrap, random_state=random_state)

    def fit(self, x: np.ndarray, y: np.ndarray) -> "RandomForestClassifier":
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=np.int64)
        self.n_classes_ = int(y.max()) + 1 if y.size else 1
        p = self.params
        rng = np.random.default_rng(p["random_state"])
        n = x.shape[0]
        self.trees_ = []
        for t in range(p["n_estimators"]):
            idx = (rng.integers(0, n, n) if p["bootstrap"]
                   else np.arange(n))
            tree = DecisionTreeClassifier(
                criterion=p["criterion"], max_depth=p["max_depth"],
                min_samples_split=p["min_samples_split"],
                min_samples_leaf=p["min_samples_leaf"],
                max_features="sqrt",
                random_state=int(rng.integers(0, 2**31 - 1)))
            # classes present in the bootstrap may be a subset; force k
            tree.n_classes_ = self.n_classes_
            tree._rng = np.random.default_rng(tree.params["random_state"])
            tree.root_ = tree._build(x[idx], y[idx], depth=0)
            self.trees_.append(tree)
        return self

    # -- persistence ----------------------------------------------------------
    def state(self) -> dict:
        """Per-tree flat node arrays (see ``DecisionTreeClassifier.state``)."""
        if not hasattr(self, "trees_"):
            return {}
        return dict(n_classes_=int(self.n_classes_),
                    trees=[t.state() for t in self.trees_])

    def load_state(self, state: dict) -> "RandomForestClassifier":
        if not state:
            return self
        self.n_classes_ = int(state["n_classes_"])
        self.trees_ = [DecisionTreeClassifier().load_state(ts)
                       for ts in state["trees"]]
        return self

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, dtype=np.float64)
        acc = np.zeros((x.shape[0], self.n_classes_))
        for tree in self.trees_:
            acc += tree.predict_proba(x)
        return acc / len(self.trees_)

    def predict(self, x: np.ndarray) -> np.ndarray:
        return self.predict_proba(x).argmax(axis=1)

    def forward_jnp(self, x):
        """Device scores (B, k): mean per-tree leaf probabilities via the
        flattened forest (:mod:`repro.core.ml.forest_jnp`); keeps forest
        selection on device in ``ReorderSelector.select_batch``."""
        from .forest_jnp import forest_forward
        return forest_forward(self, x)

    def feature_importances(self, x: np.ndarray, y: np.ndarray,
                            n_repeats: int = 3, seed: int = 0) -> np.ndarray:
        """Permutation importance (used by the EXPERIMENTS feature study)."""
        rng = np.random.default_rng(seed)
        base = self.score(x, y)
        d = x.shape[1]
        imp = np.zeros(d)
        for f in range(d):
            drops = []
            for _ in range(n_repeats):
                xp = np.array(x, dtype=np.float64)
                xp[:, f] = rng.permutation(xp[:, f])
                drops.append(base - self.score(xp, y))
            imp[f] = float(np.mean(drops))
        return imp
