"""K-nearest-neighbour classifier (euclidean / manhattan)."""
from __future__ import annotations

import numpy as np

from .base import BaseClassifier

__all__ = ["KNeighborsClassifier"]


class KNeighborsClassifier(BaseClassifier):
    def __init__(self, n_neighbors: int = 5, weights: str = "uniform",
                 metric: str = "euclidean"):
        super().__init__(n_neighbors=n_neighbors, weights=weights,
                         metric=metric)

    def fit(self, x, y):
        self.x_ = np.asarray(x, dtype=np.float64)
        self.y_ = np.asarray(y, dtype=np.int64)
        self.n_classes_ = int(self.y_.max()) + 1
        return self

    def _dist(self, x):
        if self.params["metric"] == "manhattan":
            return np.abs(x[:, None, :] - self.x_[None, :, :]).sum(-1)
        d2 = ((x ** 2).sum(1)[:, None] - 2 * x @ self.x_.T
              + (self.x_ ** 2).sum(1)[None, :])
        return np.sqrt(np.maximum(d2, 0.0))

    def predict_proba(self, x):
        x = np.asarray(x, dtype=np.float64)
        k = min(self.params["n_neighbors"], self.x_.shape[0])
        dist = self._dist(x)
        nn = np.argpartition(dist, k - 1, axis=1)[:, :k]
        out = np.zeros((x.shape[0], self.n_classes_))
        for i in range(x.shape[0]):
            labels = self.y_[nn[i]]
            if self.params["weights"] == "distance":
                w = 1.0 / np.maximum(dist[i, nn[i]], 1e-12)
            else:
                w = np.ones(k)
            np.add.at(out[i], labels, w)
        return out / np.maximum(out.sum(axis=1, keepdims=True), 1e-12)

    def predict(self, x):
        return self.predict_proba(x).argmax(axis=1)
