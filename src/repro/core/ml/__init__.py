"""The seven model families of the paper's Fig. 4, implemented from scratch."""
from .base import BaseClassifier, accuracy_score
from .decision_tree import DecisionTreeClassifier
from .forest_jnp import (ForestArrays, forest_forward_jnp, forest_to_arrays,
                         tree_to_arrays)
from .jax_models import LogisticRegression, MLPClassifier, SVMClassifier
from .knn import KNeighborsClassifier
from .naive_bayes import GaussianNB
from .random_forest import RandomForestClassifier

MODEL_ZOO = {
    "random_forest": RandomForestClassifier,
    "decision_tree": DecisionTreeClassifier,
    "logistic_regression": LogisticRegression,
    "naive_bayes": GaussianNB,
    "svm": SVMClassifier,
    "mlp": MLPClassifier,
    "knn": KNeighborsClassifier,
}

__all__ = [
    "BaseClassifier", "accuracy_score", "DecisionTreeClassifier",
    "RandomForestClassifier", "LogisticRegression", "SVMClassifier",
    "MLPClassifier", "GaussianNB", "KNeighborsClassifier", "MODEL_ZOO",
    "ForestArrays", "tree_to_arrays", "forest_to_arrays",
    "forest_forward_jnp",
]
