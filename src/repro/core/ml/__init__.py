"""The seven model families of the paper's Fig. 4, implemented from scratch.

Each family registers itself in :data:`repro.engine.MODEL_REGISTRY` under
its zoo name; ``MODEL_ZOO`` is that registry (a ``Mapping``), kept under the
legacy name so existing ``MODEL_ZOO[name]()`` / ``sorted(MODEL_ZOO)`` call
sites work unchanged — third-party families now plug in with
``@register_model("name")`` instead of editing this dict.
"""
from repro.engine.registry import MODEL_REGISTRY, register_model

from .base import BaseClassifier, accuracy_score
from .decision_tree import DecisionTreeClassifier
from .forest_jnp import (ForestArrays, arrays_to_tree, forest_forward_jnp,
                         forest_to_arrays, tree_to_arrays)
from .jax_models import LogisticRegression, MLPClassifier, SVMClassifier
from .knn import KNeighborsClassifier
from .naive_bayes import GaussianNB
from .random_forest import RandomForestClassifier

# device_capable: fitted instances expose forward_jnp, so select_batch's
# scaler+forward+argmax fuses into one jit (trees/forests via forest_jnp)
register_model("random_forest", device_capable=True)(RandomForestClassifier)
register_model("decision_tree", device_capable=True)(DecisionTreeClassifier)
register_model("logistic_regression", device_capable=True)(LogisticRegression)
register_model("naive_bayes")(GaussianNB)
register_model("svm", device_capable=True)(SVMClassifier)
register_model("mlp", device_capable=True)(MLPClassifier)
register_model("knn")(KNeighborsClassifier)

MODEL_ZOO = MODEL_REGISTRY

__all__ = [
    "BaseClassifier", "accuracy_score", "DecisionTreeClassifier",
    "RandomForestClassifier", "LogisticRegression", "SVMClassifier",
    "MLPClassifier", "GaussianNB", "KNeighborsClassifier", "MODEL_ZOO",
    "MODEL_REGISTRY", "register_model",
    "ForestArrays", "tree_to_arrays", "arrays_to_tree", "forest_to_arrays",
    "forest_forward_jnp",
]
