"""Minimal scikit-learn-flavoured classifier API (fit/predict/score/clone).

scikit-learn is not available offline, so the seven model families the paper
evaluates (Fig. 4) are implemented from scratch in this package — trees and
KNN in numpy, the differentiable models (logistic regression, SVM, MLP) in
JAX.
"""
from __future__ import annotations

import copy
from typing import Any, Dict

import numpy as np

__all__ = ["BaseClassifier", "accuracy_score"]


def accuracy_score(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    """Acc = P_true / P_all (paper Eq. 4)."""
    y_true = np.asarray(y_true)
    y_pred = np.asarray(y_pred)
    return float((y_true == y_pred).mean()) if y_true.size else 0.0


class BaseClassifier:
    """Subclasses set hyperparameters in __init__ via explicit kwargs and
    record them in ``self.params`` (used by clone / grid search)."""

    params: Dict[str, Any]

    def __init__(self, **params: Any) -> None:
        self.params = dict(params)

    def clone(self) -> "BaseClassifier":
        return type(self)(**copy.deepcopy(self.params))

    def with_params(self, **updates: Any) -> "BaseClassifier":
        p = dict(self.params)
        p.update(updates)
        return type(self)(**p)

    # persistence / identity ------------------------------------------------
    def state(self) -> Dict[str, Any]:
        """Fitted state as a plain dict — the sklearn convention of trailing
        underscores marks fitted attributes, so the default collects those.
        Families whose fitted state is an object graph (trees) override
        this to return arrays, keeping bundles array-only and fingerprints
        deterministic. Empty for an unfitted instance."""
        return {k: v for k, v in vars(self).items()
                if k.endswith("_") and not k.startswith("_")}

    def load_state(self, state: Dict[str, Any]) -> "BaseClassifier":
        for k, v in state.items():
            setattr(self, k, v)
        return self

    def fingerprint(self) -> str:
        """Stable hash of class + hyperparameters + fitted state; changes on
        every refit, which is what lets the engine version its plan cache
        off the served model automatically."""
        from repro.engine.fingerprint import component_fingerprint
        return component_fingerprint(self)

    # subclass contract -----------------------------------------------------
    def fit(self, x: np.ndarray, y: np.ndarray) -> "BaseClassifier":
        raise NotImplementedError

    def predict(self, x: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        # default: one-hot of predict
        pred = self.predict(x)
        k = int(self.n_classes_)
        out = np.zeros((pred.shape[0], k))
        out[np.arange(pred.shape[0]), pred] = 1.0
        return out

    def score(self, x: np.ndarray, y: np.ndarray) -> float:
        return accuracy_score(y, self.predict(x))
