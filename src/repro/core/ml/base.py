"""Minimal scikit-learn-flavoured classifier API (fit/predict/score/clone).

scikit-learn is not available offline, so the seven model families the paper
evaluates (Fig. 4) are implemented from scratch in this package — trees and
KNN in numpy, the differentiable models (logistic regression, SVM, MLP) in
JAX.
"""
from __future__ import annotations

import copy
from typing import Any, Dict

import numpy as np

__all__ = ["BaseClassifier", "accuracy_score"]


def accuracy_score(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    """Acc = P_true / P_all (paper Eq. 4)."""
    y_true = np.asarray(y_true)
    y_pred = np.asarray(y_pred)
    return float((y_true == y_pred).mean()) if y_true.size else 0.0


class BaseClassifier:
    """Subclasses set hyperparameters in __init__ via explicit kwargs and
    record them in ``self.params`` (used by clone / grid search)."""

    params: Dict[str, Any]

    def __init__(self, **params: Any) -> None:
        self.params = dict(params)

    def clone(self) -> "BaseClassifier":
        return type(self)(**copy.deepcopy(self.params))

    def with_params(self, **updates: Any) -> "BaseClassifier":
        p = dict(self.params)
        p.update(updates)
        return type(self)(**p)

    # subclass contract -----------------------------------------------------
    def fit(self, x: np.ndarray, y: np.ndarray) -> "BaseClassifier":
        raise NotImplementedError

    def predict(self, x: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        # default: one-hot of predict
        pred = self.predict(x)
        k = int(self.n_classes_)
        out = np.zeros((pred.shape[0], k))
        out[np.arange(pred.shape[0]), pred] = 1.0
        return out

    def score(self, x: np.ndarray, y: np.ndarray) -> float:
        return accuracy_score(y, self.predict(x))
