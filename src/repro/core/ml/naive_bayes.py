"""Gaussian naive Bayes (the paper's "Bayesian Algorithm")."""
from __future__ import annotations

import numpy as np

from .base import BaseClassifier

__all__ = ["GaussianNB"]


class GaussianNB(BaseClassifier):
    def __init__(self, var_smoothing: float = 1e-9):
        super().__init__(var_smoothing=var_smoothing)

    def fit(self, x, y):
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=np.int64)
        self.n_classes_ = int(y.max()) + 1
        k, d = self.n_classes_, x.shape[1]
        self.theta_ = np.zeros((k, d))
        self.var_ = np.ones((k, d))
        self.prior_ = np.full(k, 1.0 / k)
        eps = self.params["var_smoothing"] * max(x.var(axis=0).max(), 1e-12)
        for c in range(k):
            xc = x[y == c]
            if xc.shape[0] == 0:
                continue
            self.theta_[c] = xc.mean(axis=0)
            self.var_[c] = xc.var(axis=0) + eps
            self.prior_[c] = xc.shape[0] / x.shape[0]
        return self

    def _joint_log_likelihood(self, x):
        x = np.asarray(x, dtype=np.float64)
        jll = np.empty((x.shape[0], self.n_classes_))
        for c in range(self.n_classes_):
            ll = -0.5 * (np.log(2 * np.pi * self.var_[c])
                         + (x - self.theta_[c]) ** 2 / self.var_[c]).sum(axis=1)
            jll[:, c] = ll + np.log(max(self.prior_[c], 1e-12))
        return jll

    def predict_proba(self, x):
        jll = self._joint_log_likelihood(x)
        jll -= jll.max(axis=1, keepdims=True)
        p = np.exp(jll)
        return p / p.sum(axis=1, keepdims=True)

    def predict(self, x):
        return self._joint_log_likelihood(x).argmax(axis=1)
