"""Structured serving metrics: one registry, three instruments, pluggable sinks.

Before this module the serving plane had three divergent hand-rolled stats
dicts — :class:`PlanBuilder` counted stage work under its own lock, the
plan cache counted hits/misses under another, and the dispatcher kept a
latency deque under a third — with no way to watch any of them evolve over
time or from another process. This module gives every layer one vocabulary:

* :class:`Counter` — monotonically increasing (requests, sheds, hits).
* :class:`Gauge` — instantaneous level (queue depth, in-flight builds).
* :class:`Histogram` — bounded sliding-window observations with
  percentiles (per-stage latency).

A :class:`MetricsRegistry` hands out get-or-create instruments by name and
snapshots everything into one flat dict. It is deliberately **stdlib-only
and pull-based** (snapshot when asked) plus an optional **push** channel:
``registry.emit(event, **fields)`` writes a structured event record to
every attached :class:`MetricsSink` — :class:`JSONLSink` appends one JSON
line per event (the load generator and long-running servers use it for a
replayable trace), :class:`ListSink` captures records for tests.

Thread-safety: instrument creation is serialized by the registry lock;
each instrument carries its own lock, so hot-path updates from the RPC
handler threads, the batcher, and the build workers never contend on one
global lock.
"""
from __future__ import annotations

import collections
import json
import os
import threading
import time
from typing import Any, Deque, Dict, List, Optional, Sequence

__all__ = ["Counter", "Gauge", "Histogram", "MetricsSink", "NullSink",
           "ListSink", "JSONLSink", "MetricsRegistry", "default_registry"]


class Counter:
    """Monotonic counter. ``inc`` only — a counter that goes down is a
    gauge (``reset`` exists for test/benchmark re-zeroing, not serving)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0


class Gauge:
    """Instantaneous level: set/inc/dec."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def reset(self) -> None:
        self.set(0.0)


class Histogram:
    """Sliding-window observations with percentile readout.

    The window (default 100k) bounds memory on a long-running server —
    percentiles describe *recent* behavior, which is what an operator
    wants; lifetime totals survive in ``count``/``sum``.
    """

    __slots__ = ("name", "window", "_obs", "_count", "_sum", "_lock")

    def __init__(self, name: str, window: int = 100_000):
        self.name = name
        self.window = window
        self._obs: Deque[float] = collections.deque(maxlen=window)
        self._count = 0
        self._sum = 0.0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        with self._lock:
            self._obs.append(float(v))
            self._count += 1
            self._sum += float(v)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def values(self) -> List[float]:
        with self._lock:
            return list(self._obs)

    def percentile(self, q: float) -> float:
        """q in [0, 100]; 0.0 when empty (nearest-rank on the window)."""
        with self._lock:
            if not self._obs:
                return 0.0
            data = sorted(self._obs)
        rank = max(0, min(len(data) - 1,
                          int(round(q / 100.0 * (len(data) - 1)))))
        return data[rank]

    def summary(self) -> Dict[str, float]:
        with self._lock:
            data = sorted(self._obs)
            count, total = self._count, self._sum
        if not data:
            return dict(count=count, sum=total, p50=0.0, p99=0.0, mean=0.0)

        def pct(q: float) -> float:
            return data[max(0, min(len(data) - 1,
                                   int(round(q / 100.0 * (len(data) - 1)))))]

        return dict(count=count, sum=total, p50=pct(50.0), p99=pct(99.0),
                    mean=sum(data) / len(data))

    def reset(self) -> None:
        with self._lock:
            self._obs.clear()
            self._count = 0
            self._sum = 0.0


# ---------------------------------------------------------------------------
# sinks — the push channel for structured events
# ---------------------------------------------------------------------------

class MetricsSink:
    """Receives structured event records (plain dicts)."""

    def emit(self, record: Dict[str, Any]) -> None:  # pragma: no cover
        raise NotImplementedError

    def close(self) -> None:
        pass


class NullSink(MetricsSink):
    def emit(self, record: Dict[str, Any]) -> None:
        pass


class ListSink(MetricsSink):
    """In-memory capture (tests, the traffic-replay report)."""

    def __init__(self):
        self.records: List[Dict[str, Any]] = []
        self._lock = threading.Lock()

    def emit(self, record: Dict[str, Any]) -> None:
        with self._lock:
            self.records.append(record)

    def __len__(self) -> int:
        with self._lock:
            return len(self.records)


class JSONLSink(MetricsSink):
    """One JSON object per line, appended; flush-per-event so a crashed
    server loses at most the event in flight. Unserializable fields are
    stringified rather than dropping the record."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._lock = threading.Lock()
        self._f = open(path, "a", encoding="utf-8")

    def emit(self, record: Dict[str, Any]) -> None:
        line = json.dumps(record, default=str)
        with self._lock:
            self._f.write(line + "\n")
            self._f.flush()

    def close(self) -> None:
        with self._lock:
            try:
                self._f.close()
            except OSError:
                pass


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

class MetricsRegistry:
    """Get-or-create instruments by dotted name + event fan-out to sinks.

    One registry per serving stack (the engine owns it and threads it into
    the cache, builder, dispatcher, and RPC server) — names are therefore
    scoped by layer prefix (``dispatch.``, ``cache.``, ``rpc.``,
    ``stage.``), not by label sets.
    """

    def __init__(self, sinks: Optional[Sequence[MetricsSink]] = None):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._sinks: List[MetricsSink] = list(sinks or [])

    # -- instruments ---------------------------------------------------------
    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name)
            return g

    def histogram(self, name: str, window: int = 100_000) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(name, window)
            return h

    # -- sinks ---------------------------------------------------------------
    def add_sink(self, sink: MetricsSink) -> MetricsSink:
        with self._lock:
            self._sinks.append(sink)
        return sink

    def remove_sink(self, sink: MetricsSink) -> None:
        with self._lock:
            if sink in self._sinks:
                self._sinks.remove(sink)

    def emit(self, event: str, **fields: Any) -> None:
        """Push one structured event record to every sink. A sink failure
        (disk full under the JSONL sink) never fails the serving request
        that emitted the event."""
        with self._lock:
            sinks = list(self._sinks)
        if not sinks:
            return
        record = {"event": event, "t_unix": time.time(), **fields}
        for s in sinks:
            try:
                s.emit(record)
            except Exception:
                pass

    # -- readout -------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Flat dict: counters/gauges by name, histograms as
        ``name.count/.p50/.p99/.mean/.sum`` (milliseconds stay whatever
        unit the observer used — the serving path observes seconds and
        converts at the edge)."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._histograms)
        out: Dict[str, Any] = {}
        for name, c in sorted(counters.items()):
            out[name] = c.value
        for name, g in sorted(gauges.items()):
            out[name] = g.value
        for name, h in sorted(hists.items()):
            s = h.summary()
            for k in ("count", "p50", "p99", "mean", "sum"):
                out[f"{name}.{k}"] = s[k]
        return out

    def reset(self) -> None:
        """Zero every instrument (sinks are untouched)."""
        with self._lock:
            instruments = (list(self._counters.values())
                           + list(self._gauges.values())
                           + list(self._histograms.values()))
        for i in instruments:
            i.reset()

    def close(self) -> None:
        with self._lock:
            sinks = list(self._sinks)
        for s in sinks:
            try:
                s.close()
            except Exception:
                pass


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """Process-wide fallback registry, for layers constructed without an
    engine (ad-hoc dispatchers in tests/scripts)."""
    return _DEFAULT
