"""Dataset splitting, k-fold cross-validation and grid search (paper §3.4)."""
from __future__ import annotations

import itertools
from typing import Any, Dict, Iterable, List, Sequence, Tuple

import numpy as np

from .ml.base import BaseClassifier, accuracy_score

__all__ = ["train_test_split", "kfold_indices", "cross_val_score",
           "GridSearchCV"]


def train_test_split(x: np.ndarray, y: np.ndarray, test_size: float = 0.2,
                     seed: int = 0, stratify: bool = True):
    """8:2 split (paper default); stratified so rare labels appear in both."""
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    if stratify:
        test_idx: List[int] = []
        for c in np.unique(y):
            idx = np.nonzero(y == c)[0]
            idx = rng.permutation(idx)
            k = max(1, int(round(test_size * idx.size))) if idx.size > 1 else 0
            test_idx.extend(idx[:k].tolist())
        test_mask = np.zeros(n, dtype=bool)
        test_mask[test_idx] = True
    else:
        perm = rng.permutation(n)
        test_mask = np.zeros(n, dtype=bool)
        test_mask[perm[: int(round(test_size * n))]] = True
    return (x[~test_mask], x[test_mask], y[~test_mask], y[test_mask],
            np.nonzero(~test_mask)[0], np.nonzero(test_mask)[0])


def kfold_indices(n: int, k: int = 5, seed: int = 0) -> List[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    folds = np.array_split(perm, k)
    out = []
    for i in range(k):
        val = folds[i]
        train = np.concatenate([folds[j] for j in range(k) if j != i])
        out.append((train, val))
    return out


def cross_val_score(model: BaseClassifier, x: np.ndarray, y: np.ndarray,
                    cv: int = 5, seed: int = 0) -> float:
    scores = []
    for train, val in kfold_indices(x.shape[0], cv, seed):
        m = model.clone()
        m.fit(x[train], y[train])
        scores.append(m.score(x[val], y[val]))
    return float(np.mean(scores))


class GridSearchCV:
    """Exhaustive grid search with k-fold CV (paper Fig. 3).

    ``param_grid``: mapping name → candidate values. After ``fit``,
    ``best_model_`` is refit on the full training data with the best combo.
    """

    def __init__(self, model: BaseClassifier, param_grid: Dict[str, Sequence[Any]],
                 cv: int = 5, seed: int = 0):
        self.model = model
        self.param_grid = param_grid
        self.cv = cv
        self.seed = seed

    def _combos(self) -> Iterable[Dict[str, Any]]:
        keys = sorted(self.param_grid)
        for values in itertools.product(*(self.param_grid[k] for k in keys)):
            yield dict(zip(keys, values))

    def fit(self, x: np.ndarray, y: np.ndarray) -> "GridSearchCV":
        self.results_: List[Tuple[Dict[str, Any], float]] = []
        best = (None, -1.0)
        for combo in self._combos():
            m = self.model.with_params(**combo)
            score = cross_val_score(m, x, y, self.cv, self.seed)
            self.results_.append((combo, score))
            if score > best[1]:
                best = (combo, score)
        self.best_params_, self.best_score_ = best
        self.best_model_ = self.model.with_params(**self.best_params_)
        self.best_model_.fit(x, y)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        return self.best_model_.predict(x)

    def score(self, x: np.ndarray, y: np.ndarray) -> float:
        return accuracy_score(y, self.predict(x))
