"""RequestContext: one identity for a request across every serving layer.

Before this module, a request lost its identity at every layer boundary —
the RPC server saw a frame, the dispatcher saw a bare :class:`CSRMatrix`,
the plan builder saw positional batch slots, the cache saw a fingerprint
string — so a deadline could not follow the request, per-stage latency
could not be attributed, and shedding had nothing to key on.

:class:`RequestContext` is minted once at the edge (the RPC wire protocol
carries optional ``request_id``/``deadline_ms``/``priority`` fields;
``SolverEngine.plan/select/solve`` and ``PlanDispatcher.submit`` mint one
when the caller did not) and threaded through

    PlanRPCServer → PlanDispatcher → PlanBuilder → plan cache → solve

accumulating **span timings** (stage name → seconds) along the way, so a
``plan`` response can report exactly where its milliseconds went and the
dispatcher can *shed* a request whose deadline has already passed instead
of spending a build worker on an answer nobody is waiting for.

The typed serving errors live here too — they are the vocabulary every
layer (and the RPC client, which re-raises them by name) shares:

* :class:`DeadlineExceeded` — the request's deadline passed before a plan
  could be produced; the dispatcher sheds it at dequeue time.
* :class:`QueueFull` — admission control rejected the request because the
  dispatch queue is at ``max_queue`` (backpressure, not failure).
* :class:`DispatcherClosed` — the dispatcher shut down; pending futures
  are failed with this instead of hanging forever.

All deadlines are **absolute** ``time.perf_counter()`` instants (the
monotonic clock used everywhere in the serving path), converted from the
relative ``deadline_ms`` the client sent at mint time.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import threading
import time
import uuid
from typing import Dict, Optional

__all__ = ["RequestContext", "ServingError", "DeadlineExceeded",
           "QueueFull", "DispatcherClosed", "SERVING_ERRORS"]


class ServingError(RuntimeError):
    """Base of the typed serving-path errors (wire name = class name)."""


class DeadlineExceeded(ServingError):
    """The request's deadline passed before its plan was produced."""


class QueueFull(ServingError):
    """Admission control: the dispatch queue is at capacity."""


class DispatcherClosed(ServingError):
    """The dispatcher shut down; the request cannot be served."""


#: wire name → class, used by the RPC client to re-raise the exact typed
#: error the server-side dispatcher raised (``error_type`` in error frames)
SERVING_ERRORS: Dict[str, type] = {
    cls.__name__: cls
    for cls in (ServingError, DeadlineExceeded, QueueFull, DispatcherClosed)
}

# request ids are "req-<8 hex>-<seq>": unique within a process by the
# counter, unique across processes by the random prefix — and cheap (no
# per-request uuid4 syscall on the hot path)
_ID_PREFIX = uuid.uuid4().hex[:8]
_ID_SEQ = itertools.count()


@dataclasses.dataclass
class RequestContext:
    """Identity + budget + telemetry for one serving request.

    ``spans`` maps a stage name (``queue``, ``select``, ``reorder``,
    ``symbolic``, ``build``, ``cache``, ``permute``, ``factor``, ``solve``,
    ``total``) to accumulated seconds; re-entering a stage adds to it.
    ``deadline_s`` is an absolute :func:`time.perf_counter` instant or
    ``None`` (no deadline). ``priority`` — higher is served first; ties
    are FIFO.
    """

    request_id: str
    fingerprint: Optional[str] = None
    priority: int = 0
    t_arrival: float = dataclasses.field(default_factory=time.perf_counter)
    deadline_s: Optional[float] = None
    spans: Dict[str, float] = dataclasses.field(default_factory=dict)
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    # spans may be written from the batcher thread while (e.g.) an RPC
    # handler thread snapshots them for a response frame
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    # -- construction --------------------------------------------------------
    @classmethod
    def mint(cls, *, request_id: Optional[str] = None,
             deadline_ms: Optional[float] = None, priority: int = 0,
             fingerprint: Optional[str] = None) -> "RequestContext":
        """New context; ``deadline_ms`` is relative-to-now at mint time."""
        now = time.perf_counter()
        return cls(
            request_id=(request_id if request_id
                        else f"req-{_ID_PREFIX}-{next(_ID_SEQ)}"),
            fingerprint=fingerprint, priority=int(priority), t_arrival=now,
            deadline_s=(None if deadline_ms is None
                        else now + float(deadline_ms) / 1e3))

    # -- deadline ------------------------------------------------------------
    def remaining(self) -> Optional[float]:
        """Seconds until the deadline (negative if past); None = no deadline."""
        if self.deadline_s is None:
            return None
        return self.deadline_s - time.perf_counter()

    def expired(self) -> bool:
        return (self.deadline_s is not None
                and time.perf_counter() >= self.deadline_s)

    def elapsed(self) -> float:
        """Seconds since arrival (mint time)."""
        return time.perf_counter() - self.t_arrival

    # -- span telemetry ------------------------------------------------------
    def add_span(self, stage: str, seconds: float) -> None:
        with self._lock:
            self.spans[stage] = self.spans.get(stage, 0.0) + float(seconds)

    @contextlib.contextmanager
    def span(self, stage: str):
        """``with ctx.span("symbolic"): ...`` — accumulate wall time, even
        when the body raises (the time was still spent on this request)."""
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            self.add_span(stage, time.perf_counter() - t0)

    def spans_ms(self) -> Dict[str, float]:
        """Wire-friendly copy: stage → milliseconds."""
        with self._lock:
            return {k: v * 1e3 for k, v in self.spans.items()}

    def summary(self) -> Dict[str, object]:
        """Plain-data description (RPC responses, JSONL metric events)."""
        return dict(request_id=self.request_id, fingerprint=self.fingerprint,
                    priority=self.priority,
                    deadline_remaining_ms=(None if self.deadline_s is None
                                           else self.remaining() * 1e3),
                    spans_ms=self.spans_ms())

    # contexts travel inside futures between threads but never across
    # processes; strip the lock if something pickles one anyway
    def __getstate__(self):
        state = self.__dict__.copy()
        state.pop("_lock", None)
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._lock = threading.Lock()
