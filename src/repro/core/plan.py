"""ExecutionPlan: the selector's output as a cached end-to-end artifact.

The paper's 55.37% solve-time reduction is realized *downstream* of the
classifier — permutation, symbolic analysis, factorization — so caching
just the algorithm name (PR 1's serving path) still pays the expensive
symbolic analysis on every request. An :class:`ExecutionPlan` carries
everything that is a pure function of the sparsity structure:

    algorithm name + permutation + SymbolicFactor (etree, column counts,
    factor pattern, supernode partition) + predicted cost

so a cache hit skips straight to numeric factorization
(:func:`repro.sparse.multifrontal.multifrontal_cholesky` /
:func:`repro.sparse.numeric.sparse_cholesky` both accept the precomputed
``sym``). :class:`PlanBuilder` composes ``ReorderSelector.select_batch``
(device inference), ``repro.sparse.reorder`` and
``repro.sparse.symbolic.symbolic_cholesky`` into plans, front-ended by the
two-tier :class:`repro.core.plan_cache.TwoTierPlanCache`.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.plan_cache import PlanCache, matrix_fingerprint
from repro.core.reqctx import RequestContext
from repro.sparse.csr import CSRMatrix, permute_symmetric
from repro.sparse.reorder import get_reordering
from repro.sparse.symbolic import SymbolicFactor, symbolic_cholesky

__all__ = ["ExecutionPlan", "PlanBuilder", "execute_plan", "SOLVE_STAGES"]


@dataclasses.dataclass
class ExecutionPlan:
    """Everything structure-determined about solving one sparsity pattern.

    Valid for *any* matrix sharing ``fingerprint`` (values don't enter any
    field), which is what makes the plan cacheable and persistable.
    """

    fingerprint: str
    algorithm: str              # reordering that produced `perm`
    perm: np.ndarray            # perm[new] = old (repro.sparse.reorder convention)
    sym: SymbolicFactor         # symbolic analysis of the *permuted* pattern
    predicted_flops: int        # factorization cost model: sym.flops
    meta: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def n(self) -> int:
        return int(self.perm.shape[0])

    @property
    def nnz_L(self) -> int:
        return self.sym.nnz_L

    @property
    def fill(self) -> int:
        return self.sym.fill


class PlanBuilder:
    """select → reorder → symbolic, cache-aware and batch-first.

    ``plan_batch`` is the serving entry point: fingerprints the request,
    answers repeats from the cache (two-tier if the cache persists), runs
    the selector's device path once over the deduplicated misses, and
    builds + installs fresh plans. Counters expose how much work each stage
    actually did, which the tests use to prove a warm hit does *no*
    feature extraction, classification, or symbolic analysis.
    """

    def __init__(self, selector=None, cache: Optional[PlanCache] = None, *,
                 path: str = "device", use_pallas: bool = False,
                 batch_size: int = 16, metrics=None):
        self.selector = selector
        self.cache = cache if cache is not None else PlanCache()
        self.path = path
        self.use_pallas = use_pallas
        self.batch_size = batch_size
        # optional structured-metrics mirror (repro.core.metrics registry):
        # mesh featurize→infer work lands under `infer.*` so the serving
        # stack's one snapshot covers the device stage too
        self.metrics = metrics
        # stage counters; builds run concurrently in the async server's
        # worker pool, so updates go through _count
        self._stats_lock = threading.Lock()
        self.plans_built = 0
        self.sym_builds = 0
        self.select_calls = 0
        self.select_seconds = 0.0
        self.build_seconds = 0.0

    def _count(self, **deltas) -> None:
        with self._stats_lock:
            for k, d in deltas.items():
                setattr(self, k, getattr(self, k) + d)

    def reset_stats(self) -> None:
        """Zero the stage counters (and the cache's, via its own reset)."""
        with self._stats_lock:
            self.plans_built = self.sym_builds = self.select_calls = 0
            self.select_seconds = self.build_seconds = 0.0
        self.cache.reset_stats()

    # -- single-matrix ------------------------------------------------------
    def build(self, a: CSRMatrix, algorithm: Optional[str] = None,
              fingerprint: Optional[str] = None,
              ctx: Optional[RequestContext] = None) -> ExecutionPlan:
        """Build a plan from scratch (no cache involvement). A
        :class:`RequestContext` gets per-stage spans (``select``,
        ``reorder``, ``symbolic``) recorded into it."""
        t_sel = 0.0
        if algorithm is None:
            if self.selector is None:
                raise ValueError("no algorithm given and no selector set")
            algorithm, t_sel = self.selector.select(a)
            self._count(select_calls=1, select_seconds=t_sel)
            if ctx is not None:
                ctx.add_span("select", t_sel)
        t0 = time.perf_counter()  # select_seconds and build_seconds are
        perm = get_reordering(algorithm)(a)  # disjoint stages in reports
        t_reorder = time.perf_counter() - t0
        pa = permute_symmetric(a, perm)
        sym = symbolic_cholesky(pa)
        dt = time.perf_counter() - t0
        if ctx is not None:
            ctx.add_span("reorder", t_reorder)
            ctx.add_span("symbolic", dt - t_reorder)
        self._count(sym_builds=1, plans_built=1, build_seconds=dt)
        return ExecutionPlan(
            fingerprint or matrix_fingerprint(a), algorithm,
            np.asarray(perm, dtype=np.int64), sym, sym.flops,
            meta=dict(t_build=dt, t_select=t_sel))

    def get_or_build(self, a: CSRMatrix,
                     ctx: Optional[RequestContext] = None
                     ) -> Tuple[ExecutionPlan, bool]:
        """(plan, was_hit) for one matrix through the cache."""
        key = matrix_fingerprint(a)
        if ctx is not None:
            ctx.fingerprint = key
            with ctx.span("cache"):
                plan = self.cache.get(key)
        else:
            plan = self.cache.get(key)
        if plan is not None:
            return plan, True
        plan = self.build(a, fingerprint=key, ctx=ctx)
        self.cache.put(key, plan)
        return plan, False

    # -- batched serving path ------------------------------------------------
    def select_names(self, mats: Sequence[CSRMatrix]) -> List[str]:
        """Device-batched selection in size-tiered chunks of ``batch_size``.

        Partial device chunks are padded to ``batch_size`` (repeating a
        member) so the batch dim stays one jit bucket; filler results are
        dropped.
        """
        if self.selector is None:
            raise ValueError("PlanBuilder has no selector for cache misses")
        order = sorted(range(len(mats)), key=lambda i: (mats[i].nnz,
                                                        mats[i].n))
        names: List[Optional[str]] = [None] * len(mats)
        for lo in range(0, len(order), self.batch_size):
            chunk = order[lo : lo + self.batch_size]
            batch = [mats[i] for i in chunk]
            if self.path == "device":
                batch += [batch[0]] * (self.batch_size - len(chunk))
            got, dt = self.selector.select_batch(
                batch, path=self.path, use_pallas=self.use_pallas)
            self._count(select_calls=1, select_seconds=dt)
            if self.metrics is not None:
                self.metrics.counter("infer.batches").inc()
                self.metrics.counter("infer.matrices").inc(len(chunk))
                self.metrics.histogram("infer.batch_s").observe(dt)
                if self.path == "device":
                    # per-shard utilization of the serving mesh: how many
                    # rows of this jit bucket were live requests vs
                    # pad-filler on each shard
                    from repro.distributed.meshctx import (
                        get_serving_mesh, record_shard_utilization)

                    record_shard_utilization(self.metrics,
                                             get_serving_mesh(),
                                             len(chunk), len(batch))
            for i, name in zip(chunk, got):
                names[i] = name
        return names  # type: ignore[return-value]

    def plan_batch(self, mats: Sequence[CSRMatrix]) -> List[ExecutionPlan]:
        """Plans for a request batch; hits skip select+reorder+symbolic."""
        keys = [matrix_fingerprint(m) for m in mats]
        plans: List[Optional[ExecutionPlan]] = [None] * len(mats)
        pending: Dict[str, List[int]] = {}
        for i, key in enumerate(keys):
            hit = self.cache.get(key)
            if hit is not None:
                plans[i] = hit
            else:
                pending.setdefault(key, []).append(i)
        if pending:
            miss_idx = [idxs[0] for idxs in pending.values()]
            names = self.select_names([mats[i] for i in miss_idx])
            for i, name in zip(miss_idx, names):
                plan = self.build(mats[i], algorithm=name,
                                  fingerprint=keys[i])
                self.cache.put(keys[i], plan)
                for j in pending[keys[i]]:
                    plans[j] = plan
        return plans  # type: ignore[return-value]

    def stats(self) -> dict:
        s = self.cache.stats()
        with self._stats_lock:
            s.update(plans_built=self.plans_built,
                     sym_builds=self.sym_builds,
                     select_calls=self.select_calls,
                     select_seconds=self.select_seconds,
                     build_seconds=self.build_seconds)
        return s


#: solve-stage names as they appear in RequestContext spans and in the
#: metrics registry (``stage.<name>`` histograms, seconds)
SOLVE_STAGES = ("permute", "factor", "factor.assemble", "factor.device",
                "solve", "solve.sweep", "solve.refine")


def execute_plan(a: CSRMatrix, plan: ExecutionPlan,
                 b: Optional[np.ndarray] = None, *,
                 solver: str = "multifrontal",
                 backend: str = "numpy",
                 solve_dtype: str = "fp64",
                 pad: str = "pow2",
                 bs: Optional[int] = None,
                 sweep: str = "auto",
                 sweep_bs: Optional[int] = None,
                 rt: Optional[int] = None,
                 ctx: Optional[RequestContext] = None,
                 metrics=None) -> dict:
    """Numeric factor + solve of ``A x = b`` driven entirely by the plan.

    The only structure work left is applying the stored permutation; the
    symbolic factor is consumed as-is by the solver (no ``etree`` /
    ``column_counts`` / pattern recomputation — the warm-path guarantee).
    ``backend`` picks the front-math substrate (``numpy`` / per-front
    ``pallas`` / level-scheduled ``batched`` / async ``pipelined``) and
    ``solve_dtype`` the precision mode: ``fp64``, ``fp32``, or
    ``fp32_refine`` (fp32 factorization + fp64 iterative refinement). The
    f32-only device backends auto-promote ``fp64`` to ``fp32_refine`` so
    the residual still reaches the fp64 floor. ``pad``/``bs`` are the
    autotuned bucket/block policy (:mod:`repro.autotune.solve_tuner`);
    both the effective backend/precision and the applied policy are
    recorded in the result dict and in ``plan.meta`` (``solve_bs`` /
    ``solve_pad``) — a cached plan always tells which numeric path and
    policy last produced results from it.

    ``sweep`` picks the triangular-sweep substrate for the solve phase
    (``auto``/``seq``/``level``/``device`` — see
    :func:`repro.sparse.multifrontal.multifrontal_solve`), with
    ``sweep_bs``/``rt`` the device-sweep panel/RHS-tile knobs. The f32
    device sweeps auto-promote ``fp64`` to ``fp32_refine`` exactly like
    the device factor backends, and with ``sweep="device"`` the
    refinement loop itself runs device-resident
    (:func:`repro.sparse.refine.refine_solve_device`). ``b`` may be a
    single RHS ``(n,)`` or a block ``(n, k)``.

    A :class:`RequestContext` gets ``permute``/``factor``/``solve`` spans
    plus the solve-stage breakdown ``factor.assemble`` / ``factor.device``
    / ``solve.sweep`` / ``solve.refine`` (host assembly vs device-blocked
    vs triangular sweeps vs residual evaluation) on the level-scheduled
    backends; a :class:`repro.core.metrics.MetricsRegistry` passed as
    ``metrics`` mirrors every span into ``stage.<name>`` histograms and
    records the backend's ``solve.overlap_efficiency`` gauge, the sweep
    substrate (``solve.sweep.<mode>`` counters) and the refinement
    behavior (``solve.refine_iterations`` histogram plus per-count
    ``solve.refine_iters.<i>`` counters).
    """
    assert a.data is not None, "numeric execution needs values"
    if solve_dtype not in ("fp64", "fp32", "fp32_refine"):
        raise ValueError(f"unknown solve_dtype {solve_dtype!r}")
    if sweep not in ("auto", "seq", "level", "device"):
        raise ValueError(f"unknown sweep {sweep!r}")
    if b is None:
        b = np.random.default_rng(0).standard_normal(a.n)
    perm = plan.perm
    t0 = time.perf_counter()
    pa = permute_symmetric(a, perm)
    t_perm = time.perf_counter() - t0

    refine_info = None
    eff_dtype = solve_dtype
    eff_sweep = sweep
    fstats: dict = {}
    t0 = time.perf_counter()
    if solver == "multifrontal":
        from repro.sparse.multifrontal import (multifrontal_cholesky,
                                               multifrontal_solve)
        if (backend in ("pallas", "batched", "pipelined")
                or sweep == "device") and solve_dtype == "fp64":
            eff_dtype = "fp32_refine"  # f32 factor and/or f32 sweeps
        dtype = np.float64 if eff_dtype == "fp64" else np.float32
        # ctx rides into the numeric phase: the level-scheduled backends
        # re-check the deadline at level boundaries and abandon the
        # factorization mid-flight with DeadlineExceeded
        f = multifrontal_cholesky(pa, sym=plan.sym, backend=backend,
                                  dtype=dtype, pad=pad, bs=bs, ctx=ctx)
        fstats = f.stats
        t_fac = time.perf_counter() - t0
        t0 = time.perf_counter()
        if eff_sweep == "auto":
            eff_sweep = "seq" if f.schedule is None else "level"
        # hoisted: one permute + fp64 cast of the RHS, outside any
        # refinement loop (the closures below only ever see residuals)
        pb = np.ascontiguousarray(b[perm], dtype=np.float64)
        if eff_dtype == "fp32_refine" and eff_sweep == "device":
            from repro.sparse.refine import refine_solve_device
            z, refine_info = refine_solve_device(pa, f, pb,
                                                 sweep_bs=sweep_bs, rt=rt)
        elif eff_dtype == "fp32_refine":
            from repro.sparse.refine import refine_solve
            z, refine_info = refine_solve(
                pa.matvec,
                lambda r: multifrontal_solve(f, r, mode=eff_sweep,
                                             sweep_bs=sweep_bs, rt=rt),
                pb)
        else:
            z = multifrontal_solve(f, pb, mode=eff_sweep,
                                   sweep_bs=sweep_bs, rt=rt)
    elif solver == "simplicial":
        from repro.sparse.numeric import cholesky_solve, sparse_cholesky
        eff_dtype = "fp64"  # simplicial path is host fp64 only
        eff_sweep = "seq"
        f = sparse_cholesky(pa, sym=plan.sym)
        t_fac = time.perf_counter() - t0
        t0 = time.perf_counter()
        z = cholesky_solve(f, b[perm])
    else:
        raise ValueError(f"unknown solver {solver!r}")
    t_sol = time.perf_counter() - t0

    # solve-stage breakdown: host assembly vs device-blocked time comes
    # from the backend's own timers; on the refined paths the solve splits
    # into triangular sweeps vs residual evaluation (RefineInfo timers),
    # otherwise the sweeps are the whole of t_sol
    spans = {"permute": t_perm, "factor": t_fac, "solve": t_sol,
             "solve.sweep": t_sol}
    if refine_info is not None:
        spans["solve.sweep"] = refine_info.t_sweep
        spans["solve.refine"] = refine_info.t_residual
    if "t_factor_assemble" in fstats:
        spans["factor.assemble"] = fstats["t_factor_assemble"]
        spans["factor.device"] = (fstats.get("t_factor_dispatch", 0.0)
                                  + fstats.get("t_factor_sync", 0.0))
    if ctx is not None:
        for stage, dt in spans.items():
            ctx.add_span(stage, dt)
    if metrics is not None:
        for stage, dt in spans.items():
            metrics.histogram(f"stage.{stage}").observe(dt)
        if "overlap_efficiency" in fstats:
            metrics.gauge("solve.overlap_efficiency").set(
                fstats["overlap_efficiency"])
        metrics.counter("solve.requests").inc()
        metrics.counter(f"solve.sweep.{eff_sweep}").inc()
        if refine_info is not None:
            metrics.histogram("solve.refine_iterations").observe(
                float(refine_info.iterations))
            metrics.counter(
                f"solve.refine_iters.{min(refine_info.iterations, 8)}").inc()
    x = np.empty_like(z)
    x[perm] = z
    resid = float(np.linalg.norm(a.matvec(x) - b)
                  / max(np.linalg.norm(b), 1e-30))
    plan.meta["solve_backend"] = backend
    plan.meta["solve_dtype"] = eff_dtype
    plan.meta["solve_bs"] = bs
    plan.meta["solve_pad"] = pad
    plan.meta["solve_sweep"] = eff_sweep
    return dict(x=x, time=t_perm + t_fac + t_sol, t_permute=t_perm,
                t_factor=t_fac, t_solve=t_sol, residual=resid,
                algorithm=plan.algorithm, solver=solver,
                backend=backend, solve_dtype=eff_dtype, bs=bs, pad=pad,
                sweep=eff_sweep, rt=rt,
                overlap_efficiency=fstats.get("overlap_efficiency"),
                refine_iterations=(None if refine_info is None
                                   else refine_info.iterations),
                refine_converged=(None if refine_info is None
                                  else refine_info.converged),
                nnz_L=plan.nnz_L, flops=plan.predicted_flops,
                request_id=None if ctx is None else ctx.request_id)
