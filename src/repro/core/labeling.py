"""Labeling campaign: measure factor+solve time per (matrix, ordering) and
take the argmin as the training label — the paper's §3.2 protocol with our
multifrontal solver standing in for MUMPS.

Results are cached to disk (`artifacts/labels_<tag>.npz`) because the
campaign is the expensive step; benchmarks and examples reuse the cache.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.features import FEATURE_NAMES  # noqa: F401  (re-export)
from repro.engine.registry import get_feature_set
from repro.sparse.csr import CSRMatrix, permute_symmetric
from repro.sparse.dataset import generate_suite
from repro.sparse.multifrontal import factor_and_solve_timed
from repro.sparse.reorder import LABEL_ALGORITHMS, get_reordering

__all__ = ["LabeledDataset", "run_labeling_campaign", "load_or_build"]


@dataclasses.dataclass
class LabeledDataset:
    features: np.ndarray          # (m, 12)
    labels: np.ndarray            # (m,) index into algorithms
    times: np.ndarray             # (m, n_alg) measured factor+solve seconds
    order_times: np.ndarray       # (m, n_alg) ordering computation seconds
    fills: np.ndarray             # (m, n_alg) fill-in of L
    flops: np.ndarray             # (m, n_alg) symbolic factor FLOPs
    names: List[str]
    groups: List[str]
    dims: np.ndarray              # (m,)
    nnzs: np.ndarray              # (m,)
    algorithms: List[str]
    feature_set: str = "paper12"  # registry name of the featurizer used

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        np.savez_compressed(
            path, features=self.features, labels=self.labels,
            times=self.times, order_times=self.order_times, fills=self.fills,
            flops=self.flops, dims=self.dims, nnzs=self.nnzs,
            names=np.array(self.names), groups=np.array(self.groups),
            algorithms=np.array(self.algorithms),
            feature_set=np.array(self.feature_set),
            feature_names=np.array(
                list(get_feature_set(self.feature_set).names)))

    @staticmethod
    def load(path: str) -> "LabeledDataset":
        z = np.load(path, allow_pickle=False)
        return LabeledDataset(
            z["features"], z["labels"], z["times"], z["order_times"],
            z["fills"], z["flops"], [str(s) for s in z["names"]],
            [str(s) for s in z["groups"]], z["dims"], z["nnzs"],
            [str(s) for s in z["algorithms"]],
            # pre-registry caches carry no feature_set tag
            feature_set=(str(z["feature_set"]) if "feature_set" in z
                         else "paper12"))


def _measure_one(a: CSRMatrix, alg: str, repeats: int) -> Dict:
    t0 = time.perf_counter()
    perm = get_reordering(alg)(a)
    t_order = time.perf_counter() - t0
    ap = permute_symmetric(a, perm)
    best: Optional[Dict] = None
    for _ in range(repeats):
        r = factor_and_solve_timed(ap)
        if best is None or r["time"] < best["time"]:
            best = r
    assert best is not None
    best["t_order"] = t_order
    return best


def run_labeling_campaign(
    mats: Sequence[CSRMatrix],
    algorithms: Sequence[str] = tuple(LABEL_ALGORITHMS),
    repeats: int = 1,
    verbose: bool = False,
    feature_set: str = "paper12",
) -> LabeledDataset:
    fs = get_feature_set(feature_set)
    m = len(mats)
    n_alg = len(algorithms)
    feats = np.zeros((m, fs.dim))
    times = np.zeros((m, n_alg))
    order_times = np.zeros((m, n_alg))
    fills = np.zeros((m, n_alg), dtype=np.int64)
    flops = np.zeros((m, n_alg), dtype=np.int64)
    names, groups = [], []
    dims = np.zeros(m, dtype=np.int64)
    nnzs = np.zeros(m, dtype=np.int64)
    for i, a in enumerate(mats):
        feats[i] = fs.extract(a)
        names.append(a.name)
        groups.append(a.group)
        dims[i], nnzs[i] = a.n, a.nnz
        for j, alg in enumerate(algorithms):
            r = _measure_one(a, alg, repeats)
            times[i, j] = r["time"]
            order_times[i, j] = r["t_order"]
            fills[i, j] = r["fill"]
            flops[i, j] = r["sym_flops"]
        if verbose and (i + 1) % 50 == 0:
            print(f"  labeled {i + 1}/{m}")
    labels = times.argmin(axis=1)
    return LabeledDataset(feats, labels, times, order_times, fills, flops,
                          names, groups, dims, nnzs, list(algorithms),
                          feature_set=feature_set)


def load_or_build(cache_dir: str = "artifacts", count: int = 960,
                  seed: int = 0, size_scale: float = 1.0,
                  repeats: int = 1, verbose: bool = True,
                  feature_set: str = "paper12") -> LabeledDataset:
    tag = f"c{count}_s{seed}_x{size_scale:g}_r{repeats}"
    if feature_set != "paper12":  # paper12 keeps the pre-registry tag
        tag += f"_f{feature_set}"
    path = os.path.join(cache_dir, f"labels_{tag}.npz")
    if os.path.exists(path):
        return LabeledDataset.load(path)
    if verbose:
        print(f"[labeling] building suite ({count} matrices, scale "
              f"{size_scale}) — cached to {path}")
    mats = list(generate_suite(count=count, seed=seed, size_scale=size_scale))
    ds = run_labeling_campaign(mats, repeats=repeats, verbose=verbose,
                               feature_set=feature_set)
    ds.save(path)
    # sidecar summary for humans
    with open(path.replace(".npz", ".json"), "w") as f:
        dist = {alg: int((ds.labels == i).sum())
                for i, alg in enumerate(ds.algorithms)}
        json.dump(dict(count=len(ds.names), label_distribution=dist,
                       n_max=int(ds.dims.max()), nnz_max=int(ds.nnzs.max())),
                  f, indent=2)
    return ds
