"""LM substrate: config-driven decoder stacks (dense / MoE / SSM / xLSTM /
hybrid) with train, prefill and decode paths."""
from .config import SHAPES, ModelConfig, ShapeSpec
from .transformer import (decode_step, init_cache, init_params, loss_fn,
                          prefill)

__all__ = ["SHAPES", "ModelConfig", "ShapeSpec", "decode_step", "init_cache",
           "init_params", "loss_fn", "prefill"]
