"""Model configuration covering all 10 assigned architectures.

One frozen dataclass drives parameter shapes, layer pattern, and the
train/prefill/decode step builders in `repro.models.transformer`.

``block_pattern`` gives the per-layer *mixer* kind:
  'a' — GQA attention,  'm' — Mamba SSM,  'M' — mLSTM,  's' — sLSTM.
``moe_period > 0`` makes every ``moe_period``-th layer's MLP a top-k MoE.
The pattern must be periodic with period ``pattern_period`` (used to scan
over identical layer groups, keeping the lowered HLO small at 48 layers).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 → d_model // num_heads

    # mixer pattern ('a'/'m'/'M'/'s'), must tile num_layers
    block_pattern: Tuple[str, ...] = ("a",)

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_period: int = 0            # layer i uses MoE MLP iff (i % moe_period == moe_period-1)
    capacity_factor: float = 1.25
    moe_impl: str = "tp_ragged"    # tp_ragged (dropless, expert-TP) | ep (all-to-all)

    # MLP variant: gated SwiGLU (llama-family) vs plain GELU (starcoder2,
    # musicgen)
    mlp_gated: bool = True

    # attention details
    qk_norm: bool = False
    rope_theta: float = 1e4
    mrope: bool = False            # Qwen2-VL M-RoPE (3-section rope)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)

    # SSM (Mamba) details
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0           # 0 → ceil(d_model / 16)

    # xLSTM details
    xlstm_proj_factor: float = 2.0

    # frontend / IO
    input_mode: str = "tokens"     # tokens | embeddings (VLM/audio stubs)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # execution knobs (overridable by the autotuner / perf experiments)
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    remat: str = "layer"           # none | layer (checkpoint each block group)
    scan_layers: bool = True

    def __post_init__(self):
        assert self.num_layers % len(self.block_pattern) == 0, (
            self.name, "block_pattern must tile num_layers")
        assert self.num_heads % self.num_kv_heads == 0

    # -- derived -------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    @property
    def num_groups(self) -> int:
        return self.num_layers // self.pattern_period

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % self.pattern_period]

    def layer_is_moe(self, i: int) -> bool:
        if self.num_experts == 0 or self.layer_kind(i) in ("M", "s"):
            return False
        p = self.moe_period or 1
        return (i % p) == (p - 1)

    @property
    def attn_layers(self) -> Tuple[int, ...]:
        return tuple(i for i in range(self.num_layers)
                     if self.layer_kind(i) == "a")

    @property
    def ssm_layers(self) -> Tuple[int, ...]:
        return tuple(i for i in range(self.num_layers)
                     if self.layer_kind(i) == "m")

    @property
    def sub_quadratic(self) -> bool:
        """True when decode state does not grow quadratically with context —
        i.e. the arch may run the long_500k shape (SSM / hybrid / linear)."""
        return any(k in ("m", "M", "s") for k in self.block_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + per-layer + head)."""
        d, hd = self.d_model, self.head_dim_
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += d * self.vocab_size
        total += d  # final norm
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            total += d  # pre-mixer norm
            if kind == "a":
                total += d * (self.num_heads * hd) * 2  # wq, wo
                total += d * (self.num_kv_heads * hd) * 2  # wk, wv
                if self.qk_norm:
                    total += 2 * hd
            elif kind == "m":
                di, N, r = self.d_inner, self.ssm_state_dim, self.dt_rank
                total += d * 2 * di + self.ssm_conv_dim * di
                total += di * (r + 2 * N) + r * di + di * N + di + di * d
            elif kind in ("M", "s"):
                di = int(self.xlstm_proj_factor * d)
                total += d * 2 * di + 4 * di * di // 1 + di * d  # approx
            if kind in ("a", "m"):
                total += d  # pre-MLP norm
                n_in = 2 if self.mlp_gated else 1
                if self.layer_is_moe(i):
                    e = self.num_experts
                    total += d * e  # router
                    total += e * (n_in * d * self.d_ff + self.d_ff * d)
                elif self.d_ff:
                    total += n_in * d * self.d_ff + self.d_ff * d
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only) — the N in
        MODEL_FLOPS = 6·N_active·D."""
        if self.num_experts == 0:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        n_in = 2 if self.mlp_gated else 1
        for i in range(self.num_layers):
            if self.layer_is_moe(i):
                e, k = self.num_experts, self.experts_per_token
                expert_params = n_in * d * self.d_ff + self.d_ff * d
                total -= (e - k) * expert_params
        return total


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}
