"""Config-driven decoder stack: parameter init, train forward + loss,
prefill, and single-token decode with KV/state caches.

Layers are applied as a ``lax.scan`` over *groups* of ``pattern_period``
layers (identical structure per group), keeping the lowered HLO size
constant in depth — at 48 layers this is the difference between a 30 s and a
10 min 512-way GSPMD compile. Each group is optionally wrapped in
``jax.checkpoint`` (remat).

Caches: every slot (layer within a group) owns its state —
  'a' → k/v ring buffers (B, Hkv, S_max, hd) + the shared scalar `pos`;
  'm' → Mamba conv window + SSM state;
  'M'/'s' → xLSTM matrix / scalar states.
Stacked across groups by scan, so cache pytrees mirror the param layout.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (apply_rope, gqa_attention, init_dense,
                                 init_norm, mrope_cos_sin, rms_norm,
                                 rope_cos_sin, swiglu_mlp)
from repro.models.moe import init_moe_params, moe_ffn
from repro.models.ssm import (init_mamba_params, init_mamba_state,
                              mamba_decode_step, mamba_forward)
from repro.models.xlstm import (init_mlstm_params, init_mlstm_state,
                                init_slstm_params, init_slstm_state,
                                mlstm_decode_step, mlstm_forward,
                                slstm_decode_step, slstm_forward)

__all__ = ["init_params", "loss_fn", "prefill", "decode_step", "init_cache",
           "model_dtype"]

MOE_AUX_COEF = 0.01


def model_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _init_attn_slot(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.head_dim_
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = dict(
        wq=init_dense(ks[0], (d, hq * hd), dtype=dtype),
        wk=init_dense(ks[1], (d, hkv * hd), dtype=dtype),
        wv=init_dense(ks[2], (d, hkv * hd), dtype=dtype),
        wo=init_dense(ks[3], (hq * hd, d), dtype=dtype),
    )
    if cfg.qk_norm:
        p["q_norm"] = init_norm((hd,), dtype)
        p["k_norm"] = init_norm((hd,), dtype)
    return p


def _init_mlp_slot(key, cfg: ModelConfig, layer_idx: int, dtype
                   ) -> Optional[Dict[str, Any]]:
    if cfg.d_ff == 0 and not cfg.layer_is_moe(layer_idx):
        return None
    if cfg.layer_is_moe(layer_idx):
        return dict(kind="moe", **init_moe_params(key, cfg, dtype))
    k1, k2, k3 = jax.random.split(key, 3)
    if not cfg.mlp_gated:
        return dict(kind="dense",
                    wi=init_dense(k1, (cfg.d_model, cfg.d_ff), dtype=dtype),
                    wd=init_dense(k3, (cfg.d_ff, cfg.d_model), dtype=dtype))
    return dict(kind="dense",
                wg=init_dense(k1, (cfg.d_model, cfg.d_ff), dtype=dtype),
                wu=init_dense(k2, (cfg.d_model, cfg.d_ff), dtype=dtype),
                wd=init_dense(k3, (cfg.d_ff, cfg.d_model), dtype=dtype))


def _init_group(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    """Params for one group (pattern_period layers). `kind` markers are
    static strings stripped before jitting (see _split_static)."""
    slots = {}
    for j, kind in enumerate(cfg.block_pattern):
        key, k1, k2, k3 = jax.random.split(key, 4)
        slot: Dict[str, Any] = dict(kind=kind, norm1=init_norm((cfg.d_model,), dtype))
        if kind == "a":
            slot["attn"] = _init_attn_slot(k1, cfg, dtype)
        elif kind == "m":
            slot["mamba"] = init_mamba_params(k1, cfg, dtype)
        elif kind == "M":
            slot["mlstm"] = init_mlstm_params(k1, cfg, dtype)
        elif kind == "s":
            slot["slstm"] = init_slstm_params(k1, cfg, dtype)
        if kind in ("a", "m"):
            mlp = _init_mlp_slot(k2, cfg, j, dtype)
            if mlp is not None:
                slot["norm2"] = init_norm((cfg.d_model,), dtype)
                slot["mlp"] = mlp
        slots[f"s{j}"] = slot
    return slots


def _strip_static(tree):
    """Remove the static 'kind' strings (they're re-derived from cfg)."""
    if isinstance(tree, dict):
        return {k: _strip_static(v) for k, v in tree.items() if k != "kind"}
    return tree


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    dtype = model_dtype(cfg)
    k_embed, k_groups, k_head = jax.random.split(key, 3)
    params: Dict[str, Any] = {}
    if cfg.input_mode == "tokens" or cfg.tie_embeddings:
        params["embed"] = init_dense(k_embed, (cfg.vocab_size, cfg.d_model),
                                     scale=0.02, dtype=dtype)
    # stacked groups: init one group per key, stack leaves
    gkeys = jax.random.split(k_groups, cfg.num_groups)
    groups = [_strip_static(_init_group(k, cfg, dtype)) for k in gkeys]
    params["groups"] = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *groups)
    params["final_norm"] = init_norm((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(k_head, (cfg.d_model, cfg.vocab_size),
                                       dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _attn_apply(slot, x, cos, sin, cfg: ModelConfig, *, causal=True,
                cache=None, pos=None):
    """x: (B, S, D). If `cache` is given, append k/v at `pos` and attend over
    the whole (masked) buffer. Returns (out, new_cache)."""
    from repro.distributed.meshctx import get_mesh_context
    b, s, d = x.shape
    hd, hq, hkv = cfg.head_dim_, cfg.num_heads, cfg.num_kv_heads
    h = rms_norm(x, slot["norm1"], cfg.norm_eps)
    # DP-only attention (heads don't tile the model axis): spread the batch
    # over data+model so the model axis isn't idle during attention.
    ctx = get_mesh_context()
    reshard = None
    if (ctx.mesh is not None and ctx.attn_dp_axes is not None
            and cache is None):
        n_all = 1
        for ax in ctx.attn_dp_axes:
            n_all *= ctx.mesh.shape[ax]
        if b % n_all == 0:
            from jax.sharding import NamedSharding, PartitionSpec as P
            reshard = NamedSharding(ctx.mesh, P(ctx.attn_dp_axes, None, None))
            h = jax.lax.with_sharding_constraint(h, reshard)
    q = jnp.einsum("bsd,de->bse", h, slot["attn"]["wq"]).reshape(b, s, hq, hd)
    k = jnp.einsum("bsd,de->bse", h, slot["attn"]["wk"]).reshape(b, s, hkv, hd)
    v = jnp.einsum("bsd,de->bse", h, slot["attn"]["wv"]).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, slot["attn"]["q_norm"], cfg.norm_eps)
        k = rms_norm(k, slot["attn"]["k_norm"], cfg.norm_eps)
    q, k = q.swapaxes(1, 2), k.swapaxes(1, 2)  # (B, H, S, hd)
    v = v.swapaxes(1, 2)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    new_cache = None
    if cache is not None:
        if s == 1 and ctx.mesh is not None and ctx.decode_seq_axes:
            # sequence-sharded cache: shard_map flash-decode (never gathers
            # the cache; wire cost is O(B·H·hd) partial-softmax stats)
            from repro.models.layers import sharded_decode_attention
            att, ck, cv = sharded_decode_attention(
                q, cache["k"], cache["v"], k, v, pos, mesh=ctx.mesh,
                seq_axes=ctx.decode_seq_axes, rep=hq // hkv)
            return (x + jnp.einsum(
                "bse,ed->bsd", att.swapaxes(1, 2).reshape(b, s, hq * hd),
                slot["attn"]["wo"]), dict(k=ck, v=cv))
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(
            cache["k"].dtype), pos, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(
            cache["v"].dtype), pos, axis=2)
        new_cache = dict(k=ck, v=cv)
        if s == 1:
            # decode: read the whole (masked) buffer — the HBM-bound path
            att = gqa_attention(q, ck, cv, causal=False,
                                q_chunk=cfg.attn_q_chunk,
                                kv_chunk=cfg.attn_kv_chunk,
                                kv_valid_len=pos + s, impl="plain")
        else:
            # prefill: attend causally over the fresh k/v, not the buffer
            att = gqa_attention(q, k, v, causal=True,
                                q_chunk=cfg.attn_q_chunk,
                                kv_chunk=cfg.attn_kv_chunk)
    else:
        att = gqa_attention(q, k, v, causal=causal, q_chunk=cfg.attn_q_chunk,
                            kv_chunk=cfg.attn_kv_chunk)
    att = att.swapaxes(1, 2).reshape(b, s, hq * hd)
    out = jnp.einsum("bse,ed->bsd", att, slot["attn"]["wo"])
    if reshard is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        out = jax.lax.with_sharding_constraint(
            out, NamedSharding(ctx.mesh, P(ctx.data_axes, None, None)))
    return x + out, new_cache


def _mlp_apply(slot, x, cfg: ModelConfig, layer_idx: int):
    """Post-mixer MLP (dense or MoE). Returns (x, aux)."""
    if "mlp" not in slot:
        return x, jnp.float32(0.0)
    h = rms_norm(x, slot["norm2"], cfg.norm_eps)
    if cfg.layer_is_moe(layer_idx):
        y, aux = moe_ffn(slot["mlp"], h, cfg)
    elif cfg.mlp_gated:
        y = swiglu_mlp(h, slot["mlp"]["wg"], slot["mlp"]["wu"],
                       slot["mlp"]["wd"])
        aux = jnp.float32(0.0)
    else:
        u = jnp.einsum("...d,df->...f", h, slot["mlp"]["wi"])
        u = jax.nn.gelu(u.astype(jnp.float32)).astype(h.dtype)
        y = jnp.einsum("...f,fd->...d", u, slot["mlp"]["wd"])
        aux = jnp.float32(0.0)
    return x + y, aux


def _apply_group_train(gparams, x, cos, sin, cfg: ModelConfig):
    aux_total = jnp.float32(0.0)
    for j, kind in enumerate(cfg.block_pattern):
        slot = gparams[f"s{j}"]
        if kind == "a":
            x, _ = _attn_apply(slot, x, cos, sin, cfg)
        elif kind == "m":
            h = rms_norm(x, slot["norm1"], cfg.norm_eps)
            x = x + mamba_forward(slot["mamba"], h, cfg)
        elif kind == "M":
            h = rms_norm(x, slot["norm1"], cfg.norm_eps)
            x = x + mlstm_forward(slot["mlstm"], h, cfg)
        elif kind == "s":
            h = rms_norm(x, slot["norm1"], cfg.norm_eps)
            x = x + slstm_forward(slot["slstm"], h, cfg)
        if kind in ("a", "m"):
            x, aux = _mlp_apply(slot, x, cfg, j)
            aux_total = aux_total + aux
    return x, aux_total


# ---------------------------------------------------------------------------
# Embedding / unembedding / rope helpers
# ---------------------------------------------------------------------------

def _embed_inputs(cfg, params, batch):
    if cfg.input_mode == "tokens":
        return jnp.take(params["embed"], batch["tokens"], axis=0)
    return batch["embeds"].astype(model_dtype(cfg))


def _rope_tables(cfg, positions, batch):
    if not any(k == "a" for k in cfg.block_pattern):
        return None, None
    if cfg.mrope:
        pos3 = batch.get("positions3")
        if pos3 is None:
            pos3 = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return mrope_cos_sin(pos3, cfg.head_dim_, cfg.rope_theta,
                             cfg.mrope_sections)
    return rope_cos_sin(positions, cfg.head_dim_, cfg.rope_theta)


def _unembed(cfg, params, x):
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def _forward(cfg: ModelConfig, params, batch):
    from repro.distributed.meshctx import get_mesh_context
    x = _embed_inputs(cfg, params, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    cos, sin = _rope_tables(cfg, positions, batch)

    ctx = get_mesh_context()
    ckpt_constraint = None
    if (ctx.mesh is not None and ctx.shard_activation_ckpt
            and s % ctx.mesh.shape[ctx.model_axis] == 0):
        from jax.sharding import NamedSharding, PartitionSpec as P
        ckpt_constraint = NamedSharding(
            ctx.mesh, P(ctx.batch_spec_axes, ctx.model_axis, None))

    def group_fn(carry, gparams):
        x, aux = carry
        if ckpt_constraint is not None:
            # the scan saves this carry per group for backward; sequence-
            # sharding it cuts residency |model|× (one AG per group to use)
            x = jax.lax.with_sharding_constraint(x, ckpt_constraint)
        x, aux_g = _apply_group_train(gparams, x, cos, sin, cfg)
        return (x, aux + aux_g), None

    if cfg.remat == "layer":
        group_fn = jax.checkpoint(group_fn)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(group_fn, (x, jnp.float32(0.0)),
                                   params["groups"])
    else:
        carry = (x, jnp.float32(0.0))
        for g in range(cfg.num_groups):
            gp = jax.tree_util.tree_map(lambda t: t[g], params["groups"])
            carry, _ = group_fn(carry, gp)
        x, aux = carry
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def loss_fn(cfg: ModelConfig, params, batch) -> Tuple[jax.Array, Dict]:
    """Next-token cross entropy (+ MoE aux). batch: tokens/embeds + labels.

    The CE is computed in checkpointed chunks along the sequence so the
    (B, S, V) fp32 logits are never materialized — per chunk only
    (B, chunk, V) exists, recomputed in backward. At vocab 128k and 65k
    tokens/device the full tensor would be >2 GB × several live copies.
    """
    x, aux = _forward(cfg, params, batch)
    labels = batch["labels"]
    b, s, _ = x.shape
    n_chunks = 8 if (s % 8 == 0 and s >= 1024) else 1

    def chunk_ce(acc, xs):
        xc, lc = xs
        logits = _unembed(cfg, params, xc).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return acc + (logz - gold).sum(), None

    if n_chunks == 1:
        total, _ = chunk_ce(jnp.float32(0.0), (x, labels))
    else:
        c = s // n_chunks
        xs = (x.reshape(b, n_chunks, c, -1).swapaxes(0, 1),
              labels.reshape(b, n_chunks, c).swapaxes(0, 1))
        total, _ = jax.lax.scan(jax.checkpoint(chunk_ce), jnp.float32(0.0), xs)
    ce = total / (b * s)
    loss = ce + MOE_AUX_COEF * aux
    return loss, dict(ce=ce, aux=aux)


# ---------------------------------------------------------------------------
# Serving: cache init, prefill, decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    dtype = model_dtype(cfg)
    hd, hkv = cfg.head_dim_, cfg.num_kv_heads

    def slot_cache(kind):
        if kind == "a":
            return dict(k=jnp.zeros((batch, hkv, max_seq, hd), dtype),
                        v=jnp.zeros((batch, hkv, max_seq, hd), dtype))
        if kind == "m":
            return init_mamba_state(cfg, batch, dtype)
        if kind == "M":
            return init_mlstm_state(cfg, batch)
        return init_slstm_state(cfg, batch)

    one_group = {f"s{j}": slot_cache(k) for j, k in enumerate(cfg.block_pattern)}
    groups = jax.tree_util.tree_map(
        lambda t: jnp.broadcast_to(t[None], (cfg.num_groups,) + t.shape),
        one_group)
    return dict(pos=jnp.int32(0), groups=groups)


def _apply_group_serve(gparams, gcache, x, cos, sin, pos, cfg: ModelConfig):
    new_cache = {}
    for j, kind in enumerate(cfg.block_pattern):
        slot = gparams[f"s{j}"]
        sc = gcache[f"s{j}"]
        if kind == "a":
            x, nc = _attn_apply(slot, x, cos, sin, cfg, cache=sc, pos=pos)
        elif kind == "m":
            h = rms_norm(x, slot["norm1"], cfg.norm_eps)
            if x.shape[1] == 1:
                y, nc = mamba_decode_step(slot["mamba"], sc, h, cfg)
            else:  # prefill: parallel path, returning the decode state
                y, nc = mamba_forward(slot["mamba"], h, cfg, return_state=True)
            x = x + y
        elif kind == "M":
            h = rms_norm(x, slot["norm1"], cfg.norm_eps)
            if x.shape[1] == 1:
                y, nc = mlstm_decode_step(slot["mlstm"], sc, h, cfg)
            else:
                y, nc = mlstm_forward(slot["mlstm"], h, cfg, return_state=True)
            x = x + y
        else:
            h = rms_norm(x, slot["norm1"], cfg.norm_eps)
            if x.shape[1] == 1:
                y, nc = slstm_decode_step(slot["slstm"], sc, h, cfg)
            else:
                y, nc = slstm_forward(slot["slstm"], h, cfg, return_state=True)
            x = x + y
        if kind in ("a", "m"):
            x, _ = _mlp_apply(slot, x, cfg, j)
        new_cache[f"s{j}"] = nc
    return x, new_cache


def prefill(cfg: ModelConfig, params, batch, max_seq: int):
    """Returns (last-token logits, cache). batch: tokens/embeds (B, S)."""
    x = _embed_inputs(cfg, params, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    cos, sin = _rope_tables(cfg, positions, batch)
    cache = init_cache(cfg, b, max_seq)

    def group_fn(x, xs):
        gparams, gcache = xs
        x, nc = _apply_group_serve(gparams, gcache, x, cos, sin,
                                   jnp.int32(0), cfg)
        return x, nc

    if cfg.remat == "layer":
        group_fn = jax.checkpoint(group_fn)
    x, new_groups = jax.lax.scan(group_fn, x, (params["groups"],
                                               cache["groups"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(cfg, params, x[:, -1:])[:, 0].astype(jnp.float32)
    return logits, dict(pos=jnp.int32(s), groups=new_groups)


def decode_step(cfg: ModelConfig, params, cache, tokens_or_embeds):
    """One decode step. tokens: (B, 1) int32 (or embeds (B, 1, D)).
    Returns (logits (B, V), new cache)."""
    batch = ({"tokens": tokens_or_embeds} if cfg.input_mode == "tokens"
             else {"embeds": tokens_or_embeds})
    x = _embed_inputs(cfg, params, batch)
    b = x.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    cos, sin = _rope_tables(cfg, positions, batch)

    def group_fn(x, xs):
        gparams, gcache = xs
        x, nc = _apply_group_serve(gparams, gcache, x, cos, sin, pos, cfg)
        return x, nc

    x, new_groups = jax.lax.scan(group_fn, x, (params["groups"],
                                               cache["groups"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(cfg, params, x)[:, 0].astype(jnp.float32)
    return logits, dict(pos=pos + 1, groups=new_groups)
