"""Mixture-of-experts FFN: dropless sort + grouped GEMM (ragged_dot), with
expert weights tensor-parallel over the model axis ("expert-TP").

Why this shape: a *global* sort-based dispatch under GSPMD all-gathers the
token buffer across data shards (measured: the dominant temp allocation at
compile). Wrapping the layer in ``shard_map`` keeps routing and the sorted
gather local to each data shard; expert FFN hidden dims are sharded over the
model axis, so the only cross-device traffic is the same single psum a dense
TP MLP needs. Routing is exactly dropless (no capacity, no token dropping).

A second implementation (``moe_impl='ep'``) does classic expert-parallel
all-to-all with fixed capacity inside shard_map — the layout used when
experts >> model-axis efficiency matters; it is the §Perf hillclimb
comparison point.

Aux losses: load-balance loss (Switch-style) returned alongside the output.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map
from repro.distributed.meshctx import get_mesh_context
from repro.models.config import ModelConfig

__all__ = ["init_moe_params", "moe_ffn"]


def init_moe_params(key, cfg: ModelConfig, dtype) -> Dict[str, jax.Array]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, f ** -0.5
    return dict(
        router=(jax.random.normal(k1, (d, e), jnp.float32) * 0.02),
        wg=(jax.random.normal(k2, (e, d, f), jnp.float32) * s_in).astype(dtype),
        wu=(jax.random.normal(k3, (e, d, f), jnp.float32) * s_in).astype(dtype),
        wd=(jax.random.normal(k4, (e, f, d), jnp.float32) * s_out).astype(dtype),
    )


def _local_moe(x, router, wg, wu, wd, *, k: int, num_experts: int,
               model_axis: str | None, capacity_factor: float = 1.25):
    """Per-shard MoE with capacity-buffer grouped GEMM ("expert-TP").

    x: (T, D) local tokens; wg/wu: (E, D, F_loc); wd: (E, F_loc, D). psum
    over the model axis combines the F slices.

    Tokens are scattered into an (E, cap, D) buffer (cap = cf·T·k/E) and the
    expert FFN runs as one grouped einsum per matrix. A ragged_dot
    formulation would be exactly dropless, but its XLA lowering expands to a
    dense (T·k, E, F) product — an E× memory/FLOP blow-up (measured 870
    GB/device on moonshot train_4k); the capacity buffer keeps grouped-GEMM
    shapes explicit at the cost of dropping overflow tokens beyond cf.
    """
    t = x.shape[0]
    logits = x.astype(jnp.float32) @ router
    gates = jax.nn.softmax(logits)
    topg, topi = jax.lax.top_k(gates, k)                      # (T, k)
    topg = (topg / topg.sum(-1, keepdims=True)).astype(x.dtype)

    eflat = topi.reshape(-1)                                  # (T*k,)
    slot_tok = jnp.arange(t * k, dtype=jnp.int32) // k
    cap = int(capacity_factor * t * k / num_experts) + 1
    onehot = jax.nn.one_hot(eflat, num_experts, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)[
        jnp.arange(t * k), eflat]                             # position in expert
    keep = pos < cap
    pos = jnp.minimum(pos, cap - 1)

    buf = jnp.zeros((num_experts, cap, x.shape[1]), x.dtype)
    buf = buf.at[eflat, pos].add(
        jnp.where(keep[:, None], jnp.take(x, slot_tok, axis=0), 0))
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)
                     .astype(jnp.float32)).astype(x.dtype)
         * jnp.einsum("ecd,edf->ecf", buf, wu))
    out = jnp.einsum("ecf,efd->ecd", h, wd)                   # (E, cap, D)
    ys = out[eflat, pos] * jnp.where(keep, topg.reshape(-1), 0)[:, None]
    y = jnp.zeros_like(x).at[slot_tok].add(ys)
    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)

    # Switch-style load-balance loss: E * Σ_e f_e · p_e  (local tokens).
    me = gates.mean(axis=0)                                   # mean router prob
    ce = jnp.zeros((num_experts,), jnp.float32).at[eflat].add(1.0) / (t * k)
    aux = num_experts * jnp.sum(me * ce)
    return y, aux


def _local_moe_ep(x, router, wg, wu, wd, *, k: int, num_experts: int,
                  model_axis: str, capacity_factor: float):
    """Expert-parallel variant: experts sharded over the model axis, tokens
    exchanged with a fixed-capacity all_to_all (classic GShard/DeepSeek EP).

    x: (T, D) local; wg/wu: (E_loc, D, F); wd: (E_loc, F, D).
    """
    t = x.shape[0]
    n_shards = jax.lax.psum(1, model_axis)
    e_loc = num_experts // n_shards
    cap = int(capacity_factor * t * k / num_experts) + 1      # per (tok-shard, expert)

    logits = x.astype(jnp.float32) @ router
    gates = jax.nn.softmax(logits)
    topg, topi = jax.lax.top_k(gates, k)
    topg = (topg / topg.sum(-1, keepdims=True)).astype(x.dtype)

    eflat = topi.reshape(-1)
    slot_tok = jnp.arange(t * k, dtype=jnp.int32) // k
    # position of each routed slot within its expert's capacity buffer
    onehot = jax.nn.one_hot(eflat, num_experts, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)[jnp.arange(t * k), eflat]
    keep = pos < cap
    # send buffer: (E, cap, D) then reshaped to (n_shards, E_loc, cap, D)
    buf = jnp.zeros((num_experts, cap, x.shape[1]), x.dtype)
    buf = buf.at[eflat, pos].add(jnp.where(keep[:, None], x[slot_tok], 0))
    buf = buf.reshape(n_shards, e_loc, cap, x.shape[1])
    recv = jax.lax.all_to_all(buf, model_axis, split_axis=0, concat_axis=0,
                              tiled=False)                    # (S, E_loc, cap, D)
    h = (jax.nn.silu(jnp.einsum("secd,edf->secf", recv, wg)
                     .astype(jnp.float32)).astype(x.dtype)
         * jnp.einsum("secd,edf->secf", recv, wu))
    out = jnp.einsum("secf,efd->secd", h, wd)
    back = jax.lax.all_to_all(out, model_axis, split_axis=0, concat_axis=0,
                              tiled=False).reshape(num_experts, cap, x.shape[1])
    ys = back[eflat, pos] * jnp.where(keep, topg.reshape(-1), 0)[:, None]
    y = jnp.zeros_like(x).at[slot_tok].add(ys)

    me = gates.mean(axis=0)
    ce = jnp.zeros((num_experts,), jnp.float32).at[eflat].add(1.0) / (t * k)
    aux = num_experts * jnp.sum(me * ce)
    return y, aux


def _dense_all_experts(x, router, wg, wu, wd, *, k: int, num_experts: int):
    """Tiny-token fallback (decode shapes): compute every expert densely and
    combine top-k — O(E) FLOPs per token but trivially GSPMD-shardable, and
    for ≤ a few hundred decode tokens the expert GEMMs are bandwidth-bound
    weight reads anyway (same bytes as EP would move)."""
    gates = jax.nn.softmax(x.astype(jnp.float32) @ router)
    topg, topi = jax.lax.top_k(gates, k)
    topg = topg / topg.sum(-1, keepdims=True)
    t = x.shape[0]
    h = (jax.nn.silu(jnp.einsum("td,edf->tef", x, wg).astype(jnp.float32))
         .astype(x.dtype) * jnp.einsum("td,edf->tef", x, wu))
    ye = jnp.einsum("tef,efd->ted", h, wd)                    # (T, E, D)
    w = jnp.zeros((t, num_experts), x.dtype)
    w = w.at[jnp.arange(t)[:, None], topi].set(topg.astype(x.dtype))
    y = jnp.einsum("ted,te->td", ye, w)
    me = gates.mean(axis=0)
    ce = jnp.zeros((num_experts,), jnp.float32).at[topi.reshape(-1)].add(
        1.0) / (t * k)
    aux = num_experts * jnp.sum(me * ce)
    return y, aux


def moe_ffn(params: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (y, aux_loss). Dispatches on mesh context + cfg.moe_impl."""
    b, s, d = x.shape
    ctx = get_mesh_context()
    k, e = cfg.experts_per_token, cfg.num_experts
    xf = x.reshape(b * s, d)

    n_data = 1
    if ctx.mesh is not None:
        for ax in ctx.data_axes:
            n_data *= ctx.mesh.shape[ax]

    if b * s < max(4 * n_data, 512):
        # decode / tiny batches: tokens can't tile the data axis
        y, aux = _dense_all_experts(xf, params["router"], params["wg"],
                                    params["wu"], params["wd"], k=k,
                                    num_experts=e)
        return y.reshape(b, s, d), aux

    if ctx.mesh is None:
        y, aux = _local_moe(xf, params["router"], params["wg"], params["wu"],
                            params["wd"], k=k, num_experts=e, model_axis=None)
        return y.reshape(b, s, d), aux

    batch_axes = ctx.data_axes

    def wrap(local_fn):
        def f(*args):
            y, aux = local_fn(*args)
            return y, jax.lax.pmean(aux, batch_axes)
        return f

    if cfg.moe_impl == "ep":
        in_specs = (P(batch_axes, None), P(), P(ctx.model_axis, None, None),
                    P(ctx.model_axis, None, None), P(ctx.model_axis, None, None))
        fn = wrap(lambda *a: _local_moe_ep(
            *a, k=k, num_experts=e, model_axis=ctx.model_axis,
            capacity_factor=cfg.capacity_factor))
    else:
        in_specs = (P(batch_axes, None), P(), P(None, None, ctx.model_axis),
                    P(None, None, ctx.model_axis), P(None, ctx.model_axis, None))
        fn = wrap(lambda *a: _local_moe(
            *a, k=k, num_experts=e, model_axis=ctx.model_axis))

    y, aux = shard_map(
        fn, mesh=ctx.mesh, in_specs=in_specs,
        out_specs=(P(batch_axes, None), P()), check_vma=False,
    )(xf, params["router"], params["wg"], params["wu"], params["wd"])
    return y.reshape(b, s, d), aux
