"""Core transformer layers in pure JAX: RMSNorm, RoPE / M-RoPE, GQA
attention (einsum path for short contexts, chunked online-softmax path for
long), SwiGLU MLP.

The chunked attention (`flash_attention_xla`) is the XLA twin of the Pallas
kernel in `repro.kernels.flash_attention`: a python loop over q chunks (the
per-chunk KV extent is then *static*, so causal FLOPs are exact, not
masked-away) with a lax.scan over kv chunks carrying online-softmax stats.
It lowers on any backend — the Pallas kernel replaces it on real TPU via
``attn_impl='pallas'``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "rope_cos_sin", "apply_rope", "mrope_cos_sin",
           "gqa_attention", "flash_attention_xla", "swiglu_mlp",
           "init_dense", "init_norm"]


def init_dense(key, shape, scale: Optional[float] = None, dtype=jnp.bfloat16):
    fan_in = shape[0]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def init_norm(shape, dtype=jnp.bfloat16):
    return jnp.ones(shape, dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float
                 ) -> Tuple[jax.Array, jax.Array]:
    """positions: (..., S) int → cos/sin (..., S, head_dim/2) f32."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions3: jax.Array, head_dim: int, theta: float,
                  sections: Tuple[int, int, int]
                  ) -> Tuple[jax.Array, jax.Array]:
    """Qwen2-VL multimodal RoPE. positions3: (3, B, S) — temporal/height/
    width position ids (the vision frontend stub supplies them; for text
    all three are equal and this reduces to standard RoPE).

    Each of the head_dim/2 rotary frequencies is driven by one of the three
    position streams according to `sections` (must sum to head_dim/2).
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    cos, sin = rope_cos_sin(positions3, head_dim, theta)  # (3, B, S, half)
    parts_c, parts_s = [], []
    off = 0
    for axis, sec in enumerate(sections):
        parts_c.append(cos[axis, ..., off:off + sec])
        parts_s.append(sin[axis, ..., off:off + sec])
        off += sec
    return jnp.concatenate(parts_c, -1), jnp.concatenate(parts_s, -1)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, H, S, D); cos/sin: (B, S, D/2) — rotate-half convention."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    c = cos[:, None].astype(jnp.float32)
    s = sin[:, None].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _plain_attention(q, k, v, causal: bool, kv_valid_len=None):
    """Einsum attention; fine for short sequences. q:(B,H,S,D) k/v:(B,H,T,D)."""
    b, h, s, d = q.shape
    t = k.shape[2]
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k,
                        preferred_element_type=jnp.float32) * (d ** -0.5)
    if causal and s > 1:
        mask = jnp.arange(s)[:, None] >= jnp.arange(t)[None, :] - (t - s)
        scores = jnp.where(mask[None, None], scores, -1e30)
    if kv_valid_len is not None:
        valid = jnp.arange(t)[None, None, None, :] < kv_valid_len
        scores = jnp.where(valid, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p.astype(v.dtype), v)


def flash_attention_xla(q, k, v, *, causal: bool, q_chunk: int = 1024,
                        kv_chunk: int = 1024, kv_valid_len=None):
    """Chunked online-softmax attention in pure XLA ops.

    Python loop over q chunks (static per-chunk kv extent → causal work is
    truly skipped, not masked) with a lax.scan over kv chunks carrying
    (m, l, acc) — memory O(q_chunk × kv_chunk) instead of O(S²).
    """
    b, h, s, d = q.shape
    t = k.shape[2]
    scale = d ** -0.5
    nq = -(-s // q_chunk)
    outs = []
    for qi in range(nq):
        q0 = qi * q_chunk
        qlen = min(q_chunk, s - q0)
        qc = jax.lax.dynamic_slice_in_dim(q, q0, qlen, axis=2)
        # causal: this q chunk sees keys < kv_end (static!)
        kv_end = min(t, (t - s) + q0 + qlen) if causal else t
        nkv = -(-kv_end // kv_chunk)
        kv_pad = nkv * kv_chunk
        kc = jnp.pad(k[:, :, :kv_end], ((0, 0), (0, 0), (0, kv_pad - kv_end), (0, 0)))
        vc = jnp.pad(v[:, :, :kv_end], ((0, 0), (0, 0), (0, kv_pad - kv_end), (0, 0)))
        kc = kc.reshape(b, h, nkv, kv_chunk, d).transpose(2, 0, 1, 3, 4)
        vc = vc.reshape(b, h, nkv, kv_chunk, d).transpose(2, 0, 1, 3, 4)

        def step(carry, blk):
            m, l, acc = carry
            kb, vb, ki = blk
            sc = jnp.einsum("bhsd,bhtd->bhst", qc, kb,
                            preferred_element_type=jnp.float32) * scale
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = kpos[None, None, None, :] < kv_end
            if kv_valid_len is not None:
                mask = mask & (kpos[None, None, None, :] < kv_valid_len)
            if causal:
                qpos = (t - s) + q0 + jnp.arange(qlen)
                mask = mask & (qpos[None, None, :, None] >= kpos[None, None, None, :])
            sc = jnp.where(mask, sc, -1e30)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(sc - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhst,bhtd->bhsd", p.astype(vb.dtype), vb).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, qlen), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, qlen), jnp.float32)
        a0 = jnp.zeros((b, h, qlen, d), jnp.float32)
        # checkpoint each kv step: without this the scan stacks the
        # (q_chunk × kv_chunk) probability blocks for backward — O(S²) memory,
        # exactly what flash attention exists to avoid.
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(step), (m0, l0, a0), (kc, vc, jnp.arange(nkv)))
        outs.append((acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype))
    return jnp.concatenate(outs, axis=2)


def sharded_decode_attention(q, ck, cv, k_new, v_new, pos, *, mesh,
                             seq_axes, rep: int):
    """Flash-decode over a sequence-sharded KV cache, plus the owner-local
    cache append — all inside one shard_map, so the cache is NEVER gathered.

    q: (B, Hq, 1, hd); ck/cv: (B, Hkv, S, hd) with S sharded over
    `seq_axes`; k_new/v_new: (B, Hkv, 1, hd) replicated; pos: scalar.
    Each shard computes masked partial (max, sum, weighted-V) statistics for
    its cache slice; a pmax/psum pair combines them (wire: O(B·Hq·hd), vs
    gathering the multi-GB cache). The shard owning index `pos` writes the
    new k/v in place.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.compat import shard_map

    axes = seq_axes if isinstance(seq_axes, tuple) else (seq_axes,)

    def local(q, ck, cv, kn, vn, pos):
        b, hkv, s_loc, hd = ck.shape
        shard = jnp.int32(0)  # row-major over the (possibly tuple) axes
        for ax in axes:
            shard = shard * mesh.shape[ax] + jax.lax.axis_index(ax)
        start = shard * s_loc
        # owner-local append
        lpos = pos - start
        owner = (lpos >= 0) & (lpos < s_loc)
        lpos_c = jnp.clip(lpos, 0, s_loc - 1)
        ck_up = jax.lax.dynamic_update_slice_in_dim(
            ck, kn.astype(ck.dtype), lpos_c, axis=2)
        cv_up = jax.lax.dynamic_update_slice_in_dim(
            cv, vn.astype(cv.dtype), lpos_c, axis=2)
        ck = jnp.where(owner, ck_up, ck)
        cv = jnp.where(owner, cv_up, cv)
        # local masked flash-decode partials
        hq = q.shape[1]
        qg = q.reshape(b, hkv, rep, hd).astype(jnp.float32)
        scores = jnp.einsum("bhrd,bhsd->bhrs", qg,
                            ck.astype(jnp.float32)) * (hd ** -0.5)
        kpos = start + jnp.arange(s_loc)
        valid = kpos[None, None, None, :] <= pos
        scores = jnp.where(valid, scores, -1e30)
        m = scores.max(axis=-1)                          # (b,hkv,rep)
        p = jnp.exp(scores - m[..., None])
        p = jnp.where(valid, p, 0.0)
        l = p.sum(axis=-1)
        o = jnp.einsum("bhrs,bhsd->bhrd", p, cv.astype(jnp.float32))
        # combine across shards (tiny wire)
        m_g = jax.lax.pmax(m, axes)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, axes)
        o_g = jax.lax.psum(o * corr[..., None], axes)
        out = (o_g / jnp.maximum(l_g, 1e-30)[..., None])
        return out.reshape(b, hq, 1, hd).astype(q.dtype), ck, cv

    cache_spec = P(None, None, seq_axes, None)
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(), cache_spec, cache_spec, P(), P(), P()),
        out_specs=(P(), cache_spec, cache_spec), check_vma=False,
    )(q, ck, cv, k_new, v_new, pos)


def gqa_attention(q, k, v, *, causal: bool, q_chunk: int, kv_chunk: int,
                  kv_valid_len=None, impl: str = "auto"):
    """Grouped-query attention dispatcher. q: (B, Hq, S, D), k/v: (B, Hkv, T, D).

    KV heads are broadcast to Q head groups without materializing the repeat
    (einsum over the group axis).
    """
    b, hq, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    rep = hq // hkv
    if rep > 1:
        q = q.reshape(b, hkv, rep, s, d).reshape(b * hkv, rep, s, d)
        k = k.reshape(b * hkv, 1, t, d)
        v = v.reshape(b * hkv, 1, t, d)
        k = jnp.broadcast_to(k, (b * hkv, rep, t, d))
        v = jnp.broadcast_to(v, (b * hkv, rep, t, d))
    use_chunked = (impl == "chunked") or (impl == "auto" and max(s, t) > 2048)
    fn = (functools.partial(flash_attention_xla, q_chunk=q_chunk,
                            kv_chunk=kv_chunk)
          if use_chunked else _plain_attention)
    out = fn(q, k, v, causal=causal, kv_valid_len=kv_valid_len)
    if rep > 1:
        out = out.reshape(b, hkv, rep, s, d).reshape(b, hq, s, d)
    return out


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def swiglu_mlp(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array
               ) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, wg)
    u = jnp.einsum("...d,df->...f", x, wu)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g.astype(jnp.float32)
                                                   ).astype(x.dtype) * u, wd)
