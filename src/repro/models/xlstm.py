"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, recurrent), for the xlstm-125m architecture.

mLSTM here is the stabilized sigmoid-gated variant: per head
    C_t = f_t·C_{t-1} + i_t·k_t v_tᵀ,   n_t = f_t·n_{t-1} + i_t·k_t,
    h_t = (C_tᵀ q_t) / max(|n_tᵀ q_t|, 1)
computed chunkwise: intra-chunk decay matrix D_ij = exp(F_i − F_j)·i_j with
F = cumsum(log f) (log-space, stable), inter-chunk via a scanned (C, n)
state — the same two-level structure as the paper's parallel form. Decode is
the O(1) recurrence.

sLSTM keeps the paper's exponential gating with the m-stabilizer state and a
diagonal recurrence (simplification of the block-diagonal recurrent matrix;
noted in DESIGN.md), evaluated with lax.scan over time.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import init_dense, rms_norm

__all__ = ["init_mlstm_params", "mlstm_forward", "mlstm_decode_step",
           "init_mlstm_state", "init_slstm_params", "slstm_forward",
           "slstm_decode_step", "init_slstm_state"]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _xl_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    di = int(cfg.xlstm_proj_factor * cfg.d_model)
    h = cfg.num_heads
    di -= di % h
    return di, h, di // h


def init_mlstm_params(key, cfg: ModelConfig, dtype) -> Dict[str, jax.Array]:
    d = cfg.d_model
    di, h, dh = _xl_dims(cfg)
    ks = jax.random.split(key, 8)
    return dict(
        up_proj=init_dense(ks[0], (d, 2 * di), dtype=dtype),
        conv_w=(jax.random.normal(ks[1], (4, di), jnp.float32) * 0.2).astype(dtype),
        q_proj=init_dense(ks[2], (di, di), dtype=dtype),
        k_proj=init_dense(ks[3], (di, di), dtype=dtype),
        v_proj=init_dense(ks[4], (di, di), dtype=dtype),
        i_gate=init_dense(ks[5], (di, h), dtype=jnp.float32),
        f_gate=init_dense(ks[6], (di, h), dtype=jnp.float32),
        f_bias=jnp.full((h,), 3.0, jnp.float32),  # start remembering
        gn_scale=jnp.ones((di,), dtype),
        out_proj=init_dense(ks[7], (di, d), dtype=dtype),
    )


def _mlstm_qkvif(params, x, cfg):
    from repro.models.ssm import _causal_conv
    di, h, dh = _xl_dims(cfg)
    b, s, _ = x.shape
    up = jnp.einsum("bsd,de->bse", x, params["up_proj"])
    xm, z = jnp.split(up, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xm, params["conv_w"],
                                  jnp.zeros((di,), x.dtype)
                                  ).astype(jnp.float32)).astype(x.dtype)
    q = jnp.einsum("bsd,de->bse", xc, params["q_proj"]).reshape(b, s, h, dh)
    k = (jnp.einsum("bsd,de->bse", xc, params["k_proj"]).reshape(b, s, h, dh)
         * (dh ** -0.5))
    v = jnp.einsum("bsd,de->bse", xm, params["v_proj"]).reshape(b, s, h, dh)
    xcf = xc.astype(jnp.float32)
    i = jax.nn.sigmoid(xcf @ params["i_gate"])                 # (B,S,H)
    logf = jax.nn.log_sigmoid(xcf @ params["f_gate"] + params["f_bias"])
    return q, k, v, i, logf, z


def mlstm_forward(params, x, cfg: ModelConfig, chunk: int = 256,
                  return_state: bool = False):
    b, s, d = x.shape
    di, h, dh = _xl_dims(cfg)
    q, k, v, i, logf, z = _mlstm_qkvif(params, x, cfg)
    xm_raw = jnp.split(jnp.einsum("bsd,de->bse", x, params["up_proj"]),
                       2, axis=-1)[0] if return_state else None

    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        zeros = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))  # noqa: E731
        q, k, v = zeros(q), zeros(k), zeros(v)
        i, logf = zeros(i), zeros(logf)
    sp = s + pad
    nch = sp // c
    resh = lambda t: t.reshape(b, nch, c, *t.shape[2:]).swapaxes(0, 1)  # noqa: E731
    qs, ks, vs, is_, lfs = map(resh, (q, k, v, i, logf))

    def step(carry, inp):
        C, n = carry                                            # (B,H,dh,dh),(B,H,dh)
        qc, kc, vc, ic, lfc = inp
        qf = qc.astype(jnp.float32)
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        F = jnp.cumsum(lfc, axis=1)                             # (B,c,H)
        # intra-chunk: D_ij = exp(F_i - F_j) i_j for j<=i (log-stable)
        dmat = jnp.where(
            (jnp.arange(c)[:, None] >= jnp.arange(c)[None, :])[None, :, :, None],
            jnp.exp(F[:, :, None, :] - F[:, None, :, :]), 0.0)  # (B,c,c,H)
        att = jnp.einsum("bihe,bjhe->bijh", qf, kf) * dmat * ic[:, None]
        h_intra = jnp.einsum("bijh,bjhe->bihe", att, vf)
        # normalizer: ñ_i = Σ_j D_ij i_j k_j  then ñ·q
        nk = jnp.einsum("bijh,bjhe->bihe",
                        dmat * ic[:, None], kf)                 # (B,c,H,dh)
        # inter-chunk: state contribution scaled by exp(F_i)
        ef = jnp.exp(F)                                         # (B,c,H)
        h_inter = jnp.einsum("bihe,bhef->bihf", qf * ef[..., None], C)
        num = h_intra + h_inter
        den_q = jnp.einsum("bihe,bihe->bih", qf, nk) + jnp.einsum(
            "bihe,bhe->bih", qf * ef[..., None], n)
        out = num / jnp.maximum(jnp.abs(den_q), 1.0)[..., None]
        # update state to end of chunk
        f_end = jnp.exp(F[:, -1])                               # (B,H)
        decay_j = jnp.exp(F[:, -1][:, None] - F) * ic           # (B,c,H)
        C_new = C * f_end[..., None, None] + jnp.einsum(
            "bjh,bjhe,bjhf->bhef", decay_j, kf, vf)
        n_new = n * f_end[..., None] + jnp.einsum("bjh,bjhe->bhe", decay_j, kf)
        return (C_new, n_new), out

    C0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    (C_end, n_end), outs = jax.lax.scan(step, (C0, n0), (qs, ks, vs, is_, lfs))
    out = outs.swapaxes(0, 1).reshape(b, sp, h, dh)[:, :s]
    # per-head group norm
    mean = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = (out - mean) * jax.lax.rsqrt(var + 1e-5)
    out = out.reshape(b, s, di).astype(x.dtype) * params["gn_scale"]
    out = out * jax.nn.silu(z[:, :s].astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bsd,de->bse", out, params["out_proj"])
    if return_state:
        win = jnp.pad(xm_raw.astype(jnp.float32),
                      ((0, 0), (max(3 - s, 0), 0), (0, 0)))[:, -3:]
        return y, dict(C=C_end, n=n_end, conv=win)
    return y


def init_mlstm_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    di, h, dh = _xl_dims(cfg)
    return dict(C=jnp.zeros((batch, h, dh, dh), jnp.float32),
                n=jnp.zeros((batch, h, dh), jnp.float32),
                conv=jnp.zeros((batch, 3, di), jnp.float32))


def mlstm_decode_step(params, state, x, cfg: ModelConfig):
    """x: (B, 1, D); O(1) recurrent update."""
    b = x.shape[0]
    di, h, dh = _xl_dims(cfg)
    up = jnp.einsum("bsd,de->bse", x, params["up_proj"])
    xm, z = jnp.split(up, 2, axis=-1)                           # (B,1,di)
    window = jnp.concatenate([state["conv"], xm.astype(jnp.float32)], axis=1)
    conv = (window * params["conv_w"][None].astype(jnp.float32)).sum(axis=1)
    xc = jax.nn.silu(conv).astype(x.dtype)                      # (B,di)
    q = (xc @ params["q_proj"]).reshape(b, h, dh).astype(jnp.float32)
    k = ((xc @ params["k_proj"]).reshape(b, h, dh) * (dh ** -0.5)
         ).astype(jnp.float32)
    v = (xm[:, 0] @ params["v_proj"]).reshape(b, h, dh).astype(jnp.float32)
    xcf = xc.astype(jnp.float32)
    i = jax.nn.sigmoid(xcf @ params["i_gate"])                  # (B,H)
    f = jax.nn.sigmoid(xcf @ params["f_gate"] + params["f_bias"])
    C = state["C"] * f[..., None, None] + i[..., None, None] * (
        k[..., :, None] * v[..., None, :])                      # (B,H,dh,dh)
    n = state["n"] * f[..., None] + i[..., None] * k
    num = jnp.einsum("bhe,bhef->bhf", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhe,bhe->bh", q, n)), 1.0)
    out = num / den[..., None]
    mean = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = ((out - mean) * jax.lax.rsqrt(var + 1e-5)).reshape(b, di)
    out = out.astype(x.dtype) * params["gn_scale"]
    out = out * jax.nn.silu(z[:, 0].astype(jnp.float32)).astype(x.dtype)
    y = (out @ params["out_proj"])[:, None]
    return y, dict(C=C, n=n, conv=window[:, 1:])


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm_params(key, cfg: ModelConfig, dtype) -> Dict[str, jax.Array]:
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    dup = int(4 * d / 3 / 2) * 2  # post-up MLP width (pf 4/3)
    return dict(
        w_izfo=init_dense(ks[0], (d, 4 * d), dtype=dtype),
        r_izfo=(jax.random.normal(ks[1], (4, d), jnp.float32) * 0.1),
        b_izfo=jnp.zeros((4, d), jnp.float32),
        up_w=init_dense(ks[2], (d, 2 * dup), dtype=dtype),
        down_w=init_dense(ks[3], (dup, d), dtype=dtype),
        norm2=jnp.ones((d,), dtype),
    )


def _slstm_cell(params, xw, state):
    """One timestep. xw: (B, 4, d) pre-activations from the input proj."""
    c, n, hprev, m = state
    r = params["r_izfo"]
    b = params["b_izfo"]
    zi = xw[:, 0] + r[0] * hprev + b[0]
    zz = xw[:, 1] + r[1] * hprev + b[1]
    zf = xw[:, 2] + r[2] * hprev + b[2]
    zo = xw[:, 3] + r[3] * hprev + b[3]
    log_i = zi
    log_f = jax.nn.log_sigmoid(zf)
    m_new = jnp.maximum(log_f + m, log_i)
    i = jnp.exp(log_i - m_new)
    f = jnp.exp(log_f + m - m_new)
    z = jnp.tanh(zz)
    o = jax.nn.sigmoid(zo)
    c_new = f * c + i * z
    n_new = f * n + i
    h = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h, m_new), h


def slstm_forward(params, x, cfg: ModelConfig, return_state: bool = False):
    b, s, d = x.shape
    xw = jnp.einsum("bsd,de->bse", x, params["w_izfo"]).astype(jnp.float32)
    xw = xw.reshape(b, s, 4, d)

    def step(state, xt):
        return _slstm_cell(params, xt, state)

    z0 = jnp.zeros((b, d), jnp.float32)
    state0 = (z0, z0, z0, jnp.full((b, d), -1e30, jnp.float32))
    (ce, ne, he, me), hs = jax.lax.scan(step, state0, xw.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(x.dtype)                       # (B,S,d)
    # post-up gated MLP (pf 4/3)
    h = rms_norm(h, params["norm2"], 1e-5)
    up = jnp.einsum("bsd,de->bse", h, params["up_w"])
    u, g = jnp.split(up, 2, axis=-1)
    y = jnp.einsum("bsf,fd->bsd",
                   u * jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype),
                   params["down_w"])
    if return_state:
        return y, dict(c=ce, n=ne, h=he, m=me)
    return y


def init_slstm_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return dict(c=z, n=z, h=z, m=jnp.full((batch, d), -1e30, jnp.float32))


def slstm_decode_step(params, state, x, cfg: ModelConfig):
    b = x.shape[0]
    d = cfg.d_model
    xw = (x[:, 0] @ params["w_izfo"]).astype(jnp.float32).reshape(b, 4, d)
    st = (state["c"], state["n"], state["h"], state["m"])
    (c, n, h, m), hout = _slstm_cell(params, xw, st)
    hcast = hout[:, None].astype(x.dtype)
    hn = rms_norm(hcast, params["norm2"], 1e-5)
    up = jnp.einsum("bsd,de->bse", hn, params["up_w"])
    u, g = jnp.split(up, 2, axis=-1)
    y = jnp.einsum("bsf,fd->bsd",
                   u * jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype),
                   params["down_w"])
    return y, dict(c=c, n=n, h=h, m=m)
