"""Mamba selective-SSM block (Jamba's 'm' mixer).

Train/prefill path: chunkwise parallel scan — within a chunk the recurrence
h_t = Ābar_t·h_{t-1} + B̄x_t is evaluated with an associative scan (stable:
log Ābar = Δ·A ≤ 0, no divisions), chunks are chained with a lax.scan carrying
the (B, d_inner, N) state. This bounds the materialized state history to one
chunk (the memory trick the CUDA kernel implements on GPU; on TPU the chunked
associative scan is the natural equivalent).

Decode path: single-step recurrence + rolling conv window, O(1) per token —
what makes jamba's long_500k shape linear.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import init_dense

__all__ = ["init_mamba_params", "mamba_forward", "mamba_decode_step",
           "init_mamba_state"]


def init_mamba_params(key, cfg: ModelConfig, dtype) -> Dict[str, jax.Array]:
    d, di, n, r, dc = (cfg.d_model, cfg.d_inner, cfg.ssm_state_dim,
                       cfg.dt_rank, cfg.ssm_conv_dim)
    ks = jax.random.split(key, 6)
    a_init = jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                                      (di, n)))
    return dict(
        in_proj=init_dense(ks[0], (d, 2 * di), dtype=dtype),
        conv_w=(jax.random.normal(ks[1], (dc, di), jnp.float32) * 0.2
                ).astype(dtype),
        conv_b=jnp.zeros((di,), dtype),
        x_proj=init_dense(ks[2], (di, r + 2 * n), dtype=dtype),
        dt_proj=init_dense(ks[3], (r, di), scale=r ** -0.5, dtype=dtype),
        dt_bias=jnp.log(jnp.expm1(jnp.full((di,), 0.01, jnp.float32))
                        ).astype(jnp.float32),
        a_log=a_init,                     # (di, N) fp32
        d_skip=jnp.ones((di,), jnp.float32),
        out_proj=init_dense(ks[4], (di, d), dtype=dtype),
    )


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. x: (B, S, di); w: (dc, di)."""
    dc = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    s = x.shape[1]
    for j in range(dc):
        out = out + pad[:, j : j + s].astype(jnp.float32) * w[j].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _ssm_chunk(h0, dt, b_in, c_in, xc, a):
    """One chunk of the selective scan.

    h0: (B, di, N) carry; dt: (B, c, di); b_in/c_in: (B, c, N); xc: (B, c, di);
    a: (di, N). Returns (y (B, c, di), h_end).
    """
    log_abar = dt[..., None] * a[None, None]                   # (B,c,di,N) ≤ 0
    bx = (dt * xc)[..., None] * b_in[:, :, None, :]            # (B,c,di,N)

    def combine(e1, e2):
        l1, s1 = e1
        l2, s2 = e2
        return l1 + l2, s1 * jnp.exp(l2) + s2

    logs, acc = jax.lax.associative_scan(combine, (log_abar, bx), axis=1)
    h = acc + jnp.exp(logs) * h0[:, None]                      # (B,c,di,N)
    y = jnp.einsum("bcdn,bcn->bcd", h, c_in)
    return y, h[:, -1]


def mamba_forward(params: Dict[str, jax.Array], x: jax.Array,
                  cfg: ModelConfig, chunk: int = 256,
                  return_state: bool = False):
    """x: (B, S, D) → (B, S, D) [, decode state at the final position]."""
    b, s, d = x.shape
    di, n, r = cfg.d_inner, cfg.ssm_state_dim, cfg.dt_rank
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(x_in, params["conv_w"], params["conv_b"]
                                  ).astype(jnp.float32)).astype(x.dtype)
    proj = jnp.einsum("bsd,de->bse", xc, params["x_proj"]).astype(jnp.float32)
    dt_r, b_in, c_in = proj[..., :r], proj[..., r:r + n], proj[..., r + n:]
    dt = jax.nn.softplus(dt_r @ params["dt_proj"].astype(jnp.float32)
                         + params["dt_bias"])                  # (B,S,di) f32
    a = -jnp.exp(params["a_log"])                              # (di, N)

    c = min(chunk, s)
    if s % c:  # pad time to a chunk multiple
        pad = c - s % c
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
        xcp = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
    else:
        xcp = xc
    nch = xcp.shape[1] // c
    resh = lambda t: t.reshape(b, nch, c, *t.shape[2:]).swapaxes(0, 1)  # noqa: E731

    def step(h, inputs):
        dt_c, b_c, c_c, x_c = inputs
        y, h_new = _ssm_chunk(h, dt_c, b_c, c_c, x_c.astype(jnp.float32), a)
        return h_new, y

    h0 = jnp.zeros((b, di, n), jnp.float32)
    h_end, ys = jax.lax.scan(step, h0,
                             (resh(dt), resh(b_in), resh(c_in), resh(xcp)))
    y = ys.swapaxes(0, 1).reshape(b, nch * c, di)[:, :s]
    y = y + xc.astype(jnp.float32) * params["d_skip"]
    out = (y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype))
    out = jnp.einsum("bsd,de->bse", out, params["out_proj"])
    if return_state:
        # NOTE: h_end includes padded (dt=0 → Ābar=1, B̄x=0) steps: identity
        # updates, so the state at s is exact.
        dc = cfg.ssm_conv_dim
        conv_win = jnp.pad(x_in, ((0, 0), (max(dc - 1 - s, 0), 0), (0, 0))
                           )[:, -(dc - 1):]
        return out, dict(conv=conv_win.astype(x.dtype), ssm=h_end)
    return out


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    return dict(
        conv=jnp.zeros((batch, cfg.ssm_conv_dim - 1, cfg.d_inner), dtype),
        ssm=jnp.zeros((batch, cfg.d_inner, cfg.ssm_state_dim), jnp.float32),
    )


def mamba_decode_step(params, state, x, cfg: ModelConfig):
    """x: (B, 1, D) → (y (B, 1, D), new state). O(1) in context length."""
    b = x.shape[0]
    di, n, r, dc = cfg.d_inner, cfg.ssm_state_dim, cfg.dt_rank, cfg.ssm_conv_dim
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)                        # (B,1,di)
    window = jnp.concatenate([state["conv"], x_in], axis=1)    # (B,dc,di)
    conv = (window.astype(jnp.float32) * params["conv_w"][None].astype(jnp.float32)
            ).sum(axis=1) + params["conv_b"].astype(jnp.float32)
    xc = jax.nn.silu(conv).astype(x.dtype)                     # (B,di)
    proj = (xc @ params["x_proj"]).astype(jnp.float32)
    dt_r, b_in, c_in = proj[:, :r], proj[:, r:r + n], proj[:, r + n:]
    dt = jax.nn.softplus(dt_r @ params["dt_proj"].astype(jnp.float32)
                         + params["dt_bias"])                  # (B,di)
    a = -jnp.exp(params["a_log"])
    abar = jnp.exp(dt[..., None] * a[None])                    # (B,di,N)
    h = state["ssm"] * abar + (dt * xc.astype(jnp.float32))[..., None] \
        * b_in[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, c_in) + xc.astype(jnp.float32) * params["d_skip"]
    out = (y.astype(x.dtype) * jax.nn.silu(z[:, 0].astype(jnp.float32)
                                           ).astype(x.dtype))
    y_out = (out @ params["out_proj"])[:, None]
    return y_out, dict(conv=window[:, 1:], ssm=h)
