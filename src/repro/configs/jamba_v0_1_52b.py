"""jamba-v0.1-52b — Mamba+attention 1:7 interleave with MoE every other
layer (16 experts top-2) [arXiv:2403.19887].

Per Jamba block of 8 layers: attention at index 4, Mamba elsewhere; MoE MLP
on odd layer indices (16 of 32 layers), dense MLP on the rest."""
from repro.models.config import ModelConfig

_PATTERN = ("m", "m", "m", "m", "a", "m", "m", "m")


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=65536,
        block_pattern=_PATTERN,
        num_experts=16, experts_per_token=2, moe_period=2,
        ssm_state_dim=16, ssm_conv_dim=4, ssm_expand=2,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", family="hybrid",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=512,
        block_pattern=("m", "a", "m", "m"),
        num_experts=4, experts_per_token=2, moe_period=2,
        ssm_state_dim=8, ssm_conv_dim=4, ssm_expand=2,
    )
