"""xlstm-125m — sLSTM + mLSTM blocks, ratio ~7:1 mLSTM:sLSTM
[arXiv:2405.04517]. d_ff=0: xLSTM blocks carry their own projections."""
from repro.models.config import ModelConfig

# 12 layers, sLSTM at positions 5 and 11 (period-6 pattern, 2/12 sLSTM).
_PATTERN = ("M", "M", "M", "M", "M", "s")


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304,
        block_pattern=_PATTERN, xlstm_proj_factor=2.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
        d_ff=0, vocab_size=512,
        block_pattern=("M", "s"), xlstm_proj_factor=2.0,
    )
