"""qwen3-1.7b — qk_norm + GQA, tied embeddings [hf:Qwen/Qwen3-1.7B family]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b", family="dense",
        num_layers=28, d_model=2048, num_heads=16, num_kv_heads=8,
        head_dim=128, d_ff=6144, vocab_size=151936,
        qk_norm=True, rope_theta=1e6, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        qk_norm=True, tie_embeddings=True,
    )
