"""qwen2-vl-2b — M-RoPE + dynamic resolution [arXiv:2409.12191].

The vision frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed patch embeddings (B, S, d_model) plus the (3, B, S) M-RoPE
position ids the ViT+merger would produce."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm",
        num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
        head_dim=128, d_ff=8960, vocab_size=151936,
        mrope=True, mrope_sections=(16, 24, 24), rope_theta=1e6,
        input_mode="embeddings",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2vl-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        mrope=True, mrope_sections=(2, 3, 3), input_mode="embeddings",
    )
