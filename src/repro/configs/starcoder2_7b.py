"""starcoder2-7b — GQA (kv=4) + RoPE [arXiv:2402.19173]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b", family="dense",
        num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4,
        d_ff=18432, vocab_size=49152, mlp_gated=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke", family="dense",
        num_layers=2, d_model=72, num_heads=6, num_kv_heads=2,
        d_ff=160, vocab_size=512, mlp_gated=False,
    )
