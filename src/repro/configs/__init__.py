"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full (paper-exact) config;
``get_smoke_config(name)`` returns a reduced same-family config for CPU
smoke tests (small dims, few layers/experts, tiny vocab).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_MODULES: Dict[str, str] = {
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b_a6_6b",
    "xlstm-125m": "xlstm_125m",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "starcoder2-7b": "starcoder2_7b",
    "qwen3-1.7b": "qwen3_1_7b",
    "llama3.2-1b": "llama32_1b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "musicgen-large": "musicgen_large",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}

ARCH_NAMES: List[str] = list(ARCH_MODULES)


def _module(name: str):
    if name not in ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{ARCH_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).config()


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke()
