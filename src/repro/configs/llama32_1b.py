"""llama3.2-1b — small llama3 (GQA kv=8, theta=5e5, tied embeddings)
[hf:meta-llama/Llama-3.2-1B]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b", family="dense",
        num_layers=16, d_model=2048, num_heads=32, num_kv_heads=8,
        d_ff=8192, vocab_size=128256,
        rope_theta=5e5, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama32-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, tie_embeddings=True,
    )
