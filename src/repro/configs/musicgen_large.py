"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284].

The EnCodec frontend is a STUB: ``input_specs`` supplies precomputed frame
embeddings (the sum of the 4 codebook embeddings); the backbone predicts the
next frame's codes over the 2048-entry codebook vocabulary."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=2048, mlp_gated=False,
        input_mode="embeddings",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=128, mlp_gated=False, input_mode="embeddings",
    )
