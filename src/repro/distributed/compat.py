"""Version-compatible JAX API shims.

``jax.shard_map`` graduated from ``jax.experimental.shard_map`` (and the
``check_rep`` kwarg was renamed ``check_vma``) in newer JAX releases. Every
shard_map call site in this repo goes through :func:`shard_map` below so the
code runs unchanged on either side of the migration.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` where available, else the experimental one.

    The experimental version calls the replication-check kwarg ``check_rep``;
    the graduated version calls it ``check_vma``. Semantics are identical for
    our call sites (we only ever disable it).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
