"""int8 gradient all-reduce with error feedback (DP-axis compression).

Classic 1-bit-Adam-style trick generalized to int8: each data shard adds its
residual from the previous step to the fresh gradient, quantizes per-leaf to
int8 with a shared power-of-two-free scale, all-reduces the *quantized*
values (8× less ICI traffic on the DP axis), and keeps the quantization
error as the next step's residual — unbiased over time, 1/8 the collective
bytes. Used by the trainer when ``plan.grad_compression`` is set (pure-DP
axes; TP gradients are never compressed).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_error_state", "compressed_psum"]


def init_error_state(grads: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(grads: Any, err: Any, axis_names) -> Tuple[Any, Any]:
    """Inside shard_map over the DP axis: returns (mean gradient, new error
    residual). int8 payload is summed in int32 (≤ 2^24 shards safe)."""
    n = jax.lax.psum(1, axis_names)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        # shared scale across shards (one scalar pmax) → exact dequant grid
        scale = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_names) / 127.0 + 1e-30
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis_names)
        deq = total.astype(jnp.float32) * scale / n
        new_err = g32 - q.astype(jnp.float32) * scale
        return deq, new_err

    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree_util.tree_unflatten(tree, [o[0] for o in outs])
    new_err = jax.tree_util.tree_unflatten(tree, [o[1] for o in outs])
    return mean, new_err
