"""Mesh context threaded through model code.

Model forward functions are mesh-agnostic except for the MoE layer, whose
dropless sort+ragged_dot dispatch must stay *local* to each data shard
(a global argsort under GSPMD all-gathers the token buffer). The launcher
sets the active context; when no mesh is set (unit tests, single CPU), the
MoE layer runs its local path directly with unsharded weights.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax

__all__ = ["MeshContext", "set_mesh_context", "get_mesh_context",
           "mesh_context"]


@dataclasses.dataclass
class MeshContext:
    mesh: Optional[jax.sharding.Mesh]
    data_axes: Tuple[str, ...] = ("data",)   # ('pod', 'data') multi-pod
    model_axis: str = "model"
    # When attention is DP-only (heads don't tile the model axis), the
    # attention block reshards its activations over data+model so the model
    # axis isn't idle — see transformer._attn_apply.
    attn_dp_axes: Optional[Tuple[str, ...]] = None
    # Shard remat residuals' sequence dim over the model axis (see
    # ExecutionPlan.shard_activation_ckpt).
    shard_activation_ckpt: bool = False
    # Decode with a sequence-sharded KV cache through the shard_map
    # flash-decode path (layers.sharded_decode_attention): axes the cache's
    # seq dim is sharded over, or None for the plain GSPMD path.
    decode_seq_axes: Optional[Tuple[str, ...]] = None

    @property
    def batch_spec_axes(self):
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]


_CURRENT = MeshContext(mesh=None)


def set_mesh_context(ctx: MeshContext) -> None:
    global _CURRENT
    _CURRENT = ctx


def get_mesh_context() -> MeshContext:
    return _CURRENT


class mesh_context:
    """with mesh_context(MeshContext(mesh, ...)): ..."""

    def __init__(self, ctx: MeshContext):
        self.ctx = ctx

    def __enter__(self):
        self.prev = get_mesh_context()
        set_mesh_context(self.ctx)
        return self.ctx

    def __exit__(self, *exc):
        set_mesh_context(self.prev)
        return False
