"""Mesh contexts threaded through model and serving code.

Two independent contexts live here:

* :class:`MeshContext` — the *training* mesh (data/model axes) threaded
  through model code. Model forward functions are mesh-agnostic except for
  the MoE layer, whose dropless sort+ragged_dot dispatch must stay *local*
  to each data shard (a global argsort under GSPMD all-gathers the token
  buffer). The launcher sets the active context; when no mesh is set (unit
  tests, single CPU), the MoE layer runs its local path directly with
  unsharded weights.
* :class:`ServingMesh` — the *selection-serving* mesh: a 1-D device mesh
  over the request-batch axis. The padded-CSR featurizer
  (`repro.core.features.extract_features_batch_jnp`) and the selector's
  device inference shard_map over it, so featurize→infer scales out with
  hardware. There is no unsharded code path: when nothing is configured,
  the serving plane runs on the *degenerate 1-device mesh* (same trace
  structure, one shard).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np

__all__ = ["MeshContext", "set_mesh_context", "get_mesh_context",
           "mesh_context", "ServingMesh", "make_serving_mesh",
           "set_serving_mesh", "get_serving_mesh", "serving_mesh",
           "record_shard_utilization"]


@dataclasses.dataclass
class MeshContext:
    mesh: Optional[jax.sharding.Mesh]
    data_axes: Tuple[str, ...] = ("data",)   # ('pod', 'data') multi-pod
    model_axis: str = "model"
    # When attention is DP-only (heads don't tile the model axis), the
    # attention block reshards its activations over data+model so the model
    # axis isn't idle — see transformer._attn_apply.
    attn_dp_axes: Optional[Tuple[str, ...]] = None
    # Shard remat residuals' sequence dim over the model axis (see
    # ExecutionPlan.shard_activation_ckpt).
    shard_activation_ckpt: bool = False
    # Decode with a sequence-sharded KV cache through the shard_map
    # flash-decode path (layers.sharded_decode_attention): axes the cache's
    # seq dim is sharded over, or None for the plain GSPMD path.
    decode_seq_axes: Optional[Tuple[str, ...]] = None

    @property
    def batch_spec_axes(self):
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]


_CURRENT = MeshContext(mesh=None)


def set_mesh_context(ctx: MeshContext) -> None:
    global _CURRENT
    _CURRENT = ctx


def get_mesh_context() -> MeshContext:
    return _CURRENT


class mesh_context:
    """with mesh_context(MeshContext(mesh, ...)): ..."""

    def __init__(self, ctx: MeshContext):
        self.ctx = ctx

    def __enter__(self):
        self.prev = get_mesh_context()
        set_mesh_context(self.ctx)
        return self.ctx

    def __exit__(self, *exc):
        set_mesh_context(self.prev)
        return False


# ---------------------------------------------------------------------------
# Serving mesh — the distributed selection-serving plane's device layout
# ---------------------------------------------------------------------------

SERVING_BATCH_AXIS = "batch"


@dataclasses.dataclass(frozen=True)
class ServingMesh:
    """1-D mesh over the request-batch axis of the serving plane.

    ``num_devices`` is the shard count the featurize→infer shard_map splits
    a padded batch into; callers pad B up to a multiple of it (the sharded
    wrappers do this internally, so ragged batches just work). Hashable —
    it keys the jit caches of the sharded featurizer and inferencer.
    """

    mesh: jax.sharding.Mesh
    axis: str = SERVING_BATCH_AXIS

    @property
    def num_devices(self) -> int:
        return int(np.prod(self.mesh.devices.shape))

    def spec(self) -> "jax.sharding.PartitionSpec":
        from jax.sharding import PartitionSpec as P

        return P(self.axis)

    def shard_utilization(self, b_real: int, b_padded: int
                          ) -> "list[Tuple[int, int]]":
        """Per-shard (real_rows, pad_rows) for a batch of ``b_real`` live
        requests padded to ``b_padded`` rows. The shard_map splits the
        padded batch contiguously, so padding concentrates on the tail
        shards — exactly the imbalance these numbers make visible."""
        nd = self.num_devices
        if b_padded % nd:
            raise ValueError(
                f"padded batch {b_padded} does not divide over {nd} shards")
        per = b_padded // nd
        out = []
        for i in range(nd):
            real = min(per, max(0, b_real - i * per))
            out.append((real, per - real))
        return out


def make_serving_mesh(num_devices: Optional[int] = None) -> ServingMesh:
    """Serving mesh over the first ``num_devices`` devices (default: all).

    ``num_devices=1`` is the degenerate single-device mesh — the same code
    path the multi-device plane runs, with one shard.
    """
    devs = jax.devices()
    if num_devices is not None:
        if not 1 <= num_devices <= len(devs):
            raise ValueError(
                f"serving mesh wants {num_devices} devices but the platform "
                f"has {len(devs)}")
        devs = devs[:num_devices]
    return ServingMesh(jax.sharding.Mesh(np.array(devs),
                                         (SERVING_BATCH_AXIS,)))


_SERVING: Optional[ServingMesh] = None
_DEFAULT: Optional[ServingMesh] = None


def set_serving_mesh(sm: Optional[ServingMesh]) -> None:
    """Install the process-wide serving mesh (None → back to degenerate)."""
    global _SERVING
    _SERVING = sm


def get_serving_mesh() -> ServingMesh:
    """The active serving mesh, defaulting to the degenerate 1-device mesh.

    The default is built lazily (importing this module must not touch jax
    device state — and processes faking device counts via XLA_FLAGS fix
    them before any jax use, so caching after first use is safe) and then
    cached: this sits on the per-micro-batch serving hot path, where a
    fresh ``jax.devices()`` + Mesh construction per call would be pure
    overhead.
    """
    if _SERVING is not None:
        return _SERVING
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = make_serving_mesh(1)
    return _DEFAULT


def record_shard_utilization(metrics, sm: ServingMesh, b_real: int,
                             b_batch: int) -> None:
    """Report one device micro-batch's per-shard utilization into a
    :class:`repro.core.metrics.MetricsRegistry`: ``mesh.shards`` (gauge,
    the active width) plus per-shard ``mesh.shard<i>.requests`` /
    ``mesh.shard<i>.pad_rows`` counters (real rows served vs padding
    waste). ``b_batch`` is the jit bucket the batch was padded to (rounded
    up to a shard multiple, mirroring the sharded wrappers)."""
    if metrics is None:
        return
    nd = sm.num_devices
    b_padded = -(-max(b_batch, b_real) // nd) * nd
    metrics.gauge("mesh.shards").set(nd)
    for i, (real, pad) in enumerate(sm.shard_utilization(b_real, b_padded)):
        metrics.counter(f"mesh.shard{i}.requests").inc(real)
        metrics.counter(f"mesh.shard{i}.pad_rows").inc(pad)


class serving_mesh:
    """with serving_mesh(make_serving_mesh(4)): ... (or a ServingMesh)."""

    def __init__(self, sm: ServingMesh):
        self.sm = sm

    def __enter__(self):
        self.prev = _SERVING
        set_serving_mesh(self.sm)
        return self.sm

    def __exit__(self, *exc):
        set_serving_mesh(self.prev)
        return False
