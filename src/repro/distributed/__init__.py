"""Distribution substrate: mesh context, sharding rules, gradient
compression."""
from .gradient_compression import compressed_psum, init_error_state
from .meshctx import (MeshContext, ServingMesh, get_mesh_context,
                      get_serving_mesh, make_serving_mesh, mesh_context,
                      serving_mesh, set_mesh_context, set_serving_mesh)
from .sharding import (ExecutionPlan, batch_specs, cache_specs,
                       opt_state_spec_for, param_specs, to_shardings)

__all__ = ["compressed_psum", "init_error_state", "MeshContext",
           "get_mesh_context", "mesh_context", "set_mesh_context",
           "ServingMesh", "make_serving_mesh", "get_serving_mesh",
           "set_serving_mesh", "serving_mesh",
           "ExecutionPlan", "batch_specs", "cache_specs",
           "opt_state_spec_for", "param_specs", "to_shardings"]
