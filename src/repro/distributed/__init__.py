"""Distribution substrate: mesh context, sharding rules, gradient
compression."""
from .gradient_compression import compressed_psum, init_error_state
from .meshctx import MeshContext, get_mesh_context, mesh_context, set_mesh_context
from .sharding import (ExecutionPlan, batch_specs, cache_specs,
                       opt_state_spec_for, param_specs, to_shardings)

__all__ = ["compressed_psum", "init_error_state", "MeshContext",
           "get_mesh_context", "mesh_context", "set_mesh_context",
           "ExecutionPlan", "batch_specs", "cache_specs",
           "opt_state_spec_for", "param_specs", "to_shardings"]
