"""Sharding rules: parameter / optimizer / batch / cache PartitionSpecs.

Layout summary (mesh axes: optional 'pod', 'data', 'model'):

* batch dims           → ('pod', 'data')                     (DP)
* attention heads, FFN hidden, expert hidden, d_inner, vocab → 'model' (TP/EP)
* optimizer state      → additionally sharded over 'data'    (ZeRO)
* params               → replicated over 'data' by default; ``plan.fsdp_params``
                         shards them over 'data' too (FSDP), trading an
                         all-gather per use for 1/|data| residency.
* KV caches            → batch over 'data' when batch ≥ |data|, else the
                         sequence axis over 'data' (sequence parallelism for
                         long_500k's batch=1).

`ExecutionPlan` is the knob set the autotuner (repro.autotune) selects over.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig, ShapeSpec

__all__ = ["ExecutionPlan", "param_specs", "opt_state_spec_for",
           "batch_specs", "cache_specs", "to_shardings"]


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Execution-strategy choices for one (arch × shape × mesh) cell."""
    fsdp_params: bool = False
    remat: str = "layer"            # none | layer
    moe_impl: str = "tp_ragged"     # tp_ragged | ep
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    grad_compression: bool = False  # int8 + error feedback on the DP axis
    scan_layers: bool = True
    # pure_dp: no tensor parallelism — the whole mesh is one flat DP/FSDP
    # domain (params ZeRO-3-sharded over every axis, batch over every axis).
    # Valid for dense archs whose per-layer weights fit one chip; kills the
    # per-layer TP activation all-reduces entirely.
    pure_dp: bool = False
    # For DP-only attention (heads ∤ model axis): reshard the attention
    # block's activations over data+model. Measured NET-NEGATIVE on
    # starcoder2 (GSPMD reshard storms outweigh the extra parallelism) —
    # kept as an explicit knob, default off.
    attn_batch_reshard: bool = False
    # Shard the per-group remat residual (the scan-saved (B,S,D) stack) over
    # the model axis on the sequence dim: 1/|model| the residency for one
    # extra all-gather per group in backward (MaxText's "checkpoint
    # sharding").
    shard_activation_ckpt: bool = False
    # Decode over a sequence-sharded KV cache via the shard_map flash-decode
    # path instead of GSPMD's gather (long_500k batch-1 cells).
    seq_shard_decode: bool = False

    def apply(self, cfg: ModelConfig) -> ModelConfig:
        return dataclasses.replace(
            cfg, remat=self.remat, moe_impl=self.moe_impl,
            attn_q_chunk=self.attn_q_chunk, attn_kv_chunk=self.attn_kv_chunk,
            scan_layers=self.scan_layers)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _rule(path: Tuple[str, ...], shape: Tuple[int, ...], tp: str,
          fsdp, attn_tp: bool = True) -> P:
    """Spec for one (unstacked) parameter leaf."""
    name = path[-1]
    in_moe = "mlp" in path and ("wg" == name or "wu" == name or "wd" == name
                                ) and len(shape) == 3
    if in_moe:  # (E, D, F) / (E, F, D) — expert-TP layout (F on model)
        if name in ("wg", "wu"):
            return P(None, fsdp, tp)
        return P(None, tp, fsdp)
    if name == "router":
        return P(None, None)
    if name == "embed":
        return P(tp, fsdp)
    if name == "lm_head":
        return P(fsdp, tp)
    if name in ("wq", "wk", "wv"):
        # heads that don't tile the model axis force GSPMD into replicate-
        # and-reshard storms around the (B,S,H,hd) reshape (measured 6.7 TB
        # of all-reduce on starcoder2's 36 heads × 16-way mesh). DP-only
        # attention (replicated qkv/o weights) is strictly better then.
        return P(fsdp, tp) if attn_tp else P(fsdp, None)
    if name == "wo":
        return P(tp, fsdp) if attn_tp else P(None, fsdp)
    if name in ("wg", "wu", "wi", "up_proj", "in_proj", "up_w", "w_izfo"):
        return P(fsdp, tp)
    if name in ("wd", "out_proj", "down_w"):
        return P(tp, fsdp)
    if name in ("x_proj", "a_log", "i_gate", "f_gate"):
        return P(tp, None)
    if name in ("dt_proj",):
        return P(None, tp)
    if name in ("q_proj", "k_proj", "v_proj"):
        return P(None, tp)
    if name in ("conv_w",):
        return P(None, tp)
    if name in ("conv_b", "dt_bias", "d_skip", "gn_scale") and len(shape) == 1:
        return P(tp)
    # norms, biases, small states: replicated
    return P(*([None] * len(shape)))


def param_specs(params: Dict[str, Any], cfg: ModelConfig,
                plan: ExecutionPlan, *, model_axis: str = "model",
                data_axes: Tuple[str, ...] = ("data",),
                n_model: int = 16) -> Dict[str, Any]:
    if plan.pure_dp:
        assert cfg.num_experts == 0, (
            "pure_dp is for dense archs (experts need the model axis)")
        fsdp = tuple(dict.fromkeys(tuple(data_axes) + (model_axis,)))
        model_axis = None  # type: ignore[assignment]
    else:
        fsdp = data_axes if plan.fsdp_params else None
    # TP on attention only when the q heads tile the model axis (kv-only
    # indivisibility is handled acceptably by GSPMD: measured 4.34s vs 4.56s
    # dominant term on llama; q-head indivisibility is catastrophic:
    # 137s vs 11.8s on starcoder2)
    attn_tp = cfg.num_heads % n_model == 0

    def visit(path, leaf):
        names = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path)
        shape = leaf.shape
        if names and names[0] == "groups":
            spec = _rule(names, shape[1:], model_axis, fsdp, attn_tp)
            return P(None, *spec)
        return _rule(names, shape, model_axis, fsdp, attn_tp)

    return jax.tree_util.tree_map_with_path(visit, params)


def opt_state_spec_for(param_spec: P, shape: Tuple[int, ...],
                       data_axes: Tuple[str, ...], mesh) -> P:
    """ZeRO: additionally shard the optimizer moments / master weights over
    the data axes on the first divisible unsharded dim (skipping axes the
    param layout already uses, e.g. under pure_dp/FSDP)."""
    used = set()
    for e in param_spec:
        if e is None:
            continue
        for ax in (e if isinstance(e, tuple) else (e,)):
            used.add(ax)
    free_axes = tuple(ax for ax in data_axes if ax not in used)
    if not free_axes:
        return param_spec
    n_data = 1
    for ax in free_axes:
        n_data *= mesh.shape[ax]
    entries = list(param_spec) + [None] * (len(shape) - len(param_spec))
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % n_data == 0 and dim >= n_data:
            entries[i] = free_axes if len(free_axes) > 1 else free_axes[0]
            return P(*entries)
    return param_spec  # nothing divisible: keep the param layout


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeSpec,
                data_axes: Tuple[str, ...] = ("data",)) -> Dict[str, P]:
    da = data_axes if len(data_axes) > 1 else data_axes[0]
    specs: Dict[str, P] = {}
    if cfg.input_mode == "tokens":
        specs["tokens"] = P(da, None)
    else:
        specs["embeds"] = P(da, None, None)
        if cfg.mrope:
            specs["positions3"] = P(None, da, None)
    if shape.kind == "train":
        specs["labels"] = P(da, None)
    return specs


def cache_specs(cfg: ModelConfig, shape: ShapeSpec, mesh,
                *, model_axis: str = "model",
                data_axes: Tuple[str, ...] = ("data",)) -> Dict[str, Any]:
    """Specs mirroring init_cache's pytree."""
    n_data = 1
    for ax in data_axes:
        n_data *= mesh.shape[ax]
    n_model = mesh.shape[model_axis]
    da = data_axes if len(data_axes) > 1 else data_axes[0]
    batch_sharded = shape.global_batch % n_data == 0 and shape.global_batch >= n_data
    bspec = da if batch_sharded else None
    seq_data = None if batch_sharded else da  # sequence parallelism (batch=1)

    # KV heads shard over 'model' only when divisible; otherwise the model
    # axis moves to the sequence dim (flash-decode style sharded-softmax).
    heads_on_model = cfg.num_kv_heads % n_model == 0
    head_spec = model_axis if heads_on_model else None
    if heads_on_model:
        seq_spec = seq_data
    elif seq_data is None:
        seq_spec = model_axis
    else:  # both data (batch=1) and model on the sequence axis
        seq_spec = (tuple(data_axes) + (model_axis,)
                    if isinstance(da, tuple) else (da, model_axis))

    def slot_spec(kind):
        if kind == "a":
            kv = P(bspec, head_spec, seq_spec, None)
            return dict(k=kv, v=kv)
        if kind == "m":
            return dict(conv=P(bspec, None, model_axis),
                        ssm=P(bspec, model_axis, None))
        if kind == "M":
            return dict(C=P(bspec, None, None, None),
                        n=P(bspec, None, None),
                        conv=P(bspec, None, model_axis))
        return dict(c=P(bspec, None), n=P(bspec, None), h=P(bspec, None),
                    m=P(bspec, None))

    groups = {f"s{j}": slot_spec(k) for j, k in enumerate(cfg.block_pattern)}
    groups = jax.tree_util.tree_map(
        lambda p: P(None, *p), groups,
        is_leaf=lambda x: isinstance(x, P))
    return dict(pos=P(), groups=groups)


def to_shardings(tree_specs, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))
