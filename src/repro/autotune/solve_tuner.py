"""Autotuned bucket/block policy for the numeric solve backends.

The level-scheduled backends (``batched`` / ``pipelined``) have two
device-dependent knobs:

* ``bs`` — the panel-width cap of the batched partial-Cholesky kernel
  (:func:`repro.kernels.ops.pick_block_size`): small blocks shorten the
  sequential chol-tile critical path, big blocks keep the rank-``bs``
  updates matmul-shaped.
* ``pad`` — the schedule's bucket pad policy
  (:data:`repro.sparse.schedule.PAD_POLICIES`): ``pow2`` minimizes the
  number of compiled kernel shapes, ``mult8`` minimizes padded FLOPs.

Neither has a device-independent best setting (compile cost vs wasted FLOPs
vs MXU shape efficiency), so :func:`tune` *measures*: it times warm
factorizations of a small representative suite over a candidate grid and
persists the winner per **device kind** under ``artifacts/autotune/``
(``solve_policy_<device-kind>.json``). Candidate ordering is seeded from
``BENCH_solve.json`` roofline records when present — a suite whose measured
bucket occupancy is already high gets the cheap ``pow2``-first ordering,
a low-occupancy one tries ``mult8`` first.

Cache invalidation: a persisted policy records the schema version, device
kind, and backend it was tuned for; :func:`load_policy` rejects records
that mismatch any of them (and malformed files), so a toolchain/device
change simply re-tunes. Delete the JSON (or pass ``force=True`` to
:func:`get_policy`) to re-measure on demand.

The engine threads the policy through
:class:`repro.engine.config.EngineConfig` (``autotune_solve`` /
``autotune_dir``) into :func:`repro.core.plan.execute_plan`, which records
the applied knobs in ``ExecutionPlan.meta["solve_bs"/"solve_pad"]`` — a
cached plan always tells which policy last produced numbers from it.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.sparse.schedule import PAD_POLICIES

__all__ = ["SolvePolicy", "DEFAULT_AUTOTUNE_DIR", "device_kind",
           "policy_path", "load_policy", "save_policy", "seed_order",
           "tune", "get_policy"]

SCHEMA = 1
DEFAULT_AUTOTUNE_DIR = os.path.join("artifacts", "autotune")

#: default candidate grid: panel-width caps × pad policies
DEFAULT_BS_GRID: Tuple[Optional[int], ...] = (16, 32, 64)
#: stage-2 grid: device-sweep tri-solve panel caps × RHS tile widths
DEFAULT_SWEEP_BS_GRID: Tuple[Optional[int], ...] = (None, 16)
DEFAULT_RT_GRID: Tuple[Optional[int], ...] = (None, 8)


@dataclasses.dataclass(frozen=True)
class SolvePolicy:
    """One (device kind, backend)'s tuned bucket/block policy."""

    bs: Optional[int] = None     # panel-width cap (None = kernel default)
    pad: str = "pow2"            # bucket pad policy
    device_kind: str = ""        # jax device kind the numbers came from
    backend: str = "batched"     # backend the timing loop ran
    warm_factor_s: float = 0.0   # best measured warm factor time (suite sum)
    source: str = "default"      # "default" | "tuned" | "cached"
    # device-sweep knobs (sweep="device"): tri-solve panel cap and RHS
    # tile width, measured in the stage-2 grid over warm multi-RHS solves
    # (None = kernel defaults; absent in pre-sweep records, defaulted on
    # load)
    sweep_bs: Optional[int] = None
    rt: Optional[int] = None
    warm_sweep_s: float = 0.0    # best measured warm device-solve time

    def to_json(self) -> dict:
        return dict(schema=SCHEMA, **dataclasses.asdict(self))

    @classmethod
    def from_json(cls, doc: dict) -> "SolvePolicy":
        doc = {k: v for k, v in doc.items() if k != "schema"}
        return cls(**doc)


def device_kind() -> str:
    """The accelerator kind policies are keyed by (e.g. ``cpu``,
    ``TPU v4``)."""
    import jax

    return jax.devices()[0].device_kind


def _slug(kind: str) -> str:
    return re.sub(r"[^a-z0-9]+", "-", kind.lower()).strip("-") or "unknown"


def policy_path(dirpath: str, kind: str) -> str:
    return os.path.join(dirpath, f"solve_policy_{_slug(kind)}.json")


def save_policy(policy: SolvePolicy,
                dirpath: str = DEFAULT_AUTOTUNE_DIR) -> str:
    os.makedirs(dirpath, exist_ok=True)
    path = policy_path(dirpath, policy.device_kind)
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(policy.to_json(), fh, indent=2)
    os.replace(tmp, path)
    return path


def load_policy(dirpath: str, kind: str,
                backend: Optional[str] = None) -> Optional[SolvePolicy]:
    """The persisted policy for ``kind``, or None if absent/stale.

    Stale = schema or device-kind mismatch, unknown pad policy, or (when
    ``backend`` is given) a record tuned for a different backend — all
    treated as a miss so the caller re-tunes rather than serving numbers
    measured under different rules.
    """
    path = policy_path(dirpath, kind)
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError):
        return None
    if doc.get("schema") != SCHEMA or doc.get("device_kind") != kind:
        return None
    if doc.get("pad") not in PAD_POLICIES:
        return None
    if backend is not None and doc.get("backend") != backend:
        return None
    try:
        return dataclasses.replace(SolvePolicy.from_json(doc),
                                   source="cached")
    except TypeError:
        return None


def seed_order(bench_path: str = "BENCH_solve.json",
               pads: Sequence[str] = PAD_POLICIES) -> List[str]:
    """Pad-policy candidate ordering seeded from benchmark rooflines.

    ``BENCH_solve.json`` records the realized bucket occupancy per matrix.
    When the suite's mean occupancy under the recorded (pow2) schedule is
    already high, padding waste is not the bottleneck — try ``pow2`` first
    and let the early-out keep tuning cheap. Low occupancy means measured
    padded-FLOP waste — try ``mult8`` first. Without a benchmark file the
    declared order stands.
    """
    pads = [p for p in pads if p in PAD_POLICIES]
    try:
        with open(bench_path) as fh:
            doc = json.load(fh)
        occ = [r["occupancy"] for r in doc.get("records", [])
               if "occupancy" in r]
        mean_occ = float(np.mean(occ)) if occ else 1.0
    except (OSError, json.JSONDecodeError, KeyError):
        return list(pads)
    if mean_occ < 0.5 and "mult8" in pads:
        return ["mult8"] + [p for p in pads if p != "mult8"]
    return list(pads)


def _default_suite():
    from repro.sparse.dataset import block_arrow, grid2d

    rng = np.random.default_rng(0)
    return [grid2d(12, 12, "tune_grid"),
            block_arrow(3, 20, 8, rng, "tune_arrow")]


def tune(mats=None, *, backend: str = "pipelined",
         bs_grid: Sequence[Optional[int]] = DEFAULT_BS_GRID,
         pads: Optional[Sequence[str]] = None, repeats: int = 2,
         sweep_bs_grid: Sequence[Optional[int]] = DEFAULT_SWEEP_BS_GRID,
         rt_grid: Sequence[Optional[int]] = DEFAULT_RT_GRID,
         bench_path: str = "BENCH_solve.json",
         out_dir: Optional[str] = DEFAULT_AUTOTUNE_DIR) -> SolvePolicy:
    """Measure the candidate grid and persist the winner for this device.

    Stage 1, per (pad, bs): one cold factorization (compile) then
    ``repeats`` warm factorizations of every suite matrix; the score is the
    summed best warm factor time. Stage 2 re-factors once with the stage-1
    winner and grids the device-sweep knobs (tri-solve panel cap ×
    RHS tile) over warm multi-RHS ``sweep="device"`` solves.
    ``out_dir=None`` skips persistence (pure measurement).
    """
    from repro.sparse.multifrontal import (factor_and_solve_timed,
                                           multifrontal_cholesky,
                                           multifrontal_solve)
    from repro.sparse.symbolic import symbolic_cholesky

    if mats is None:
        mats = _default_suite()
    pads = seed_order(bench_path, PAD_POLICIES if pads is None else pads)
    syms = [symbolic_cholesky(a) for a in mats]
    kind = device_kind()
    results: Dict[Tuple[str, Optional[int]], float] = {}
    for pad in pads:
        for bs in bs_grid:
            total = 0.0
            for a, sym in zip(mats, syms):
                factor_and_solve_timed(a, sym=sym, backend=backend,
                                       pad=pad, bs=bs)  # cold/compile
                best = float("inf")
                for _ in range(max(repeats, 1)):
                    t0 = time.perf_counter()
                    factor_and_solve_timed(a, sym=sym, backend=backend,
                                           pad=pad, bs=bs)
                    best = min(best, time.perf_counter() - t0)
                total += best
            results[(pad, bs)] = total
    (pad, bs), t_best = min(results.items(), key=lambda kv: kv[1])

    # stage 2: device-sweep knobs over the winning factorization policy
    facs = [multifrontal_cholesky(a, sym=sym, backend=backend,
                                  pad=pad, bs=bs)
            for a, sym in zip(mats, syms)]
    rhss = [np.random.default_rng(1).standard_normal((a.n, 4))
            for a in mats]
    sweep_results: Dict[Tuple[Optional[int], Optional[int]], float] = {}
    for sbs in sweep_bs_grid:
        for rt in rt_grid:
            total = 0.0
            for f, B in zip(facs, rhss):
                multifrontal_solve(f, B, mode="device",
                                   sweep_bs=sbs, rt=rt)  # cold/compile
                best = float("inf")
                for _ in range(max(repeats, 1)):
                    t0 = time.perf_counter()
                    multifrontal_solve(f, B, mode="device",
                                       sweep_bs=sbs, rt=rt)
                    best = min(best, time.perf_counter() - t0)
                total += best
            sweep_results[(sbs, rt)] = total
    (sweep_bs, rt), t_sweep = min(sweep_results.items(),
                                  key=lambda kv: kv[1])
    policy = SolvePolicy(bs=bs, pad=pad, device_kind=kind, backend=backend,
                         warm_factor_s=t_best, source="tuned",
                         sweep_bs=sweep_bs, rt=rt, warm_sweep_s=t_sweep)
    if out_dir:
        save_policy(policy, out_dir)
    return policy


def get_policy(dirpath: str = DEFAULT_AUTOTUNE_DIR, *,
               backend: str = "pipelined", autotune: bool = False,
               force: bool = False, **tune_kwargs) -> SolvePolicy:
    """The policy the engine should apply: cached > (re)tuned > default.

    ``autotune=False`` never measures — it returns the persisted policy if
    one is valid for this device/backend, else the conservative default
    (``bs=None``, ``pad="pow2"``). ``autotune=True`` tunes on a cache miss;
    ``force=True`` ignores the cache and re-measures.
    """
    kind = device_kind()
    if not force:
        cached = load_policy(dirpath, kind, backend=backend)
        if cached is not None:
            return cached
    if autotune:
        return tune(backend=backend, out_dir=dirpath, **tune_kwargs)
    return SolvePolicy(device_kind=kind, backend=backend, source="default")
