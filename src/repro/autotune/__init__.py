"""Learned execution-plan selection — the paper's technique generalized.

The paper: features(sparse matrix) → best reordering algorithm.
Here:      features(arch × shape × mesh) → best ExecutionPlan.

Same supervised machinery (`repro.core.ml`), different domain: the training
corpus is the dry-run artifact table (roofline terms + memory per plan),
labels are the plan with the best dominant-term/residency trade-off per
cell. See `plan_selector.PlanSelector`.

`solve_tuner` is the measured (not learned) sibling for the numeric solve
backends: per-device-kind search over the kernel block size and bucket pad
policy, persisted under ``artifacts/autotune/``.
"""
from .plan_selector import (CANDIDATE_PLANS, PlanSelector, plan_label,
                            workload_features)
from .solve_tuner import (DEFAULT_AUTOTUNE_DIR, SolvePolicy, get_policy,
                          load_policy, save_policy, tune)

__all__ = ["CANDIDATE_PLANS", "PlanSelector", "plan_label",
           "workload_features", "SolvePolicy", "DEFAULT_AUTOTUNE_DIR",
           "get_policy", "load_policy", "save_policy", "tune"]
