"""Learned execution-plan selection — the paper's technique generalized.

The paper: features(sparse matrix) → best reordering algorithm.
Here:      features(arch × shape × mesh) → best ExecutionPlan.

Same supervised machinery (`repro.core.ml`), different domain: the training
corpus is the dry-run artifact table (roofline terms + memory per plan),
labels are the plan with the best dominant-term/residency trade-off per
cell. See `plan_selector.PlanSelector`.
"""
from .plan_selector import (CANDIDATE_PLANS, PlanSelector, plan_label,
                            workload_features)

__all__ = ["CANDIDATE_PLANS", "PlanSelector", "plan_label",
           "workload_features"]
