"""Execution-plan selection from workload features.

``workload_features`` mirrors the paper's Table 3 for the LM domain: cheap
static descriptors of the (arch, shape, mesh) cell. ``PlanSelector`` trains
any `repro.core.ml` classifier on dry-run artifacts
(artifacts/dryrun/**.json — one per cell × plan tag) and predicts the best
plan for unseen cells; when fewer than `min_samples` artifacts exist it
falls back to an analytic rule set (the same defaults a MaxText-style config
would ship).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ml import MODEL_ZOO
from repro.core.scaling import StandardScaler
from repro.distributed.sharding import ExecutionPlan
from repro.models.config import ModelConfig, ShapeSpec

__all__ = ["workload_features", "CANDIDATE_PLANS", "plan_label",
           "PlanSelector"]

WORKLOAD_FEATURE_NAMES = [
    "num_layers", "d_model", "num_heads", "num_kv_heads", "d_ff",
    "log_vocab", "num_experts", "experts_per_token", "is_ssm", "is_hybrid",
    "log_seq", "log_batch", "log_tokens", "is_train", "is_decode",
    "n_data", "n_model", "log_params", "log_active_params",
]

CANDIDATE_PLANS: Dict[str, ExecutionPlan] = {
    "baseline": ExecutionPlan(),
    "fsdp": ExecutionPlan(fsdp_params=True),
    "fsdp_ep": ExecutionPlan(fsdp_params=True, moe_impl="ep"),
    "ep": ExecutionPlan(moe_impl="ep"),
    "no_remat": ExecutionPlan(remat="none"),
    "small_chunks": ExecutionPlan(attn_q_chunk=512, attn_kv_chunk=512),
    "pure_dp": ExecutionPlan(pure_dp=True, fsdp_params=True),
    # plans discovered/validated in the §Perf hillclimb
    "fsdp_actshard": ExecutionPlan(fsdp_params=True,
                                   shard_activation_ckpt=True),
    "seqshard_decode": ExecutionPlan(seq_shard_decode=True),
}


def plan_label(plan_dict: dict) -> str:
    for name, plan in CANDIDATE_PLANS.items():
        if all(plan_dict.get(k) == v for k, v in plan.__dict__.items()):
            return name
    return "custom"


def workload_features(cfg: ModelConfig, shape: ShapeSpec, n_data: int,
                      n_model: int) -> np.ndarray:
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    return np.array([
        cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
        cfg.d_ff, np.log1p(cfg.vocab_size), cfg.num_experts,
        cfg.experts_per_token,
        float(any(k in ("M", "s") for k in cfg.block_pattern)),
        float("m" in cfg.block_pattern),
        np.log1p(shape.seq_len), np.log1p(shape.global_batch),
        np.log1p(tokens), float(shape.kind == "train"),
        float(shape.kind == "decode"), n_data, n_model,
        np.log1p(cfg.param_count()), np.log1p(cfg.active_param_count()),
    ], dtype=np.float64)


def _score(record: dict) -> float:
    """Lower is better: dominant roofline term, with an HBM-overflow
    penalty proportional to the overflow (a plan that does not fit cannot
    run, whatever its FLOP schedule says)."""
    if record.get("status") != "ok":
        return float("inf")
    r = record["roofline"]
    dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
    resident = record.get("resident_bytes", 0)
    overflow = max(0.0, resident - 16e9) / 16e9
    return dom * (1.0 + 4.0 * overflow)


def load_artifacts(art_dir: str = "artifacts/dryrun") -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*", "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


class PlanSelector:
    def __init__(self, model_name: str = "random_forest",
                 min_samples: int = 12):
        self.model_name = model_name
        self.min_samples = min_samples
        self.model = None
        self.scaler = None
        self.plan_names: List[str] = []

    # -- training corpus from artifacts ---------------------------------------
    def build_dataset(self, artifacts: Sequence[dict]
                      ) -> Tuple[np.ndarray, np.ndarray]:
        from repro.configs import get_config
        from repro.models.config import SHAPES
        by_cell: Dict[Tuple[str, str, str], Dict[str, dict]] = {}
        for rec in artifacts:
            if "roofline" not in rec and rec.get("status") != "ok":
                if "plan" not in rec:
                    continue
            key = (rec["arch"], rec["shape"], rec["mesh"])
            by_cell.setdefault(key, {})[plan_label(rec.get("plan", {}))] = rec
        feats, labels = [], []
        self.plan_names = sorted(CANDIDATE_PLANS)
        for (arch, shape_name, mesh_name), plans in by_cell.items():
            scored = {p: _score(r) for p, r in plans.items()
                      if p in self.plan_names and _score(r) < float("inf")}
            if len(scored) < 2:
                continue  # need at least two plans to have a choice
            best = min(scored, key=scored.get)
            cfg = get_config(arch)
            shape = SHAPES[shape_name]
            n_model = 16
            n_data = 32 if "2x16" in mesh_name else 16
            feats.append(workload_features(cfg, shape, n_data, n_model))
            labels.append(self.plan_names.index(best))
        if not feats:
            return np.zeros((0, len(WORKLOAD_FEATURE_NAMES))), np.zeros(0, int)
        return np.stack(feats), np.array(labels)

    def fit(self, artifacts: Optional[Sequence[dict]] = None,
            art_dir: str = "artifacts/dryrun") -> "PlanSelector":
        arts = list(artifacts) if artifacts is not None else load_artifacts(art_dir)
        x, y = self.build_dataset(arts)
        if x.shape[0] >= self.min_samples and np.unique(y).size >= 2:
            self.scaler = StandardScaler().fit(x)
            self.model = MODEL_ZOO[self.model_name](n_estimators=50)
            self.model.fit(self.scaler.transform(x), y)
        return self

    # -- inference --------------------------------------------------------------
    def _analytic_rule(self, cfg: ModelConfig, shape: ShapeSpec,
                       n_data: int) -> str:
        if shape.kind != "train":
            return "baseline"
        if cfg.param_count() * 2 / 16 > 4e9:  # params won't comfortably fit
            return "fsdp_ep" if cfg.num_experts else "fsdp"
        return "baseline"

    def recommend(self, cfg: ModelConfig, shape: ShapeSpec, n_data: int,
                  n_model: int) -> Tuple[str, ExecutionPlan]:
        if self.model is None:
            name = self._analytic_rule(cfg, shape, n_data)
            return name, CANDIDATE_PLANS[name]
        f = workload_features(cfg, shape, n_data, n_model)[None]
        idx = int(self.model.predict(self.scaler.transform(f))[0])
        name = self.plan_names[idx]
        return name, CANDIDATE_PLANS[name]
