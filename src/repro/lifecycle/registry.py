"""Versioned bundle registry: the durable half of the promotion loop.

A :class:`BundleRegistry` owns a directory (``artifacts/bundles/`` by
default) holding immutable, versioned :class:`~repro.engine.bundle
.SelectorBundle` artifacts plus one ``registry.json`` index with lineage
metadata:

    <root>/registry.json          # index: serving pointer + entry list
    <root>/v0001-<fp12>.bundle    # immutable bundle payloads
    <root>/v0002-<fp12>.bundle

Each entry records *where a bundle came from* (``parent`` = the version
that was serving when it was registered, ``source`` = who registered it)
and *what happened to it* (``status``: candidate → serving → retired /
rolled_back, with promotion timestamps), so ``lineage()`` can answer "what
chain of retrains produced the model now in production" without the
training runs. Registration is content-addressed on the bundle
fingerprint — re-registering the same fitted state is a no-op returning
the existing entry, which is what makes ``SolverEngine.promote()``
idempotent about its incumbent.

Index updates are crash-safe (tmp + atomic replace) and cross-process
safe (the same advisory :class:`~repro.core.locking.FileLock` discipline
the replica-shared plan cache uses), so N serving replicas can share one
registry the way they already share one disk cache tier.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional, Union

from repro.core.locking import FileLock
from repro.engine.bundle import SelectorBundle

__all__ = ["BundleRegistry", "BundleRegistryError", "DEFAULT_BUNDLE_DIR"]

DEFAULT_BUNDLE_DIR = os.path.join("artifacts", "bundles")

_INDEX_SCHEMA = 1


class BundleRegistryError(RuntimeError):
    """Registry misuse: unknown version, rollback with no predecessor."""


def _empty_index() -> Dict[str, Any]:
    return {"schema": _INDEX_SCHEMA, "serving": None, "previous": None,
            "next_seq": 1, "entries": []}


class BundleRegistry:
    """Content-addressed, lineage-tracking store of selector bundles."""

    def __init__(self, root: str = DEFAULT_BUNDLE_DIR):
        self.root = root
        self._lock = FileLock(os.path.join(root, ".registry.lock"))

    # -- index I/O -----------------------------------------------------------
    @property
    def index_path(self) -> str:
        return os.path.join(self.root, "registry.json")

    def _read_index(self) -> Dict[str, Any]:
        try:
            with open(self.index_path, "r", encoding="utf-8") as f:
                idx = json.load(f)
        except (OSError, json.JSONDecodeError):
            return _empty_index()
        if idx.get("schema", 0) > _INDEX_SCHEMA:
            raise BundleRegistryError(
                f"registry index schema v{idx.get('schema')} is newer than "
                f"this build understands (v{_INDEX_SCHEMA})")
        return idx

    def _write_index(self, idx: Dict[str, Any]) -> None:
        os.makedirs(self.root, exist_ok=True)
        tmp = self.index_path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(idx, f, indent=2, default=str)
        os.replace(tmp, self.index_path)

    @staticmethod
    def _find(idx: Dict[str, Any], version: str) -> Optional[Dict[str, Any]]:
        for e in idx["entries"]:
            if e["version"] == version:
                return e
        return None

    # -- registration --------------------------------------------------------
    def register(self, bundle: Union[SelectorBundle, str], *,
                 source: Optional[str] = None,
                 parent: Optional[str] = None,
                 notes: Optional[str] = None) -> Dict[str, Any]:
        """Add a bundle (object or path) to the registry; returns its entry.

        Content-addressed on the fingerprint: a bundle whose fitted state
        is already registered returns the existing entry untouched (the
        file is not rewritten). ``parent`` defaults to whatever version is
        serving at registration time — the lineage edge.
        """
        if isinstance(bundle, str):
            bundle = SelectorBundle.load(bundle)
        bundle.validate()
        with self._lock.exclusive():
            idx = self._read_index()
            for e in idx["entries"]:
                if e["fingerprint"] == bundle.fingerprint:
                    return dict(e)
            version = f"v{idx['next_seq']:04d}-{bundle.fingerprint[:12]}"
            idx["next_seq"] += 1
            path = os.path.join(self.root, f"{version}.bundle")
            bundle.save(path)
            entry = dict(
                version=version, path=path, status="candidate",
                parent=(parent if parent is not None else idx["serving"]),
                registered_unix=time.time(), promoted_unix=None,
                source=source, notes=notes, **bundle.describe())
            idx["entries"].append(entry)
            self._write_index(idx)
            return dict(entry)

    # -- lookup --------------------------------------------------------------
    def entries(self) -> List[Dict[str, Any]]:
        return [dict(e) for e in self._read_index()["entries"]]

    def entry(self, version: str) -> Dict[str, Any]:
        e = self._find(self._read_index(), version)
        if e is None:
            raise BundleRegistryError(
                f"no bundle version {version!r} in {self.root}")
        return dict(e)

    def load(self, version: str) -> SelectorBundle:
        """The validated bundle payload for a registered version."""
        return SelectorBundle.load(self.entry(version)["path"])

    def serving_version(self) -> Optional[str]:
        return self._read_index()["serving"]

    def previous_version(self) -> Optional[str]:
        return self._read_index()["previous"]

    def serving_entry(self) -> Optional[Dict[str, Any]]:
        idx = self._read_index()
        if idx["serving"] is None:
            return None
        e = self._find(idx, idx["serving"])
        return dict(e) if e is not None else None

    # -- serving pointer -----------------------------------------------------
    def mark_serving(self, version: str) -> Dict[str, Any]:
        """Atomically point ``serving`` at ``version`` (the promote step's
        registry half); the displaced version becomes ``previous`` (the
        rollback target) with status ``retired``."""
        with self._lock.exclusive():
            idx = self._read_index()
            entry = self._find(idx, version)
            if entry is None:
                raise BundleRegistryError(
                    f"cannot serve unregistered version {version!r}")
            prev = idx["serving"]
            if prev == version:
                return dict(entry)
            idx["previous"] = prev
            idx["serving"] = version
            entry["status"] = "serving"
            entry["promoted_unix"] = time.time()
            if prev is not None:
                pe = self._find(idx, prev)
                if pe is not None:
                    pe["status"] = "retired"
            self._write_index(idx)
            return dict(entry)

    def rollback(self) -> Dict[str, Any]:
        """Swap ``serving`` back to ``previous``; the demoted version is
        marked ``rolled_back`` (and becomes the new ``previous``, so a
        second rollback re-promotes it — the pointer swap is symmetric)."""
        with self._lock.exclusive():
            idx = self._read_index()
            prev = idx["previous"]
            if prev is None:
                raise BundleRegistryError(
                    "nothing to roll back to: no previous serving version")
            demoted = idx["serving"]
            idx["serving"], idx["previous"] = prev, demoted
            entry = self._find(idx, prev)
            if entry is None:
                raise BundleRegistryError(
                    f"previous version {prev!r} missing from the index")
            entry["status"] = "serving"
            if demoted is not None:
                de = self._find(idx, demoted)
                if de is not None:
                    de["status"] = "rolled_back"
            self._write_index(idx)
            return dict(entry)

    # -- lineage -------------------------------------------------------------
    def lineage(self, version: Optional[str] = None
                ) -> List[Dict[str, Any]]:
        """Parent chain starting at ``version`` (default: the serving
        version), newest first. Cycles (hand-edited indexes) terminate."""
        idx = self._read_index()
        v = version if version is not None else idx["serving"]
        chain: List[Dict[str, Any]] = []
        seen = set()
        while v is not None and v not in seen:
            seen.add(v)
            e = self._find(idx, v)
            if e is None:
                break
            chain.append(dict(e))
            v = e.get("parent")
        return chain

    def __len__(self) -> int:
        return len(self._read_index()["entries"])

    def __repr__(self) -> str:
        idx = self._read_index()
        return (f"BundleRegistry(root={self.root!r}, "
                f"entries={len(idx['entries'])}, "
                f"serving={idx['serving']!r})")
