"""Bundle lifecycle control plane: campaign → shadow → promote → rollback.

The ML-ops layer that turns the repo from "a model we trained once" into a
continuously-trainable serving system:

* :mod:`repro.lifecycle.campaign` — sharded, resumable labeling campaigns
  over the (matrix × reordering algorithm) grid, with per-matrix JSON
  artifacts and a ``BENCH_campaign.json`` report.
* :mod:`repro.lifecycle.shadow` — a candidate bundle shadow-serves next to
  the incumbent, scored by agreement and counterfactual predicted-flops
  win rate, entirely off the hot path.
* :mod:`repro.lifecycle.promote` — the configurable promotion gate
  (report-card accuracy + shadow win rate) with typed rejections.
* :mod:`repro.lifecycle.registry` — versioned bundles under
  ``artifacts/bundles/`` with lineage metadata and the serving/previous
  pointers that ``SolverEngine.promote()`` / ``rollback()`` swap.
"""
# PEP 562 lazy re-exports (the repro.engine idiom): importing the package
# must not import every submodule — `python -m repro.lifecycle.campaign`
# would otherwise warn about the module being in sys.modules pre-exec
_LAZY = {
    "BundleRegistry": "registry", "BundleRegistryError": "registry",
    "DEFAULT_BUNDLE_DIR": "registry",
    "PromotionGate": "promote", "PromotionError": "promote",
    "NotPromotable": "promote", "GateRejected": "promote",
    "evaluate_gate": "promote",
    "ShadowEvaluator": "shadow",
    "CampaignConfig": "campaign", "CampaignResult": "campaign",
    "run_campaign": "campaign", "assemble_dataset": "campaign",
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    if name in _LAZY:
        import importlib

        mod = importlib.import_module(f"repro.lifecycle.{_LAZY[name]}")
        return getattr(mod, name)
    raise AttributeError(f"module 'repro.lifecycle' has no attribute "
                         f"{name!r}")
