"""Promotion gate: the policy between "retrained candidate" and "serving".

A candidate bundle replaces the incumbent only when it clears two
independent kinds of evidence:

* **Report card** (offline): the bundle's schema-v2 training report must
  exist and its held-out ``test_accuracy`` must clear
  ``min_test_accuracy``. A schema-v1 bundle — or a v2 bundle saved without
  training — carries no report card and is *never* auto-promotable
  (:class:`NotPromotable`): it may still be loaded and served explicitly,
  but the automated loop refuses to swap production onto a model whose
  quality was never measured.
* **Shadow traffic** (online): the candidate must have shadow-served at
  least ``min_shadow_requests`` real requests next to the incumbent
  (:mod:`repro.lifecycle.shadow`) and its counterfactual predicted-flops
  win rate must clear ``min_shadow_win_rate``. ``require_shadow=False``
  turns the online half off (offline-only promotion, e.g. bootstrap).

:func:`evaluate_gate` is pure policy — it inspects a bundle and a shadow
stats dict and either returns a decision record (every check with its
measured value and threshold) or raises the typed error; the engine's
``promote()`` does the cache-consistent swap only after the gate passes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.engine.bundle import SelectorBundle

__all__ = ["PromotionGate", "PromotionError", "NotPromotable",
           "GateRejected", "evaluate_gate"]


class PromotionError(RuntimeError):
    """Base of the typed promotion-path errors."""


class NotPromotable(PromotionError):
    """The candidate can never pass the gate as-is (no report card — a
    schema-v1 bundle or an untrained save). Distinct from
    :class:`GateRejected`: no amount of shadow traffic fixes this."""


class GateRejected(PromotionError):
    """The candidate failed one or more gate thresholds. Carries the full
    ``decision`` record (every check, measured vs required) so callers and
    logs can see exactly which check failed by how much."""

    def __init__(self, message: str, decision: Dict[str, Any]):
        super().__init__(message)
        self.decision = decision


@dataclasses.dataclass(frozen=True)
class PromotionGate:
    """Configurable promotion thresholds (see module docstring)."""

    min_test_accuracy: float = 0.5
    min_shadow_requests: int = 10
    min_shadow_win_rate: float = 0.5
    require_shadow: bool = True

    @classmethod
    def from_config(cls, config) -> "PromotionGate":
        """Thresholds from an :class:`repro.engine.config.EngineConfig`."""
        return cls(
            min_test_accuracy=config.promote_min_accuracy,
            min_shadow_requests=config.promote_min_shadow_requests,
            min_shadow_win_rate=config.promote_min_win_rate)


def _check(name: str, value, threshold, ok: bool) -> Dict[str, Any]:
    return dict(check=name, value=value, threshold=threshold,
                passed=bool(ok))


def evaluate_gate(candidate: SelectorBundle, gate: PromotionGate,
                  shadow_stats: Optional[Dict[str, Any]] = None
                  ) -> Dict[str, Any]:
    """Run every gate check against a candidate; the decision record.

    Raises :class:`NotPromotable` (no report card) or
    :class:`GateRejected` (threshold failures, all listed); returns the
    decision dict — ``{fingerprint, passed: True, checks: [...]}`` — when
    the candidate clears the gate.
    """
    if candidate.report_card is None:
        raise NotPromotable(
            f"bundle {candidate.fingerprint[:12]} (schema "
            f"v{candidate.schema_version}) has no training report card — "
            "legacy v1 bundles and untrained saves cannot be auto-promoted; "
            "retrain and re-save through SolverEngine.train()/save() to get "
            "a v2 report card, or serve it explicitly via SolverEngine.load()")

    checks: List[Dict[str, Any]] = []
    acc = candidate.report_card.get("test_accuracy")
    checks.append(_check(
        "report_card.test_accuracy", acc, gate.min_test_accuracy,
        acc is not None and float(acc) >= gate.min_test_accuracy))

    if gate.require_shadow:
        evaluated = 0 if shadow_stats is None else int(
            shadow_stats.get("evaluated", 0))
        win_rate = None if shadow_stats is None else shadow_stats.get(
            "win_rate")
        checks.append(_check(
            "shadow.evaluated", evaluated, gate.min_shadow_requests,
            evaluated >= gate.min_shadow_requests))
        checks.append(_check(
            "shadow.win_rate", win_rate, gate.min_shadow_win_rate,
            win_rate is not None
            and float(win_rate) >= gate.min_shadow_win_rate))

    decision = dict(fingerprint=candidate.fingerprint,
                    passed=all(c["passed"] for c in checks), checks=checks,
                    gate=dataclasses.asdict(gate))
    if not decision["passed"]:
        failed = ", ".join(
            f"{c['check']}={c['value']!r} (need ≥ {c['threshold']!r})"
            for c in checks if not c["passed"])
        raise GateRejected(
            f"candidate {candidate.fingerprint[:12]} rejected by the "
            f"promotion gate: {failed}", decision)
    return decision
