"""Shadow serving: a candidate selector rides next to the incumbent.

A :class:`ShadowEvaluator` receives a *mirror* of the serving path's
selection decisions — ``observe(mat, incumbent_algorithm)`` is called by
the dispatcher at the same points it resolves real traffic — and scores a
candidate bundle against them **entirely off the hot path**:

* ``observe`` is O(enqueue): it never runs inference, never raises, and
  never blocks (a full mirror queue drops the observation and counts it —
  shadow fidelity degrades before client latency does).
* A daemon worker drains the queue, runs the candidate's selection on the
  host path (no contention with the serving mesh's jit caches), and
  scores the disagreements by **counterfactual predicted flops**: reorder
  + symbolic analysis under each choice, win = the candidate's ordering
  would have cost no more factorization flops than the incumbent's.
  Agreements count as wins (matching production is never a regression).
  Symbolic analyses are memoized per (structure, algorithm), so hot
  structures are scored once.
* Everything lands in ``shadow.*`` metrics (requests / evaluated /
  agreements / disagreements / wins / losses / dropped / errors counters,
  agreement-rate and win-rate gauges, per-evaluation latency histogram)
  and in ``stats()`` — the evidence :func:`repro.lifecycle.promote
  .evaluate_gate` consumes.

The client-visible response is untouched by construction: the dispatcher
only ever hands the evaluator a reference after the real plan is already
resolved (or its build already queued).
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from typing import Any, Dict, Optional, Tuple

from repro.core.plan_cache import matrix_fingerprint
from repro.engine.bundle import SelectorBundle

__all__ = ["ShadowEvaluator"]

_SENTINEL = object()


class ShadowEvaluator:
    """Score a candidate selector against mirrored incumbent decisions.

    ``candidate`` may be a :class:`SelectorBundle`, a path to one, or a
    fitted ``ReorderSelector`` (in which case no bundle rides along and
    ``SolverEngine.promote()`` must be given the bundle explicitly).
    """

    def __init__(self, candidate, *, metrics=None, max_queue: int = 512,
                 flops_cache: int = 4096):
        from repro.core.selector import ReorderSelector

        self.bundle: Optional[SelectorBundle] = None
        if isinstance(candidate, str):
            candidate = SelectorBundle.load(candidate)
        if isinstance(candidate, SelectorBundle):
            self.bundle = candidate
            self.selector = candidate.to_selector()
        elif isinstance(candidate, ReorderSelector):
            self.selector = candidate
        else:
            raise TypeError(
                f"candidate must be a SelectorBundle, a bundle path, or a "
                f"ReorderSelector, got {type(candidate).__name__}")
        self.candidate_fingerprint = (
            self.bundle.fingerprint if self.bundle is not None
            else SelectorBundle.from_selector(self.selector).fingerprint)

        if metrics is None:
            from repro.core.metrics import MetricsRegistry
            metrics = MetricsRegistry()
        self.metrics = metrics
        m = metrics
        self._c_requests = m.counter("shadow.requests")
        self._c_evaluated = m.counter("shadow.evaluated")
        self._c_agree = m.counter("shadow.agreements")
        self._c_disagree = m.counter("shadow.disagreements")
        self._c_wins = m.counter("shadow.wins")
        self._c_losses = m.counter("shadow.losses")
        self._c_dropped = m.counter("shadow.dropped")
        self._c_errors = m.counter("shadow.errors")
        self._g_agree = m.gauge("shadow.agreement_rate")
        self._g_win = m.gauge("shadow.win_rate")
        self._h_eval = m.histogram("shadow.eval_s")

        # (structure fingerprint, algorithm) → predicted factorization
        # flops; bounded LRU so a long-lived shadow can't grow unboundedly
        self._flops_cache: "collections.OrderedDict[Tuple[str, str], int]" \
            = collections.OrderedDict()
        self._flops_cache_cap = flops_cache
        self._cache_lock = threading.Lock()

        self._queue: "queue.Queue" = queue.Queue(maxsize=max(1, max_queue))
        self._pending = 0
        self._pending_lock = threading.Lock()
        self._closed = False
        self._worker = threading.Thread(target=self._loop,
                                        name="shadow-eval", daemon=True)
        self._worker.start()

    # -- hot-path surface ----------------------------------------------------
    def observe(self, mat, incumbent_algorithm: str,
                key: Optional[str] = None) -> None:
        """Mirror one serving decision to the candidate. Non-blocking,
        never raises: a full queue (or a closed evaluator) drops the
        observation and counts it under ``shadow.dropped``."""
        try:
            self._c_requests.inc()
            if self._closed:
                self._c_dropped.inc()
                return
            with self._pending_lock:
                self._pending += 1
            try:
                self._queue.put_nowait((mat, incumbent_algorithm, key))
            except queue.Full:
                with self._pending_lock:
                    self._pending -= 1
                self._c_dropped.inc()
        except Exception:
            # the mirror must never surface anything into the serving path
            self._c_errors.inc()

    # -- worker --------------------------------------------------------------
    def _loop(self) -> None:
        while True:
            item = self._queue.get()
            if item is _SENTINEL:
                return
            try:
                self._evaluate(*item)
            except Exception:
                self._c_errors.inc()
            finally:
                with self._pending_lock:
                    self._pending -= 1

    def _evaluate(self, mat, incumbent: str, key: Optional[str]) -> None:
        t0 = time.perf_counter()
        cand, _ = self.selector.select(mat)
        if cand == incumbent:
            self._c_agree.inc()
            self._c_wins.inc()  # matching production is never a regression
        else:
            self._c_disagree.inc()
            key = key if key is not None else matrix_fingerprint(mat)
            f_cand = self._predicted_flops(mat, cand, key)
            f_inc = self._predicted_flops(mat, incumbent, key)
            if f_cand <= f_inc:
                self._c_wins.inc()
            else:
                self._c_losses.inc()
        self._c_evaluated.inc()
        n = self._c_evaluated.value
        self._g_agree.set(self._c_agree.value / n)
        self._g_win.set(self._c_wins.value / n)
        self._h_eval.observe(time.perf_counter() - t0)

    def _predicted_flops(self, mat, algorithm: str, key: str) -> int:
        """Counterfactual cost of serving ``mat`` under ``algorithm``:
        symbolic-factorization flops of the reordered pattern (the same
        cost model ``ExecutionPlan.predicted_flops`` carries). Memoized
        per (structure, algorithm)."""
        ck = (key, algorithm)
        with self._cache_lock:
            if ck in self._flops_cache:
                self._flops_cache.move_to_end(ck)
                return self._flops_cache[ck]
        from repro.sparse.csr import permute_symmetric
        from repro.sparse.reorder import get_reordering
        from repro.sparse.symbolic import symbolic_cholesky

        perm = get_reordering(algorithm)(mat)
        flops = int(symbolic_cholesky(permute_symmetric(mat, perm)).flops)
        with self._cache_lock:
            self._flops_cache[ck] = flops
            while len(self._flops_cache) > self._flops_cache_cap:
                self._flops_cache.popitem(last=False)
        return flops

    # -- readout / lifecycle -------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Plain-data scorecard (the promotion gate's online evidence)."""
        n = self._c_evaluated.value
        return dict(
            candidate_fingerprint=self.candidate_fingerprint,
            requests=self._c_requests.value, evaluated=n,
            agreements=self._c_agree.value,
            disagreements=self._c_disagree.value,
            wins=self._c_wins.value, losses=self._c_losses.value,
            dropped=self._c_dropped.value, errors=self._c_errors.value,
            agreement_rate=(self._c_agree.value / n) if n else None,
            win_rate=(self._c_wins.value / n) if n else None,
            backlog=self._queue.qsize())

    def drain(self, timeout: float = 30.0) -> bool:
        """Wait until every enqueued observation has been evaluated (or
        dropped); False on timeout. Tests and the promotion path use this
        so the gate reads a settled scorecard."""
        deadline = time.perf_counter() + timeout
        while time.perf_counter() < deadline:
            with self._pending_lock:
                if self._pending == 0:
                    return True
            time.sleep(0.002)
        return False

    def close(self, timeout: float = 10.0) -> None:
        """Stop the worker (pending observations are still evaluated)."""
        if self._closed:
            return
        self._closed = True
        self._queue.put(_SENTINEL)
        self._worker.join(timeout)

    def __repr__(self) -> str:
        return (f"ShadowEvaluator(candidate="
                f"{self.candidate_fingerprint[:12]}, "
                f"evaluated={self._c_evaluated.value})")
