"""Sharded, resumable labeling campaigns: the paper-scale data engine.

The paper's headline number needs argmin-solve-time labels over a large
matrix collection — a grid of (matrix × reordering algorithm) **cells**,
each one independent: reorder, symbolically analyze, factor + solve, time
it. :mod:`repro.core.labeling` runs that grid as one in-process loop; this
module turns it into an operable campaign:

* **Sharding.** Cells fan out across a worker pool in-process (one task
  per matrix, the same pool shape as the dispatcher's build workers), and
  across *processes* via ``shard_index/shard_count`` (matrices are
  partitioned round-robin) — the CLI's ``--processes N`` launches N
  shard subprocesses, one per serving-mesh slot, and assembles their
  artifacts afterwards.
* **Resume-by-artifact.** Every matrix writes one JSON label artifact
  under ``artifacts/labels/<campaign_id>/`` recording its features and the
  measured cells so far (atomic tmp + replace). A killed run restarts by
  *reading* those artifacts and measuring only the missing cells —
  completed cells are never re-labeled, which also makes process shards
  coordination-free (disjoint matrices, disjoint files).
* **Reporting.** ``run_campaign`` returns a report dict (written as
  ``BENCH_campaign.json`` by the CLI): throughput, per-algorithm win
  counts, the label-time breakdown (ordering vs symbolic vs factor vs
  solve seconds), and the labeled/skipped cell split that the CI resume
  gate checks.
* **Assembly.** A complete campaign assembles into the exact
  :class:`repro.core.labeling.LabeledDataset` layout, so
  ``train_selector`` / ``SolverEngine.train`` consume it unchanged.

    PYTHONPATH=src python -m repro.lifecycle.campaign \\
        --campaign-id tiny --count 12 --scale 0.25 --workers 4 \\
        --out BENCH_campaign.json --dataset-out artifacts/labels_tiny.npz
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import re
import subprocess
import sys
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.labeling import LabeledDataset
from repro.engine.registry import get_feature_set
from repro.sparse.csr import CSRMatrix, permute_symmetric
from repro.sparse.multifrontal import factor_and_solve_timed
from repro.sparse.reorder import LABEL_ALGORITHMS, get_reordering

__all__ = ["CampaignConfig", "CampaignResult", "run_campaign",
           "assemble_dataset", "DEFAULT_LABELS_DIR"]

DEFAULT_LABELS_DIR = os.path.join("artifacts", "labels")

#: per-cell measurement fields persisted in the matrix artifact
_CELL_FIELDS = ("time", "t_order", "t_symbolic", "t_factor", "t_solve",
                "fill", "sym_flops")


@dataclasses.dataclass
class CampaignConfig:
    """One labeling campaign's identity and execution knobs."""

    campaign_id: str
    labels_dir: str = DEFAULT_LABELS_DIR
    algorithms: Sequence[str] = tuple(LABEL_ALGORITHMS)
    feature_set: str = "paper12"
    repeats: int = 1
    backend: str = "numpy"       # front-math substrate for the label solves
    workers: int = 4             # in-process worker pool (one task/matrix)
    shard_index: int = 0         # this process labels matrices with
    shard_count: int = 1         #   index % shard_count == shard_index
    max_cells: Optional[int] = None  # stop after N fresh cells (budget /
    #                                  kill-simulation; resume finishes it)

    def __post_init__(self) -> None:
        if not 0 <= self.shard_index < self.shard_count:
            raise ValueError(
                f"shard_index {self.shard_index} not in "
                f"[0, {self.shard_count})")
        if self.repeats < 1:
            raise ValueError("repeats must be >= 1")

    @property
    def directory(self) -> str:
        return os.path.join(self.labels_dir, self.campaign_id)


@dataclasses.dataclass
class CampaignResult:
    report: Dict[str, Any]
    #: assembled only when every matrix of the input suite is fully
    #: labeled (single shard, or after all shards ran) — None otherwise
    dataset: Optional[LabeledDataset]


# ---------------------------------------------------------------------------
# per-cell measurement + per-matrix artifact I/O
# ---------------------------------------------------------------------------

def _measure_cell(a: CSRMatrix, alg: str, repeats: int,
                  backend: str) -> Dict[str, Any]:
    """One grid cell: ordering time + best-of-``repeats`` factor+solve —
    the same protocol as :func:`repro.core.labeling._measure_one`, with
    the backend selectable so campaigns can label the device paths."""
    t0 = time.perf_counter()
    perm = get_reordering(alg)(a)
    t_order = time.perf_counter() - t0
    ap = permute_symmetric(a, perm)
    best: Optional[Dict[str, Any]] = None
    for _ in range(repeats):
        r = factor_and_solve_timed(ap, backend=backend)
        if best is None or r["time"] < best["time"]:
            best = r
    assert best is not None
    best["t_order"] = t_order
    return {k: (float(best[k]) if k.startswith("t") or k == "time"
                else int(best[k]))
            for k in _CELL_FIELDS}


def _safe_name(name: str) -> str:
    return re.sub(r"[^A-Za-z0-9._-]", "_", name) or "matrix"


def _artifact_path(cfg: CampaignConfig, name: str) -> str:
    return os.path.join(cfg.directory, f"{_safe_name(name)}.json")


def _load_artifact(path: str) -> Optional[Dict[str, Any]]:
    try:
        with open(path, "r", encoding="utf-8") as f:
            rec = json.load(f)
        return rec if isinstance(rec.get("cells"), dict) else None
    except (OSError, json.JSONDecodeError):
        return None  # corrupt / partial write: relabel the matrix


def _write_artifact(path: str, rec: Dict[str, Any]) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(rec, f)
    os.replace(tmp, path)


def _fresh_record(a: CSRMatrix, cfg: CampaignConfig) -> Dict[str, Any]:
    fs = get_feature_set(cfg.feature_set)
    return dict(name=a.name, group=a.group, n=int(a.n), nnz=int(a.nnz),
                feature_set=cfg.feature_set,
                features=[float(v) for v in fs.extract(a)],
                repeats=cfg.repeats, backend=cfg.backend, cells={})


class _CellBudget:
    """Shared fresh-cell budget (``max_cells``): thread-safe take()."""

    def __init__(self, limit: Optional[int]):
        self._left = limit
        self._lock = threading.Lock()

    def take(self) -> bool:
        with self._lock:
            if self._left is None:
                return True
            if self._left <= 0:
                return False
            self._left -= 1
            return True


def _label_matrix(a: CSRMatrix, cfg: CampaignConfig, budget: _CellBudget
                  ) -> Tuple[int, int, bool]:
    """Label the missing cells of one matrix, resuming from its artifact.
    Returns (cells_labeled, cells_skipped, complete)."""
    path = _artifact_path(cfg, a.name)
    rec = _load_artifact(path)
    if rec is None or rec.get("feature_set") != cfg.feature_set:
        rec = _fresh_record(a, cfg)
    cells = rec["cells"]
    skipped = sum(1 for alg in cfg.algorithms if alg in cells)
    labeled = 0
    dirty = False
    for alg in cfg.algorithms:
        if alg in cells:
            continue
        if not budget.take():
            break
        cells[alg] = _measure_cell(a, alg, cfg.repeats, cfg.backend)
        labeled += 1
        dirty = True
        # persist after every cell: a kill between cells loses at most
        # the measurement in flight, and the artifact stays resumable
        _write_artifact(path, rec)
    if dirty and labeled == 0:  # pragma: no cover - defensive
        _write_artifact(path, rec)
    complete = all(alg in cells for alg in cfg.algorithms)
    return labeled, skipped, complete


# ---------------------------------------------------------------------------
# campaign driver
# ---------------------------------------------------------------------------

def _shard(mats: Sequence[CSRMatrix], cfg: CampaignConfig
           ) -> List[CSRMatrix]:
    return [a for i, a in enumerate(mats)
            if i % cfg.shard_count == cfg.shard_index]


def run_campaign(mats: Sequence[CSRMatrix], cfg: CampaignConfig, *,
                 metrics=None, verbose: bool = False) -> CampaignResult:
    """Label this shard's slice of the (matrix × algorithm) grid.

    Embarrassingly parallel: one worker task per matrix (matrix-level
    granularity keeps each artifact single-writer), ``cfg.workers`` tasks
    in flight — the numeric kernels release the GIL inside BLAS, and
    process-level sharding (``shard_index/shard_count``) covers the rest.
    Completed cells found on disk are skipped, never re-measured.
    """
    os.makedirs(cfg.directory, exist_ok=True)
    mine = _shard(mats, cfg)
    budget = _CellBudget(cfg.max_cells)
    t0 = time.perf_counter()
    results: List[Tuple[int, int, bool]] = []
    if cfg.workers <= 1 or len(mine) <= 1:
        for a in mine:
            results.append(_label_matrix(a, cfg, budget))
    else:
        with ThreadPoolExecutor(max_workers=cfg.workers,
                                thread_name_prefix="campaign") as pool:
            results = list(pool.map(
                lambda a: _label_matrix(a, cfg, budget), mine))
    wall = time.perf_counter() - t0

    labeled = sum(r[0] for r in results)
    skipped = sum(r[1] for r in results)
    complete_mats = sum(1 for r in results if r[2])
    if metrics is not None:
        metrics.counter("campaign.cells_labeled").inc(labeled)
        metrics.counter("campaign.cells_skipped").inc(skipped)
        metrics.counter("campaign.matrices").inc(len(mine))

    # aggregate the scorecard over *everything on disk for this shard*
    # (this run's fresh cells plus resumed ones — the campaign's state,
    # not this process invocation's)
    wins = {alg: 0 for alg in cfg.algorithms}
    breakdown = dict(order_s=0.0, symbolic_s=0.0, factor_s=0.0, solve_s=0.0)
    for a in mine:
        rec = _load_artifact(_artifact_path(cfg, a.name))
        if rec is None:
            continue
        cells = rec["cells"]
        for alg in cfg.algorithms:
            c = cells.get(alg)
            if c is None:
                continue
            breakdown["order_s"] += c["t_order"]
            breakdown["symbolic_s"] += c["t_symbolic"]
            breakdown["factor_s"] += c["t_factor"]
            breakdown["solve_s"] += c["t_solve"]
        done = {alg: cells[alg]["time"] for alg in cfg.algorithms
                if alg in cells}
        if len(done) == len(cfg.algorithms):
            wins[min(done, key=done.get)] += 1

    report = dict(
        campaign_id=cfg.campaign_id,
        shard=dict(index=cfg.shard_index, count=cfg.shard_count),
        workers=cfg.workers, backend=cfg.backend, repeats=cfg.repeats,
        algorithms=list(cfg.algorithms), feature_set=cfg.feature_set,
        matrices=len(mine), matrices_complete=complete_mats,
        cells_total=len(mine) * len(cfg.algorithms),
        cells_labeled=labeled, cells_skipped=skipped,
        cells_incomplete=(len(mine) * len(cfg.algorithms)
                          - labeled - skipped),
        wall_s=wall,
        cells_per_s=(labeled / wall) if wall > 0 and labeled else 0.0,
        per_algorithm_wins=wins, label_time_breakdown=breakdown,
        complete=(complete_mats == len(mine)))
    if verbose:
        print(f"[campaign {cfg.campaign_id}] shard "
              f"{cfg.shard_index}/{cfg.shard_count}: {labeled} cells "
              f"labeled, {skipped} resumed, "
              f"{report['cells_incomplete']} left "
              f"({wall:.2f} s, {report['cells_per_s']:.1f} cells/s)")

    dataset = None
    if cfg.shard_count == 1 and report["complete"]:
        dataset = assemble_dataset(mats, cfg)
    return CampaignResult(report=report, dataset=dataset)


def assemble_dataset(mats: Sequence[CSRMatrix],
                     cfg: CampaignConfig) -> LabeledDataset:
    """Fold the per-matrix artifacts back into a
    :class:`~repro.core.labeling.LabeledDataset` (the exact layout
    ``train_selector`` consumes). Raises if any cell is missing — run the
    remaining shards (or resume) first."""
    fs = get_feature_set(cfg.feature_set)
    algs = list(cfg.algorithms)
    m, n_alg = len(mats), len(algs)
    feats = np.zeros((m, fs.dim))
    times = np.zeros((m, n_alg))
    order_times = np.zeros((m, n_alg))
    fills = np.zeros((m, n_alg), dtype=np.int64)
    flops = np.zeros((m, n_alg), dtype=np.int64)
    names, groups = [], []
    dims = np.zeros(m, dtype=np.int64)
    nnzs = np.zeros(m, dtype=np.int64)
    for i, a in enumerate(mats):
        rec = _load_artifact(_artifact_path(cfg, a.name))
        if rec is None:
            raise RuntimeError(
                f"campaign {cfg.campaign_id!r}: no label artifact for "
                f"matrix {a.name!r} — the campaign is incomplete")
        missing = [alg for alg in algs if alg not in rec["cells"]]
        if missing:
            raise RuntimeError(
                f"campaign {cfg.campaign_id!r}: matrix {a.name!r} is "
                f"missing cells for {missing} — resume the campaign first")
        feats[i] = np.asarray(rec["features"], dtype=float)
        names.append(rec["name"])
        groups.append(rec.get("group", ""))
        dims[i], nnzs[i] = rec["n"], rec["nnz"]
        for j, alg in enumerate(algs):
            c = rec["cells"][alg]
            times[i, j] = c["time"]
            order_times[i, j] = c["t_order"]
            fills[i, j] = c["fill"]
            flops[i, j] = c["sym_flops"]
    labels = times.argmin(axis=1)
    return LabeledDataset(feats, labels, times, order_times, fills, flops,
                          names, groups, dims, nnzs, algs,
                          feature_set=cfg.feature_set)


# ---------------------------------------------------------------------------
# CLI: shard fan-out + BENCH_campaign.json + resume gate
# ---------------------------------------------------------------------------

def _spawn_shards(argv_base: List[str], processes: int) -> None:
    """Run ``processes`` shard subprocesses (one serving-mesh slot each)
    and wait; any nonzero child fails the parent."""
    procs = []
    for i in range(processes):
        cmd = [sys.executable, "-m", "repro.lifecycle.campaign",
               *argv_base, "--shard", f"{i}/{processes}"]
        procs.append(subprocess.Popen(cmd))
    codes = [p.wait() for p in procs]
    bad = [c for c in codes if c != 0]
    if bad:
        raise SystemExit(f"{len(bad)}/{processes} campaign shard "
                         f"processes failed (exit codes {codes})")


def parse_args(argv=None):
    p = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    p.add_argument("--campaign-id", default=None,
                   help="campaign identity (default derived from "
                        "count/seed/scale); artifacts land under "
                        "<labels-dir>/<campaign-id>/")
    p.add_argument("--labels-dir", default=DEFAULT_LABELS_DIR)
    p.add_argument("--count", type=int, default=12,
                   help="suite size (repro.sparse.dataset.generate_suite)")
    p.add_argument("--seed", type=int, default=7)
    p.add_argument("--scale", type=float, default=0.25,
                   help="suite size_scale")
    p.add_argument("--repeats", type=int, default=1)
    p.add_argument("--backend", default="numpy",
                   choices=["numpy", "pallas", "batched", "pipelined"])
    p.add_argument("--feature-set", default="paper12")
    p.add_argument("--workers", type=int, default=4)
    p.add_argument("--shard", default="0/1", metavar="I/N",
                   help="label only matrices with index %% N == I")
    p.add_argument("--processes", type=int, default=0,
                   help="fan the campaign out over N shard subprocesses "
                        "(then assemble); 0 = this process only")
    p.add_argument("--max-cells", type=int, default=None,
                   help="stop after labeling N fresh cells (budgeted / "
                        "kill-simulation runs; a later run resumes)")
    p.add_argument("--out", default="BENCH_campaign.json",
                   help="campaign report path ('' to skip)")
    p.add_argument("--dataset-out", default=None,
                   help="write the assembled LabeledDataset .npz here "
                        "(requires a complete campaign)")
    p.add_argument("--gate-resume", action="store_true",
                   help="exit nonzero unless this run *resumed* work "
                        "(cells_skipped > 0 and the campaign completed) — "
                        "the CI resume-correctness gate")
    return p.parse_args(argv)


def main(argv=None) -> int:
    args = parse_args(argv)
    try:
        shard_index, shard_count = map(int, args.shard.split("/"))
    except ValueError:
        raise SystemExit(f"--shard must be I/N, got {args.shard!r}")
    campaign_id = (args.campaign_id
                   or f"c{args.count}_s{args.seed}_x{args.scale:g}")

    if args.processes > 0:
        base = ["--campaign-id", campaign_id,
                "--labels-dir", args.labels_dir,
                "--count", str(args.count), "--seed", str(args.seed),
                "--scale", str(args.scale), "--repeats", str(args.repeats),
                "--backend", args.backend,
                "--feature-set", args.feature_set,
                "--workers", str(args.workers), "--out", ""]
        if args.max_cells is not None:
            base += ["--max-cells", str(args.max_cells)]
        _spawn_shards(base, args.processes)

    from repro.sparse.dataset import generate_suite
    mats = list(generate_suite(count=args.count, seed=args.seed,
                               size_scale=args.scale))
    cfg = CampaignConfig(
        campaign_id=campaign_id, labels_dir=args.labels_dir,
        feature_set=args.feature_set, repeats=args.repeats,
        backend=args.backend, workers=args.workers,
        shard_index=shard_index, shard_count=shard_count,
        # after a subprocess fan-out this invocation only aggregates +
        # assembles: the children already spent the cell budget
        max_cells=(0 if args.processes > 0 else args.max_cells))
    res = run_campaign(mats, cfg, verbose=True)

    if args.out:
        with open(args.out, "w", encoding="utf-8") as f:
            json.dump(res.report, f, indent=2)
        print(f"[campaign {campaign_id}] report → {args.out}")
    if args.dataset_out:
        if res.dataset is None:
            ds = assemble_dataset(mats, cfg)  # raises if incomplete
        else:
            ds = res.dataset
        ds.save(args.dataset_out)
        print(f"[campaign {campaign_id}] dataset "
              f"({len(ds.names)} matrices) → {args.dataset_out}")
    if args.gate_resume:
        r = res.report
        ok = r["cells_skipped"] > 0 and r["complete"]
        print(f"[campaign {campaign_id}] resume gate: "
              f"skipped={r['cells_skipped']} labeled={r['cells_labeled']} "
              f"complete={r['complete']} → {'OK' if ok else 'FAIL'}")
        if not ok:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
