#!/usr/bin/env python
"""engine status: render a bundle's report card, a registry's lineage,
and/or a live server's stats.

    PYTHONPATH=src python scripts/engine_status.py --bundle selector.bundle
    PYTHONPATH=src python scripts/engine_status.py --host 127.0.0.1 --port 7077
    PYTHONPATH=src python scripts/engine_status.py --registry artifacts/bundles

Three independent views, composable in one invocation:

* ``--bundle PATH`` — load a :class:`SelectorBundle` (validating it) and
  render its schema-v2 report card: fingerprint, model/scaler/feature-set
  names, held-out accuracy, per-algorithm recall, the confusion matrix,
  and the dataset provenance.
* ``--registry DIR`` — render a :class:`repro.lifecycle.registry
  .BundleRegistry`: every registered version with its status, accuracy,
  and the lineage chain of the serving bundle (which retrains produced
  production).
* ``--host/--port`` — connect a :class:`PlanRPCClient` to a running plan
  server and print its live ``stats()`` (requests, hit rates, shed /
  rejected counts, queue depth, latency percentiles) plus the structured
  metrics snapshot (``--metrics`` for every instrument), including the
  shadow-evaluation scorecard and the serving mesh's per-shard
  utilization when those subsystems are active.

Stdlib + repro only; exits nonzero if a requested view cannot be produced.
"""
from __future__ import annotations

import argparse
import sys


def _fmt_pct(x) -> str:
    return "—" if x is None else f"{100.0 * float(x):5.1f}%"


def render_bundle(path: str) -> int:
    from repro.engine.bundle import BundleValidationError, SelectorBundle

    try:
        b = SelectorBundle.load(path)
    except (OSError, BundleValidationError) as exc:
        print(f"[engine-status] cannot load bundle {path!r}: {exc}")
        return 1
    print(f"bundle      {path}")
    print(f"schema      v{b.schema_version}")
    print(f"fingerprint {b.fingerprint}")
    print(f"model       {b.model_name}   scaler {b.scaler_name}")
    print(f"features    {b.feature_set} ({len(list(b.feature_names))} dims)")
    print(f"algorithms  {', '.join(b.algorithms)}")
    rc = b.report_card
    if not rc:
        print("report card —  (schema v1 bundle, or saved without training)")
        return 0
    print(f"report card")
    print(f"  test accuracy  {_fmt_pct(rc.get('test_accuracy'))}"
          + (f"   cv score {_fmt_pct(rc.get('cv_score'))}"
             if rc.get("cv_score") is not None else ""))
    recall = rc.get("per_algorithm_recall") or {}
    support = rc.get("test_support") or {}
    for alg in b.algorithms:
        if alg in recall:
            sup = support.get(alg)
            print(f"  recall {alg:<12} {_fmt_pct(recall[alg])}"
                  + (f"   (n={sup})" if sup is not None else ""))
    conf = rc.get("confusion")
    if conf:
        width = max(len(a) for a in b.algorithms)
        head = " ".join(f"{a[:6]:>6}" for a in b.algorithms)
        print(f"  confusion (rows=true)  {'':<{width}} {head}")
        for alg, row in zip(b.algorithms, conf):
            cells = " ".join(f"{int(c):>6}" for c in row)
            print(f"  {'':<21}  {alg:<{width}} {cells}")
    prov = b.provenance
    if prov:
        print(f"provenance  {prov.get('n_samples')} samples, "
              f"feature set {prov.get('feature_set')}, "
              f"dims {prov.get('dim_range')}, nnz {prov.get('nnz_range')}")
        counts = prov.get("label_counts") or {}
        if counts:
            print("  labels      "
                  + ", ".join(f"{k}: {v}" for k, v in counts.items()))
    return 0


def render_registry(root: str) -> int:
    from repro.lifecycle.registry import BundleRegistry, BundleRegistryError

    try:
        reg = BundleRegistry(root)
        entries = reg.entries()
        serving = reg.serving_version()
        previous = reg.previous_version()
    except (OSError, BundleRegistryError) as exc:
        print(f"[engine-status] cannot read registry {root!r}: {exc}")
        return 1
    if not entries:
        print(f"registry    {root}  (empty)")
        return 0
    print(f"registry    {root}  ({len(entries)} bundles)")
    for e in entries:
        mark = ("▶" if e["version"] == serving
                else "↩" if e["version"] == previous else " ")
        acc = e.get("test_accuracy")
        print(f"  {mark} {e['version']}  {e['status']:<11} "
              f"model={e.get('model')}  acc={_fmt_pct(acc).strip()}"
              + (f"  source={e['source']}" if e.get("source") else ""))
    chain = reg.lineage()
    if chain:
        arrows = " → ".join(e["version"] for e in reversed(chain))
        print(f"lineage     {arrows}  (oldest → serving)")
    if previous:
        print(f"rollback    would restore {previous}")
    return 0


def _render_shadow_panel(m: dict) -> None:
    """The shadow.* scorecard, when a candidate is (or was) riding."""
    if not m.get("shadow.requests"):
        return
    n = m.get("shadow.evaluated", 0)
    print(f"shadow      {int(m['shadow.requests'])} mirrored, "
          f"{int(n)} evaluated "
          f"({int(m.get('shadow.agreements', 0))} agree / "
          f"{int(m.get('shadow.disagreements', 0))} disagree), "
          f"{int(m.get('shadow.dropped', 0))} dropped")
    if n:
        print(f"  agreement rate {_fmt_pct(m.get('shadow.agreement_rate'))}"
              f"   win rate {_fmt_pct(m.get('shadow.win_rate'))}"
              f"   (counterfactual predicted flops)")


def _render_refine_histogram(m: dict) -> None:
    """Refinement-iteration distribution from the solve.refine_iters.<i>
    counters (the last bucket, 8, collects everything beyond it)."""
    counts = {}
    for k, v in m.items():
        if k.startswith("solve.refine_iters."):
            counts[int(k.rsplit(".", 1)[-1])] = int(v)
    if not counts:
        return
    total = sum(counts.values())
    peak = max(counts.values())
    print(f"  refine iterations ({total} refined solves, "
          f"mean {m.get('solve.refine_iterations.mean', 0.0):.1f})")
    for i in sorted(counts):
        bar = "█" * max(1, round(counts[i] / peak * 24))
        label = f"{i}+" if i >= 8 else f"{i} "
        print(f"    {label} {bar} {counts[i]}")


def _render_mesh_panel(m: dict) -> None:
    """Per-shard serving-mesh utilization from the mesh.* instruments."""
    nd = int(m.get("mesh.shards", 0) or 0)
    if nd <= 0:
        return
    rows = []
    for i in range(nd):
        req = m.get(f"mesh.shard{i}.requests")
        pad = m.get(f"mesh.shard{i}.pad_rows")
        if req is None:
            break
        rows.append((i, int(req), int(pad or 0)))
    if not rows:
        return
    print(f"mesh        {nd} shard(s), per-shard rows (real/pad):")
    for i, req, pad in rows:
        total = req + pad
        waste = (pad / total) if total else 0.0
        print(f"  shard {i:<3} {req:>8} real  {pad:>8} pad  "
              f"({waste * 100:4.1f}% waste)")


def render_server(host: str, port: int, show_all_metrics: bool) -> int:
    from repro.launch.rpc import PlanRPCClient

    try:
        client = PlanRPCClient(host, port, timeout=30, connect_retries=1)
    except ConnectionError as exc:
        print(f"[engine-status] cannot reach {host}:{port}: {exc}")
        return 1
    with client as c:
        pong = c.ping()
        s = c.stats()
        try:
            m = c.metrics()
        except Exception:  # pre-metrics server
            m = {}
    print(f"server      {host}:{port}  up {pong.get('uptime_s', 0.0):.0f} s")
    print(f"fingerprint-versioned cache: "
          f"{s.get('size', 0)}/{s.get('capacity', 0)} in memory"
          + (f", {s.get('disk_entries')} on disk"
             if s.get("disk_entries") is not None else ""))
    total = s.get("requests", 0)
    print(f"traffic     {total} requests: {s.get('warm_hits', 0)} warm, "
          f"{s.get('shed', 0)} shed, {s.get('rejected', 0)} rejected, "
          f"{s.get('errors', 0)} errors")
    print(f"cache       hit rate {s.get('hit_rate', 0.0):.2f} "
          f"({s.get('hits', 0)} hits / {s.get('misses', 0)} misses"
          + (f", {s.get('disk_hits')} disk" if "disk_hits" in s else "")
          + ")")
    if "p50_ms" in s:
        print(f"latency     p50 {s['p50_ms']:.2f} ms   "
              f"p99 {s['p99_ms']:.2f} ms   mean {s['mean_ms']:.2f} ms")
    for stage in ("queue", "select", "build"):
        k = f"stage_{stage}_p50_ms"
        if k in s:
            print(f"  stage {stage:<7} p50 {s[k]:8.2f} ms   "
                  f"p99 {s[f'stage_{stage}_p99_ms']:8.2f} ms")
    # numeric solve-stage breakdown (repro.core.plan.execute_plan mirrors
    # its RequestContext spans into stage.* histograms): host assembly vs
    # device-blocked time vs triangular sweeps
    solve_stages = [st for st in ("permute", "factor", "factor.assemble",
                                  "factor.device", "solve.sweep",
                                  "solve.refine")
                    if f"stage.{st}.p50" in m]
    if solve_stages:
        print("solve stages")
        for st in solve_stages:
            print(f"  {st:<16} p50 {m[f'stage.{st}.p50'] * 1e3:8.2f} ms   "
                  f"p99 {m[f'stage.{st}.p99'] * 1e3:8.2f} ms   "
                  f"n={int(m.get(f'stage.{st}.count', 0))}")
        ov = m.get("solve.overlap_efficiency")
        if ov is not None:
            print(f"  overlap efficiency {ov:.2f} "
                  f"(host-busy fraction of assembly + device wait)")
        # which triangular-sweep substrate served the solves
        modes = {k.rsplit(".", 1)[-1]: int(m[k]) for k in m
                 if k.startswith("solve.sweep.") and k.count(".") == 2}
        if modes:
            print("  sweep backends  "
                  + "  ".join(f"{mode}={cnt}"
                              for mode, cnt in sorted(modes.items())))
        _render_refine_histogram(m)
    _render_shadow_panel(m)
    _render_mesh_panel(m)
    print(f"queue       depth {s.get('queue_depth', 0)}"
          + (f" / max_queue {s.get('max_queue')}"
             if s.get("max_queue") else " (unbounded)")
          + f", {s.get('inflight_keys', 0)} builds in flight")
    print(f"cold stages {s.get('select_calls', 0)} select calls "
          f"({s.get('select_seconds', 0.0) * 1e3:.0f} ms), "
          f"{s.get('plans_built', 0)} plans built "
          f"({s.get('build_seconds', 0.0) * 1e3:.0f} ms)")
    if m and show_all_metrics:
        print("metrics")
        for k in sorted(m):
            v = m[k]
            print(f"  {k:<32} "
                  + (f"{v:.4f}" if isinstance(v, float) else str(v)))
    elif m:
        interesting = [k for k in sorted(m)
                       if not k.rsplit(".", 1)[-1] in ("sum", "mean")]
        shown = ", ".join(f"{k.split('.', 1)[-1]}={m[k]:.0f}"
                          for k in interesting
                          if isinstance(m[k], (int, float))
                          and k.startswith(("rpc.", "dispatch."))
                          and not k.endswith(("_s.p50", "_s.p99",
                                              "_s.count")))
        if shown:
            print(f"metrics     {shown}  (--metrics for all)")
    return 0


def main() -> int:
    p = argparse.ArgumentParser(
        description="Render a SelectorBundle report card and/or a live "
                    "plan server's stats + metrics.")
    p.add_argument("--bundle", default=None,
                   help="path to a SelectorBundle to render")
    p.add_argument("--registry", default=None, metavar="DIR",
                   help="bundle registry directory to render "
                        "(versions, statuses, serving lineage)")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=None,
                   help="RPC port of a running plan server")
    p.add_argument("--metrics", action="store_true",
                   help="print the full metrics snapshot")
    args = p.parse_args()
    if args.bundle is None and args.port is None and args.registry is None:
        p.error("nothing to do: pass --bundle, --registry, and/or --port")
    rc = 0
    shown = False
    if args.bundle:
        rc |= render_bundle(args.bundle)
        shown = True
    if args.registry:
        if shown:
            print()
        rc |= render_registry(args.registry)
        shown = True
    if args.port is not None:
        if shown:
            print()
        rc |= render_server(args.host, args.port, args.metrics)
    return rc


if __name__ == "__main__":
    sys.exit(main())
