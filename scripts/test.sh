#!/usr/bin/env bash
# Tier-1 verification — the exact command CI and the ROADMAP use.
#
#   scripts/test.sh              # full suite, fail-fast
#   scripts/test.sh tests/test_features.py -k jnp   # pass-through args
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
