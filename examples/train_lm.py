"""End-to-end driver: train a ~100M-class model for a few hundred steps with
the full production stack (AdamW+ZeRO, cosine schedule, checkpointing,
fault-tolerant loop, synthetic data pipeline).

Default is a width-reduced llama3.2 (~26M params) for CPU practicality; pass
--full-width for the real 100M-class run (slower on CPU).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import dataclasses

from repro.configs import get_smoke_config
from repro.models.config import ShapeSpec
from repro.train import Trainer, TrainerConfig


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--full-width", action="store_true")
    p.add_argument("--ckpt-dir", default="/tmp/repro_example_lm")
    args = p.parse_args()

    cfg = get_smoke_config("llama3.2-1b")
    if args.full_width:
        cfg = dataclasses.replace(cfg, d_model=512, num_layers=8,
                                  num_heads=8, num_kv_heads=4, d_ff=2048,
                                  vocab_size=32000, name="llama-100m")
    else:
        cfg = dataclasses.replace(cfg, d_model=256, num_layers=4,
                                  num_heads=8, num_kv_heads=4, d_ff=1024,
                                  vocab_size=8192, name="llama-26m")
    from repro.models.transformer import ModelConfig  # noqa: F401
    print(f"model: {cfg.name}, params ≈ {cfg.param_count()/1e6:.0f}M")

    shape = ShapeSpec("train", seq_len=256, global_batch=8, kind="train")
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=100,
                         total_steps=args.steps,
                         warmup_steps=max(args.steps // 20, 10),
                         log_every=20)
    trainer = Trainer(cfg, shape, tcfg)
    losses = []
    trainer.run(args.steps, on_metrics=lambda s, m: losses.append(m["loss"]))
    print(f"loss: {losses[0]:.3f} → {losses[-1]:.3f} over {args.steps} steps")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
