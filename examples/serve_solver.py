"""Solver-as-a-service: batched sparse solve requests with learned ordering
selection — the paper's deployment scenario, through
:class:`repro.engine.SolverEngine`.

A stream of solve requests (matrix + rhs) arrives; ``engine.solve`` plans
each structure (cached ExecutionPlan: selection + permutation + symbolic
analysis run once per structure) and runs the multifrontal solver. Compares
total service time vs an AMD-only policy, and shows the on-device
(block-ELL SpMV Pallas kernel) residual check.

    PYTHONPATH=src python examples/serve_solver.py
"""
import time

import numpy as np

from repro.core.labeling import run_labeling_campaign
from repro.core.plan import execute_plan
from repro.engine import EngineConfig, SolverEngine
from repro.kernels import ops
from repro.sparse.dataset import generate_suite


def main():
    print("== training the engine on a small campaign")
    mats = list(generate_suite(count=48, seed=3, size_scale=0.5))
    ds = run_labeling_campaign(mats)
    engine = SolverEngine(EngineConfig(
        model="random_forest", fast_grids=True, cv=3,
        cache_dir=None, path="host"))
    rep = engine.train(ds)
    print(f"   selector accuracy {rep['test_accuracy']:.2%} "
          f"(fingerprint {engine.fingerprint[:12]})")

    print("== serving 8 requests")
    rng = np.random.default_rng(11)
    requests = list(generate_suite(count=8, seed=123, size_scale=0.6))
    t_sel_total = t_amd_total = 0.0
    for a in requests:
        b = rng.standard_normal(a.n)
        t0 = time.perf_counter()
        res = engine.solve(a, b)
        t_sel = time.perf_counter() - t0
        t0 = time.perf_counter()
        execute_plan(a, engine.builder.build(a, algorithm="amd"), b)
        t_amd = time.perf_counter() - t0
        t_sel_total += t_sel
        t_amd_total += t_amd
        # on-device residual check through the block-ELL SpMV kernel
        ax = ops.spmv(a.indptr, a.indices, a.data,
                      res["x"].astype(np.float32))
        resid = np.linalg.norm(ax - b) / np.linalg.norm(b)
        print(f"   {a.name:16s} → {res['algorithm']:6s} "
              f"solve {t_sel*1e3:6.1f} ms (amd {t_amd*1e3:6.1f} ms)  "
              f"residual {resid:.2e}")
    print(f"== totals: selected {t_sel_total*1e3:.0f} ms vs AMD-only "
          f"{t_amd_total*1e3:.0f} ms "
          f"({(1 - t_sel_total / t_amd_total) * 100:+.1f}% reduction)")


if __name__ == "__main__":
    main()
