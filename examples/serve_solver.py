"""Solver-as-a-service: batched sparse solve requests with learned ordering
selection — the paper's deployment scenario.

A stream of solve requests (matrix + rhs) arrives; per request the service
extracts features, predicts the ordering, and runs the multifrontal solver.
Compares total service time vs an AMD-only policy, and shows the on-device
(block-ELL SpMV Pallas kernel) residual check.

    PYTHONPATH=src python examples/serve_solver.py
"""
import time

import numpy as np

from repro.core.labeling import run_labeling_campaign
from repro.core.selector import train_selector
from repro.kernels import ops
from repro.sparse.csr import permute_symmetric
from repro.sparse.dataset import generate_suite
from repro.sparse.multifrontal import (multifrontal_cholesky,
                                       multifrontal_solve)
from repro.sparse.reorder import get_reordering


def solve_with(alg, a, b):
    t0 = time.perf_counter()
    perm = get_reordering(alg)(a)
    ap = permute_symmetric(a, perm)
    f = multifrontal_cholesky(ap)
    xp = multifrontal_solve(f, b[perm])
    x = np.empty_like(xp)
    x[perm] = xp
    return x, time.perf_counter() - t0


def main():
    print("== training the selector on a small campaign")
    mats = list(generate_suite(count=48, seed=3, size_scale=0.5))
    ds = run_labeling_campaign(mats)
    sel, rep = train_selector(ds, "random_forest", "standard", fast=True,
                              cv=3)
    print(f"   selector accuracy {rep['test_accuracy']:.2%}")

    print("== serving 8 requests")
    rng = np.random.default_rng(11)
    requests = list(generate_suite(count=8, seed=123, size_scale=0.6))
    t_sel_total = t_amd_total = 0.0
    for a in requests:
        b = rng.standard_normal(a.n)
        alg, t_pred = sel.select(a)
        x, t_sel = solve_with(alg, a, b)
        _, t_amd = solve_with("amd", a, b)
        t_sel_total += t_sel + t_pred
        t_amd_total += t_amd
        # on-device residual check through the block-ELL SpMV kernel
        ax = ops.spmv(a.indptr, a.indices, a.data, x.astype(np.float32))
        resid = np.linalg.norm(ax - b) / np.linalg.norm(b)
        print(f"   {a.name:16s} → {alg:6s} solve {t_sel*1e3:6.1f} ms "
              f"(amd {t_amd*1e3:6.1f} ms)  residual {resid:.2e}")
    print(f"== totals: selected {t_sel_total*1e3:.0f} ms vs AMD-only "
          f"{t_amd_total*1e3:.0f} ms "
          f"({(1 - t_sel_total / t_amd_total) * 100:+.1f}% reduction)")


if __name__ == "__main__":
    main()
