"""Quickstart: the paper's pipeline end to end, in under a minute.

1. Generate a small Florida-like matrix suite.
2. Measure factor+solve time per reordering (AMD/SCOTCH/ND/RCM) → labels.
3. Train the selector (random forest + standardization, grid-searched).
4. Predict the ordering for an unseen matrix and solve with it.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro.core.labeling import run_labeling_campaign
from repro.core.selector import train_selector
from repro.sparse.csr import permute_symmetric
from repro.sparse.dataset import generate_suite
from repro.sparse.multifrontal import factor_and_solve_timed
from repro.sparse.reorder import get_reordering


def main():
    print("== 1. generating 120 matrices (small scale)")
    mats = list(generate_suite(count=120, seed=1, size_scale=0.5))

    print("== 2. labeling campaign (4 orderings × 60 matrices)")
    t0 = time.perf_counter()
    ds = run_labeling_campaign(mats)
    dist = {a: int((ds.labels == i).sum()) for i, a in enumerate(ds.algorithms)}
    print(f"   done in {time.perf_counter()-t0:.1f}s; winners: {dist}")

    print("== 3. training the selector (RF + standardization)")
    sel, rep = train_selector(ds, "random_forest", "standard", fast=True,
                              cv=3)
    print(f"   test accuracy {rep['test_accuracy']:.2%}, "
          f"solve-time reduction vs AMD-only {rep['reduction_vs_amd']:.2%}, "
          f"mean speedup {rep['mean_speedup_vs_amd']:.2f}x")
    print("   (tiny-sample demo — the full 960-matrix campaign in "
          "benchmarks/run.py is the real evaluation)")

    print("== 4. selecting + solving an unseen matrix")
    unseen = list(generate_suite(count=3, seed=99, size_scale=0.6))[0]
    alg, dt = sel.select(unseen)
    print(f"   {unseen.name}: predicted ordering = {alg} "
          f"(prediction took {dt*1e3:.1f} ms)")
    perm = get_reordering(alg)(unseen)
    stats = factor_and_solve_timed(permute_symmetric(unseen, perm))
    amd_stats = factor_and_solve_timed(
        permute_symmetric(unseen, get_reordering("amd")(unseen)))
    print(f"   solve with {alg}: {stats['time']*1e3:.1f} ms "
          f"(fill {stats['fill']}); with amd: {amd_stats['time']*1e3:.1f} ms "
          f"(fill {amd_stats['fill']}); residual {stats['residual']:.1e}")


if __name__ == "__main__":
    main()
