"""Quickstart: the paper's pipeline end to end, in under a minute —
through the one production API, :class:`repro.engine.SolverEngine`.

1. Generate a small Florida-like matrix suite.
2. Measure factor+solve time per reordering (AMD/SCOTCH/ND/RCM) → labels.
3. ``engine.train(ds)``: selector (random forest + standardization,
   grid-searched) with a fingerprinted model.
4. ``engine.select`` / ``engine.solve`` on an unseen matrix, and
   ``engine.save`` → a versioned SelectorBundle artifact.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile
import time

from repro.core.labeling import run_labeling_campaign
from repro.engine import EngineConfig, SolverEngine
from repro.sparse.dataset import generate_suite


def main():
    print("== 1. generating 120 matrices (small scale)")
    mats = list(generate_suite(count=120, seed=1, size_scale=0.5))

    print("== 2. labeling campaign (4 orderings × 60 matrices)")
    t0 = time.perf_counter()
    ds = run_labeling_campaign(mats)
    dist = {a: int((ds.labels == i).sum()) for i, a in enumerate(ds.algorithms)}
    print(f"   done in {time.perf_counter()-t0:.1f}s; winners: {dist}")

    print("== 3. training the engine (RF + standardization)")
    engine = SolverEngine(EngineConfig(
        model="random_forest", scaling="standard", fast_grids=True, cv=3,
        cache_dir=None,  # demo stays in-memory; serving uses the disk tier
        path="host"))
    rep = engine.train(ds)
    print(f"   test accuracy {rep['test_accuracy']:.2%}, "
          f"solve-time reduction vs AMD-only {rep['reduction_vs_amd']:.2%}, "
          f"mean speedup {rep['mean_speedup_vs_amd']:.2f}x")
    print(f"   model fingerprint {engine.fingerprint[:16]} "
          "(versions the plan cache automatically)")
    print("   (tiny-sample demo — the full 960-matrix campaign in "
          "benchmarks/run.py is the real evaluation)")

    print("== 4. selecting + solving an unseen matrix")
    unseen = list(generate_suite(count=3, seed=99, size_scale=0.6))[0]
    alg, dt = engine.select(unseen)
    print(f"   {unseen.name}: predicted ordering = {alg} "
          f"(prediction took {dt*1e3:.1f} ms)")
    res = engine.solve(unseen)
    # same pipeline forced to AMD, for the comparison the paper reports
    from repro.core.plan import execute_plan
    res_amd = execute_plan(unseen,
                           engine.builder.build(unseen, algorithm="amd"))
    print(f"   solve with {alg}: {res['time']*1e3:.1f} ms "
          f"(nnz_L {res['nnz_L']}); with amd: {res_amd['time']*1e3:.1f} ms "
          f"(nnz_L {res_amd['nnz_L']}); residual {res['residual']:.1e}")

    print("== 5. persisting the trained engine as a SelectorBundle")
    with tempfile.TemporaryDirectory() as d:
        path = engine.save(os.path.join(d, "selector.bundle"))
        engine2 = SolverEngine.load(path)
        alg2, _ = engine2.select(unseen)
        print(f"   round-trip OK: fingerprint matches "
              f"{engine2.fingerprint == engine.fingerprint}, "
              f"same selection {alg2 == alg}")


if __name__ == "__main__":
    main()
