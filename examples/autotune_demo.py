"""Beyond-paper demo: the paper's selection idea applied to execution plans.

Loads the dry-run artifact table (roofline terms per arch × shape × mesh ×
plan), trains the plan selector on it, and recommends plans for every
assigned architecture.

    PYTHONPATH=src python examples/autotune_demo.py
"""
from repro.autotune import CANDIDATE_PLANS, PlanSelector
from repro.autotune.plan_selector import load_artifacts
from repro.configs import ARCH_NAMES, get_config
from repro.models.config import SHAPES


def main():
    arts = load_artifacts("artifacts/dryrun")
    print(f"loaded {len(arts)} dry-run artifacts")
    sel = PlanSelector(min_samples=8).fit(artifacts=arts)
    mode = "learned" if sel.model is not None else "analytic fallback"
    print(f"plan selector mode: {mode}")
    print(f"{'arch':24s} {'shape':12s} plan")
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape_name in ("train_4k", "decode_32k"):
            shape = SHAPES[shape_name]
            name, plan = sel.recommend(cfg, shape, 16, 16)
            print(f"{arch:24s} {shape_name:12s} {name} "
                  f"(fsdp={plan.fsdp_params}, moe={plan.moe_impl}, "
                  f"remat={plan.remat})")


if __name__ == "__main__":
    main()
