"""Symbolic factorization vs a dense Cholesky oracle."""
import numpy as np
import pytest

from repro.sparse.symbolic import (cholesky_flops, column_counts, etree,
                                   postorder, supernodes, symbolic_cholesky)


def dense_chol_pattern(a, tol=1e-12):
    """Nonzero pattern of L from dense Cholesky (no-cancellation values)."""
    L = np.linalg.cholesky(a)
    return np.abs(L) > tol


@pytest.mark.parametrize("idx", [0, 1, 2, 3, 4])
def test_symbolic_pattern_matches_dense(idx, small_suite):
    m = small_suite[idx]
    if m.n > 200:
        pytest.skip("dense oracle too big")
    sym = symbolic_cholesky(m)
    patt = dense_chol_pattern(m.to_dense())
    for j in range(m.n):
        ours = set(sym.Li[sym.Lp[j]:sym.Lp[j + 1]].tolist())
        dense = set(np.nonzero(patt[:, j])[0].tolist())
        # symbolic must be a superset (numeric cancellation can only shrink)
        assert dense <= ours, (j, dense - ours)
    # counts consistent with pattern
    np.testing.assert_array_equal(sym.counts, np.diff(sym.Lp))


def test_counts_equal_pattern_sizes(small_suite):
    for m in small_suite:
        sym = symbolic_cholesky(m)
        counts = column_counts(m)
        np.testing.assert_array_equal(counts, sym.counts)


def test_etree_parents_increase(small_suite):
    for m in small_suite:
        parent = etree(m)
        j = np.arange(m.n)
        ok = (parent == -1) | (parent > j)
        assert ok.all()


def test_postorder_is_permutation(small_suite):
    for m in small_suite:
        po = postorder(etree(m))
        assert np.array_equal(np.sort(po), np.arange(m.n))


def test_flops_positive_and_consistent(small_suite):
    for m in small_suite:
        sym = symbolic_cholesky(m)
        assert sym.flops == cholesky_flops(m)
        assert sym.flops >= m.n  # at least one sqrt per column


def test_supernodes_partition(small_suite):
    for m in small_suite:
        sym = symbolic_cholesky(m)
        ptr, of = supernodes(sym)
        assert ptr[0] == 0 and ptr[-1] == m.n
        assert (np.diff(ptr) > 0).all()
        for k in range(len(ptr) - 1):
            assert (of[ptr[k]:ptr[k + 1]] == k).all()
