"""Autotune plan selector + HLO analyzer unit behaviour."""
import numpy as np

from repro.autotune import CANDIDATE_PLANS, PlanSelector, workload_features
from repro.configs import get_config
from repro.distributed.sharding import ExecutionPlan
from repro.launch.hlo_analysis import analyze_hlo
from repro.models.config import SHAPES


def _fake_record(arch, shape, mesh, plan_name, dom, resident=8e9):
    plan = CANDIDATE_PLANS[plan_name]
    return dict(arch=arch, shape=shape, mesh=mesh, status="ok",
                plan=dict(plan.__dict__),
                resident_bytes=resident,
                roofline=dict(compute_s=dom, memory_s=dom * 0.5,
                              collective_s=dom * 0.2))


def test_plan_selector_learns_from_artifacts():
    arts = []
    archs = ["llama3.2-1b", "qwen3-1.7b", "codeqwen1.5-7b", "starcoder2-7b",
             "phi3.5-moe-42b-a6.6b", "moonshot-v1-16b-a3b",
             "jamba-v0.1-52b", "musicgen-large"]
    # synthetic ground truth: big models prefer fsdp, small prefer baseline
    for arch in archs:
        big = get_config(arch).param_count() > 5e9
        for shape in ["train_4k", "prefill_32k"]:
            better, worse = (("fsdp", "baseline") if big
                             else ("baseline", "fsdp"))
            arts.append(_fake_record(arch, shape, "pod16x16", better, 1.0))
            arts.append(_fake_record(arch, shape, "pod16x16", worse, 2.0))
    sel = PlanSelector(min_samples=8).fit(artifacts=arts)
    assert sel.model is not None
    name, plan = sel.recommend(get_config("phi3.5-moe-42b-a6.6b"),
                               SHAPES["train_4k"], 16, 16)
    assert name == "fsdp"
    name2, _ = sel.recommend(get_config("llama3.2-1b"), SHAPES["train_4k"],
                             16, 16)
    assert name2 == "baseline"


def test_plan_selector_analytic_fallback():
    sel = PlanSelector()  # not fitted
    name, plan = sel.recommend(get_config("phi3.5-moe-42b-a6.6b"),
                               SHAPES["train_4k"], 16, 16)
    assert isinstance(plan, ExecutionPlan)
    assert name in CANDIDATE_PLANS


def test_workload_features_finite():
    f = workload_features(get_config("jamba-v0.1-52b"), SHAPES["decode_32k"],
                          16, 16)
    assert np.isfinite(f).all()


def test_analyze_hlo_trip_counts():
    """The analyzer multiplies while bodies by known_trip_count (validated
    against an unrolled reference)."""
    import jax
    import jax.numpy as jnp

    def body(x, w):
        return jnp.tanh(x @ w), None

    def f_scan(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
    st = analyze_hlo(jax.jit(f_scan).lower(x, ws).compile().as_text())
    expect = 6 * 2 * 64 * 128 * 128
    assert abs(st.dot_flops - expect) / expect < 0.01
    assert st.unknown_trip_loops == 0
