"""Backpressure + deadlines on the RequestContext spine.

These tests drive :class:`PlanDispatcher` directly with stub selectors
whose timing the test controls (an Event-gated selector to hold the
batcher mid-select, a sleepy selector to burn a deadline between dequeue
and build), so queue-full rejection, shed-at-dequeue, shed-before-build,
priority ordering, and close() semantics are all deterministic — no model
training, no RPC sockets.
"""
import threading
import time

import pytest

from repro.core.dispatch import PlanDispatcher
from repro.core.plan import PlanBuilder
from repro.core.plan_cache import PlanCache, matrix_fingerprint
from repro.core.reqctx import (SERVING_ERRORS, DeadlineExceeded,
                               DispatcherClosed, QueueFull, RequestContext,
                               ServingError)
from repro.sparse.dataset import generate_suite


@pytest.fixture(scope="module")
def mats():
    return list(generate_suite(count=8, seed=3, size_scale=0.25))


class _GatedSelector:
    """Blocks the *first* select_batch until ``release`` is set; records
    the fingerprint order in which matrices reach selection."""

    def __init__(self):
        self.entered = threading.Event()
        self.release = threading.Event()
        self.order = []
        self._calls = 0

    def select_batch(self, batch, path="host", use_pallas=False):
        self._calls += 1
        self.order.extend(matrix_fingerprint(m) for m in batch)
        if self._calls == 1:
            self.entered.set()
            self.release.wait(30)
        return ["amd"] * len(batch), 0.0

    def select(self, a):
        return "amd", 0.0


class _SleepySelector:
    """Every selection takes ``delay`` seconds of wall time."""

    def __init__(self, delay):
        self.delay = delay

    def select_batch(self, batch, path="host", use_pallas=False):
        time.sleep(self.delay)
        return ["amd"] * len(batch), self.delay

    def select(self, a):
        time.sleep(self.delay)
        return "amd", self.delay


def _dispatcher(selector, **kw):
    builder = PlanBuilder(selector, PlanCache(64), batch_size=4, path="host")
    kw.setdefault("batch_size", 1)
    kw.setdefault("max_wait_ms", 1.0)
    kw.setdefault("build_workers", 1)
    return PlanDispatcher(builder, **kw)


# ---------------------------------------------------------------------------
# RequestContext
# ---------------------------------------------------------------------------

def test_mint_ids_unique_and_deadline_absolute():
    a = RequestContext.mint()
    b = RequestContext.mint(deadline_ms=50.0, priority=3)
    assert a.request_id != b.request_id
    assert a.deadline_s is None and a.remaining() is None
    assert not a.expired()
    assert b.priority == 3
    assert 0.0 < b.remaining() <= 0.050 + 1e-6
    assert not b.expired()
    c = RequestContext.mint(deadline_ms=-1.0)
    assert c.expired() and c.remaining() < 0


def test_spans_accumulate_and_context_manager():
    ctx = RequestContext.mint()
    ctx.add_span("select", 0.010)
    ctx.add_span("select", 0.005)
    with ctx.span("build"):
        time.sleep(0.01)
    assert ctx.spans["select"] == pytest.approx(0.015)
    assert ctx.spans["build"] >= 0.01
    ms = ctx.spans_ms()
    assert ms["select"] == pytest.approx(15.0)
    # span() records even when the body raises — the time was still spent
    with pytest.raises(ValueError):
        with ctx.span("factor"):
            raise ValueError("boom")
    assert "factor" in ctx.spans


def test_context_pickles_without_lock():
    import pickle

    ctx = RequestContext.mint(deadline_ms=100.0)
    ctx.add_span("cache", 0.001)
    back = pickle.loads(pickle.dumps(ctx))
    assert back.request_id == ctx.request_id
    assert back.spans == ctx.spans
    back.add_span("cache", 0.001)  # fresh lock works after unpickling


def test_serving_error_taxonomy():
    for cls in (DeadlineExceeded, QueueFull, DispatcherClosed):
        assert issubclass(cls, ServingError)
        assert issubclass(cls, RuntimeError)
        assert SERVING_ERRORS[cls.__name__] is cls


# ---------------------------------------------------------------------------
# admission control: queue-full rejection
# ---------------------------------------------------------------------------

def test_queue_full_rejects_submit(mats):
    sel = _GatedSelector()
    d = _dispatcher(sel, max_queue=2)
    try:
        blocker = d.submit(mats[0])       # taken by the batcher, held in
        assert sel.entered.wait(30)       # select by the gate
        q1 = d.submit(mats[1])
        q2 = d.submit(mats[2])            # queue now at max_queue
        with pytest.raises(QueueFull):
            d.submit(mats[3])
        assert d.stats()["rejected"] == 1
        sel.release.set()
        for f in (blocker, q1, q2):
            assert f.result(timeout=60).algorithm == "amd"
    finally:
        sel.release.set()
        d.close()


# ---------------------------------------------------------------------------
# deadline shedding
# ---------------------------------------------------------------------------

def test_expired_at_submit_fails_fast(mats):
    d = _dispatcher(_SleepySelector(0.0))
    try:
        fut = d.submit(mats[0], RequestContext.mint(deadline_ms=-1.0))
        with pytest.raises(DeadlineExceeded):
            fut.result(timeout=10)
        assert d.builder.plans_built == 0     # never reached a build worker
        assert d.stats()["shed"] == 1
    finally:
        d.close()


def test_shed_at_dequeue_spends_nothing(mats):
    """A request whose deadline passes while it waits in the queue is shed
    by the batcher — the selector never even sees its matrix."""
    sel = _GatedSelector()
    d = _dispatcher(sel)
    try:
        blocker = d.submit(mats[0])
        assert sel.entered.wait(30)
        doomed = d.submit(mats[1], RequestContext.mint(deadline_ms=30.0))
        time.sleep(0.1)                   # deadline passes in the queue
        sel.release.set()
        with pytest.raises(DeadlineExceeded):
            doomed.result(timeout=60)
        assert blocker.result(timeout=60).algorithm == "amd"
        assert matrix_fingerprint(mats[1]) not in sel.order
        assert d.builder.plans_built == 1  # only the blocker was built
    finally:
        sel.release.set()
        d.close()


def test_shed_before_build_never_occupies_worker(mats):
    """Deadline expires between dequeue and build (selection took too
    long): the build worker prunes the waiter and skips the build."""
    d = _dispatcher(_SleepySelector(0.15))
    try:
        fut = d.submit(mats[0], RequestContext.mint(deadline_ms=50.0))
        with pytest.raises(DeadlineExceeded):
            fut.result(timeout=60)
        assert d.builder.plans_built == 0
        assert d.builder.select_calls == 1    # selection ran, build didn't
    finally:
        d.close()


def test_warm_hit_served_despite_expired_deadline(mats):
    d = _dispatcher(_SleepySelector(0.0))
    try:
        d.submit(mats[0]).result(timeout=60)  # populate the cache
        ctx = RequestContext.mint(deadline_ms=-1.0)
        fut = d.submit(mats[0], ctx)
        assert fut.result(timeout=10).algorithm == "amd"
        assert set(ctx.spans) == {"cache", "total"}  # never queued
        assert fut.ctx is ctx
        assert d.stats()["warm_hits"] == 1 and d.stats()["shed"] == 0
    finally:
        d.close()


# ---------------------------------------------------------------------------
# priority ordering
# ---------------------------------------------------------------------------

def test_priority_order_under_load(mats):
    """With the batcher held, queued requests drain highest-priority
    first (FIFO within a priority)."""
    sel = _GatedSelector()
    d = _dispatcher(sel)
    try:
        blocker = d.submit(mats[0])
        assert sel.entered.wait(30)
        futs = [d.submit(mats[i], RequestContext.mint(priority=p))
                for i, p in ((1, 0), (2, 5), (3, 2), (4, 5))]
        sel.release.set()
        for f in [blocker] + futs:
            f.result(timeout=60)
        # arrival order 1,2,3,4 with priorities 0,5,2,5 → served 2,4,3,1
        want = [matrix_fingerprint(mats[i]) for i in (0, 2, 4, 3, 1)]
        assert sel.order == want
    finally:
        sel.release.set()
        d.close()


# ---------------------------------------------------------------------------
# close(): typed failure, never a hung future
# ---------------------------------------------------------------------------

def test_close_fails_pending_with_dispatcher_closed(mats):
    sel = _GatedSelector()
    d = _dispatcher(sel)
    blocker = d.submit(mats[0])
    assert sel.entered.wait(30)
    q1 = d.submit(mats[1])
    q2 = d.submit(mats[2])
    closer = threading.Thread(target=d.close, kwargs=dict(timeout=60))
    closer.start()
    # queued requests are failed immediately, even while the batcher is
    # still wedged in selection
    with pytest.raises(DispatcherClosed):
        q1.result(timeout=30)
    with pytest.raises(DispatcherClosed):
        q2.result(timeout=30)
    sel.release.set()
    closer.join(60)
    assert not closer.is_alive()
    # the in-flight request was already past the queue: it completes
    assert blocker.result(timeout=10).algorithm == "amd"
    with pytest.raises(DispatcherClosed):
        d.submit(mats[3])
    assert d.stats()["closed_rejects"] >= 3
    d.close()  # idempotent


def test_handle_round_trip_and_stats_shape(mats):
    d = _dispatcher(_SleepySelector(0.0), batch_size=4, max_wait_ms=2.0)
    try:
        plans = d.handle(mats[:4] + [mats[0]], timeout=60)
        assert [p.fingerprint for p in plans] == \
            [matrix_fingerprint(m) for m in mats[:4] + [mats[0]]]
        s = d.stats()
        assert s["requests"] == 5
        assert s["p99_ms"] >= s["p50_ms"] >= 0.0
        assert s["max_queue"] is None and s["queue_depth"] == 0
        assert "stage_queue_p50_ms" in s and "stage_build_p50_ms" in s
        snap = d.metrics.snapshot()
        assert snap["dispatch.requests"] == 5
        assert snap["dispatch.latency_s.count"] == 5
        d.reset_stats()
        assert d.stats()["requests"] == 0
        assert d.metrics.snapshot()["dispatch.latency_s.count"] == 0
    finally:
        d.close()
