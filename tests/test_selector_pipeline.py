"""End-to-end paper pipeline on a miniature campaign."""
import numpy as np
import pytest

from repro.core.labeling import run_labeling_campaign
from repro.core.selector import ReorderSelector, train_selector
from repro.sparse.dataset import generate_suite


@pytest.fixture(scope="module")
def mini_ds():
    mats = list(generate_suite(count=36, seed=7, size_scale=0.35))
    return run_labeling_campaign(mats)


def test_campaign_shapes(mini_ds):
    ds = mini_ds
    assert ds.features.shape == (36, 12)
    assert ds.times.shape == (36, 4)
    assert set(np.unique(ds.labels)) <= {0, 1, 2, 3}
    assert (ds.times > 0).all()
    # at least two different winners across the suite (heterogeneity claim)
    assert np.unique(ds.labels).size >= 2


def test_train_selector_and_report(mini_ds, tmp_path):
    sel, rep = train_selector(mini_ds, "random_forest", "standard",
                              fast=True, cv=3)
    assert 0.0 <= rep["test_accuracy"] <= 1.0
    assert rep["time_ideal"] <= rep["time_predicted"] + 1e-9
    assert rep["time_ideal"] <= rep["time_amd"] + 1e-9
    # persistence roundtrip
    p = tmp_path / "sel.pkl"
    sel.save(str(p))
    sel2 = ReorderSelector.load(str(p))
    f = mini_ds.features[:5]
    np.testing.assert_array_equal(sel.predict_features(f),
                                  sel2.predict_features(f))


def test_select_on_matrix(mini_ds):
    sel, _ = train_selector(mini_ds, "decision_tree", "minmax", fast=True,
                            cv=3)
    mats = list(generate_suite(count=3, seed=9, size_scale=0.3))
    alg, dt = sel.select(mats[0])
    assert alg in mini_ds.algorithms
    assert dt < 1.0  # prediction is negligible vs solve (paper Table 5)
