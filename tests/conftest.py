"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 device
(the 512-device override belongs exclusively to repro.launch.dryrun)."""
import numpy as np
import pytest

from repro.sparse.dataset import (banded, block_arrow, grid2d,
                                  permuted_banded, scalefree)


@pytest.fixture(scope="session")
def small_suite():
    rng = np.random.default_rng(0)
    return [
        grid2d(12, 12, "g12"),
        banded(150, 4, 0.8, rng, "band150"),
        permuted_banded(150, 3, 0.85, rng, "pband150"),
        scalefree(120, 2, rng, "sf120"),
        block_arrow(3, 20, 8, rng, "arrow"),
    ]


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(42)
