"""Checkpoint roundtrip + fault-tolerant trainer behaviour."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.config import ShapeSpec
from repro.train import (Trainer, TrainerConfig, latest_step,
                         restore_checkpoint, save_checkpoint)


def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"a": jnp.asarray(np.arange(6).reshape(2, 3), jnp.bfloat16),
            "b": {"c": jnp.ones((4,), jnp.float32),
                  "d": jnp.int32(7)}}
    save_checkpoint(str(tmp_path), 3, {"state": tree})
    step, out, _ = restore_checkpoint(str(tmp_path), {"state": tree})
    assert step == 3
    got = out["state"]
    assert str(np.asarray(got["a"]).dtype) == "bfloat16"
    np.testing.assert_array_equal(np.asarray(got["a"], np.float32),
                                  np.asarray(tree["a"], np.float32))
    np.testing.assert_array_equal(got["b"]["c"], np.ones((4,)))


def test_checkpoint_gc_keep_last(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in [1, 2, 3, 4, 5]:
        save_checkpoint(str(tmp_path), s, {"t": tree}, keep_last=2)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000004", "step_00000005"]
    assert latest_step(str(tmp_path)) == 5


@pytest.fixture()
def tiny_setup(tmp_path):
    cfg = get_smoke_config("llama3.2-1b")
    shape = ShapeSpec("t", 32, 4, "train")
    return cfg, shape, str(tmp_path / "ckpt")


def test_trainer_restart_matches_uninterrupted(tiny_setup):
    cfg, shape, ckpt = tiny_setup
    steps = 8
    # uninterrupted run
    t1 = Trainer(cfg, shape, TrainerConfig(
        ckpt_dir=ckpt + "_a", ckpt_every=4, total_steps=steps,
        warmup_steps=2, log_every=100))
    losses_a = []
    t1.run(steps, on_metrics=lambda s, m: losses_a.append((s, m["loss"])))
    # interrupted at step 6, restarts from the step-4 checkpoint
    shutil.rmtree(ckpt + "_b", ignore_errors=True)
    t2 = Trainer(cfg, shape, TrainerConfig(
        ckpt_dir=ckpt + "_b", ckpt_every=4, total_steps=steps,
        warmup_steps=2, log_every=100, fail_at_step=6))
    losses_b = []
    t2.run_with_restart(steps)
    t3 = Trainer(cfg, shape, TrainerConfig(
        ckpt_dir=ckpt + "_b", ckpt_every=4, total_steps=steps,
        warmup_steps=2, log_every=100))
    # deterministic data + restored state ⇒ final checkpoints must agree
    _, tr_a, _ = restore_checkpoint(ckpt + "_a",
                                    {"params": t1.init_state()[0]})
    _, tr_b, _ = restore_checkpoint(ckpt + "_b",
                                    {"params": t1.init_state()[0]})
    la = jax.tree_util.tree_leaves(tr_a["params"])
    lb = jax.tree_util.tree_leaves(tr_b["params"])
    for a, b in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-2)


def test_loss_decreases(tiny_setup):
    cfg, shape, ckpt = tiny_setup
    t = Trainer(cfg, shape, TrainerConfig(
        ckpt_dir=ckpt + "_c", ckpt_every=100, total_steps=30,
        warmup_steps=3, log_every=100))
    losses = []
    t.run(30, on_metrics=lambda s, m: losses.append(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2
