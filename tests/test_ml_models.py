"""The seven Fig.-4 model families: separable-data sanity + API contract."""
import numpy as np
import pytest

from repro.core.ml import MODEL_ZOO, accuracy_score
from repro.core.model_selection import (GridSearchCV, cross_val_score,
                                        kfold_indices, train_test_split)


def blobs(n=240, k=3, d=6, seed=0, spread=4.0):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((k, d)) * spread
    y = rng.integers(0, k, n)
    x = centers[y] + rng.standard_normal((n, d))
    return x, y


@pytest.mark.parametrize("name", sorted(MODEL_ZOO))
def test_model_learns_blobs(name):
    x, y = blobs()
    xtr, xte, ytr, yte, _, _ = train_test_split(x, y, 0.25, seed=1)
    model = MODEL_ZOO[name]()
    model.fit(xtr, ytr)
    acc = model.score(xte, yte)
    assert acc > 0.85, (name, acc)


@pytest.mark.parametrize("name", sorted(MODEL_ZOO))
def test_clone_contract(name):
    m = MODEL_ZOO[name]()
    c = m.clone()
    assert type(c) is type(m)
    assert c.params == m.params
    assert c is not m


def test_kfold_partitions():
    folds = kfold_indices(53, k=5, seed=0)
    all_val = np.concatenate([v for _, v in folds])
    assert np.array_equal(np.sort(all_val), np.arange(53))
    for tr, va in folds:
        assert np.intersect1d(tr, va).size == 0


def test_grid_search_picks_reasonable_tree():
    x, y = blobs(n=300, spread=2.0, seed=3)
    gs = GridSearchCV(MODEL_ZOO["decision_tree"](),
                      {"max_depth": [1, None]}, cv=4)
    gs.fit(x, y)
    assert gs.best_params_["max_depth"] is None  # depth-1 stump can't fit 3 blobs
    assert gs.best_score_ > 0.8


def test_cross_val_score_range():
    x, y = blobs()
    s = cross_val_score(MODEL_ZOO["naive_bayes"](), x, y, cv=4)
    assert 0.7 < s <= 1.0


def test_accuracy_score_formula():
    assert accuracy_score([1, 2, 3, 4], [1, 2, 0, 4]) == 0.75
