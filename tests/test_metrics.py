"""Metrics registry: instruments, sinks, snapshots, thread-safety."""
import json
import threading

import pytest

from repro.core.metrics import (Counter, Gauge, Histogram, JSONLSink,
                                ListSink, MetricsRegistry, default_registry)


# ---------------------------------------------------------------------------
# instruments
# ---------------------------------------------------------------------------

def test_counter_basics():
    c = Counter("x")
    c.inc()
    c.inc(4)
    assert c.value == 5
    c.reset()
    assert c.value == 0


def test_gauge_basics():
    g = Gauge("depth")
    g.set(7)
    g.inc(2)
    g.dec(1)
    assert g.value == 8
    g.reset()
    assert g.value == 0.0


def test_histogram_percentiles_nearest_rank():
    h = Histogram("lat")
    for v in range(1, 101):
        h.observe(float(v))
    assert h.count == 100
    assert h.sum == pytest.approx(5050.0)
    s = h.summary()
    assert s["p50"] == 51.0  # nearest rank on the sorted window
    assert s["p99"] == 99.0
    assert s["mean"] == pytest.approx(50.5)
    assert h.percentile(0) == 1.0 and h.percentile(100) == 100.0


def test_histogram_window_bounds_memory_not_count():
    h = Histogram("lat", window=10)
    for v in range(100):
        h.observe(float(v))
    assert h.count == 100          # lifetime total survives
    assert h.values() == [float(v) for v in range(90, 100)]
    assert h.percentile(0) == 90.0  # percentiles describe the window


def test_histogram_empty_is_zero():
    h = Histogram("lat")
    assert h.percentile(50) == 0.0
    assert h.summary() == dict(count=0, sum=0.0, p50=0.0, p99=0.0, mean=0.0)


def test_counter_thread_safety():
    c = Counter("x")

    def worker():
        for _ in range(1000):
            c.inc()

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 8000


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_get_or_create_and_snapshot():
    r = MetricsRegistry()
    assert r.counter("a") is r.counter("a")
    assert r.gauge("g") is r.gauge("g")
    assert r.histogram("h") is r.histogram("h")
    r.counter("a").inc(3)
    r.gauge("g").set(2.5)
    r.histogram("h").observe(0.1)
    snap = r.snapshot()
    assert snap["a"] == 3 and snap["g"] == 2.5
    assert snap["h.count"] == 1 and snap["h.p50"] == pytest.approx(0.1)
    r.reset()
    snap = r.snapshot()
    assert snap["a"] == 0 and snap["h.count"] == 0


def test_registry_concurrent_get_or_create_and_update():
    """Many threads racing get-or-create + update on the same names end
    with exact totals — the failure mode would be two instruments under
    one name, silently splitting the counts."""
    r = MetricsRegistry()

    def worker():
        for _ in range(500):
            r.counter("req").inc()
            r.histogram("lat").observe(1.0)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = r.snapshot()
    assert snap["req"] == 4000
    assert snap["lat.count"] == 4000


def test_default_registry_is_shared():
    assert default_registry() is default_registry()


# ---------------------------------------------------------------------------
# sinks — the push channel
# ---------------------------------------------------------------------------

def test_emit_fans_out_and_stamps_records():
    r = MetricsRegistry()
    sink = r.add_sink(ListSink())
    r.emit("dispatch.shed", request_id="req-1", late_by_ms=12.5)
    assert len(sink) == 1
    rec = sink.records[0]
    assert rec["event"] == "dispatch.shed"
    assert rec["request_id"] == "req-1" and rec["late_by_ms"] == 12.5
    assert rec["t_unix"] > 0
    r.remove_sink(sink)
    r.emit("dispatch.shed", request_id="req-2")
    assert len(sink) == 1  # removed sinks see nothing


def test_failing_sink_never_fails_the_emitter():
    class _Boom(ListSink):
        def emit(self, record):
            raise OSError("disk full")

    r = MetricsRegistry()
    r.add_sink(_Boom())
    good = r.add_sink(ListSink())
    r.emit("x")  # must not raise
    assert len(good) == 1  # siblings still receive the record


def test_jsonl_sink_appends_parseable_lines(tmp_path):
    path = str(tmp_path / "events" / "metrics.jsonl")
    r = MetricsRegistry()
    r.add_sink(JSONLSink(path))
    r.emit("dispatch.reject", depth=3)
    r.emit("dispatch.shed", request_id="req-9")
    r.close()
    lines = [json.loads(l) for l in open(path, encoding="utf-8")]
    assert [l["event"] for l in lines] == ["dispatch.reject",
                                          "dispatch.shed"]
    assert lines[0]["depth"] == 3 and lines[1]["request_id"] == "req-9"
