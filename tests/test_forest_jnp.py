"""Device forest inference (`forest_jnp`) vs the host tree/forest path."""
import numpy as np
import pytest

from repro.core.features import FEATURE_NAMES, extract_features_batch
from repro.core.ml import (DecisionTreeClassifier, RandomForestClassifier,
                           forest_forward_jnp, forest_to_arrays)
from repro.core.scaling import StandardScaler
from repro.core.selector import ReorderSelector
from repro.sparse.dataset import generate_suite


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(42)
    x = rng.standard_normal((240, 12))
    y = ((x[:, 0] + 0.5 * x[:, 3] > 0).astype(int)
         + 2 * (x[:, 5] > 0.8).astype(int))
    return x[:160], y[:160], x[160:]


def test_tree_flatten_invariants(data):
    xtr, ytr, _ = data
    tree = DecisionTreeClassifier(max_depth=6).fit(xtr, ytr)
    fa = forest_to_arrays([tree], tree.n_classes_)
    T, N = fa.feature.shape
    assert T == 1 and fa.depth <= 6
    assert fa.value.shape == (1, N, tree.n_classes_)
    idx = np.arange(N)
    leaves = fa.left[0] == idx
    assert (fa.right[0][leaves] == idx[leaves]).all()  # leaves self-loop
    assert leaves.any() and (~leaves).any()
    # internal nodes point strictly forward (DFS order): no cycles
    assert (fa.left[0][~leaves] > idx[~leaves]).all()
    assert (fa.right[0][~leaves] > idx[~leaves]).all()


def test_tree_agreement(data):
    xtr, ytr, xte = data
    tree = DecisionTreeClassifier().fit(xtr, ytr)
    probs = np.asarray(tree.forward_jnp(xte))
    np.testing.assert_allclose(probs, tree.predict_proba(xte), atol=1e-6)
    np.testing.assert_array_equal(probs.argmax(1), tree.predict(xte))


def test_forest_agreement(data):
    xtr, ytr, xte = data
    rf = RandomForestClassifier(n_estimators=25).fit(xtr, ytr)
    probs = np.asarray(rf.forward_jnp(xte))
    np.testing.assert_allclose(probs, rf.predict_proba(xte), atol=1e-6)
    np.testing.assert_array_equal(probs.argmax(1), rf.predict(xte))


def test_forest_agreement_under_jit(data):
    import jax

    xtr, ytr, xte = data
    rf = RandomForestClassifier(n_estimators=10).fit(xtr, ytr)
    fa = forest_to_arrays(rf.trees_, rf.n_classes_)
    fn = jax.jit(lambda z: forest_forward_jnp(fa, z))
    np.testing.assert_array_equal(np.asarray(fn(xte)).argmax(1),
                                  rf.predict(xte))


def test_refit_invalidates_flat_cache(data):
    xtr, ytr, xte = data
    rf = RandomForestClassifier(n_estimators=5).fit(xtr, ytr)
    rf.forward_jnp(xte)
    key0 = rf._flat[0]
    rf.fit(xtr[::2], ytr[::2])
    pred = np.asarray(rf.forward_jnp(xte)).argmax(1)
    assert rf._flat[0] != key0
    np.testing.assert_array_equal(pred, rf.predict(xte))


@pytest.fixture(scope="module")
def rf_selector_and_mats():
    mats = list(generate_suite(count=10, seed=5, size_scale=0.25))
    feats = extract_features_batch(mats)
    labels = (feats[:, FEATURE_NAMES.index("bandwidth")]
              / np.maximum(feats[:, 0], 1) > 0.5).astype(int)
    scaler = StandardScaler().fit(feats)
    rf = RandomForestClassifier(n_estimators=15).fit(
        scaler.transform(feats), labels)
    return ReorderSelector(rf, scaler, ["amd", "rcm"]), mats


def test_device_jit_invalidated_on_refit(rf_selector_and_mats):
    """Refitting the served model in place must rebuild the device jit
    (whose trace baked the old forest as constants), not serve stale
    predictions from the pre-refit trees."""
    import copy

    sel, mats = rf_selector_and_mats
    sel = copy.deepcopy(sel)  # don't mutate the shared fixture
    sel.select_batch(mats, path="device")
    feats = extract_features_batch(mats)
    flipped = 1 - (feats[:, FEATURE_NAMES.index("bandwidth")]
                   / np.maximum(feats[:, 0], 1) > 0.5).astype(int)
    sel.model.fit(sel.scaler.transform(feats), flipped)
    names_host, _ = sel.select_batch(mats, path="host")
    names_dev, _ = sel.select_batch(mats, path="device")
    assert names_dev == names_host


def test_select_batch_forest_stays_on_device(rf_selector_and_mats):
    """Acceptance: a fitted random_forest serves `select_batch` through the
    jnp forest path — the host `predict` fallback is never taken — and the
    device decisions match host inference."""
    sel, mats = rf_selector_and_mats
    assert hasattr(sel.model, "forward_jnp")
    names_host, _ = sel.select_batch(mats, path="host")

    def boom(*a, **k):  # any host-inference call fails the test
        raise AssertionError("host predict fallback taken on device path")

    orig_predict, orig_proba = sel.model.predict, sel.model.predict_proba
    sel.model.predict = boom
    sel.model.predict_proba = boom
    try:
        names_dev, _ = sel.select_batch(mats, path="device")
    finally:
        sel.model.predict, sel.model.predict_proba = orig_predict, orig_proba
    assert names_dev == names_host
