"""Distributed serving plane: shard_map featurization/inference identity.

Two layers of coverage:

* in-process tests run on the degenerate 1-device serving mesh (tier-1
  sees one CPU device) — they prove the shard_map path *is* the production
  path and matches the raw unsharded impl bit-for-bit, including a
  hypothesis property sweep over ragged batch sizes;
* subprocess tests re-launch with ``--xla_force_host_platform_device_count=4``
  (the `test_distributed.py` idiom) and prove multi-shard runs are
  element-wise identical to the 1-device run for batch sizes that do and
  do not divide the device count — the acceptance criterion of the
  distributed-serving refactor.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.features import (FEATURE_NAMES, extract_features_batch,
                                 extract_features_batch_jnp, pad_csr_batch)
from repro.core.ml import RandomForestClassifier
from repro.core.scaling import StandardScaler
from repro.core.selector import ReorderSelector
from repro.distributed.meshctx import (ServingMesh, get_serving_mesh,
                                       make_serving_mesh, serving_mesh,
                                       set_serving_mesh)
from repro.sparse.dataset import generate_suite

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 4, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


@pytest.fixture(scope="module")
def mats():
    return list(generate_suite(count=9, seed=3, size_scale=0.25))


@pytest.fixture(scope="module")
def selector(mats):
    feats = extract_features_batch(mats)
    labels = (feats[:, FEATURE_NAMES.index("bandwidth")]
              / np.maximum(feats[:, 0], 1) > 0.5).astype(int)
    scaler = StandardScaler().fit(feats)
    rf = RandomForestClassifier(n_estimators=8).fit(
        scaler.transform(feats), labels)
    return ReorderSelector(rf, scaler, ["amd", "rcm"])


# ---------------------------------------------------------------------------
# mesh context plumbing (single device)
# ---------------------------------------------------------------------------

def test_default_mesh_is_degenerate():
    sm = get_serving_mesh()
    assert isinstance(sm, ServingMesh)
    assert sm.num_devices == 1
    assert sm.axis == "batch"


def test_serving_mesh_context_restores():
    outer = get_serving_mesh()
    with serving_mesh(make_serving_mesh(1)) as sm:
        assert get_serving_mesh() is sm
    assert get_serving_mesh() == outer
    set_serving_mesh(None)


def test_make_serving_mesh_rejects_bad_width():
    import jax

    with pytest.raises(ValueError):
        make_serving_mesh(len(jax.devices()) + 1)
    with pytest.raises(ValueError):
        make_serving_mesh(0)


def test_serving_mesh_is_hashable_jit_key():
    a, b = make_serving_mesh(1), make_serving_mesh(1)
    assert hash(a) == hash(b) and a == b  # same devices → one jit bucket


# ---------------------------------------------------------------------------
# sharded featurizer == raw impl (degenerate mesh, tier-1)
# ---------------------------------------------------------------------------

def test_sharded_path_matches_unsharded_impl(mats):
    batch = pad_csr_batch(mats, bucket=True)
    raw = np.asarray(extract_features_batch_jnp(batch, jit=False))
    via_mesh = np.asarray(extract_features_batch_jnp(batch))
    assert np.array_equal(raw, via_mesh)


def test_sharded_path_matches_host_features(mats):
    batch = pad_csr_batch(mats, bucket=True)
    dev = np.asarray(extract_features_batch_jnp(batch))
    host = extract_features_batch(mats)
    np.testing.assert_allclose(dev, host, rtol=1e-5, atol=1e-5)


def test_ragged_batches_all_sizes(mats):
    """Every prefix size B=1..len(mats) through the sharded path — the
    pad-to-multiple logic must be invisible at every raggedness."""
    for b in range(1, len(mats) + 1):
        sub = mats[:b]
        batch = pad_csr_batch(sub, bucket=True)
        raw = np.asarray(extract_features_batch_jnp(batch, jit=False))
        out = np.asarray(extract_features_batch_jnp(batch))
        assert out.shape == (b, len(FEATURE_NAMES))
        assert np.array_equal(raw, out), f"mismatch at B={b}"


def test_select_batch_device_path_on_mesh(mats, selector):
    names_dev, _ = selector.select_batch(mats, path="device")
    names_host, _ = selector.select_batch(mats, path="host")
    assert names_dev == names_host


def test_property_sharded_featurization_identity():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    pool = list(generate_suite(count=12, seed=5, size_scale=0.2))

    @settings(max_examples=20, deadline=None)
    @given(idx=st.lists(st.integers(0, len(pool) - 1), min_size=1,
                        max_size=7))
    def prop(idx):
        sub = [pool[i] for i in idx]
        batch = pad_csr_batch(sub, bucket=True)
        raw = np.asarray(extract_features_batch_jnp(batch, jit=False))
        out = np.asarray(extract_features_batch_jnp(batch))
        assert np.array_equal(raw, out)

    prop()


# ---------------------------------------------------------------------------
# multi-device identity (4 virtual host devices, subprocess)
# ---------------------------------------------------------------------------

def test_multidevice_featurize_and_infer_identity():
    """Mesh widths 1/2/3/4 over ragged batch sizes (including B < ndev and
    B % ndev != 0) must produce element-wise identical features and
    identical selections."""
    out = run_py("""
        import numpy as np
        from repro.core.features import (FEATURE_NAMES,
            extract_features_batch, extract_features_batch_jnp,
            pad_csr_batch)
        from repro.core.ml import RandomForestClassifier
        from repro.core.scaling import StandardScaler
        from repro.core.selector import ReorderSelector
        from repro.distributed.meshctx import (make_serving_mesh,
                                               serving_mesh)
        from repro.sparse.dataset import generate_suite

        pool = list(generate_suite(count=13, seed=3, size_scale=0.25))
        feats = extract_features_batch(pool)
        labels = (feats[:, FEATURE_NAMES.index("bandwidth")]
                  / np.maximum(feats[:, 0], 1) > 0.5).astype(int)
        scaler = StandardScaler().fit(feats)
        rf = RandomForestClassifier(n_estimators=8).fit(
            scaler.transform(feats), labels)
        sel = ReorderSelector(rf, scaler, ["amd", "rcm"])

        for b in (1, 2, 3, 5, 7, 8, 13):   # 5, 7, 13 don't divide 4
            sub = pool[:b]
            batch = pad_csr_batch(sub, bucket=True)
            ref = np.asarray(extract_features_batch_jnp(batch))  # 1-device
            ref_names, _ = sel.select_batch(sub, path="device")
            for nd in (2, 3, 4):
                with serving_mesh(make_serving_mesh(nd)):
                    out = np.asarray(extract_features_batch_jnp(batch))
                    outp = np.asarray(extract_features_batch_jnp(
                        batch, use_pallas=True))
                    names, _ = sel.select_batch(sub, path="device")
                assert np.array_equal(ref, out), (b, nd)
                assert np.array_equal(ref, outp), (b, nd, "pallas")
                assert names == ref_names, (b, nd)
        print("IDENTITY-OK")
    """)
    assert "IDENTITY-OK" in out


def test_multidevice_engine_serving_mesh():
    """EngineConfig(serving_devices=4) installs the mesh and the async
    server plans correctly through the sharded cold path."""
    out = run_py("""
        import numpy as np
        from repro.core.features import FEATURE_NAMES, extract_features_batch
        from repro.core.ml import RandomForestClassifier
        from repro.core.scaling import StandardScaler
        from repro.core.selector import ReorderSelector
        from repro.distributed.meshctx import get_serving_mesh
        from repro.engine import EngineConfig, SolverEngine
        from repro.sparse.dataset import generate_suite

        pool = list(generate_suite(count=10, seed=3, size_scale=0.25))
        feats = extract_features_batch(pool)
        labels = (feats[:, FEATURE_NAMES.index("bandwidth")]
                  / np.maximum(feats[:, 0], 1) > 0.5).astype(int)
        scaler = StandardScaler().fit(feats)
        rf = RandomForestClassifier(n_estimators=8).fit(
            scaler.transform(feats), labels)
        sel = ReorderSelector(rf, scaler, ["amd", "rcm"])

        engine = SolverEngine(EngineConfig(
            cache_dir=None, serving_devices=4, batch_size=4,
            max_wait_ms=2.0), selector=sel)
        server = engine.serve()
        plans = server.handle(pool)
        server.close()
        assert get_serving_mesh().num_devices == 4
        for m, p in zip(pool, plans):
            assert p.algorithm in ("amd", "rcm")
            assert sorted(p.perm.tolist()) == list(range(m.n))
        # warm identity: same structures come back from cache
        engine2_plans = engine.plan_batch(pool)
        assert [p.fingerprint for p in engine2_plans] == [
            p.fingerprint for p in plans]
        print("ENGINE-MESH-OK")
    """)
    assert "ENGINE-MESH-OK" in out
