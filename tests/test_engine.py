"""repro.engine: registries, SelectorBundle, SolverEngine, fingerprint →
plan-cache invalidation, and the deprecation shims."""
import os
import pickle
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.core.labeling import LabeledDataset
from repro.core.plan_cache import TwoTierPlanCache
from repro.core.selector import ReorderSelector
from repro.engine import (FEATURE_SET_REGISTRY, MODEL_REGISTRY,
                          REORDERING_REGISTRY, SCALER_REGISTRY,
                          BundleValidationError, DuplicateNameError,
                          EngineConfig, EngineError, RegistryLookupError,
                          SelectorBundle, SolverEngine, register_reordering)
from repro.sparse.dataset import grid2d
from repro.sparse.reorder import LABEL_ALGORITHMS, get_reordering


def synth_dataset(seed=0, m=40, dim=12):
    """Synthetic LabeledDataset — train-path plumbing without a labeling
    campaign (features are random; only shapes/labels matter here)."""
    rng = np.random.default_rng(seed)
    return LabeledDataset(
        features=rng.standard_normal((m, dim)) + 1.0,
        labels=rng.integers(0, 4, m),
        times=rng.uniform(0.01, 0.1, (m, 4)),
        order_times=np.full((m, 4), 0.001),
        fills=np.ones((m, 4), np.int64),
        flops=np.ones((m, 4), np.int64),
        names=[f"m{i}" for i in range(m)], groups=["g"] * m,
        dims=np.full(m, 100), nnzs=np.full(m, 500),
        algorithms=list(LABEL_ALGORITHMS))


def make_engine(tmp_path, model="decision_tree", seed=0, **cfg):
    cfg.setdefault("cache_dir", str(tmp_path / "plan_cache"))
    engine = SolverEngine(EngineConfig(model=model, path="host",
                                       fast_grids=True, cv=3, **cfg))
    engine.train(synth_dataset(seed=seed))
    return engine


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------

def test_registry_duplicate_name_conflict():
    @register_reordering("_test_dup_order")
    def order_a(a):
        return np.arange(a.n)

    try:
        # same object re-registered: harmless no-op
        register_reordering("_test_dup_order")(order_a)
        with pytest.raises(DuplicateNameError):
            @register_reordering("_test_dup_order")
            def order_b(a):
                return np.arange(a.n)
    finally:
        REORDERING_REGISTRY.unregister("_test_dup_order")


def test_registry_reregistration_of_reloaded_object_is_tolerated():
    # importlib.reload re-executes decorators with fresh objects that share
    # the original's module + qualname; that must replace, not conflict
    def _make():
        def _prov_order(a):
            return np.arange(a.n)
        return _prov_order

    f1, f2 = _make(), _make()
    try:
        register_reordering("_test_reload_order")(f1)
        register_reordering("_test_reload_order")(f2)  # no DuplicateNameError
        assert REORDERING_REGISTRY["_test_reload_order"] is f2
    finally:
        REORDERING_REGISTRY.unregister("_test_reload_order")
    import importlib

    import repro.core.scaling as scaling
    importlib.reload(scaling)  # re-registers minmax/standard/none: no raise
    assert "standard" in SCALER_REGISTRY


def test_registry_lookup_error_is_consistent_and_suggests():
    for registry in (REORDERING_REGISTRY, MODEL_REGISTRY, SCALER_REGISTRY,
                     FEATURE_SET_REGISTRY):
        with pytest.raises(RegistryLookupError):
            registry["no_such_name"]
    with pytest.raises(RegistryLookupError, match="did you mean"):
        MODEL_REGISTRY["random_forst"]
    # RegistryLookupError is a KeyError, so legacy handlers still catch it
    with pytest.raises(KeyError):
        SCALER_REGISTRY["no_such_scaler"]


def test_get_reordering_no_chained_traceback():
    with pytest.raises(RegistryLookupError) as ei:
        get_reordering("amdd")
    assert ei.value.__cause__ is None
    assert ei.value.__suppress_context__  # raise ... from None
    assert "amd" in str(ei.value)  # suggestion present


def test_legacy_dict_shims_importable_and_mapping_like():
    from repro.core.ml import MODEL_ZOO
    from repro.core.scaling import SCALERS
    from repro.sparse.reorder import CATEGORY_OF, REORDERINGS

    assert "amd" in REORDERINGS and callable(REORDERINGS["amd"])
    assert sorted(MODEL_ZOO)  # iterable
    assert SCALERS["standard"]().fit(np.ones((3, 2)))
    assert CATEGORY_OF["rcm"] == "bandwidth-reduction"
    assert len(CATEGORY_OF) == len(REORDERINGS)


def test_registry_metadata():
    assert REORDERING_REGISTRY.metadata("amd")["category"] == \
        "fill-in-reduction"
    assert MODEL_REGISTRY.metadata("random_forest")["device_capable"]
    assert not MODEL_REGISTRY.metadata("knn")["device_capable"]
    fs = FEATURE_SET_REGISTRY["paper12"]
    assert fs.dim == 12 and fs.device_capable
    assert FEATURE_SET_REGISTRY["extended19"].dim == 19


# ---------------------------------------------------------------------------
# SelectorBundle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["decision_tree", "random_forest",
                                   "naive_bayes"])
def test_bundle_roundtrip_preserves_predictions(tmp_path, model):
    engine = make_engine(tmp_path, model=model)
    path = engine.save(str(tmp_path / "sel.bundle"))
    engine2 = SolverEngine.load(path)
    x = synth_dataset(seed=3).features[:10]
    np.testing.assert_array_equal(engine.selector.predict_features(x),
                                  engine2.selector.predict_features(x))
    assert engine2.fingerprint == engine.fingerprint
    assert engine2.config.feature_set == "paper12"


def test_bundle_rejects_feature_schema_mismatch(tmp_path):
    engine = make_engine(tmp_path)
    bundle = SelectorBundle.from_selector(engine.selector)
    bundle.feature_names = bundle.feature_names[:-1] + ["bogus_feature"]
    bundle.fingerprint = bundle.compute_fingerprint()  # internally coherent
    with pytest.raises(BundleValidationError, match="feature schema"):
        bundle.validate()
    p = str(tmp_path / "bad.bundle")
    bundle.save(p)
    with pytest.raises(BundleValidationError, match="feature schema"):
        SelectorBundle.load(p)


def test_engine_load_rejects_feature_set_mismatch(tmp_path):
    engine = make_engine(tmp_path)
    path = engine.save(str(tmp_path / "sel.bundle"))
    with pytest.raises(EngineError, match="feature set"):
        SolverEngine.load(path, EngineConfig(feature_set="extended19"))


def test_bundle_rejects_tampered_payload(tmp_path):
    engine = make_engine(tmp_path, model="naive_bayes")
    bundle = SelectorBundle.from_selector(engine.selector)
    bundle.model_state["theta_"] = bundle.model_state["theta_"] + 1.0
    with pytest.raises(BundleValidationError, match="fingerprint"):
        bundle.validate()


def test_bundle_rejects_unknown_registry_names(tmp_path):
    engine = make_engine(tmp_path)
    bundle = SelectorBundle.from_selector(engine.selector)
    bundle.model_name = "not_a_model"
    bundle.fingerprint = bundle.compute_fingerprint()
    with pytest.raises(BundleValidationError, match="unknown model"):
        bundle.validate()


def test_legacy_raw_pickle_shim(tmp_path):
    engine = make_engine(tmp_path)
    p = str(tmp_path / "legacy.pkl")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        engine.selector.save(p)  # old raw-pickle format
    with pytest.warns(DeprecationWarning, match="legacy raw"):
        bundle = SelectorBundle.load(p)
    x = synth_dataset(seed=3).features[:5]
    np.testing.assert_array_equal(
        bundle.to_selector().predict_features(x),
        engine.selector.predict_features(x))
    # and the deprecated loader reads new bundles (migrate one side first)
    bp = engine.save(str(tmp_path / "new.bundle"))
    with pytest.warns(DeprecationWarning):
        sel = ReorderSelector.load(bp)
    np.testing.assert_array_equal(sel.predict_features(x),
                                  engine.selector.predict_features(x))


def test_train_rejects_mismatched_algorithm_assertion(tmp_path):
    engine = SolverEngine(EngineConfig(algorithms=["amd", "rcm"],
                                       cache_dir=None, path="host",
                                       fast_grids=True, cv=3))
    with pytest.raises(EngineError, match="algorithms"):
        engine.train(synth_dataset())  # dataset labels all four


def test_load_syncs_capability_fields_to_bundle(tmp_path):
    engine = make_engine(tmp_path, model="naive_bayes")
    path = engine.save(str(tmp_path / "sel.bundle"))
    # config lies about the model family; load must sync it to the truth
    engine2 = SolverEngine.load(path, EngineConfig(model="mlp",
                                                   cache_dir=None,
                                                   path="host"))
    assert engine2.config.model == "naive_bayes"
    assert engine2.stats()["model"] == "naive_bayes"
    assert list(engine2.config.algorithms) == list(LABEL_ALGORITHMS)
    assert engine2.config.cache_dir is None  # serving knobs kept


def test_train_validates_feature_dim():
    engine = SolverEngine(EngineConfig(feature_set="extended19",
                                       cache_dir=None, path="host",
                                       fast_grids=True, cv=3))
    with pytest.raises(ValueError, match="dim"):
        engine.train(synth_dataset(dim=12))


# ---------------------------------------------------------------------------
# fingerprint → plan-cache invalidation (the ROADMAP stale-plan hazard)
# ---------------------------------------------------------------------------

def test_refit_invalidates_persisted_plans(tmp_path):
    a = grid2d(12, 12, "g12")
    cache_dir = str(tmp_path / "shared_cache")

    engine = make_engine(tmp_path, seed=0, cache_dir=cache_dir)
    fp1 = engine.fingerprint
    engine.plan(a)
    engine.plan(a)
    s = engine.builder.stats()
    assert s["plans_built"] == 1 and s["hits"] == 1  # warm within one fit
    assert s["disk_entries"] == 1

    # refit through the engine: new fingerprint, same cache dir
    engine.train(synth_dataset(seed=9))
    assert engine.fingerprint != fp1
    builder2 = engine.builder
    engine.plan(a)
    s2 = builder2.stats()
    # the old plan file is still on disk, but invisible under the new
    # version: the first plan() after retraining MUST rebuild, not hit
    assert s2["misses"] >= 1 and s2["plans_built"] == 1
    assert s2["hits"] == 0
    files = [f for f in os.listdir(cache_dir) if f.endswith(".plan.pkl")]
    assert len(files) == 2  # one plan file per fingerprint version
    assert len({f.split(".")[1] for f in files}) == 2

    # same fit → same fingerprint → the disk tier survives a process
    # restart (fresh engine, identical training) and serves warm
    engine3 = make_engine(tmp_path, seed=9, cache_dir=cache_dir)
    assert engine3.fingerprint == engine.fingerprint
    engine3.plan(a)
    s3 = engine3.builder.stats()
    assert s3["hits"] == 1 and s3["plans_built"] == 0


def test_engine_serve_and_solve(tmp_path):
    engine = make_engine(tmp_path)
    a = grid2d(10, 10, "g10")
    res = engine.solve(a)
    assert res["residual"] < 1e-8
    assert res["algorithm"] in LABEL_ALGORITHMS
    server = engine.serve(build_workers=1)
    try:
        plans = server.handle([a, grid2d(8, 8, "g8"), a])
        assert plans[0].fingerprint == plans[2].fingerprint
    finally:
        server.close()


# ---------------------------------------------------------------------------
# plan-cache disk-tier bounds (ROADMAP item)
# ---------------------------------------------------------------------------

def _put_many(cache, n, blob_size=2000):
    for i in range(n):
        cache.put(f"key{i:03d}", b"x" * blob_size)


def test_disk_tier_entry_cap(tmp_path):
    cache = TwoTierPlanCache(capacity=64, cache_dir=str(tmp_path),
                             max_disk_entries=3)
    _put_many(cache, 6)
    s = cache.stats()
    assert s["disk_entries"] <= 3
    assert s["disk_evictions"] >= 3
    assert s["max_disk_entries"] == 3
    # memory tier still answers everything (bounds are disk-only)
    assert all(cache.get(f"key{i:03d}") is not None for i in range(6))


def test_disk_tier_byte_budget(tmp_path):
    cache = TwoTierPlanCache(capacity=64, cache_dir=str(tmp_path),
                             max_disk_bytes=9000)
    _put_many(cache, 6, blob_size=2000)
    s = cache.stats()
    assert s["disk_bytes"] <= 9000
    assert s["disk_evictions"] >= 1
    assert s["disk_entries"] < 6


def test_disk_eviction_prefers_oldest(tmp_path):
    cache = TwoTierPlanCache(capacity=64, cache_dir=str(tmp_path),
                             max_disk_entries=2)
    for i in range(4):
        cache.put(f"k{i}", i)
        # force distinct mtimes so LRU-by-mtime order is deterministic
        os.utime(cache._path(f"k{i}"), (1_000_000 + i, 1_000_000 + i))
        cache._evict_disk()
    kept = sorted(f for f in os.listdir(str(tmp_path))
                  if f.endswith(".plan.pkl"))
    assert [f.split(".")[0] for f in kept] == ["k2", "k3"]


def test_disk_hit_refreshes_lru_position(tmp_path):
    c1 = TwoTierPlanCache(capacity=64, cache_dir=str(tmp_path))
    for i in range(3):
        c1.put(f"k{i}", i)
        os.utime(c1._path(f"k{i}"), (1_000_000 + i, 1_000_000 + i))
    # fresh cache (cold memory tier): get() is a disk hit → mtime refresh,
    # so the oldest-written-but-just-used entry survives the sweep
    c2 = TwoTierPlanCache(capacity=64, cache_dir=str(tmp_path),
                          max_disk_entries=2)
    assert c2.get("k0") == 0
    c2.put("k9", 9)
    kept = {f.split(".")[0] for f in os.listdir(str(tmp_path))
            if f.endswith(".plan.pkl")}
    assert kept == {"k0", "k9"}


# ---------------------------------------------------------------------------
# import gate (mirrors the CI step)
# ---------------------------------------------------------------------------

def test_engine_imports_clean_of_deprecation_warnings():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-W", "error::DeprecationWarning", "-c",
         "import repro.engine; import repro.core.selector"],
        capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stderr


# ---------------------------------------------------------------------------
# bundle schema v2: report card + provenance
# ---------------------------------------------------------------------------

def test_v2_bundle_carries_report_card_and_provenance(tmp_path):
    engine = make_engine(tmp_path)
    path = str(tmp_path / "sel.bundle")
    engine.save(path)
    b = SelectorBundle.load(path)
    assert b.schema_version == 2
    # report card: held-out accuracy + per-algorithm recall + kxk confusion
    card = b.report_card
    assert card is not None
    assert card["test_accuracy"] == engine.last_report["test_accuracy"]
    k = len(b.algorithms)
    assert len(card["confusion"]) == k
    assert all(len(row) == k for row in card["confusion"])
    assert set(card["per_algorithm_recall"]) == set(b.algorithms)
    assert sum(card["test_support"].values()) == sum(
        sum(row) for row in card["confusion"])
    # provenance: the dataset the selector was fitted on
    prov = b.provenance
    assert prov is not None
    assert prov["n_samples"] == 40 and prov["algorithms"] == list(
        b.algorithms)
    assert prov["feature_set"] == "paper12"
    assert sum(prov["label_counts"].values()) == prov["n_samples"]


def test_v1_bundle_still_loads(tmp_path):
    """A pre-report-card (schema v1) envelope loads with both v2 sections
    None and the same fingerprint (the card is fingerprint-exempt)."""
    engine = make_engine(tmp_path)
    path = str(tmp_path / "sel.bundle")
    engine.save(path)
    with open(path, "rb") as f:
        env = pickle.load(f)
    env["schema_version"] = 1
    env["bundle"]["schema_version"] = 1
    del env["bundle"]["report_card"]
    del env["bundle"]["provenance"]
    v1_path = str(tmp_path / "v1.bundle")
    with open(v1_path, "wb") as f:
        pickle.dump(env, f)

    b = SelectorBundle.load(v1_path)
    assert b.schema_version == 1
    assert b.report_card is None and b.provenance is None
    assert b.fingerprint == SelectorBundle.load(path).fingerprint
    engine2 = SolverEngine.load(v1_path)
    assert engine2.fingerprint == engine.fingerprint


def test_newer_schema_rejected(tmp_path):
    engine = make_engine(tmp_path)
    path = str(tmp_path / "sel.bundle")
    engine.save(path)
    with open(path, "rb") as f:
        env = pickle.load(f)
    env["bundle"]["schema_version"] = 99
    with open(path, "wb") as f:
        pickle.dump(env, f)
    with pytest.raises(BundleValidationError, match="newer"):
        SelectorBundle.load(path)


def test_report_card_is_fingerprint_exempt(tmp_path):
    """Editing the card must not trip the tamper check (it is descriptive,
    not behavioural) — but a malformed confusion matrix is rejected."""
    engine = make_engine(tmp_path)
    path = str(tmp_path / "sel.bundle")
    engine.save(path)
    with open(path, "rb") as f:
        env = pickle.load(f)
    env["bundle"]["report_card"]["test_accuracy"] = 1.0  # embellished, fine
    with open(path, "wb") as f:
        pickle.dump(env, f)
    assert SelectorBundle.load(path).report_card["test_accuracy"] == 1.0

    env["bundle"]["report_card"]["confusion"] = [[1, 2]]  # wrong shape
    with open(path, "wb") as f:
        pickle.dump(env, f)
    with pytest.raises(BundleValidationError, match="confusion"):
        SelectorBundle.load(path)


def test_attach_built_engine_saves_without_card(tmp_path):
    engine = make_engine(tmp_path)
    fresh = SolverEngine(EngineConfig(path="host"),
                         selector=engine.selector)
    path = str(tmp_path / "attached.bundle")
    fresh.save(path)
    b = SelectorBundle.load(path)
    assert b.schema_version == 2
    assert b.report_card is None and b.provenance is None
