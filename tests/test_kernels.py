"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 2, 2, 32, 16), (2, 4, 2, 64, 32), (1, 8, 1, 96, 64), (2, 2, 2, 33, 32),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_attention_sweep(b, hq, hkv, s, d, causal, dtype):
    q = jnp.asarray(RNG.standard_normal((b, hq, s, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), dtype)
    out = ops.attention(q, k, v, causal=causal, block_q=32, block_kv=32)
    rep = hq // hkv
    kk = jnp.repeat(k, rep, axis=1).reshape(b * hq, s, d)
    vv = jnp.repeat(v, rep, axis=1).reshape(b * hq, s, d)
    want = ref.attention_ref(q.reshape(b * hq, s, d), kk, vv,
                             causal=causal).reshape(b, hq, s, d)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# frontal partial Cholesky
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,npiv,bs", [
    (8, 3, 8), (24, 24, 8), (40, 17, 16), (65, 1, 32), (70, 33, 32),
])
def test_frontal_factor_sweep(m, npiv, bs):
    a = RNG.standard_normal((m, m))
    f = a @ a.T + m * np.eye(m)
    L11, L21, S = ops.frontal_factor(jnp.asarray(f), npiv, bs=bs)
    r11, r21, rS = ref.partial_cholesky_ref(jnp.asarray(f), npiv)
    np.testing.assert_allclose(np.asarray(L11), np.asarray(r11),
                               rtol=1e-4, atol=1e-4)
    if npiv < m:
        np.testing.assert_allclose(np.asarray(L21), np.asarray(r21),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(S), np.asarray(rS),
                                   rtol=1e-3, atol=1e-3)


def test_matmul_nt_tiles():
    a = jnp.asarray(RNG.standard_normal((64, 32)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((48, 32)), jnp.float32)
    c = jnp.asarray(RNG.standard_normal((64, 48)), jnp.float32)
    out = ops.matmul_nt_padded(a, b, c, alpha=-1.0, beta=1.0, bs=16)
    want = ref.matmul_nt_ref(a, b, c, alpha=-1.0, beta=1.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# block-ELL SpMV
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,band,bs", [(64, 3, 8), (100, 5, 8), (37, 2, 16)])
def test_spmv_sweep(n, band, bs):
    from repro.sparse.dataset import banded
    rng = np.random.default_rng(n)
    m = banded(n, band, 0.7, rng, "b")
    x = rng.standard_normal(n)
    y = ops.spmv(m.indptr, m.indices, m.data, x, bs=bs)
    np.testing.assert_allclose(y, m.matvec(x), rtol=1e-4, atol=1e-4)
