"""Distributed behaviour: runs in subprocesses with 8 host devices so the
main test process keeps its single-device view."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_sharded_train_step_runs():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models.config import ShapeSpec
        from repro.train import Trainer, TrainerConfig
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_smoke_config('jamba-v0.1-52b')
        shape = ShapeSpec('t', 32, 8, 'train')
        t = Trainer(cfg, shape, TrainerConfig(ckpt_dir='/tmp/t_dist',
                    ckpt_every=100, total_steps=3, warmup_steps=1,
                    log_every=100), mesh=mesh)
        losses = []
        t.run(3, on_metrics=lambda s, m: losses.append(m['loss']))
        import numpy as np
        assert all(np.isfinite(l) for l in losses), losses
        print('LOSSES', losses)
    """)
    assert "LOSSES" in out


def test_sharded_equals_single_device():
    """The sharded train step must compute the same loss as 1 device."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import init_params, loss_fn
        from repro.distributed.meshctx import MeshContext, mesh_context
        from repro.distributed.sharding import (param_specs, batch_specs,
            to_shardings, ExecutionPlan)
        from repro.models.config import ShapeSpec
        cfg = get_smoke_config('phi3.5-moe-42b-a6.6b')
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        batch = {'tokens': jnp.asarray(rng.integers(0, cfg.vocab_size,
                 (8, 32)), jnp.int32),
                 'labels': jnp.asarray(rng.integers(0, cfg.vocab_size,
                 (8, 32)), jnp.int32)}
        l0, _ = jax.jit(lambda p, b: loss_fn(cfg, p, b))(params, batch)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ctx = MeshContext(mesh, ("data",), "model")
        with mesh_context(ctx):
            pspecs = param_specs(params, cfg, ExecutionPlan())
            shard = to_shardings(pspecs, mesh)
            bspec = to_shardings(batch_specs(cfg,
                ShapeSpec('t', 32, 8, 'train')), mesh)
            ps = jax.device_put(params, shard)
            bs = jax.device_put(batch, bspec)
            l1, _ = jax.jit(lambda p, b: loss_fn(cfg, p, b),
                            in_shardings=(shard, bspec))(ps, bs)
        print('L0', float(l0), 'L1', float(l1))
        assert abs(float(l0) - float(l1)) < 0.05, (float(l0), float(l1))
    """)
    assert "L0" in out


def test_grad_compression_close_to_exact():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compat import shard_map
        from repro.distributed.gradient_compression import (compressed_psum,
            init_error_state)
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g_all = jnp.asarray(rng.standard_normal((8, 64, 32)), jnp.float32)
        # shard_map local view: per-device g (1, 64, 32) -> squeeze
        def local2(gs, err):
            mean, new_err = compressed_psum({'w': gs[0]}, {'w': err[0]},
                                            'data')
            return mean['w'], new_err['w'][None]
        f2 = jax.jit(shard_map(local2, mesh=mesh,
                in_specs=(P('data', None, None), P('data', None, None)),
                out_specs=(P(), P('data', None, None))))
        err = jnp.zeros((8, 64, 32))
        mean, err = f2(g_all, err)
        true = g_all.mean(axis=0)
        rel = float(jnp.abs(mean - true).max() / jnp.abs(true).max())
        print('REL', rel)
        assert rel < 0.05, rel
        # error feedback: second round with same grads reduces bias
        mean2, err = f2(g_all, err)
        two_step = (np.asarray(mean) + np.asarray(mean2)) / 2
        rel2 = float(np.abs(two_step - np.asarray(true)).max()
                     / np.abs(np.asarray(true)).max())
        print('REL2', rel2)
        assert rel2 <= rel + 1e-6
    """)
    assert "REL" in out


def test_moe_ep_variant_compiles_and_matches():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_smoke_config
        from repro.models import init_params, loss_fn
        from repro.distributed.meshctx import MeshContext, mesh_context
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_smoke_config('phi3.5-moe-42b-a6.6b')
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        batch = {'tokens': jnp.asarray(rng.integers(0, cfg.vocab_size,
                 (8, 128)), jnp.int32),
                 'labels': jnp.asarray(rng.integers(0, cfg.vocab_size,
                 (8, 128)), jnp.int32)}
        l_base, _ = jax.jit(lambda p, b: loss_fn(cfg, p, b))(params, batch)
        cfg_ep = dataclasses.replace(cfg, moe_impl='ep')
        ctx = MeshContext(mesh, ("data",), "model")
        with mesh_context(ctx):
            l_ep, _ = jax.jit(lambda p, b: loss_fn(cfg_ep, p, b))(params, batch)
        print('BASE', float(l_base), 'EP', float(l_ep))
        assert abs(float(l_base) - float(l_ep)) < 0.08
    """)
    assert "EP" in out


def test_sharded_decode_matches_plain():
    """shard_map flash-decode over a seq-sharded cache must equal the plain
    single-device decode path."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.layers import sharded_decode_attention
        from repro.models.layers import _plain_attention
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        B, Hq, Hkv, S, hd = 1, 4, 2, 64, 16
        q = jnp.asarray(rng.standard_normal((B, Hq, 1, hd)), jnp.float32)
        ck = jnp.asarray(rng.standard_normal((B, Hkv, S, hd)), jnp.float32)
        cv = jnp.asarray(rng.standard_normal((B, Hkv, S, hd)), jnp.float32)
        kn = jnp.asarray(rng.standard_normal((B, Hkv, 1, hd)), jnp.float32)
        vn = jnp.asarray(rng.standard_normal((B, Hkv, 1, hd)), jnp.float32)
        pos = jnp.int32(37)
        out, ck2, cv2 = jax.jit(lambda *a: sharded_decode_attention(
            *a, mesh=mesh, seq_axes=("data", "model"), rep=2))(
            q, ck, cv, kn, vn, pos)
        # reference: plain attention over the updated cache
        ck_ref = ck.at[:, :, 37].set(kn[:, :, 0])
        cv_ref = cv.at[:, :, 37].set(vn[:, :, 0])
        kk = jnp.repeat(ck_ref, 2, axis=1)
        vv = jnp.repeat(cv_ref, 2, axis=1)
        want = _plain_attention(q, kk, vv, causal=False, kv_valid_len=38)
        err = float(jnp.abs(out - want).max())
        print('ERR', err)
        assert err < 1e-4, err
        # cache update landed exactly once
        np.testing.assert_allclose(np.asarray(ck2), np.asarray(ck_ref),
                                   rtol=1e-6)
    """)
    assert "ERR" in out
