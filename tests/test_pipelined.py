"""Pipelined device-resident backend: kernel parity, backend parity,
pad/bs policy plumbing, the autotuner, and refinement edge cases."""
import json
import os

import numpy as np
import pytest

from repro.sparse.csr import make_spd
from repro.sparse.dataset import block_arrow, grid2d
from repro.sparse.multifrontal import (factor_and_solve_timed,
                                       multifrontal_cholesky,
                                       multifrontal_solve)
from repro.sparse.schedule import build_schedule
from repro.sparse.symbolic import symbolic_cholesky


@pytest.fixture(scope="module")
def spd_grid():
    return make_spd(grid2d(12, 12, "g12"))


# -- on-device extend-add kernel ---------------------------------------------

def _ref_extend_add(w, u, dst, rows):
    w = np.array(w)
    for c in range(u.shape[0]):
        act = rows[c] >= 0
        idx = rows[c][act]
        w[dst[c]][np.ix_(idx, idx)] += u[c][np.ix_(act, act)]
    return w


def test_extend_add_kernel_matches_reference():
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    B, M, C, R = 3, 16, 6, 8
    w = rng.standard_normal((B, M, M)).astype(np.float32)
    u = rng.standard_normal((C, R, R)).astype(np.float32)
    dst = np.array([0, 0, 0, 1, 2, 2], dtype=np.int32)  # sorted, repeats
    rows = np.full((C, R), -1, dtype=np.int32)
    for c in range(C):
        k = int(rng.integers(1, R + 1))
        rows[c, :k] = np.sort(rng.choice(M, size=k, replace=False))
    got = np.asarray(ops.extend_add_batch(w, u, dst, rows))
    np.testing.assert_allclose(got, _ref_extend_add(w, u, dst, rows),
                               rtol=1e-5, atol=1e-5)


def test_extend_add_all_masked_rows_are_inert():
    from repro.kernels import ops

    rng = np.random.default_rng(1)
    w = rng.standard_normal((2, 16, 16)).astype(np.float32)
    u = rng.standard_normal((2, 8, 8)).astype(np.float32)
    dst = np.array([0, 1], dtype=np.int32)
    rows = np.full((2, 8), -1, dtype=np.int32)  # fully masked
    got = np.asarray(ops.extend_add_batch(w, u, dst, rows))
    np.testing.assert_array_equal(got, w)


# -- backend parity ----------------------------------------------------------

@pytest.mark.parametrize("pad", ["pow2", "mult8"])
def test_pipelined_matches_batched_exactly(spd_grid, pad):
    a = spd_grid
    b = np.random.default_rng(3).standard_normal(a.n)
    fb = multifrontal_cholesky(a, backend="batched", pad=pad)
    fp_ = multifrontal_cholesky(a, backend="pipelined", pad=pad)
    xb = multifrontal_solve(fb, b)
    xp = multifrontal_solve(fp_, b)
    # same kernels, same schedule — the two paths agree to the last bit
    np.testing.assert_array_equal(xp, xb)


def test_pipelined_end_to_end_residual(small_suite):
    for a in small_suite:
        a = make_spd(a)
        b = np.random.default_rng(0).standard_normal(a.n)
        f = multifrontal_cholesky(a, backend="pipelined")
        x = multifrontal_solve(f, b)
        resid = np.linalg.norm(a.matvec(x) - b) / np.linalg.norm(b)
        assert resid < 1e-5, (a.name, resid)


def test_pipelined_reports_overlap_stats(spd_grid):
    f = multifrontal_cholesky(spd_grid, backend="pipelined")
    s = f.stats
    for k in ("t_factor_assemble", "t_factor_dispatch", "t_factor_sync",
              "overlap_efficiency"):
        assert k in s
    assert 0.0 <= s["overlap_efficiency"] <= 1.0
    assert s["t_factor_assemble"] > 0


def test_factor_and_solve_timed_forwards_pad_bs(spd_grid):
    r = factor_and_solve_timed(spd_grid, backend="pipelined", pad="mult8",
                               bs=16)
    assert r["bs"] == 16
    assert r["residual"] < 1e-5


# -- schedule pad policy + per-level occupancy -------------------------------

def test_mult8_schedule_invariants(spd_grid):
    sym = symbolic_cholesky(spd_grid)
    s8 = build_schedule(sym, pad="mult8")
    s2 = build_schedule(sym, pad="pow2")
    assert s8.pad == "mult8" and s2.pad == "pow2"
    for lvl in s8.buckets:
        for bkt in lvl:
            assert bkt.P % 8 == 0
            assert bkt.R % 8 == 0
            for k in bkt.members:
                fp = s8.fronts[k]
                assert fp.npiv <= bkt.P and fp.nrest <= bkt.R
    st8, st2 = s8.stats(), s2.stats()
    # tighter padding can only improve (or match) occupancy
    assert st8["occupancy"] >= st2["occupancy"]
    assert len(st8["per_level_occupancy"]) == s8.nlevels
    assert all(0 < o <= 1 for o in st8["per_level_occupancy"])
    assert st8["min_level_occupancy"] == min(st8["per_level_occupancy"])


def test_unknown_pad_policy_rejected(spd_grid):
    sym = symbolic_cholesky(spd_grid)
    with pytest.raises(ValueError, match="pad policy"):
        build_schedule(sym, pad="pow3")


# -- autotuner ---------------------------------------------------------------

def test_tuner_persists_and_round_trips(tmp_path):
    from repro.autotune.solve_tuner import (device_kind, get_policy,
                                            load_policy, policy_path, tune)

    d = str(tmp_path / "autotune")
    rng = np.random.default_rng(0)
    mats = [make_spd(block_arrow(3, 12, 6, rng, "t"))]
    pol = tune(mats, backend="pipelined", bs_grid=(16, 32),
               pads=("pow2",), repeats=1, out_dir=d)
    assert pol.source == "tuned" and pol.bs in (16, 32)
    path = policy_path(d, device_kind())
    assert os.path.exists(path)
    got = load_policy(d, device_kind(), backend="pipelined")
    assert got is not None and (got.bs, got.pad) == (pol.bs, pol.pad)
    assert got.source == "cached"
    # get_policy serves the cached record without re-measuring
    assert get_policy(d, backend="pipelined").source == "cached"
    # invalidation: device-kind or backend mismatch is a miss
    assert load_policy(d, "TPU v9", backend="pipelined") is None
    assert load_policy(d, device_kind(), backend="batched") is None
    # corrupt file is a miss, not a crash
    with open(path, "w") as fh:
        fh.write("{not json")
    assert load_policy(d, device_kind()) is None
    assert get_policy(d, backend="pipelined").source == "default"


def test_policy_meta_round_trips_through_plan_cache(tmp_path, spd_grid):
    from repro.core.plan import PlanBuilder, execute_plan
    from repro.core.plan_cache import TwoTierPlanCache, matrix_fingerprint

    cache = TwoTierPlanCache(8, str(tmp_path / "plans"), version="t1")
    builder = PlanBuilder(cache=cache)
    a = spd_grid
    key = matrix_fingerprint(a)
    plan = builder.build(a, algorithm="amd", fingerprint=key)
    r = execute_plan(a, plan, backend="pipelined", solve_dtype="fp32_refine",
                     pad="mult8", bs=16)
    assert r["residual"] < 1e-9
    assert plan.meta["solve_bs"] == 16
    assert plan.meta["solve_pad"] == "mult8"
    cache.put(key, plan)
    # a fresh cold-tier cache (same dir/version) must serve the meta back
    cache2 = TwoTierPlanCache(8, str(tmp_path / "plans"), version="t1")
    back = cache2.get(key)
    assert back is not None
    assert back.meta["solve_bs"] == 16
    assert back.meta["solve_pad"] == "mult8"


def test_execute_plan_promotes_fp64_on_pipelined(spd_grid):
    from repro.core.plan import PlanBuilder, execute_plan

    plan = PlanBuilder().build(spd_grid, algorithm="amd")
    r = execute_plan(spd_grid, plan, backend="pipelined", solve_dtype="fp64")
    assert r["solve_dtype"] == "fp32_refine"
    assert r["refine_converged"]
    assert r["overlap_efficiency"] is not None


# -- refinement edge cases ---------------------------------------------------

def test_refine_zero_iterations_when_inner_solver_exact():
    from repro.sparse.refine import refine_solve

    rng = np.random.default_rng(0)
    A = np.diag(rng.uniform(1.0, 2.0, 32))
    b = rng.standard_normal(32)
    x, info = refine_solve(lambda v: A @ v, lambda r: np.linalg.solve(A, r),
                           b)
    assert info.iterations == 0
    assert info.converged
    np.testing.assert_allclose(A @ x, b, rtol=1e-12)


def test_refine_zero_rhs_short_circuits():
    from repro.sparse.refine import refine_solve

    called = []
    x, info = refine_solve(lambda v: v, lambda r: called.append(1) or r,
                           np.zeros(8))
    assert not called  # no solve for b = 0
    assert info.converged and info.iterations == 0
    np.testing.assert_array_equal(x, np.zeros(8))


def test_refine_stall_detection_on_singularish_system():
    from repro.sparse.refine import refine_solve

    rng = np.random.default_rng(0)
    n = 24
    # near-singular: tiny eigenvalue makes fp32 corrections cycle
    A = np.diag(np.concatenate([np.ones(n - 1), [1e-14]]))
    b = rng.standard_normal(n)
    # inner solver that is badly wrong in the tiny direction (as an fp32
    # factorization would be): refinement cannot contract the residual
    bad = np.diag(np.concatenate([np.ones(n - 1), [1.0]]))
    x, info = refine_solve(lambda v: A @ v, lambda r: bad @ r, b,
                           max_iter=10)
    assert not info.converged
    assert info.iterations < 10  # stall guard fired before max_iter
    assert len(info.residuals) >= 2
    assert info.residuals[-1] > 0.5 * info.residuals[-2] * 0.99


def test_engine_config_warns_on_fp64_device_backend():
    from repro.engine.config import EngineConfig

    for backend in ("batched", "pipelined"):
        with pytest.warns(UserWarning, match="fp32_refine"):
            cfg = EngineConfig(backend=backend, solve_dtype="fp64")
        assert cfg.backend == backend
    # explicit fp32_refine is silent
    import warnings as _w

    with _w.catch_warnings():
        _w.simplefilter("error")
        EngineConfig(backend="pipelined", solve_dtype="fp32_refine")


def test_engine_config_accepts_pipelined_and_autotune_knobs(tmp_path):
    from repro.engine.config import EngineConfig

    cfg = EngineConfig(backend="pipelined", solve_dtype="fp32_refine",
                       autotune_solve=True,
                       autotune_dir=str(tmp_path / "at"))
    assert cfg.autotune_solve
    with pytest.raises(ValueError, match="backend"):
        EngineConfig(backend="vectorized")
