"""CSR container: construction, permutation, bandwidth/profile oracles."""
import numpy as np
import pytest

from repro.sparse.csr import (bandwidth, coo_to_csr, csr_from_dense,
                              make_spd, permute_symmetric, profile,
                              symmetrize_pattern)


def dense_bandwidth(a):
    idx = np.nonzero(a)
    return int(np.abs(idx[0] - idx[1]).max()) if idx[0].size else 0


def dense_profile(a):
    total = 0
    for i in range(a.shape[0]):
        nz = np.nonzero(a[i])[0]
        if nz.size and nz[0] < i:
            total += i - nz[0]
    return total


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_roundtrip_and_metrics(seed):
    rng = np.random.default_rng(seed)
    n = 40
    a = (rng.random((n, n)) < 0.1) * rng.standard_normal((n, n))
    m = csr_from_dense(a)
    np.testing.assert_allclose(m.to_dense(), a)
    assert bandwidth(m) == dense_bandwidth(a)
    assert profile(m) == dense_profile(a)


@pytest.mark.parametrize("seed", [0, 1])
def test_permute_symmetric_matches_dense(seed):
    rng = np.random.default_rng(seed)
    n = 30
    a = (rng.random((n, n)) < 0.15) * rng.standard_normal((n, n))
    a = a + a.T
    m = csr_from_dense(a)
    perm = rng.permutation(n)
    mp = permute_symmetric(m, perm)
    np.testing.assert_allclose(mp.to_dense(), a[np.ix_(perm, perm)])


def test_make_spd_is_spd(small_suite):
    for m in small_suite:
        d = m.to_dense()
        np.testing.assert_allclose(d, d.T)
        np.linalg.cholesky(d)  # raises if not SPD


def test_symmetrize_pattern():
    rng = np.random.default_rng(3)
    a = (rng.random((25, 25)) < 0.1) * 1.0
    m = csr_from_dense(a)
    s = symmetrize_pattern(m)
    assert s.is_structurally_symmetric()
    # idempotent on already-symmetric input
    s2 = symmetrize_pattern(s)
    assert np.array_equal(s2.indices, s.indices)


def test_matvec(small_suite):
    for m in small_suite:
        x = np.random.default_rng(0).standard_normal(m.n)
        np.testing.assert_allclose(m.matvec(x), m.to_dense() @ x, rtol=1e-10)
