"""repro.lifecycle: sharded resumable campaigns, shadow serving, the
promotion gate + bundle registry, and the lifecycle satellites (deadline
propagation into the numeric solve, per-shard mesh utilization)."""
import json
import os
import pickle

import pytest

from repro.engine import (EngineConfig, EngineError, SelectorBundle,
                          SolverEngine)
from repro.lifecycle import (BundleRegistry, BundleRegistryError,
                             CampaignConfig, GateRejected, NotPromotable,
                             PromotionGate, ShadowEvaluator,
                             assemble_dataset, evaluate_gate, run_campaign)
from repro.sparse.dataset import generate_suite
from repro.sparse.reorder import LABEL_ALGORITHMS

from test_engine import make_engine, synth_dataset


def tiny_suite(count=4):
    return list(generate_suite(count=count, seed=3, size_scale=0.2))


def campaign_cfg(tmp_path, **kw):
    kw.setdefault("campaign_id", "t")
    kw.setdefault("labels_dir", str(tmp_path / "labels"))
    kw.setdefault("workers", 2)
    return CampaignConfig(**kw)


# ---------------------------------------------------------------------------
# campaign: resume, sharding, assembly
# ---------------------------------------------------------------------------

def test_campaign_killed_midway_resumes_without_relabeling(tmp_path):
    mats = tiny_suite()
    cfg = campaign_cfg(tmp_path, max_cells=5)  # "killed" after 5 cells
    r1 = run_campaign(mats, cfg).report
    assert r1["cells_labeled"] == 5 and not r1["complete"]

    # poison every completed cell with a sentinel: a resume that
    # re-measured any of them would overwrite it
    poisoned = 0
    camp_dir = tmp_path / "labels" / "t"
    for fn in os.listdir(camp_dir):
        path = camp_dir / fn
        rec = json.loads(path.read_text())
        for cell in rec["cells"].values():
            cell["time"] = 123.456
            poisoned += 1
        path.write_text(json.dumps(rec))
    assert poisoned == 5

    cfg2 = campaign_cfg(tmp_path)  # no budget: finish the campaign
    r2 = run_campaign(mats, cfg2).report
    assert r2["cells_skipped"] == 5
    assert r2["cells_labeled"] == r2["cells_total"] - 5
    assert r2["complete"]
    survivors = 0
    for fn in os.listdir(camp_dir):
        rec = json.loads((camp_dir / fn).read_text())
        survivors += sum(1 for c in rec["cells"].values()
                         if c["time"] == 123.456)
    assert survivors == poisoned  # completed cells were never re-labeled


def test_campaign_report_shape(tmp_path):
    mats = tiny_suite()
    res = run_campaign(mats, campaign_cfg(tmp_path))
    r = res.report
    assert r["cells_total"] == len(mats) * len(LABEL_ALGORITHMS)
    assert r["cells_labeled"] == r["cells_total"]
    assert sum(r["per_algorithm_wins"].values()) == len(mats)
    bd = r["label_time_breakdown"]
    assert all(bd[k] >= 0 for k in ("order_s", "symbolic_s", "factor_s",
                                    "solve_s"))
    assert res.dataset is not None  # single shard + complete → assembled


def test_campaign_shards_partition_and_assemble(tmp_path):
    mats = tiny_suite()
    for i in range(2):
        cfg = campaign_cfg(tmp_path, shard_index=i, shard_count=2)
        r = run_campaign(mats, cfg).report
        assert r["complete"]
        assert r["matrices"] == len([m for j, m in enumerate(mats)
                                     if j % 2 == i])
    # the union of the shards covers the suite: assembly succeeds and
    # matches the sequential labeling layout
    ds = assemble_dataset(mats, campaign_cfg(tmp_path))
    assert ds.names == [a.name for a in mats]
    assert ds.times.shape == (len(mats), len(LABEL_ALGORITHMS))
    assert (ds.labels == ds.times.argmin(axis=1)).all()


def test_assemble_incomplete_campaign_raises(tmp_path):
    mats = tiny_suite()
    run_campaign(mats, campaign_cfg(tmp_path, max_cells=3))
    with pytest.raises(RuntimeError, match="missing cells|no label"):
        assemble_dataset(mats, campaign_cfg(tmp_path))


def test_assembled_dataset_trains_an_engine(tmp_path):
    # 8 matrices over 4 algorithms: however noisy the timings, some
    # winner class has >= 2 members, so the stratified held-out split
    # is never empty
    mats = tiny_suite(count=8)
    res = run_campaign(mats, campaign_cfg(tmp_path))
    engine = SolverEngine(EngineConfig(
        model="decision_tree", path="host", fast_grids=True, cv=2,
        test_size=0.5, cache_dir=None))
    report = engine.train(res.dataset)
    assert engine.is_trained and "test_accuracy" in report
    name, _ = engine.select(mats[0])
    assert name in LABEL_ALGORITHMS


# ---------------------------------------------------------------------------
# shadow serving
# ---------------------------------------------------------------------------

def test_shadow_never_touches_client_responses(tmp_path, small_suite):
    engine = make_engine(tmp_path, bundle_dir=str(tmp_path / "bundles"))
    cand = make_engine(tmp_path / "cand", seed=9)
    cand_path = str(tmp_path / "cand.bundle")
    cand.save(cand_path)

    baseline = [engine.plan(a).algorithm for a in small_suite]
    built0 = engine.builder.plans_built
    engine.start_shadow(cand_path)
    shadowed = [engine.plan(a).algorithm for a in small_suite]
    assert shadowed == baseline
    assert engine.builder.plans_built == built0  # all warm, no rebuilds
    assert engine.shadow.drain(30)
    st = engine.shadow.stats()
    assert st["requests"] == len(small_suite)
    assert st["evaluated"] == len(small_suite)
    assert st["agreements"] + st["disagreements"] == st["evaluated"]
    assert st["wins"] + st["losses"] == st["evaluated"]
    # the scorecard also lands in the engine's metrics registry
    snap = engine.metrics.snapshot()
    assert snap["shadow.evaluated"] == len(small_suite)
    assert 0.0 <= snap["shadow.win_rate"] <= 1.0
    final = engine.stop_shadow()
    assert final["evaluated"] == len(small_suite)
    assert engine.shadow is None


def test_dispatcher_mirrors_warm_and_cold_decisions(tmp_path, small_suite):
    engine = make_engine(tmp_path)
    cand = make_engine(tmp_path / "cand", seed=9)
    engine.start_shadow(SelectorBundle.from_selector(cand.selector))
    server = engine.serve(batch_size=2, max_wait_ms=1.0)
    try:
        cold = [f.result(60) for f in [server.submit(a)
                                       for a in small_suite]]
        warm = [f.result(60) for f in [server.submit(a)
                                       for a in small_suite]]
        assert [p.algorithm for p in cold] == [p.algorithm for p in warm]
        assert engine.shadow.drain(30)
        st = engine.shadow.stats()
        # cold path mirrors once per unique structure, warm once per hit
        assert st["requests"] == 2 * len(small_suite)
    finally:
        server.close()
        engine.stop_shadow()


def test_shadow_observe_never_raises_and_drops_when_full(tmp_path):
    cand = make_engine(tmp_path, seed=9)
    ev = ShadowEvaluator(SelectorBundle.from_selector(cand.selector),
                         max_queue=1)
    try:
        ev.close()  # worker gone: observations can only queue up / drop
        mats = tiny_suite(2)
        for _ in range(5):
            ev.observe(mats[0], "amd")
        st = ev.stats()
        assert st["requests"] == 5
        assert st["dropped"] >= 3  # capacity 1 (+1 possibly consumed)
    finally:
        ev.close()


# ---------------------------------------------------------------------------
# promotion gate + registry
# ---------------------------------------------------------------------------

def make_v1_bundle_path(tmp_path, engine) -> str:
    """The PR 6 v1-envelope recipe: strip the v2 descriptive sections."""
    path = str(tmp_path / "v1.bundle")
    engine.save(path)
    with open(path, "rb") as f:
        env = pickle.load(f)
    env["schema_version"] = 1
    env["bundle"]["schema_version"] = 1
    del env["bundle"]["report_card"]
    del env["bundle"]["provenance"]
    with open(path, "wb") as f:
        pickle.dump(env, f)
    return path


def test_v1_bundle_loads_but_is_never_auto_promotable(tmp_path):
    engine = make_engine(tmp_path, bundle_dir=str(tmp_path / "bundles"))
    v1_path = make_v1_bundle_path(tmp_path, make_engine(tmp_path / "c",
                                                        seed=9))
    # loadable and servable...
    b = SelectorBundle.load(v1_path)
    assert b.schema_version == 1 and b.report_card is None
    assert SolverEngine.load(v1_path).is_trained
    # ...but the gate refuses it with the typed error, however permissive
    gate = PromotionGate(min_test_accuracy=0.0, require_shadow=False)
    with pytest.raises(NotPromotable, match="report card"):
        evaluate_gate(b, gate)
    with pytest.raises(NotPromotable):
        engine.promote(v1_path, gate=gate)
    # nothing changed: no registration, no swap
    assert len(engine.registry) == 0


def test_gate_rejects_on_each_threshold(tmp_path):
    cand = make_engine(tmp_path, seed=9)
    b = SelectorBundle.from_selector(cand.selector,
                                     report_card=dict(test_accuracy=0.8))
    ok_stats = dict(evaluated=20, win_rate=0.75)

    dec = evaluate_gate(b, PromotionGate(0.5, 10, 0.5), ok_stats)
    assert dec["passed"] and dec["fingerprint"] == b.fingerprint

    with pytest.raises(GateRejected) as ei:
        evaluate_gate(b, PromotionGate(0.9, 10, 0.5), ok_stats)
    assert [c["check"] for c in ei.value.decision["checks"]
            if not c["passed"]] == ["report_card.test_accuracy"]
    with pytest.raises(GateRejected):
        evaluate_gate(b, PromotionGate(0.5, 100, 0.5), ok_stats)
    with pytest.raises(GateRejected):
        evaluate_gate(b, PromotionGate(0.5, 10, 0.9), ok_stats)
    with pytest.raises(GateRejected):  # no shadow evidence at all
        evaluate_gate(b, PromotionGate(0.5, 10, 0.5), None)
    # offline-only gate ignores the missing shadow
    assert evaluate_gate(b, PromotionGate(0.5, require_shadow=False),
                         None)["passed"]


def test_registry_lineage_statuses_and_dedup(tmp_path):
    reg = BundleRegistry(str(tmp_path / "bundles"))
    b1 = SelectorBundle.from_selector(make_engine(tmp_path / "a").selector)
    b2 = SelectorBundle.from_selector(
        make_engine(tmp_path / "b", seed=9).selector)
    e1 = reg.register(b1, source="train")
    assert e1["status"] == "candidate" and e1["parent"] is None
    assert reg.register(b1)["version"] == e1["version"]  # content dedup
    assert len(reg) == 1
    reg.mark_serving(e1["version"])
    e2 = reg.register(b2, source="retrain")
    assert e2["parent"] == e1["version"]  # lineage edge to serving
    reg.mark_serving(e2["version"])
    assert reg.serving_version() == e2["version"]
    assert reg.entry(e1["version"])["status"] == "retired"
    chain = reg.lineage()
    assert [e["version"] for e in chain] == [e2["version"], e1["version"]]
    # loaded payload round-trips
    assert reg.load(e2["version"]).fingerprint == b2.fingerprint
    # rollback swaps the pointers and marks the demoted version
    back = reg.rollback()
    assert back["version"] == e1["version"]
    assert reg.entry(e2["version"])["status"] == "rolled_back"
    assert reg.previous_version() == e2["version"]
    with pytest.raises(BundleRegistryError):
        reg.entry("v9999-nope")


def test_rollback_with_no_previous_raises(tmp_path):
    with pytest.raises(BundleRegistryError, match="roll back"):
        BundleRegistry(str(tmp_path / "bundles")).rollback()


def test_promote_swaps_cache_version_and_rollback_restores(
        tmp_path, small_suite):
    engine = make_engine(tmp_path, bundle_dir=str(tmp_path / "bundles"),
                         promote_min_accuracy=0.0,
                         promote_min_shadow_requests=1,
                         promote_min_win_rate=0.0)
    fp0 = engine.fingerprint
    cand = make_engine(tmp_path / "cand", seed=9)
    cand_path = str(tmp_path / "cand.bundle")
    cand.save(cand_path)

    for a in small_suite:           # warm the incumbent's two-tier cache
        engine.plan(a)
    engine.start_shadow(cand_path)
    for a in small_suite:
        engine.plan(a)
    engine.shadow.drain(30)

    # a gate the candidate cannot clear leaves everything untouched
    with pytest.raises(GateRejected):
        engine.promote(gate=PromotionGate(0.0, 1, 1.01))
    assert engine.fingerprint == fp0

    decision = engine.promote()     # config thresholds: permissive
    assert decision["passed"] and engine.fingerprint == cand.fingerprint
    assert engine.shadow is None    # promote retires the shadow
    assert engine.config.model == "decision_tree"
    # old plans are invisible under the new cache version
    assert engine.builder.sym_builds == 0
    engine.plan(small_suite[0])
    assert engine.builder.sym_builds == 1
    # registry recorded the swap with lineage
    assert engine.registry.serving_version() == decision["version"]
    assert (engine.registry.entry(decision["version"])["parent"]
            == decision["previous_version"])

    entry = engine.rollback()
    assert entry["version"] == decision["previous_version"]
    assert engine.fingerprint == fp0
    # the incumbent's plans come back from disk: no symbolic rebuild
    sb = engine.builder.sym_builds
    engine.plan(small_suite[0])
    assert engine.builder.sym_builds == sb


def test_promote_same_bundle_twice_preserves_report_card(tmp_path):
    """After promote #1 the engine's last_report describes the OLD fit;
    registering the incumbent at promote #2 must reuse the adopted
    bundle's own card (fingerprint-matched), not a stale report."""
    engine = make_engine(tmp_path, bundle_dir=str(tmp_path / "bundles"))
    c1 = make_engine(tmp_path / "c1", seed=9)
    p1 = str(tmp_path / "c1.bundle")
    c1.save(p1)
    gate = PromotionGate(min_test_accuracy=0.0, require_shadow=False)
    d1 = engine.promote(p1, gate=gate)
    c2 = make_engine(tmp_path / "c2", seed=11)
    p2 = str(tmp_path / "c2.bundle")
    c2.save(p2)
    d2 = engine.promote(p2, gate=gate)
    # promote #2's "incumbent" registration deduped onto promote #1's
    # candidate entry (same fingerprint) — no phantom third lineage node
    assert d2["previous_version"] == d1["version"]
    reg = engine.registry
    inc = reg.entry(d1["version"])
    assert inc["fingerprint"] == c1.fingerprint
    assert inc["test_accuracy"] == pytest.approx(
        c1.last_report["test_accuracy"])


def test_promote_without_candidate_or_shadow_raises(tmp_path):
    engine = make_engine(tmp_path, bundle_dir=str(tmp_path / "bundles"))
    with pytest.raises(EngineError, match="no candidate"):
        engine.promote()


# ---------------------------------------------------------------------------
# satellites: deadline propagation + per-shard mesh utilization
# ---------------------------------------------------------------------------

class _ExpiringCtx:
    """RequestContext stand-in whose deadline passes after N expiry checks
    — deterministic mid-factorization expiry without wall-clock sleeps."""

    def __init__(self, after: int):
        self.after = after
        self.calls = 0

    def expired(self) -> bool:
        self.calls += 1
        return self.calls > self.after

    def remaining(self) -> float:
        return -0.005


@pytest.mark.parametrize("backend", ["batched", "pipelined"])
def test_deadline_exceeded_mid_factorization(small_suite, backend):
    from repro.core.reqctx import DeadlineExceeded
    from repro.sparse.multifrontal import multifrontal_cholesky

    a = small_suite[0]
    ctx = _ExpiringCtx(after=1)  # passes the entry check, expires at L0
    with pytest.raises(DeadlineExceeded, match="factorization abandoned"):
        multifrontal_cholesky(a, backend=backend, ctx=ctx)
    assert ctx.calls >= 2  # entry check + at least one level boundary
    # an unexpired context leaves the result untouched
    live = _ExpiringCtx(after=10_000)
    f = multifrontal_cholesky(a, backend=backend, ctx=live)
    assert f.stats["nsup"] > 0 and live.calls >= 2


def test_execute_plan_threads_ctx_into_numeric_phase(small_suite):
    from repro.core.plan import PlanBuilder, execute_plan
    from repro.core.reqctx import DeadlineExceeded

    a = small_suite[0]
    plan = PlanBuilder().build(a, algorithm="amd")
    ctx = _ExpiringCtx(after=1)
    with pytest.raises(DeadlineExceeded):
        execute_plan(a, plan, backend="batched", solve_dtype="fp32",
                     ctx=ctx)


def test_shard_utilization_math():
    from repro.distributed.meshctx import ServingMesh, make_serving_mesh

    sm = make_serving_mesh(1)  # tests always see one device
    assert sm.shard_utilization(3, 4) == [(3, 1)]
    assert sm.shard_utilization(4, 4) == [(4, 0)]
    assert sm.shard_utilization(0, 4) == [(0, 4)]

    class _Wide:  # the 4-shard math without needing 4 devices
        num_devices = 4
        shard_utilization = ServingMesh.shard_utilization

    wide = _Wide()
    # contiguous split: padding concentrates on the tail shards
    assert wide.shard_utilization(5, 8) == [(2, 0), (2, 0), (1, 1), (0, 2)]
    assert wide.shard_utilization(8, 8) == [(2, 0)] * 4
    with pytest.raises(ValueError):
        wide.shard_utilization(5, 6)  # 6 rows don't divide over 4 shards


def test_record_shard_utilization_metrics():
    from repro.core.metrics import MetricsRegistry
    from repro.distributed.meshctx import (make_serving_mesh,
                                           record_shard_utilization)

    m = MetricsRegistry()
    sm = make_serving_mesh(1)
    record_shard_utilization(m, sm, 3, 4)
    record_shard_utilization(m, sm, 4, 4)
    snap = m.snapshot()
    assert snap["mesh.shards"] == 1
    assert snap["mesh.shard0.requests"] == 7
    assert snap["mesh.shard0.pad_rows"] == 1
    record_shard_utilization(None, sm, 3, 4)  # metrics=None: no-op


def test_device_path_records_mesh_utilization(tmp_path, small_suite):
    engine = SolverEngine(EngineConfig(
        model="decision_tree", path="device", fast_grids=True, cv=3,
        batch_size=4, cache_dir=str(tmp_path / "plan_cache")))
    engine.train(synth_dataset())
    engine.plan_batch(small_suite)
    snap = engine.metrics.snapshot()
    assert snap["mesh.shards"] >= 1
    total = snap["mesh.shard0.requests"]
    assert total >= len(small_suite)  # every live row was accounted
