"""ExecutionPlan pipeline: builder, two-tier cache, async server."""
import threading

import numpy as np
import pytest

from repro.core.features import FEATURE_NAMES, extract_features_batch
from repro.core.ml import RandomForestClassifier
from repro.core.plan import ExecutionPlan, PlanBuilder, execute_plan
from repro.core.plan_cache import (PlanCache, TwoTierPlanCache,
                                   matrix_fingerprint)
from repro.core.scaling import StandardScaler
from repro.core.selector import ReorderSelector
from repro.sparse.dataset import generate_suite


@pytest.fixture(scope="module")
def mats():
    return list(generate_suite(count=8, seed=3, size_scale=0.25))


@pytest.fixture(scope="module")
def rf_selector(mats):
    feats = extract_features_batch(mats)
    labels = (feats[:, FEATURE_NAMES.index("bandwidth")]
              / np.maximum(feats[:, 0], 1) > 0.5).astype(int)
    scaler = StandardScaler().fit(feats)
    rf = RandomForestClassifier(n_estimators=10).fit(
        scaler.transform(feats), labels)
    return ReorderSelector(rf, scaler, ["amd", "rcm"])


# ---------------------------------------------------------------------------
# PlanBuilder + execute_plan
# ---------------------------------------------------------------------------

def test_plan_batch_builds_valid_plans(mats, rf_selector):
    builder = PlanBuilder(rf_selector, PlanCache(64), batch_size=4)
    plans = builder.plan_batch(mats)
    assert len(plans) == len(mats)
    for m, p in zip(mats, plans):
        assert isinstance(p, ExecutionPlan)
        assert p.fingerprint == matrix_fingerprint(m)
        assert p.algorithm in rf_selector.algorithms
        assert sorted(p.perm.tolist()) == list(range(m.n))
        assert p.predicted_flops == p.sym.flops > 0


def test_execute_plan_solves(mats, rf_selector):
    builder = PlanBuilder(rf_selector, PlanCache(64), batch_size=4)
    m = mats[2]
    plan = builder.plan_batch([m])[0]
    b = np.random.default_rng(1).standard_normal(m.n)
    res = execute_plan(m, plan, b)
    assert res["residual"] < 1e-8
    res2 = execute_plan(m, plan, b, solver="simplicial")
    assert res2["residual"] < 1e-8
    np.testing.assert_allclose(res["x"], res2["x"], rtol=1e-8, atol=1e-10)


def test_warm_hit_skips_select_and_symbolic(mats, rf_selector, monkeypatch):
    """Acceptance: a warm hit does no feature extraction, no classifier
    call, no symbolic analysis — the selector can be removed outright and
    the symbolic routine booby-trapped, and warm serving still works."""
    builder = PlanBuilder(rf_selector, PlanCache(64), batch_size=4)
    cold = builder.plan_batch(mats)
    built, selected = builder.plans_built, builder.select_calls

    class _NoSelector:
        def select_batch(self, *a, **k):
            raise AssertionError("selector ran on a warm hit")

        select = select_batch

    monkeypatch.setattr(builder, "selector", _NoSelector())
    monkeypatch.setattr("repro.core.plan.symbolic_cholesky",
                        lambda *a, **k: (_ for _ in ()).throw(
                            AssertionError("symbolic ran on a warm hit")))
    warm = builder.plan_batch(mats)
    assert [p.fingerprint for p in warm] == [p.fingerprint for p in cold]
    assert builder.plans_built == built and builder.select_calls == selected
    assert builder.stats()["hit_rate"] == 0.5  # second pass all hits


def test_execute_plan_runs_no_symbolic(mats, rf_selector, monkeypatch):
    builder = PlanBuilder(rf_selector, PlanCache(8), batch_size=4)
    plan = builder.plan_batch([mats[1]])[0]
    monkeypatch.setattr("repro.sparse.multifrontal.symbolic_cholesky",
                        lambda *a, **k: (_ for _ in ()).throw(
                            AssertionError("symbolic ran under a plan")))
    res = execute_plan(mats[1], plan)
    assert res["residual"] < 1e-8


def test_factor_and_solve_timed_accepts_plan_sym(mats, rf_selector):
    from repro.sparse.csr import permute_symmetric
    from repro.sparse.multifrontal import factor_and_solve_timed

    builder = PlanBuilder(rf_selector, PlanCache(8), batch_size=4)
    plan = builder.plan_batch([mats[3]])[0]
    pa = permute_symmetric(mats[3], plan.perm)
    res = factor_and_solve_timed(pa, sym=plan.sym)
    assert res["t_symbolic"] == 0.0
    assert res["residual"] < 1e-8


# ---------------------------------------------------------------------------
# two-tier cache
# ---------------------------------------------------------------------------

def test_two_tier_persistence_roundtrip(mats, rf_selector, tmp_path):
    d = str(tmp_path / "plans")
    builder = PlanBuilder(rf_selector, TwoTierPlanCache(16, d), batch_size=4)
    plan = builder.plan_batch([mats[0]])[0]
    key = matrix_fingerprint(mats[0])

    fresh = TwoTierPlanCache(16, d)  # simulated process restart
    got = fresh.get(key)
    assert got is not None and got.algorithm == plan.algorithm
    np.testing.assert_array_equal(got.perm, plan.perm)
    np.testing.assert_array_equal(got.sym.Li, plan.sym.Li)
    s = fresh.stats()
    assert s["disk_hits"] == 1 and s["hits"] == 1 and s["misses"] == 0
    assert fresh.get(key) is got or fresh.get(key) is not None
    assert fresh.stats()["memory_hits"] >= 1  # promoted into the LRU


def test_two_tier_lru_eviction_falls_to_disk(tmp_path):
    c = TwoTierPlanCache(2, str(tmp_path / "plans"))
    for key, val in [("a", 1), ("b", 2), ("c", 3)]:
        c.put(key, val)
    assert c.stats()["evictions"] == 1 and len(c) == 2
    assert c.peek("a") is None          # gone from memory...
    assert c.get("a") == 1              # ...recovered from disk
    s = c.stats()
    assert s["disk_hits"] == 1 and s["misses"] == 0
    assert c.peek("a") == 1             # promoted back (evicting "b")
    assert len(c) == 2 and c.disk_entries() == 3


def test_two_tier_version_namespaces_disk(tmp_path):
    """Bumping the cache version (e.g. after retraining the selector)
    makes every old disk entry a miss without touching its file."""
    d = str(tmp_path / "plans")
    old = TwoTierPlanCache(4, d, version="m1")
    old.put("k", "plan-from-old-model")
    new = TwoTierPlanCache(4, d, version="m2")
    assert new.get("k") is None
    assert new.disk_entries() == 0 and old.disk_entries() == 1
    assert TwoTierPlanCache(4, d, version="m1").get("k") \
        == "plan-from-old-model"


def test_two_tier_ignores_corrupt_entry(tmp_path):
    c = TwoTierPlanCache(2, str(tmp_path / "plans"))
    c.put("a", {"x": 1})
    with open(c._path("a"), "wb") as f:
        f.write(b"not a pickle")
    c2 = TwoTierPlanCache(2, str(tmp_path / "plans"))
    assert c2.get("a") is None
    assert c2.stats()["misses"] == 1


@pytest.mark.parametrize("factory", [
    lambda tmp: PlanCache(capacity=32),
    lambda tmp: TwoTierPlanCache(32, str(tmp / "plans")),
])
def test_plan_cache_thread_safety(tmp_path, factory):
    cache = factory(tmp_path)
    keys = [f"k{i}" for i in range(100)]
    gets_per_thread = 300
    errors = []

    def worker(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(gets_per_thread):
                k = keys[int(rng.integers(len(keys)))]
                if cache.get(k) is None:
                    cache.put(k, seed)
        except Exception as exc:  # pragma: no cover - only on races
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    s = cache.stats()
    assert s["hits"] + s["misses"] == 8 * gets_per_thread
    assert len(cache) <= 32


# ---------------------------------------------------------------------------
# async server
# ---------------------------------------------------------------------------

def test_async_plan_server(mats, rf_selector):
    from repro.launch.serve_selector import AsyncPlanServer

    builder = PlanBuilder(rf_selector, PlanCache(64), batch_size=4)
    server = AsyncPlanServer(builder, batch_size=4, max_wait_ms=2.0,
                             build_workers=2)
    try:
        req = list(mats) + [mats[0], mats[3]]  # duplicates in-flight
        plans = server.handle(req)
        assert [p.fingerprint for p in plans] == \
            [matrix_fingerprint(m) for m in req]
        assert plans[-2].fingerprint == plans[0].fingerprint
        # one plan built per distinct structure, despite the duplicates
        assert builder.plans_built == len(mats)

        warm = server.handle(list(mats))
        assert [p.fingerprint for p in warm] == \
            [p.fingerprint for p in plans[: len(mats)]]
        assert builder.plans_built == len(mats)  # nothing rebuilt
        s = server.stats()
        assert s["warm_hits"] >= len(mats)
        assert s["p50_ms"] >= 0.0 and s["p99_ms"] >= s["p50_ms"]
        assert s["requests"] == len(req) + len(mats)
    finally:
        server.close()
    server.close()  # idempotent
