"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, shape + finiteness assertions; decode-vs-forward consistency for one
arch per mixer family (attention / mamba / xlstm)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.models import decode_step, init_params, loss_fn, prefill

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def make_batch(cfg, b=B, s=S, with_labels=True):
    rng = np.random.default_rng(0)
    batch = {}
    if cfg.input_mode == "tokens":
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    else:
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
        if cfg.mrope:
            batch["positions3"] = jnp.broadcast_to(
                jnp.arange(s)[None, None], (3, b, s)).astype(jnp.int32)
    if with_labels:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_train_step(name):
    cfg = get_smoke_config(name)
    params = init_params(cfg, KEY)
    batch = make_batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
    assert jnp.isfinite(loss), name
    assert loss.shape == ()
    gnorm = sum(float(jnp.abs(g.astype(jnp.float32)).sum())
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_decode_step(name):
    cfg = get_smoke_config(name)
    params = init_params(cfg, KEY)
    batch = make_batch(cfg, with_labels=False)
    logits, cache = prefill(cfg, params, batch, max_seq=S + 4)
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), name
    tok = (jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
           if cfg.input_mode == "tokens"
           else jnp.zeros((B, 1, cfg.d_model), jnp.float32))
    logits2, cache2 = decode_step(cfg, params, cache, tok)
    assert logits2.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits2).all(), name
    assert int(cache2["pos"]) == S + 1


@pytest.mark.parametrize("name", ["llama3.2-1b", "jamba-v0.1-52b",
                                  "xlstm-125m"])
def test_decode_matches_full_forward(name):
    """Teacher-forcing equivalence: logits from incremental decode must
    match the full parallel forward at each position (validates the KV
    cache AND the mamba/xlstm recurrent-vs-parallel state math)."""
    cfg = get_smoke_config(name)
    params = init_params(cfg, KEY)
    s_total = 12
    batch = make_batch(cfg, s=s_total, with_labels=False)

    # full forward logits at every position
    from repro.models.transformer import _forward, _unembed, rms_norm
    x, _ = _forward(cfg, params, batch)
    full_logits = _unembed(cfg, params, x).astype(jnp.float32)

    # prefill on the first half, decode the rest one token at a time
    half = s_total // 2
    if cfg.input_mode == "tokens":
        pre = {"tokens": batch["tokens"][:, :half]}
        feed = [batch["tokens"][:, i:i + 1] for i in range(half, s_total)]
    else:
        pre = {"embeds": batch["embeds"][:, :half]}
        if cfg.mrope:
            pre["positions3"] = batch["positions3"][:, :, :half]
        feed = [batch["embeds"][:, i:i + 1] for i in range(half, s_total)]
    logits, cache = prefill(cfg, params, pre, max_seq=s_total + 1)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, half - 1]),
        rtol=0.15, atol=0.15)
    for i, tok in enumerate(feed[:-1]):
        logits, cache = decode_step(cfg, params, cache, tok)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, half + i]),
            rtol=0.2, atol=0.25, err_msg=f"{name} pos {half + i}")


def test_full_configs_match_assignment():
    """Exact dims from the assignment table."""
    expect = {
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840, 64, 6),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064, 16, 2),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304, 0, 0),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416, 0, 0),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152, 0, 0),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936, 0, 0),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256, 0, 0),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936, 0, 0),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048, 0, 0),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536, 16, 2),
    }
    for name, (L, d, h, kv, ff, v, e, k) in expect.items():
        cfg = get_config(name)
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size, cfg.num_experts,
               cfg.experts_per_token)
        assert got == (L, d, h, kv, ff, v, e, k), (name, got)


def test_jamba_pattern_ratio():
    cfg = get_config("jamba-v0.1-52b")
    attn = sum(1 for k in cfg.block_pattern if k == "a")
    mamba = sum(1 for k in cfg.block_pattern if k == "m")
    assert (attn, mamba) == (1, 7)  # 1:7 interleave
    moe_layers = sum(cfg.layer_is_moe(i) for i in range(cfg.num_layers))
    assert moe_layers == 16  # every other layer
