"""Replica-shared disk tier: FileLock semantics + the concurrent-sweep fix.

The bug under test: two processes sharing one ``plan_cache`` dir both run
the budget-eviction sweep, both list the same files, both compute the same
overage, and together delete far more than the budget requires while each
miscounts its evictions. The fix serializes sweeps under a cross-process
``flock`` (non-blocking: the loser skips). The multi-process tests below
drive real ``fork``-ed processes at one directory.
"""
import multiprocessing as mp
import os
import threading
import time

import pytest

from repro.core.locking import FileLock
from repro.core.plan_cache import TwoTierPlanCache

pytestmark = pytest.mark.skipif(os.name != "posix",
                                reason="flock is POSIX-only")


# ---------------------------------------------------------------------------
# FileLock semantics
# ---------------------------------------------------------------------------

def test_exclusive_excludes_other_process(tmp_path):
    path = str(tmp_path / ".lock")
    lock = FileLock(path)
    ctx = mp.get_context("fork")

    def try_child(q):
        child = FileLock(path)
        q.put(child.acquire(blocking=False))
        if not q.empty():
            pass

    with lock.exclusive():
        q = ctx.Queue()
        p = ctx.Process(target=try_child, args=(q,))
        p.start()
        got = q.get(timeout=30)
        p.join(30)
    assert got is False  # child's non-blocking exclusive try must fail
    # released now: a fresh child succeeds
    q2 = ctx.Queue()
    p2 = ctx.Process(target=try_child, args=(q2,))
    p2.start()
    assert q2.get(timeout=30) is True
    p2.join(30)


def test_shared_allows_shared_across_processes(tmp_path):
    path = str(tmp_path / ".lock")
    lock = FileLock(path)
    ctx = mp.get_context("fork")

    def shared_child(q):
        child = FileLock(path)
        q.put(child.acquire(blocking=False, shared=True))

    with lock.shared():
        q = ctx.Queue()
        p = ctx.Process(target=shared_child, args=(q,))
        p.start()
        assert q.get(timeout=30) is True  # SH + SH coexist
        p.join(30)


def test_nonblocking_try_within_process(tmp_path):
    lock = FileLock(str(tmp_path / ".lock"))
    assert lock.acquire(blocking=False)
    t_result = []
    t = threading.Thread(
        target=lambda: t_result.append(lock.acquire(blocking=False)))
    t.start()
    t.join(10)
    assert t_result == [False]  # thread mutex held → try fails, no deadlock
    lock.release()
    assert lock.acquire(blocking=False)
    lock.release()


def test_lock_survives_pickle(tmp_path):
    import pickle

    lock = FileLock(str(tmp_path / ".lock"))
    with lock.exclusive():
        pass
    clone = pickle.loads(pickle.dumps(lock))
    assert clone.path == lock.path
    assert clone.acquire(blocking=False)
    clone.release()


# ---------------------------------------------------------------------------
# the concurrent-sweep bugfix, multi-process
# ---------------------------------------------------------------------------

def _fill(cache, start, count, size=400):
    for i in range(start, start + count):
        cache.put(f"key-{i:04d}", {"i": i, "pad": "x" * size})


def _sweep_replica(cache_dir, barrier, results):
    """One serving replica: open the shared tier with a tight entry budget
    and trigger the eviction sweep at the same instant as its sibling."""
    cache = TwoTierPlanCache(capacity=8, cache_dir=cache_dir,
                             version="shared", max_disk_entries=10)
    barrier.wait(timeout=60)
    # the put triggers _evict_disk after its write
    cache.put("trigger-" + str(os.getpid()), {"pad": "y" * 400})
    results.put(cache.stats()["disk_evictions"])


def test_concurrent_sweeps_do_not_over_evict(tmp_path):
    """Two replicas sweeping one over-budget tier concurrently must not
    double-delete: the flock serializes them, the loser skips, and the
    tier ends exactly at the budget — never below it."""
    d = str(tmp_path / "tier")
    seed = TwoTierPlanCache(capacity=64, cache_dir=d, version="shared")
    _fill(seed, 0, 30)  # no budget on the seeder: 30 files on disk
    assert seed.disk_entries() == 30

    ctx = mp.get_context("fork")
    barrier = ctx.Barrier(2)
    results = ctx.Queue()
    procs = [ctx.Process(target=_sweep_replica, args=(d, barrier, results))
             for _ in range(2)]
    for p in procs:
        p.start()
    evictions = [results.get(timeout=120) for _ in procs]
    for p in procs:
        p.join(60)
        assert p.exitcode == 0

    survivor = TwoTierPlanCache(capacity=8, cache_dir=d, version="shared",
                                max_disk_entries=10)
    remaining = survivor.disk_entries()
    # NEVER below budget: over-eviction (the old double-sweep bug, where
    # both replicas list 30+ files and both delete their overage) would
    # leave far fewer than 10
    assert remaining >= 10, (remaining, evictions)
    # bounded drift: the budget is soft under concurrency — a trigger file
    # written after the winning sweep's listdir survives until the next
    # sweep — but by at most one file per skipped sweeper
    assert remaining <= 11, (remaining, evictions)
    # exact accounting: evictions across replicas == files actually gone
    # (30 seeded + 2 triggers − survivors); the old bug double-counted
    assert sum(evictions) == 32 - remaining, (remaining, evictions)


def test_sequential_replicas_share_warm_tier(tmp_path):
    """A second replica process reads plans the first persisted (the
    replica-shared warm start the tier exists for)."""
    d = str(tmp_path / "tier")
    first = TwoTierPlanCache(capacity=4, cache_dir=d, version="v1")
    first.put("shared-key", {"payload": 42})

    ctx = mp.get_context("fork")

    def replica(q):
        second = TwoTierPlanCache(capacity=4, cache_dir=d, version="v1")
        got = second.get("shared-key")
        q.put((got, second.stats()["disk_hits"]))

    q = ctx.Queue()
    p = ctx.Process(target=replica, args=(q,))
    p.start()
    got, disk_hits = q.get(timeout=60)
    p.join(30)
    assert got == {"payload": 42}
    assert disk_hits == 1


def test_stats_scan_consistent_under_sweep(tmp_path):
    """stats() (shared lock) interleaved with eviction sweeps (exclusive
    lock) never crashes or reports negative/garbage usage."""
    d = str(tmp_path / "tier")
    cache = TwoTierPlanCache(capacity=16, cache_dir=d, version="v1",
                             max_disk_entries=12)
    stop = threading.Event()
    errs = []

    def hammer_stats():
        while not stop.is_set():
            try:
                s = cache.stats()
                assert s["disk_entries"] >= 0 and s["disk_bytes"] >= 0
            except Exception as exc:  # pragma: no cover - diagnostic
                errs.append(exc)
                return

    t = threading.Thread(target=hammer_stats)
    t.start()
    try:
        _fill(cache, 0, 40)
    finally:
        stop.set()
        t.join(30)
    assert not errs
    assert cache.disk_entries() <= 12
