"""Serving-path coverage: CSR-native batched featurizer vs host oracle,
batched selector inference, and the fingerprint plan cache."""
import numpy as np
import pytest

from repro.core.features import (FEATURE_NAMES, extract_features,
                                 extract_features_batch,
                                 extract_features_batch_jnp, pad_csr_batch)
from repro.core.ml import MODEL_ZOO
from repro.core.plan_cache import PlanCache, matrix_fingerprint
from repro.core.scaling import StandardScaler
from repro.core.selector import ReorderSelector
from repro.sparse.csr import CSRMatrix, coo_to_csr, make_spd


def _random_csr(rng, n, density) -> CSRMatrix:
    mask = rng.random((n, n)) < density
    rows, cols = np.nonzero(mask)
    if rows.size == 0:
        rows, cols = np.array([0]), np.array([0])
    return make_spd(coo_to_csr(rows, cols, np.ones(rows.size), (n, n)))


def _edge_cases():
    diag = coo_to_csr(np.arange(7), np.arange(7), np.ones(7), (7, 7))
    # empty rows: only rows 0 and 4 have (off-diagonal) entries
    sparse_rows = coo_to_csr(np.array([0, 0, 4]), np.array([1, 3, 2]),
                             np.ones(3), (6, 6))
    one = coo_to_csr(np.array([0]), np.array([0]), np.ones(1), (1, 1))
    # structurally unsymmetric pattern (exercises the reciprocal search)
    unsym = coo_to_csr(np.array([0, 1, 2, 2]), np.array([2, 0, 1, 3]),
                       np.ones(4), (5, 5))
    return [diag, sparse_rows, one, unsym]


@pytest.fixture(scope="module")
def ragged_batch():
    """≥16 random CSR matrices of ragged sizes plus structural edge cases."""
    rng = np.random.default_rng(0)
    mats = [_random_csr(rng, int(n), float(d))
            for n, d in zip(rng.integers(2, 120, size=14),
                            rng.uniform(0.02, 0.4, size=14))]
    return mats + _edge_cases()


@pytest.mark.parametrize("use_pallas", [False, True])
def test_batch_jnp_matches_host(ragged_batch, use_pallas):
    """Acceptance: all 12 features within 1e-4 relative of the host path,
    with no dense (n, n) materialization (inputs are CSR buffers only)."""
    assert len(ragged_batch) >= 16
    host = np.stack([extract_features(m) for m in ragged_batch])
    dev = np.asarray(extract_features_batch_jnp(
        pad_csr_batch(ragged_batch), use_pallas=use_pallas))
    assert dev.shape == (len(ragged_batch), len(FEATURE_NAMES))
    np.testing.assert_allclose(dev, host, rtol=1e-4, atol=1e-5)


def test_batch_jnp_bucketed_padding_invariant(ragged_batch):
    """Extra pow2 padding must not change any feature value."""
    tight = np.asarray(extract_features_batch_jnp(pad_csr_batch(ragged_batch)))
    padded = np.asarray(extract_features_batch_jnp(
        pad_csr_batch(ragged_batch, bucket=True)))
    np.testing.assert_allclose(padded, tight, rtol=1e-6)


def test_pad_csr_batch_layout(ragged_batch):
    b = pad_csr_batch(ragged_batch)
    nmax = max(m.n for m in ragged_batch)
    emax = max(m.nnz for m in ragged_batch)
    assert b.indptr.shape == (len(ragged_batch), nmax + 1)
    assert b.indices.shape == (len(ragged_batch), emax)
    for i, m in enumerate(ragged_batch):
        assert b.n[i] == m.n and b.nnz[i] == m.nnz
        # rows past n padded with nnz → padded row lengths are 0
        assert (np.diff(b.indptr[i])[m.n:] == 0).all()


@pytest.fixture(scope="module")
def tiny_selector(ragged_batch):
    """Selector trained directly on features (no labeling campaign) with a
    JAX-zoo model, so the device inference path is exercised."""
    feats = extract_features_batch(ragged_batch)
    labels = (feats[:, FEATURE_NAMES.index("bandwidth")]
              / np.maximum(feats[:, 0], 1) > 0.5).astype(int)
    scaler = StandardScaler().fit(feats)
    model = MODEL_ZOO["logistic_regression"](steps=200)
    model.fit(scaler.transform(feats), labels)
    return ReorderSelector(model, scaler, ["amd", "rcm"])


def test_select_batch_paths_agree(tiny_selector, ragged_batch):
    names_host, _ = tiny_selector.select_batch(ragged_batch, path="host")
    names_dev, _ = tiny_selector.select_batch(ragged_batch, path="device")
    names_pl, _ = tiny_selector.select_batch(ragged_batch, path="device",
                                             use_pallas=True)
    singles = [tiny_selector.select(m)[0] for m in ragged_batch]
    assert names_host == singles
    assert names_dev == names_host
    assert names_pl == names_host


def test_select_batch_host_model_device_features(ragged_batch, tiny_selector):
    """Non-JAX zoo members still accept device features (host inference)."""
    feats = extract_features_batch(ragged_batch)
    labels = np.asarray([0, 1] * (len(ragged_batch) // 2 + 1))[
        : len(ragged_batch)]
    model = MODEL_ZOO["decision_tree"](max_depth=4)
    model.fit(tiny_selector.scaler.transform(feats), labels)
    sel = ReorderSelector(model, tiny_selector.scaler, ["amd", "rcm"])
    nh, _ = sel.select_batch(ragged_batch, path="host")
    nd, _ = sel.select_batch(ragged_batch, path="device")
    assert nh == nd


def test_profile_no_int32_overflow():
    """A tall first-column pattern drives profile past 2^31; the device sum
    must accumulate in f32, not wrap in int32."""
    n = 80_000  # profile = n(n-1)/2 ≈ 3.2e9 > 2^31
    rows = np.concatenate([np.arange(n), np.arange(n)])
    cols = np.concatenate([np.zeros(n, np.int64), np.arange(n)])
    m = coo_to_csr(rows, cols, np.ones(rows.size), (n, n))
    host = extract_features(m)
    dev = np.asarray(extract_features_batch_jnp(pad_csr_batch([m])))[0]
    i = FEATURE_NAMES.index("profile")
    assert host[i] == n * (n - 1) / 2
    np.testing.assert_allclose(dev[i], host[i], rtol=1e-4)
    assert dev[i] > 0


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

def test_fingerprint_is_structural(ragged_batch):
    m = ragged_batch[0]
    twin = m.copy()
    if twin.data is not None:
        twin.data = twin.data * 3.0  # same structure, different values
    assert matrix_fingerprint(twin) == matrix_fingerprint(m)
    keys = {matrix_fingerprint(x) for x in ragged_batch}
    assert len(keys) == len(ragged_batch)  # distinct structures → distinct


def test_plan_cache_hit_miss_eviction():
    c = PlanCache(capacity=2)
    assert c.get("a") is None          # miss
    c.put("a", "amd")
    assert c.get("a") == "amd"         # hit
    c.put("b", "rcm")
    c.put("c", "nd")                   # evicts LRU ("a": b was put later,
    assert c.get("a") is None          # and "a" unused since)
    assert c.get("b") == "rcm"
    c.put("d", "amd")                  # "c" is now LRU → evicted
    assert c.get("c") is None
    assert c.get("b") == "rcm"         # survived: recently used
    s = c.stats()
    assert s["evictions"] == 2 and s["size"] == 2
    assert s["hits"] == 3 and s["misses"] == 3
    assert 0.0 < s["hit_rate"] < 1.0


def test_selector_server_batches_and_caches(tiny_selector, ragged_batch):
    from repro.launch.serve_selector import SelectorServer

    server = SelectorServer(tiny_selector, batch_size=4, cache_capacity=64,
                            path="device")
    want, _ = tiny_selector.select_batch(ragged_batch, path="device")
    # duplicates within one request batch are featurized once
    req = list(ragged_batch) + [ragged_batch[0], ragged_batch[3]]
    plans = server.handle(req)
    assert plans[: len(ragged_batch)] == want
    assert plans[-2] == want[0] and plans[-1] == want[3]
    assert server.cache.stats()["misses"] == len(ragged_batch) + 2
    # repeat request: all hits, no extra selector work
    before = server.select_seconds
    plans2 = server.handle(list(ragged_batch))
    assert plans2 == want
    assert server.select_seconds == before
    assert server.cache.stats()["hits"] >= len(ragged_batch)
