"""Level-scheduled batched factorization: kernel parity, schedule
invariants, level sweeps, mixed-precision refinement, plumbing."""
import numpy as np
import pytest

from repro.sparse.multifrontal import (_partial_factor_numpy,
                                       factor_and_solve_timed,
                                       multifrontal_cholesky,
                                       multifrontal_solve)
from repro.sparse.refine import refine_solve
from repro.sparse.schedule import build_schedule
from repro.sparse.symbolic import symbolic_cholesky

RNG = np.random.default_rng(7)


def _spd(m):
    a = RNG.standard_normal((m, m))
    return a @ a.T + m * np.eye(m)


def _solve_ref(m, b):
    return np.linalg.solve(m.to_dense(), b)


# ---------------------------------------------------------------------------
# backend parity: numpy ↔ per-front pallas ↔ batched (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,npiv,batch", [
    (12, 5, 3), (24, 24, 2), (40, 17, 4), (9, 1, 5), (33, 8, 1),
])
def test_partial_factor_three_way_parity(m, npiv, batch):
    from repro.kernels import ops

    fs = np.stack([_spd(m) for _ in range(batch)])
    bL11, bL21, bS = ops.frontal_factor_batch(fs, npiv)
    for i in range(batch):
        nL11, nL21, nS = _partial_factor_numpy(fs[i].copy(), npiv)
        pL11, pL21, pS = ops.frontal_factor(fs[i], npiv)
        for got in (np.asarray(pL11), np.asarray(bL11[i])):
            np.testing.assert_allclose(got, nL11, rtol=1e-4, atol=1e-4)
        if npiv < m:
            for got in (np.asarray(pL21), np.asarray(bL21[i])):
                np.testing.assert_allclose(got, nL21, rtol=1e-4, atol=1e-4)
            for got in (np.asarray(pS), np.asarray(bS[i])):
                np.testing.assert_allclose(got, nS, rtol=1e-3, atol=1e-3)


def test_batched_backend_matches_numpy_elementwise(small_suite):
    """The level-scheduled factor equals the numpy factor front-by-front
    (f32 tolerance) — same supernodes, same rows, same L blocks."""
    for m in small_suite:
        fn = multifrontal_cholesky(m, backend="numpy")
        fb = multifrontal_cholesky(m, backend="batched")
        assert len(fn.fronts) == len(fb.fronts)
        for a, b in zip(fn.fronts, fb.fronts):
            assert a.cols == b.cols
            np.testing.assert_array_equal(a.rows, b.rows)
            np.testing.assert_allclose(b.L11, a.L11, rtol=1e-4, atol=1e-5)
            np.testing.assert_allclose(b.L21, a.L21, rtol=1e-4, atol=1e-5)


def test_batched_backend_end_to_end(small_suite, rng):
    for m in small_suite:
        b = rng.standard_normal(m.n)
        f = multifrontal_cholesky(m, backend="batched")
        x = multifrontal_solve(f, b)
        resid = np.linalg.norm(m.matvec(x) - b) / np.linalg.norm(b)
        assert resid < 1e-5  # f32 factorization floor
        assert f.stats["backend"] == "batched"
        assert f.stats["dtype"] == "float32"


# ---------------------------------------------------------------------------
# schedule invariants
# ---------------------------------------------------------------------------

def test_schedule_invariants(small_suite):
    for m in small_suite:
        sym = symbolic_cholesky(m)
        sched = build_schedule(sym)
        seen = np.concatenate([lv for lv in sched.levels]) \
            if sched.levels else np.empty(0, dtype=np.int64)
        # levels partition the supernodes
        assert sorted(seen.tolist()) == list(range(sched.nsup))
        for fp in sched.fronts:
            # parents live on strictly higher levels (the batching invariant)
            if fp.parent >= 0:
                assert sched.fronts[fp.parent].level > fp.level
            else:
                assert fp.nrest == 0  # roots have no update rows
        # buckets cover their level, pads dominate true sizes
        for li, lvl_buckets in enumerate(sched.buckets):
            members = [k for b in lvl_buckets for k in b.members]
            assert sorted(members) == sorted(sched.levels[li].tolist())
            for b in lvl_buckets:
                for k in b.members:
                    fp = sched.fronts[k]
                    assert fp.npiv <= b.P and fp.nrest <= b.R
        s = sched.stats()
        assert 0 < s["occupancy"] <= 1.0
        assert s["nlevels"] == max(fp.level for fp in sched.fronts) + 1


def test_schedule_flops_match_factor_stats(small_suite):
    for m in small_suite[:2]:
        sym = symbolic_cholesky(m)
        sched = build_schedule(sym)
        f = multifrontal_cholesky(m, sym)
        assert f.stats["front_flops"] == sched.stats()["front_flops"]


# ---------------------------------------------------------------------------
# level-batched triangular sweeps
# ---------------------------------------------------------------------------

def test_level_sweeps_match_sequential(small_suite, rng):
    for m in small_suite:
        b = rng.standard_normal(m.n)
        f = multifrontal_cholesky(m)
        x_level = multifrontal_solve(f, b, mode="level")
        x_seq = multifrontal_solve(f, b, mode="seq")
        np.testing.assert_allclose(x_level, x_seq, rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(x_level, _solve_ref(m, b),
                                   rtol=1e-8, atol=1e-8)


def test_level_sweeps_cache_reused(small_suite, rng):
    m = small_suite[0]
    f = multifrontal_cholesky(m)
    multifrontal_solve(f, rng.standard_normal(m.n))
    sweeps = f._sweeps
    assert sweeps is not None
    multifrontal_solve(f, rng.standard_normal(m.n))
    assert f._sweeps is sweeps  # stacked tensors built once


# ---------------------------------------------------------------------------
# mixed precision + iterative refinement
# ---------------------------------------------------------------------------

def test_refinement_reaches_fp64_floor(small_suite, rng):
    """Property: fp32 batched factor + fp64 refinement converges to ~fp64
    residual, strictly better than the unrefined fp32 solve."""
    for m in small_suite:
        b = rng.standard_normal(m.n)
        f = multifrontal_cholesky(m, backend="batched")
        x0 = multifrontal_solve(f, b)
        r0 = np.linalg.norm(m.matvec(x0) - b) / np.linalg.norm(b)
        x, info = refine_solve(m.matvec,
                               lambda r: multifrontal_solve(f, r), b)
        assert info.converged
        assert info.final_residual <= 1e-10
        assert info.final_residual < r0
        # residual history is monotone decreasing until convergence
        assert all(b_ <= a_ for a_, b_ in zip(info.residuals,
                                              info.residuals[1:]))


def test_refine_zero_rhs():
    from repro.sparse.dataset import grid2d
    m = grid2d(6, 6, "g6")
    f = multifrontal_cholesky(m, backend="batched")
    x, info = refine_solve(m.matvec, lambda r: multifrontal_solve(f, r),
                           np.zeros(m.n))
    assert np.all(x == 0) and info.converged


# ---------------------------------------------------------------------------
# plumbing: factor_and_solve_timed + execute_plan + EngineConfig
# ---------------------------------------------------------------------------

def test_factor_and_solve_timed_forwards_relax_and_backend(monkeypatch):
    from repro.sparse import multifrontal as mf
    m = __import__("repro.sparse.dataset", fromlist=["grid2d"]).grid2d(
        8, 8, "g8")
    seen = {}
    real = mf.multifrontal_cholesky

    def spy(a, sym=None, **kw):
        seen.update(kw)
        return real(a, sym, **kw)

    monkeypatch.setattr(mf, "multifrontal_cholesky", spy)
    rb = factor_and_solve_timed(m, relax=3, backend="batched")
    assert seen == {"relax": 3, "backend": "batched", "pad": "pow2",
                    "bs": None}
    assert rb["backend"] == "batched"
    assert rb["residual"] < 1e-5


def test_execute_plan_solve_dtype_paths():
    from repro.core.plan import PlanBuilder, execute_plan
    from repro.sparse.dataset import grid2d

    m = grid2d(8, 8, "g8")
    b = np.random.default_rng(0).standard_normal(m.n)
    plan = PlanBuilder().build(m, algorithm="amd")
    r64 = execute_plan(m, plan, b, backend="numpy", solve_dtype="fp64")
    assert r64["solve_dtype"] == "fp64" and r64["residual"] < 1e-10
    # f32-only backend auto-promotes fp64 -> fp32_refine
    rb = execute_plan(m, plan, b, backend="batched", solve_dtype="fp64")
    assert rb["solve_dtype"] == "fp32_refine"
    assert rb["refine_converged"] and rb["residual"] < 1e-10
    # the cached plan records the numeric path that last produced results
    assert plan.meta["solve_backend"] == "batched"
    assert plan.meta["solve_dtype"] == "fp32_refine"
    r32 = execute_plan(m, plan, b, backend="batched", solve_dtype="fp32")
    assert r32["solve_dtype"] == "fp32" and r32["residual"] < 1e-5
    with pytest.raises(ValueError):
        execute_plan(m, plan, b, solve_dtype="fp16")


def test_engine_config_validates_solve_knobs():
    from repro.engine import EngineConfig

    cfg = EngineConfig(backend="batched", solve_dtype="fp32_refine")
    assert cfg.backend == "batched"
    with pytest.raises(ValueError):
        EngineConfig(backend="cuda")
    with pytest.raises(ValueError):
        EngineConfig(solve_dtype="fp16")
